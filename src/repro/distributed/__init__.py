from .sharding import (lm_param_specs, lm_batch_specs, lm_cache_specs,   # noqa: F401
                       gnn_batch_specs, recsys_param_specs,
                       recsys_batch_specs, valid_spec, spec_tree_for,
                       DP_AXES, MODEL_AXIS)
from .collectives import (compress_bf16, compress_int8_ef,               # noqa: F401
                          decompress_int8, psum_compressed)
from .fault_tolerance import StragglerMonitor, ElasticPlan               # noqa: F401
