"""Sharding rules per architecture family.

Mesh axes: ``('pod', 'data', 'model')`` multi-pod or ``('data', 'model')``
single-pod.  ``pod``+``data`` together form the data-parallel dimension
(grad all-reduce crosses pods hierarchically — XLA emits ring reductions
per axis); ``model`` carries tensor/expert/table parallelism.

Rules are *structural*: a spec function inspects a param pytree and returns
a matching PartitionSpec tree.  ``valid_spec`` drops any axis that does not
divide the dimension (replicating instead) so imperfect shapes — e.g.
qwen2's 14 heads on a 16-way model axis — degrade gracefully rather than
failing to lower; the roofline then shows the cost and the perf loop can
fix the layout.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"


def DP_AXES(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axsize(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def valid_spec(mesh, shape, spec: P) -> P:
    """Replace non-dividing spec entries with None (replicate)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    fixed = []
    for dim, ax in zip(shape, entries):
        fixed.append(ax if ax is not None and dim % _axsize(mesh, ax) == 0
                     else None)
    return P(*fixed)


def spec_tree_for(mesh, params: Any, rule) -> Any:
    """Apply ``rule(path, leaf) -> PartitionSpec`` across a pytree, running
    every result through ``valid_spec``."""
    def fix(path, leaf):
        spec = rule(path, leaf)
        return valid_spec(mesh, leaf.shape, spec)

    return jax.tree_util.tree_map_with_path(fix, params)


# ---------------------------------------------------------------------------
# LM rules
# ---------------------------------------------------------------------------

def _lm_rule(path, leaf):
    keys = [getattr(p, "key", "") for p in path]
    name = keys[-1] if keys else ""
    in_layers = "layers" in keys
    nd = leaf.ndim

    def L(*spec):                     # layer-stacked params: leading L axis
        return P(None, *spec) if in_layers else P(*spec)

    if name == "embed":
        return P(MODEL_AXIS, None)            # vocab-sharded
    if name == "unembed":
        return P(None, MODEL_AXIS)
    if name in ("final_ln",):
        return P(None)
    if name in ("ln1", "ln2"):
        return L(None)
    # attention
    if name in ("wq", "wk", "wv", "w_uk", "w_uv"):
        return L(None, MODEL_AXIS)            # output-feature sharded
    if name in ("bq", "bk", "bv"):
        return L(MODEL_AXIS)
    if name == "wo":
        return L(MODEL_AXIS, None)
    if name in ("w_dkv", "w_kr"):
        return L(None, None)                  # small latent projections
    # dense FFN (incl. MoE shared expert)
    if name in ("w1", "w3") and nd == (3 if in_layers else 2):
        return L(None, MODEL_AXIS)
    if name == "w2" and nd == (3 if in_layers else 2):
        return L(MODEL_AXIS, None)
    # MoE experts: (L, E, d, f) -> expert-sharded on model axis
    if name in ("w1", "w2", "w3"):
        return L(MODEL_AXIS, None, None)
    if name == "router":
        return L(None, None)
    return P(*([None] * nd))


def lm_param_specs(mesh, params):
    return spec_tree_for(mesh, params, _lm_rule)


def lm_batch_specs(mesh):
    dp = DP_AXES(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_specs(mesh, cache):
    """KVCache(a, b, length): shard batch over DP, head/latent dims over
    model where divisible."""
    dp = DP_AXES(mesh)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.ndim == 5:            # (L, B, S, Hkv, hd)
            return valid_spec(mesh, leaf.shape,
                              P(None, dp, None, MODEL_AXIS, None))
        if leaf.ndim == 4:            # (L, B, S, r)
            return valid_spec(mesh, leaf.shape, P(None, dp, None, None))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map(spec, cache)


# ---------------------------------------------------------------------------
# GNN / recsys rules
# ---------------------------------------------------------------------------

def gnn_batch_specs(mesh, batch):
    """Edges and node tables row-sharded over the DP axes; small index
    structures (CSR indptr, seeds) replicated."""
    dp = DP_AXES(mesh)

    def spec(path, leaf):
        name = getattr(path[-1], "key", "") if path else ""
        if leaf.ndim == 0 or name in ("indptr", "offsets"):
            return P(*([None] * leaf.ndim))
        return valid_spec(mesh, leaf.shape,
                          P(dp, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(spec, batch)


def _recsys_rule(path, leaf):
    keys = [getattr(p, "key", "") for p in path]
    name = keys[-1] if keys else ""
    if name in ("table", "first_order"):
        return P(MODEL_AXIS, *([None] * (leaf.ndim - 1)))   # row-sharded
    return P(*([None] * leaf.ndim))


def recsys_param_specs(mesh, params):
    return spec_tree_for(mesh, params, _recsys_rule)


def recsys_batch_specs(mesh):
    dp = DP_AXES(mesh)
    return {"dense": P(dp, None), "sparse": P(dp, None), "label": P(dp),
            "offsets": P(None)}
