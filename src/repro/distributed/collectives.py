"""Collective helpers: gradient compression for the DP all-reduce.

Two schemes, both usable inside ``shard_map`` data-parallel steps:

* **bf16** — halve all-reduce bytes; unbiased enough for grads in practice.
* **int8 + error feedback** — 4x compression with a per-tensor scale; the
  quantization residual is carried in optimizer-side state and re-added the
  next step, so the scheme is convergent (Seide et al. / EF-SGD).

These target the *explicit* shard_map trainer (examples/ + tests).  The
pjit path leaves grad reduction to GSPMD; compression there is a documented
config flag that swaps the step function to the shard_map trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(tree):
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), tree)


def compress_int8_ef(grads, residual):
    """-> (q_int8, scales, new_residual).  Per-tensor symmetric scale."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale, g - q.astype(jnp.float32) * scale

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    unf = lambda i: jax.tree_util.tree_unflatten(tdef, [o[i] for o in out])
    return unf(0), unf(1), unf(2)


def decompress_int8(q_tree, scale_tree):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)


def psum_compressed(grads, axis, scheme: str = "none", residual=None):
    """All-reduce ``grads`` over ``axis`` inside shard_map, optionally
    compressed.  Returns (mean_grads, new_residual)."""
    n = jax.lax.psum(1, axis)
    if scheme == "none":
        return jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g, axis) / n, grads), residual
    if scheme == "bf16":
        red = jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis)
            .astype(jnp.float32) / n, grads)
        return red, residual
    if scheme == "int8_ef":
        q, s, new_res = compress_int8_ef(grads, residual)
        # int8 buffers are summed in int32 to avoid overflow across shards
        red = jax.tree_util.tree_map(
            lambda qq, ss: jax.lax.psum(qq.astype(jnp.int32), axis)
            .astype(jnp.float32) * jax.lax.pmean(ss, axis) / n, q, s)
        return red, new_res
    raise ValueError(f"unknown compression scheme {scheme!r}")
