"""Fault-tolerance logic: straggler detection and elastic rescale planning.

Pure, clock-injected logic (unit-testable without hardware):

* ``StragglerMonitor`` — EMA of step wall-times with a deadline multiplier;
  flags slow steps so the launcher can re-dispatch the microbatch to a hot
  spare / skip the straggling host's shard for one step (the standard
  "backup worker" mitigation).
* ``ElasticPlan`` — given old/new device counts, decides the new mesh shape
  and the data-parallel rescale factor; together with
  ``checkpoint.restore_checkpoint(shardings=...)`` this is the restart path
  when a pod drops out (512 -> 256 chips keeps the model axis, halves DP,
  doubles grad-accumulation to preserve global batch).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerMonitor:
    ema_decay: float = 0.9
    deadline_factor: float = 2.5
    warmup_steps: int = 5

    _ema: float = 0.0
    _count: int = 0
    stragglers: int = 0

    def record(self, step_time: float) -> bool:
        """Record a step time; True -> the step straggled (re-dispatch)."""
        self._count += 1
        if self._count <= self.warmup_steps:
            self._ema = step_time if self._ema == 0.0 else (
                self.ema_decay * self._ema
                + (1 - self.ema_decay) * step_time)
            return False
        is_straggler = step_time > self.deadline_factor * self._ema
        if is_straggler:
            self.stragglers += 1
        else:                       # stragglers don't poison the EMA
            self._ema = (self.ema_decay * self._ema
                         + (1 - self.ema_decay) * step_time)
        return is_straggler

    @property
    def deadline(self) -> float:
        return self.deadline_factor * self._ema if self._count else float(
            "inf")

    @property
    def expected(self) -> float:
        """EMA-predicted next step time (0.0 until warm-up completes).

        The serving executor's deadline budgeting reads this to decide
        skip-vs-launch BEFORE paying a bucket's dispatch cost: if the
        predicted wall time does not fit the request's remaining budget,
        the bucket is skipped instead of silently blocking past the
        deadline.  Returning 0.0 while cold means a cold monitor never
        vetoes a launch — only the hard budget does."""
        return self._ema if self._count >= self.warmup_steps else 0.0


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Mesh rescale plan preserving the model axis and the global batch."""

    old_devices: int
    new_devices: int
    model_parallel: int
    old_grad_accum: int = 1

    def __post_init__(self):
        if self.new_devices % self.model_parallel:
            raise ValueError(
                f"cannot keep model axis {self.model_parallel} on "
                f"{self.new_devices} devices")

    @property
    def old_dp(self) -> int:
        return self.old_devices // self.model_parallel

    @property
    def new_dp(self) -> int:
        return self.new_devices // self.model_parallel

    @property
    def new_grad_accum(self) -> int:
        """Keep global batch: accum scales by the DP shrink factor."""
        scale = max(1, self.old_dp // max(1, self.new_dp))
        return self.old_grad_accum * scale

    def new_mesh_shape(self, multi_pod_pods: int | None = None):
        if multi_pod_pods:
            return (multi_pod_pods, self.new_dp // multi_pod_pods,
                    self.model_parallel)
        return (self.new_dp, self.model_parallel)
