"""CSR adjacency index — the engine's join index over ``edges.from``.

PosDB/PostgreSQL accelerate the recursive join with a B-tree/hash index on
the join column.  The TPU-native equivalent is a CSR permutation index:

    perm    : (E,) int32 — edge positions sorted by their ``from`` vertex
    indptr  : (V+1,) int32 — per-vertex range into ``perm``

Lookup of "all edges with from == v" is then the contiguous slice
``perm[indptr[v] : indptr[v+1]]`` — positions in, positions out, no values
touched.  This is what makes the PRecursive expansion purely positional.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CSRIndex", "build_csr", "expand_frontier", "csr_degrees"]


class CSRIndex(NamedTuple):
    indptr: jax.Array      # (V+1,) int32
    perm: jax.Array        # (E,)  int32 — edge positions grouped by source

    @property
    def num_vertices(self) -> int:
        return self.indptr.shape[0] - 1   # static under tracing

    @property
    def num_edges(self) -> int:
        return self.perm.shape[0]


def build_csr(src: jax.Array, num_vertices: int) -> CSRIndex:
    """Build the index (sort-based, O(E log E)); jit-safe."""
    e = src.shape[0]
    perm = jnp.argsort(src, stable=True).astype(jnp.int32)
    counts = jnp.zeros((num_vertices,), jnp.int32).at[src].add(1, mode="drop")
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts, dtype=jnp.int32)])
    return CSRIndex(indptr=indptr, perm=perm)


def csr_degrees(csr: CSRIndex, vertices: jax.Array, valid: jax.Array) -> jax.Array:
    v = jnp.clip(vertices, 0, csr.num_vertices - 1)
    deg = csr.indptr[v + 1] - csr.indptr[v]
    return jnp.where(valid & (vertices >= 0) & (vertices < csr.num_vertices),
                     deg, 0)


def expand_frontier(csr: CSRIndex, targets: jax.Array, valid: jax.Array,
                    capacity: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One BFS level: expand every target vertex through the CSR index.

    Pure positional dataflow — the PRecursive hot loop.  For each live
    ``targets[i]`` emits the positions of all edges whose source is that
    vertex, concatenated in frontier order, padded to ``capacity``.

    Returns (edge_positions (capacity,), total (scalar), overflowed (bool)).

    Vectorized two-phase expansion: per-target degrees -> exclusive scan for
    output offsets -> searchsorted inverts the scan so each output slot finds
    its producing target.  (The Pallas ``frontier_expand`` kernel implements
    the same contract with VMEM-tiled binary search; see kernels/.)
    """
    deg = csr_degrees(csr, targets, valid)                        # (F,)
    ends = jnp.cumsum(deg, dtype=jnp.int32)                       # inclusive
    starts = ends - deg
    total = ends[-1] if deg.shape[0] > 0 else jnp.zeros((), jnp.int32)

    j = jnp.arange(capacity, dtype=jnp.int32)
    srcslot = jnp.searchsorted(ends, j, side="right").astype(jnp.int32)
    srcslot = jnp.minimum(srcslot, deg.shape[0] - 1)
    within = j - starts[srcslot]
    v = jnp.clip(targets[srcslot], 0, csr.num_vertices - 1)
    epos = csr.perm[jnp.minimum(csr.indptr[v] + within, csr.num_edges - 1)]
    live = j < jnp.minimum(total, capacity)
    epos = jnp.where(live, epos, csr.num_edges)                   # sentinel pad
    return epos.astype(jnp.int32), jnp.minimum(total, capacity), total > capacity
