"""CSR adjacency index — the engine's join index over ``edges.from``.

PosDB/PostgreSQL accelerate the recursive join with a B-tree/hash index on
the join column.  The TPU-native equivalent is a CSR permutation index:

    perm    : (E,) int32 — edge positions sorted by their ``from`` vertex
    indptr  : (V+1,) int32 — per-vertex range into ``perm``

Lookup of "all edges with from == v" is then the contiguous slice
``perm[indptr[v] : indptr[v+1]]`` — positions in, positions out, no values
touched.  This is what makes the PRecursive expansion purely positional.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CSRIndex", "build_csr", "expand_frontier", "csr_degrees",
           "merged_indptr", "bidir_degrees", "expand_frontier_both"]


class CSRIndex(NamedTuple):
    indptr: jax.Array      # (V+1,) int32
    perm: jax.Array        # (E,)  int32 — edge positions grouped by source

    @property
    def num_vertices(self) -> int:
        return self.indptr.shape[0] - 1   # static under tracing

    @property
    def num_edges(self) -> int:
        return self.perm.shape[0]


def build_csr(src: jax.Array, num_vertices: int) -> CSRIndex:
    """Build the index (sort-based, O(E log E)); jit-safe."""
    e = src.shape[0]
    perm = jnp.argsort(src, stable=True).astype(jnp.int32)
    counts = jnp.zeros((num_vertices,), jnp.int32).at[src].add(1, mode="drop")
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts, dtype=jnp.int32)])
    return CSRIndex(indptr=indptr, perm=perm)


def csr_degrees(csr: CSRIndex, vertices: jax.Array, valid: jax.Array) -> jax.Array:
    v = jnp.clip(vertices, 0, csr.num_vertices - 1)
    deg = csr.indptr[v + 1] - csr.indptr[v]
    return jnp.where(valid & (vertices >= 0) & (vertices < csr.num_vertices),
                     deg, 0)


def expand_frontier(csr: CSRIndex, targets: jax.Array, valid: jax.Array,
                    capacity: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One BFS level: expand every target vertex through the CSR index.

    Pure positional dataflow — the PRecursive hot loop.  For each live
    ``targets[i]`` emits the positions of all edges whose source is that
    vertex, concatenated in frontier order, padded to ``capacity``.

    Returns (edge_positions (capacity,), total (scalar), overflowed (bool)).

    Vectorized two-phase expansion: per-target degrees -> exclusive scan for
    output offsets -> searchsorted inverts the scan so each output slot finds
    its producing target.  (The Pallas ``frontier_expand`` kernel implements
    the same contract with VMEM-tiled binary search; see kernels/.)
    """
    deg = csr_degrees(csr, targets, valid)                        # (F,)
    ends = jnp.cumsum(deg, dtype=jnp.int32)                       # inclusive
    starts = ends - deg
    total = ends[-1] if deg.shape[0] > 0 else jnp.zeros((), jnp.int32)

    j = jnp.arange(capacity, dtype=jnp.int32)
    srcslot = jnp.searchsorted(ends, j, side="right").astype(jnp.int32)
    srcslot = jnp.minimum(srcslot, deg.shape[0] - 1)
    within = j - starts[srcslot]
    v = jnp.clip(targets[srcslot], 0, csr.num_vertices - 1)
    epos = csr.perm[jnp.minimum(csr.indptr[v] + within, csr.num_edges - 1)]
    live = j < jnp.minimum(total, capacity)
    epos = jnp.where(live, epos, csr.num_edges)                   # sentinel pad
    return epos.astype(jnp.int32), jnp.minimum(total, capacity), total > capacity


# ---------------------------------------------------------------------------
# fused bidirectional CSR — ONE E-sized edge array per adjacency direction
# plus a merged indptr, replacing the old doubled (2E) edge view for
# direction='both'.  Join-space positions stay 2E-VIRTUAL: p < E is edge p
# traversed forward, p >= E is edge p-E traversed backward — exactly the
# layout the old concat(from,to) view materialized, so results (including
# emission order) are bit-identical while the stored arrays are E-scale.
# ---------------------------------------------------------------------------

def merged_indptr(out_csr: CSRIndex, in_csr: CSRIndex) -> jax.Array:
    """The fused view's merged indptr: per-vertex out-degree + in-degree,
    cumulated.  (V+1,) int32 — the only array 'both' adds on top of the
    out/in CSRs that ``outbound``/``inbound`` already need."""
    out_deg = out_csr.indptr[1:] - out_csr.indptr[:-1]
    in_deg = in_csr.indptr[1:] - in_csr.indptr[:-1]
    return jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        jnp.cumsum(out_deg + in_deg, dtype=jnp.int32)])


def bidir_degrees(both_indptr: jax.Array, vertices: jax.Array,
                  valid: jax.Array) -> jax.Array:
    """Per-target merged (out+in) degree, masked like :func:`csr_degrees`."""
    nv = both_indptr.shape[0] - 1
    v = jnp.clip(vertices, 0, nv - 1)
    deg = both_indptr[v + 1] - both_indptr[v]
    return jnp.where(valid & (vertices >= 0) & (vertices < nv), deg, 0)


def expand_frontier_both(out_csr: CSRIndex, in_csr: CSRIndex,
                         both_indptr: jax.Array, targets: jax.Array,
                         valid: jax.Array, capacity: int
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One BFS level over the FUSED bidirectional view: each target vertex
    emits its out-edge positions (forward, ``p``) followed by its in-edge
    positions (backward, ``E + p``) — the same join-space ordering the old
    doubled-CSR view produced, without materializing any 2E array.

    Same contract as :func:`expand_frontier`: returns
    (edge_positions (capacity,), total (scalar), overflowed (bool)); the
    join-space sentinel is ``2E``."""
    e = out_csr.num_edges
    deg = bidir_degrees(both_indptr, targets, valid)              # (F,)
    ends = jnp.cumsum(deg, dtype=jnp.int32)
    starts = ends - deg
    total = ends[-1] if deg.shape[0] > 0 else jnp.zeros((), jnp.int32)

    j = jnp.arange(capacity, dtype=jnp.int32)
    srcslot = jnp.searchsorted(ends, j, side="right").astype(jnp.int32)
    srcslot = jnp.minimum(srcslot, deg.shape[0] - 1)
    within = j - starts[srcslot]
    v = jnp.clip(targets[srcslot], 0, out_csr.num_vertices - 1)
    out_deg = out_csr.indptr[v + 1] - out_csr.indptr[v]
    fwd = within < out_deg
    out_idx = jnp.minimum(out_csr.indptr[v] + within, max(e - 1, 0))
    in_idx = jnp.clip(in_csr.indptr[v] + within - out_deg, 0,
                      max(e - 1, 0))
    epos = jnp.where(fwd, out_csr.perm[out_idx], e + in_csr.perm[in_idx])
    live = j < jnp.minimum(total, capacity)
    epos = jnp.where(live, epos, 2 * e)                           # sentinel
    return epos.astype(jnp.int32), jnp.minimum(total, capacity), \
        total > capacity
