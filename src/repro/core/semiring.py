"""The semiring value plane: pluggable (⊕, ⊗) algebra for traversal.

The paper's positional operators decide *reachability + depth* only.  This
module generalizes them: a traversal can carry one float32 value per
vertex, edges ⊗-propagate the value along the traversed edge, and
multi-path conflicts at a target vertex resolve with the semiring's
⊕-combine instead of a hardcoded boolean ``.at[...].max`` race.  BFS is
the boolean special case (``reach``), weighted SSSP is (min, +), and path
aggregation (e.g. bill-of-materials explosion) is (sum|min|max|mul, ×).

Registry
--------
========================  =====  =====  ==========  ==========  =========
name                      ⊕      ⊗      identity    seed        improving
========================  =====  =====  ==========  ==========  =========
``reach``                 or     —      False       True        —
``shortest_path``         min    +      +inf        0.0         yes
``aggregate_sum``         sum    ×      0.0         1.0         no
``aggregate_max``         max    ×      -inf        1.0         no
``aggregate_min``         min    ×      +inf        1.0         no
``aggregate_mul``         mul    ×      1.0         1.0         no
========================  =====  =====  ==========  ==========  =========

``improving`` marks label-correcting semirings: the next frontier is the
set of vertices whose value STRICTLY improved this round (Bellman-Ford
style), and the fixed point is value stabilization — the monotone
decreasing (min, +) iteration converges when no vertex improves, which is
exactly the existing ``frontier_count > 0`` loop condition.  Walk
semirings (the aggregates) re-expand every vertex that received a value
this level; they are depth-bounded and rely on ⊗ distributing over ⊕ to
combine per-vertex per level yet stay equal to the per-path UNION-ALL
fold.

``or_combine`` is the boolean ⊕ hook: it compiles to the identical
``arr.at[idx].max(vals)`` scatter the operators used before the refactor,
which is what keeps ``reach`` bit-identical by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Semiring", "SEMIRINGS", "WORKLOADS", "get_semiring", "or_combine",
    "scatter_combine", "elem_combine", "propagate",
]


@dataclasses.dataclass(frozen=True)
class Semiring:
    """One (⊕, ⊗) pair plus the constants the operators need.

    ``combine``    ⊕ name: ``min`` | ``max`` | ``add`` | ``mul``.
    ``propagate``  ⊗ name: ``plus`` | ``mul`` (applied as value ⊗ weight).
    ``identity``   ⊕-identity; the initial per-vertex value.
    ``seed_value`` the root's value (the ⊗-identity: 0 for +, 1 for ×).
    ``improving``  label-correcting: frontier = strictly improved vertices.
    """
    name: str
    combine: str
    propagate: str
    identity: float
    seed_value: float
    improving: bool


SEMIRINGS: Dict[str, Semiring] = {
    s.name: s for s in (
        Semiring("shortest_path", "min", "plus", float("inf"), 0.0, True),
        Semiring("aggregate_sum", "add", "mul", 0.0, 1.0, False),
        Semiring("aggregate_max", "max", "mul", float("-inf"), 1.0, False),
        Semiring("aggregate_min", "min", "mul", float("inf"), 1.0, False),
        Semiring("aggregate_mul", "mul", "mul", 1.0, 1.0, False),
    )
}

# Every workload name a query can carry: the boolean case plus the value
# semirings.  ``reach`` deliberately has NO Semiring entry — the boolean
# pipelines never consult the registry, so get_semiring("reach") raising
# is a bug trap, not a missing feature.
WORKLOADS: Tuple[str, ...] = ("reach", *SEMIRINGS)


def get_semiring(name: str) -> Semiring:
    try:
        return SEMIRINGS[name]
    except KeyError:
        raise ValueError(
            f"unknown semiring {name!r}; known: {sorted(SEMIRINGS)}"
        ) from None


def or_combine(arr: jax.Array, idx: jax.Array, vals: jax.Array,
               *, mode: str = "drop") -> jax.Array:
    """Boolean ⊕: scatter-or, spelled as the ``.max`` scatter it replaces.

    This is the one hook every boolean dedup site in ``operators.py`` now
    routes through.  It must stay ``arr.at[idx].max(vals)`` — same
    primitive, same lowering — so the ``reach`` workload remains
    bit-identical to the pre-refactor operators.
    """
    return arr.at[idx].max(vals, mode=mode)


def scatter_combine(sr: Semiring, arr: jax.Array, idx: jax.Array,
                    vals: jax.Array, *, mode: str = "drop") -> jax.Array:
    """⊕-scatter ``vals`` into ``arr`` at ``idx`` (the dense combine)."""
    at = arr.at[idx]
    if sr.combine == "min":
        return at.min(vals, mode=mode)
    if sr.combine == "max":
        return at.max(vals, mode=mode)
    if sr.combine == "add":
        return at.add(vals, mode=mode)
    if sr.combine == "mul":
        return at.mul(vals, mode=mode)
    raise ValueError(f"unknown combine {sr.combine!r}")


def elem_combine(sr: Semiring, a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise ⊕ of two value planes."""
    if sr.combine == "min":
        return jnp.minimum(a, b)
    if sr.combine == "max":
        return jnp.maximum(a, b)
    if sr.combine == "add":
        return a + b
    if sr.combine == "mul":
        return a * b
    raise ValueError(f"unknown combine {sr.combine!r}")


def propagate(sr: Semiring, vals: jax.Array, weights: jax.Array) -> jax.Array:
    """⊗: carry ``vals`` across edges with per-edge ``weights``."""
    if sr.propagate == "plus":
        return vals + weights
    if sr.propagate == "mul":
        return vals * weights
    raise ValueError(f"unknown propagate {sr.propagate!r}")
