"""The paper's recursive (BFS) engines as *operator-pipeline compositions*.

Every engine is now a declarative :class:`~repro.core.operators.Pipeline`
over the positional operator algebra in :mod:`repro.core.operators`, run by
the single shared :func:`~repro.core.operators.fixed_point` driver (one
``jax.lax.while_loop``).  The engines differ ONLY in what flows through the
recursion — exactly the axis the paper studies:

=================  ==========================================================
``precursive``     ReadCol → VisitedDedup → CSRIndexJoin → AppendUnionAll,
                   finished by ONE LateMaterialize (PRecursive/PRecursiveCTE,
                   the paper's Fig. 4 plan).
``trecursive``     the same loop with an EarlyMaterialize before every
                   append: the recursion carries value tuples and pays (3+N)
                   column gathers per level (TRecursive, Fig. 3).
``rowstore``       PostgreSQL emulation: ScanHashJoin (full interleaved-row
                   SeqScan probing the frontier hash) + full-row gathers.
``rowstore_index`` the CSRIndexJoin avoids the scan but row gathers still
                   read full heap rows.
``*_rewrite``      Exp-3: the slim (id, to) pipeline finished by ONE
                   TopLevelJoin on ``id``.
=================  ==========================================================

Direction: the columnar pipelines traverse ``outbound`` (from→to),
``inbound`` (to→from via the reverse CSR) or ``both`` (a doubled edge view;
each edge can be emitted once per direction).  The row-store emulation is
outbound-only, like the PostgreSQL baseline it models.

Positions contract (asserted in tests/test_operators.py): positional
pipelines return real edge positions in ``BFSResult.positions``; tuple/row
pipelines return all ``-1`` — after early materialization positions are
gone, which is precisely the information the Fig. 3 plan discards.

Semantics note: the SQL in the paper is ``UNION ALL`` over a *tree*, where
every edge is reached at most once and BFS/UNION-ALL coincide.  On general
graphs the pipelines implement BFS semantics (per-vertex dedup via a visited
bitmap) when ``dedup=True``; with ``dedup=False`` the VisitedDedup operator
is simply dropped from the composition and they reproduce raw UNION ALL
walks up to ``max_depth``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from .csr import CSRIndex
from .operators import (DIRECTIONS, AppendUnionAll, BFSResult, Context,
                        CSRIndexJoin, EarlyMaterialize, EmitTuples,
                        EngineCaps, LateMaterialize, Pipeline, ProjectRows,
                        ReadTargets, ScanHashJoin, Seed, TopLevelJoin,
                        VisitedDedup, WeightedExpand,
                        check_direction as _check_direction,
                        dedup_targets, execute)
from .table import ColumnTable, RowTable

__all__ = [
    "EngineCaps", "BFSResult", "precursive_bfs", "trecursive_bfs",
    "rowstore_bfs", "trecursive_rewrite_bfs", "rowstore_rewrite_bfs",
    "dedup_targets", "precursive_plan", "trecursive_plan", "rowstore_plan",
    "trecursive_rewrite_plan", "rowstore_rewrite_plan", "DIRECTIONS",
    "weighted_precursive_plan",
]

# per-direction (seed filter column label, tuple-rep next-vertex column)
_DIRECTION_COLS = {
    "outbound": ("from", "to"),
    "inbound": ("to", "from"),
    "both": ("from|to", "__next__"),
}


# ---------------------------------------------------------------------------
# plan builders — the declarative engine definitions
# ---------------------------------------------------------------------------

def precursive_plan(caps: EngineCaps, max_depth: int,
                    out_cols: Tuple[str, ...], dedup: bool = True,
                    direction: str = "outbound",
                    expand_fn: Optional[Callable] = None) -> Pipeline:
    """The paper's positional engine: positions flow through the recursion;
    one column read per level; ONE materialize after the fixed point."""
    _check_direction(direction)
    seed_label, _ = _DIRECTION_COLS[direction]
    return Pipeline(
        name="PRecursive", rep="pos",
        seed=Seed(label=seed_label),
        ops=(ReadTargets("pos"),
             *((VisitedDedup(),) if dedup else ()),
             CSRIndexJoin(expand_fn=expand_fn),
             AppendUnionAll("pos")),
        finisher=LateMaterialize(tuple(out_cols)),
        caps=caps, max_depth=max_depth)


def weighted_precursive_plan(caps: EngineCaps, max_depth: int,
                             out_cols: Tuple[str, ...], semiring: str,
                             direction: str = "outbound") -> Pipeline:
    """The positional engine under a value semiring: the same
    position-carrying recursion and single late materialize, with the
    level body fused into ONE :class:`WeightedExpand` (⊗-propagate,
    per-vertex ⊕-combine, winner select, CSR expansion).  BFS's
    VisitedDedup is subsumed: improving semirings re-expand exactly the
    strictly-improved vertices, walk semirings every receiving vertex."""
    _check_direction(direction)
    seed_label, _ = _DIRECTION_COLS[direction]
    return Pipeline(
        name="PRecursiveWeighted", rep="pos",
        seed=Seed(label=seed_label, semiring=semiring),
        ops=(WeightedExpand(semiring=semiring),
             AppendUnionAll("pos")),
        finisher=LateMaterialize(tuple(out_cols)),
        caps=caps, max_depth=max_depth, semiring=semiring)


def trecursive_plan(caps: EngineCaps, max_depth: int,
                    out_cols: Tuple[str, ...], dedup: bool = True,
                    direction: str = "outbound") -> Pipeline:
    """The tuple engine: an EarlyMaterialize inside the loop turns every
    level's join output into full value tuples (Fig. 3's plan shape)."""
    _check_direction(direction)
    seed_label, next_col = _DIRECTION_COLS[direction]
    out_cols = tuple(out_cols)
    with_next = next_col == "__next__"
    carry = (out_cols if with_next
             else tuple(dict.fromkeys(out_cols + (next_col,))))
    return Pipeline(
        name="TRecursive", rep="vals",
        seed=Seed(label=seed_label),
        ops=(ReadTargets("vals", col=next_col),
             *((VisitedDedup(),) if dedup else ()),
             CSRIndexJoin(),
             EarlyMaterialize(cols=carry, with_next=with_next),
             AppendUnionAll("vals", cols=out_cols)),
        finisher=EmitTuples(out_cols),
        caps=caps, max_depth=max_depth)


def rowstore_plan(caps: EngineCaps, max_depth: int,
                  out_cols: Tuple[str, ...], dedup: bool = True,
                  use_index: bool = False,
                  direction: str = "outbound") -> Pipeline:
    """PostgreSQL emulation: the recursion carries full interleaved rows.
    Without an index the per-level join is a full SeqScan probing the
    frontier hash; with one, a CSRIndexJoin — but row gathers still read
    the full heap width either way."""
    if direction != "outbound":
        raise ValueError("the row-store emulation is outbound-only "
                         "(like the PostgreSQL baseline it models)")
    return Pipeline(
        name="Recursive", rep="rows",
        seed=Seed(scan="rows", label="from"),
        ops=(ReadTargets("rows", col="to"),
             *((VisitedDedup(),) if dedup else ()),
             CSRIndexJoin() if use_index else ScanHashJoin(),
             EarlyMaterialize(rows=True),
             AppendUnionAll("rows")),
        finisher=ProjectRows(tuple(out_cols)),
        caps=caps, max_depth=max_depth)


def trecursive_rewrite_plan(caps: EngineCaps, max_depth: int,
                            out_cols: Tuple[str, ...], dedup: bool = True,
                            direction: str = "outbound") -> Pipeline:
    """Exp-3 rewriting of the tuple engine: the CTE carries only (id, to);
    payloads come back through ONE top-level hash join on ``id``."""
    slim = trecursive_plan(caps, max_depth, ("id",), dedup, direction)
    return dataclasses.replace(
        slim, name="TRecursiveRewrite",
        finisher=TopLevelJoin(tuple(out_cols), inner=slim.finisher))


def rowstore_rewrite_plan(caps: EngineCaps, max_depth: int,
                          out_cols: Tuple[str, ...], dedup: bool = True,
                          use_index: bool = False,
                          direction: str = "outbound") -> Pipeline:
    """Exp-3 rewriting on the row store: the slim CTE still gathers full
    rows per level AND the top-level join gathers them again — the rewrite
    cannot rescue a heap table."""
    slim = rowstore_plan(caps, max_depth, ("id",), dedup, use_index,
                         direction)
    return dataclasses.replace(
        slim, name="RecursiveRewrite",
        finisher=TopLevelJoin(tuple(out_cols), inner=slim.finisher,
                              use_rows=True))


# ---------------------------------------------------------------------------
# legacy function API — thin wrappers over the pipelines
# ---------------------------------------------------------------------------

def _columnar_ctx(table: ColumnTable, csr: CSRIndex) -> Context:
    return Context(table=table, rows=None, csr=csr,
                   join_src=table.column("from"),
                   join_dst=table.column("to"))


def _row_ctx(rt: RowTable, csr: CSRIndex) -> Context:
    return Context(table=None, rows=rt, csr=csr,
                   join_src=rt.column("from").astype("int32"),
                   join_dst=rt.column("to").astype("int32"))


def precursive_bfs(table: ColumnTable, csr: CSRIndex, root,
                   *, caps: EngineCaps, max_depth: int,
                   out_cols: tuple[str, ...], dedup: bool = True,
                   expand_fn: Callable | None = None) -> BFSResult:
    """Positional BFS with late materialization (Fig. 4)."""
    plan = precursive_plan(caps, max_depth, out_cols, dedup,
                           expand_fn=expand_fn)
    return execute(plan, _columnar_ctx(table, csr), root, csr.num_vertices)


def trecursive_bfs(table: ColumnTable, csr: CSRIndex, root,
                   *, caps: EngineCaps, max_depth: int,
                   out_cols: tuple[str, ...], dedup: bool = True
                   ) -> BFSResult:
    """Tuple-based BFS: the recursion carries materialized tuples (Fig. 3)."""
    plan = trecursive_plan(caps, max_depth, out_cols, dedup)
    return execute(plan, _columnar_ctx(table, csr), root, csr.num_vertices)


def rowstore_bfs(rt: RowTable, csr: CSRIndex, root,
                 *, caps: EngineCaps, max_depth: int,
                 out_cols: tuple[str, ...], dedup: bool = True,
                 use_index: bool = False) -> BFSResult:
    """Row-store BFS (PostgreSQL / PostgreSQL+index emulation)."""
    plan = rowstore_plan(caps, max_depth, out_cols, dedup, use_index)
    return execute(plan, _row_ctx(rt, csr), root, csr.num_vertices)


def trecursive_rewrite_bfs(table: ColumnTable, csr: CSRIndex, root,
                           *, caps: EngineCaps, max_depth: int,
                           out_cols: tuple[str, ...], dedup: bool = True
                           ) -> BFSResult:
    """Exp-3 rewrite of the tuple engine (slim CTE + one top-level join)."""
    plan = trecursive_rewrite_plan(caps, max_depth, out_cols, dedup)
    return execute(plan, _columnar_ctx(table, csr), root, csr.num_vertices)


def rowstore_rewrite_bfs(rt: RowTable, csr: CSRIndex, root,
                         *, caps: EngineCaps, max_depth: int,
                         out_cols: tuple[str, ...], dedup: bool = True,
                         use_index: bool = False) -> BFSResult:
    """Exp-3 rewrite on the row store (still reads full heap rows twice)."""
    plan = rowstore_rewrite_plan(caps, max_depth, out_cols, dedup, use_index)
    return execute(plan, _row_ctx(rt, csr), root, csr.num_vertices)
