"""The paper's contribution: recursive (BFS) query engines.

Four engines share one fixed-point skeleton (``jax.lax.while_loop``) and
differ only in what flows through the recursion — exactly the axis the paper
studies:

=================  ==========================================================
``precursive``     position blocks only; join columns read per level; ALL
                   output columns gathered once at the end (late
                   materialization).  The paper's main contribution
                   (PRecursive/PRecursiveCTE, Fig. 4).
``trecursive``     materialized tuple blocks over columnar storage (early
                   materialization; TRecursive/TRecursiveCTE, Fig. 3).
``rowstore``       PostgreSQL emulation: interleaved rows, per-level hash
                   join realized as a full scan + membership probe; every
                   row access reads the full row width.
``rowstore_index`` PostgreSQL-with-index emulation: CSR join index avoids
                   the scan but row gathers still read full rows.
=================  ==========================================================

Beyond the paper, :mod:`repro.core.bitmap` adds a dense-frontier engine and
:mod:`repro.core.distributed_bfs` the multi-device one.

Semantics note: the SQL in the paper is ``UNION ALL`` over a *tree*, where
every edge is reached at most once and BFS/UNION-ALL coincide.  On general
graphs the engines implement BFS semantics (per-vertex dedup via a visited
bitmap, within-level dedup via scatter-argmin) when ``dedup=True``; with
``dedup=False`` they reproduce raw UNION ALL walks up to ``max_depth``.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from .csr import CSRIndex, expand_frontier
from .positions import PosBlock, append_block, compact_mask, empty_block
from .table import ColumnTable, RowTable

__all__ = [
    "EngineCaps", "BFSResult", "precursive_bfs", "trecursive_bfs",
    "rowstore_bfs", "trecursive_rewrite_bfs", "rowstore_rewrite_bfs",
    "dedup_targets",
]


class EngineCaps(NamedTuple):
    """Static buffer capacities (the Volcano block sizes of the TPU port)."""

    frontier: int   # max edges emitted by a single BFS level
    result: int     # max edges in the full result


class BFSResult(NamedTuple):
    values: Dict[str, jax.Array]   # (result_cap, ...) materialized outputs
    positions: jax.Array           # (result_cap,) edge positions (or -1s)
    count: jax.Array               # () live rows
    depth: jax.Array               # () levels actually executed
    overflow: jax.Array            # () any capacity overflow observed


def dedup_targets(targets: jax.Array, valid: jax.Array, visited: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """BFS vertex dedup: drop already-visited targets and, within the level,
    keep only the first occurrence of each vertex (scatter-argmin ticket).

    Returns (keep_mask, new_visited)."""
    cap = targets.shape[0]
    nv = visited.shape[0]
    safe = jnp.clip(targets, 0, nv - 1)
    fresh = valid & ~visited[safe]
    slots = jnp.arange(cap, dtype=jnp.int32)
    ticket = jnp.full((nv,), cap, jnp.int32).at[safe].min(
        jnp.where(fresh, slots, cap), mode="drop")
    keep = fresh & (ticket[safe] == slots)
    new_visited = visited.at[safe].set(jnp.where(keep, True, visited[safe]),
                                       mode="drop")
    return keep, new_visited


def _seed_block(from_col: jax.Array, root, cap: int, sentinel: int) -> PosBlock:
    return compact_mask(from_col == root, cap, sentinel)


# ---------------------------------------------------------------------------
# PRecursive — the paper's positional engine
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("caps", "max_depth", "out_cols",
                                             "dedup", "expand_fn"))
def precursive_bfs(table: ColumnTable, csr: CSRIndex, root: jax.Array,
                   *, caps: EngineCaps, max_depth: int,
                   out_cols: tuple[str, ...], dedup: bool = True,
                   expand_fn: Callable | None = None) -> BFSResult:
    """Positional BFS with late materialization.

    Per level the engine touches exactly one value column (``to``) to turn
    edge positions into target vertices; everything else is positions.  The
    single materialize happens after the fixed point.
    """
    expand = expand_fn or expand_frontier
    e = table.num_rows
    to_col = table.column("to")
    nv = csr.num_vertices

    frontier = _seed_block(table.column("from"), root, caps.frontier, e)
    result = jnp.full((caps.result,), e, jnp.int32)
    result, rcount, roverflow = append_block(result, jnp.zeros((), jnp.int32),
                                             frontier)
    visited = jnp.zeros((nv,), bool).at[jnp.clip(root, 0, nv - 1)].set(True)

    def cond(state):
        frontier, _, _, visited, depth, _ = state
        return (frontier.count > 0) & (depth < max_depth)

    def body(state):
        frontier, result, rcount, visited, depth, overflow = state
        fvalid = frontier.valid_mask()
        # the ONLY per-level value read: positions -> target vertices
        targets = jnp.where(fvalid,
                            to_col[jnp.minimum(frontier.positions, e - 1)], -1)
        if dedup:
            keep, visited = dedup_targets(targets, fvalid, visited)
        else:
            keep = fvalid
        targets = jnp.where(keep, targets, -1)
        epos, total, ovf = expand(csr, targets, keep, caps.frontier)
        nxt = PosBlock(epos, total)
        result, rcount, ovf2 = append_block(result, rcount, nxt)
        return (nxt, result, rcount, visited, depth + 1,
                overflow | ovf | ovf2)

    state = (frontier, result, rcount, visited, jnp.zeros((), jnp.int32),
             roverflow)
    frontier, result, rcount, visited, depth, overflow = jax.lax.while_loop(
        cond, body, state)

    block = PosBlock(result, rcount)
    values = table.take(block.positions, out_cols)     # the late materialize
    return BFSResult(values, block.positions, rcount, depth, overflow)


# ---------------------------------------------------------------------------
# TRecursive — tuple blocks over columnar storage (early materialization)
# ---------------------------------------------------------------------------

def _append_values(bufs, count, vals, block_count, cap_r):
    cap_f = next(iter(vals.values())).shape[0]
    slots = count + jnp.arange(cap_f, dtype=jnp.int32)
    live = (jnp.arange(cap_f, dtype=jnp.int32) < block_count) & (slots < cap_r)
    safe = jnp.where(live, slots, cap_r)
    out = {}
    for k, buf in bufs.items():
        v = vals[k]
        mask = live.reshape(live.shape + (1,) * (v.ndim - 1))
        out[k] = buf.at[safe].set(jnp.where(mask, v, 0), mode="drop")
    new_count = jnp.minimum(count + block_count, cap_r)
    return out, new_count, (count + block_count) > cap_r


@functools.partial(jax.jit, static_argnames=("caps", "max_depth", "out_cols",
                                             "dedup"))
def trecursive_bfs(table: ColumnTable, csr: CSRIndex, root: jax.Array,
                   *, caps: EngineCaps, max_depth: int,
                   out_cols: tuple[str, ...], dedup: bool = True) -> BFSResult:
    """Tuple-based BFS: the recursion carries fully materialized tuples.

    Per level, the join output is immediately materialized into ALL
    ``out_cols`` (the paper's Fig. 3 plan: Join over Materialize) — (3+N)
    column gathers per level instead of PRecursive's one.
    """
    e = table.num_rows
    nv = csr.num_vertices

    seed = _seed_block(table.column("from"), root, caps.frontier, e)
    carry_cols = tuple(dict.fromkeys(out_cols + ("to",)))  # 'to' drives join
    seed_vals = table.take(seed.positions, carry_cols)      # early materialize

    rbufs = {k: jnp.zeros((caps.result,) + v.shape[1:], v.dtype)
             for k, v in seed_vals.items() if k in out_cols}
    rbufs, rcount, rovf = _append_values(
        rbufs, jnp.zeros((), jnp.int32),
        {k: seed_vals[k] for k in rbufs}, seed.count, caps.result)
    visited = jnp.zeros((nv,), bool).at[jnp.clip(root, 0, nv - 1)].set(True)

    def cond(state):
        _, fcount, _, _, visited, depth, _ = state
        return (fcount > 0) & (depth < max_depth)

    def body(state):
        fvals, fcount, rbufs, rcount, visited, depth, overflow = state
        fvalid = jnp.arange(caps.frontier, dtype=jnp.int32) < fcount
        targets = jnp.where(fvalid, fvals["to"], -1)   # from the tuple block
        if dedup:
            keep, visited = dedup_targets(targets, fvalid, visited)
        else:
            keep = fvalid
        targets = jnp.where(keep, targets, -1)
        epos, total, ovf = expand_frontier(csr, targets, keep, caps.frontier)
        nxt_vals = table.take(epos, carry_cols)         # early materialize
        rbufs2, rcount2, ovf2 = _append_values(
            rbufs, rcount, {k: nxt_vals[k] for k in rbufs}, total, caps.result)
        return (nxt_vals, total, rbufs2, rcount2, visited, depth + 1,
                overflow | ovf | ovf2)

    state = (seed_vals, seed.count, rbufs, rcount, visited,
             jnp.zeros((), jnp.int32), rovf)
    fvals, fcount, rbufs, rcount, visited, depth, overflow = \
        jax.lax.while_loop(cond, body, state)

    return BFSResult({k: rbufs[k] for k in out_cols},
                     jnp.full((caps.result,), -1, jnp.int32),
                     rcount, depth, overflow)


# ---------------------------------------------------------------------------
# Row-store emulation (PostgreSQL / PostgreSQL+index baselines)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("caps", "max_depth", "out_cols",
                                             "dedup", "use_index"))
def rowstore_bfs(rt: RowTable, csr: CSRIndex, root: jax.Array,
                 *, caps: EngineCaps, max_depth: int,
                 out_cols: tuple[str, ...], dedup: bool = True,
                 use_index: bool = False) -> BFSResult:
    """Row-store BFS.  ``use_index=False`` = hash-join-by-scan (PostgreSQL
    default): every level scans the full interleaved table to probe the
    frontier's vertex set.  ``use_index=True`` = index join via CSR, but row
    gathers still read full rows (heap pages)."""
    e = rt.num_rows
    nv = csr.num_vertices
    from_col = rt.column("from")           # strided: drags full rows along
    to_slot, width = rt.slot("to"), rt.width

    seed = compact_mask(from_col == root, caps.frontier, e)
    seed_rows = rt.take_rows(seed.positions)            # full-width gather

    rbuf = jnp.zeros((caps.result, width), jnp.float32)
    rbufs, rcount, rovf = _append_values({"rows": rbuf},
                                         jnp.zeros((), jnp.int32),
                                         {"rows": seed_rows}, seed.count,
                                         caps.result)
    visited = jnp.zeros((nv,), bool).at[jnp.clip(root, 0, nv - 1)].set(True)

    def cond(state):
        _, fcount, _, _, visited, depth, _ = state
        return (fcount > 0) & (depth < max_depth)

    def body(state):
        frows, fcount, rbufs, rcount, visited, depth, overflow = state
        fvalid = jnp.arange(caps.frontier, dtype=jnp.int32) < fcount
        targets = jnp.where(fvalid, frows[:, to_slot].astype(jnp.int32), -1)
        if dedup:
            keep, visited = dedup_targets(targets, fvalid, visited)
        else:
            keep = fvalid
        targets = jnp.where(keep, targets, -1)
        if use_index:
            epos, total, ovf = expand_frontier(csr, targets, keep,
                                               caps.frontier)
            nxt = PosBlock(epos, total)
        else:
            # hash-join emulation: build the frontier's vertex set, then SCAN
            # the whole table probing it (row-store: the scan touches every
            # byte of every row, not just `from`).
            probe = jnp.zeros((nv,), bool).at[
                jnp.clip(targets, 0, nv - 1)].set(keep, mode="drop")
            scan_from = from_col.astype(jnp.int32)       # full-table read
            hit = probe[jnp.clip(scan_from, 0, nv - 1)] & (scan_from >= 0)
            nxt = compact_mask(hit, caps.frontier, e)
            ovf = jnp.sum(hit, dtype=jnp.int32) > caps.frontier
            total = nxt.count
        nxt_rows = rt.take_rows(nxt.positions)           # full-width gather
        rbufs2, rcount2, ovf2 = _append_values(rbufs, rcount,
                                               {"rows": nxt_rows}, total,
                                               caps.result)
        return (nxt_rows, total, rbufs2, rcount2, visited, depth + 1,
                overflow | ovf | ovf2)

    state = (seed_rows, seed.count, rbufs, rcount, visited,
             jnp.zeros((), jnp.int32), rovf)
    frows, fcount, rbufs, rcount, visited, depth, overflow = \
        jax.lax.while_loop(cond, body, state)

    values = rt.project(rbufs["rows"], out_cols)
    return BFSResult(values, jnp.full((caps.result,), -1, jnp.int32),
                     rcount, depth, overflow)


# ---------------------------------------------------------------------------
# Experiment-3 rewrites: slim recursive core + one top-level join on id
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("caps", "max_depth", "out_cols",
                                             "dedup"))
def trecursive_rewrite_bfs(table: ColumnTable, csr: CSRIndex, root: jax.Array,
                           *, caps: EngineCaps, max_depth: int,
                           out_cols: tuple[str, ...], dedup: bool = True
                           ) -> BFSResult:
    """The paper's Exp-3 rewriting for the tuple engine: the CTE carries only
    (id, to); payload columns are joined back once at the top level via a
    hash table on ``id`` (realized as an inverse-permutation probe array)."""
    slim = trecursive_bfs(table, csr, root, caps=caps, max_depth=max_depth,
                          out_cols=("id",), dedup=dedup)
    e = table.num_rows
    id_col = table.column("id")
    # hash build: id -> position (ids are a permutation of positions)
    probe = jnp.zeros((e,), jnp.int32).at[id_col].set(
        jnp.arange(e, dtype=jnp.int32), mode="drop")
    live = jnp.arange(caps.result, dtype=jnp.int32) < slim.count
    ids = jnp.where(live, slim.values["id"].astype(jnp.int32), -1)
    pos = jnp.where(live, probe[jnp.clip(ids, 0, e - 1)], e)
    values = table.take(pos, out_cols)                   # single wide gather
    return BFSResult(values, pos, slim.count, slim.depth, slim.overflow)


@functools.partial(jax.jit, static_argnames=("caps", "max_depth", "out_cols",
                                             "dedup", "use_index"))
def rowstore_rewrite_bfs(rt: RowTable, csr: CSRIndex, root: jax.Array,
                         *, caps: EngineCaps, max_depth: int,
                         out_cols: tuple[str, ...], dedup: bool = True,
                         use_index: bool = False) -> BFSResult:
    """Exp-3 rewriting on the row-store: the slim CTE still gathers full rows
    (heap pages) per level, and the top-level join gathers them again —
    demonstrating the paper's point that the rewrite cannot rescue a
    row-store."""
    slim = rowstore_bfs(rt, csr, root, caps=caps, max_depth=max_depth,
                        out_cols=("id",), dedup=dedup, use_index=use_index)
    e = rt.num_rows
    id_col = rt.column("id").astype(jnp.int32)           # strided scan
    probe = jnp.zeros((e,), jnp.int32).at[jnp.clip(id_col, 0, e - 1)].set(
        jnp.arange(e, dtype=jnp.int32), mode="drop")
    live = jnp.arange(caps.result, dtype=jnp.int32) < slim.count
    ids = jnp.where(live, slim.values["id"].astype(jnp.int32), -1)
    pos = jnp.where(live, probe[jnp.clip(ids, 0, e - 1)], e)
    rows = rt.take_rows(pos)                             # full rows again
    values = rt.project(rows, out_cols)
    return BFSResult(values, pos, slim.count, slim.depth, slim.overflow)
