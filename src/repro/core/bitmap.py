"""Beyond-paper engine: dense-frontier (bitmap) BFS.

The paper's engines keep the frontier *sparse* (position lists).  On TPU a
dense boolean frontier over vertices is often better: one level becomes a
masked scatter over the full edge list — a boolean-semiring SpMV with no
data-dependent shapes, perfectly vectorizable on the VPU and trivially
shardable (edges split across devices, frontier psum-OR'ed).

``hybrid_bfs`` direction-optimizes per level: while the frontier is small it
runs the paper's positional expansion (work ∝ frontier edges); once the
frontier covers more than ``switch_frac`` of vertices it flips to the dense
step (work ∝ E but stream-friendly).  Late materialization is preserved:
the result is an edge *mask*, compacted to positions and gathered once.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .csr import CSRIndex, expand_frontier
from .positions import PosBlock, compact_mask
from .recursive import BFSResult, EngineCaps, dedup_targets
from .table import ColumnTable

__all__ = ["bitmap_bfs", "hybrid_bfs", "bitmap_level"]


def bitmap_level(from_col: jax.Array, to_col: jax.Array,
                 frontier_v: jax.Array, visited: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One dense push step.  Returns (edge_hit_mask, next_frontier, visited).

    edge_hit_mask marks edges whose source is in the frontier (these are the
    rows the CTE emits this level)."""
    nv = frontier_v.shape[0]
    hit = frontier_v[jnp.clip(from_col, 0, nv - 1)]
    tgt = jnp.clip(to_col, 0, nv - 1)
    nxt = jnp.zeros((nv,), bool).at[tgt].max(hit, mode="drop")
    nxt = nxt & ~visited
    visited = visited | nxt
    return hit, nxt, visited


@functools.partial(jax.jit, static_argnames=("caps", "max_depth", "out_cols",
                                             "num_vertices"))
def bitmap_bfs(table: ColumnTable, num_vertices: int, root: jax.Array,
               *, caps: EngineCaps, max_depth: int,
               out_cols: tuple[str, ...]) -> BFSResult:
    """Dense-frontier BFS (always-push).  Work per level is O(E) regardless
    of frontier size; intermediate state is 2 bitmaps + 1 edge mask."""
    from_col = table.column("from")
    to_col = table.column("to")
    nv = num_vertices

    frontier = jnp.zeros((nv,), bool).at[jnp.clip(root, 0, nv - 1)].set(True)
    visited = frontier
    emitted = jnp.zeros((table.num_rows,), bool)

    def cond(state):
        frontier, _, _, depth = state
        return jnp.any(frontier) & (depth <= max_depth)

    def body(state):
        frontier, visited, emitted, depth = state
        hit, nxt, visited = bitmap_level(from_col, to_col, frontier, visited)
        return nxt, visited, emitted | hit, depth + 1

    frontier, visited, emitted, depth = jax.lax.while_loop(
        cond, body, (frontier, visited, emitted, jnp.zeros((), jnp.int32)))

    block = compact_mask(emitted, caps.result, table.num_rows)
    values = table.take(block.positions, out_cols)      # late materialize
    overflow = jnp.sum(emitted, dtype=jnp.int32) > caps.result
    return BFSResult(values, block.positions, block.count, depth, overflow)


@functools.partial(jax.jit, static_argnames=("caps", "max_depth", "out_cols",
                                             "switch_frac"))
def hybrid_bfs(table: ColumnTable, csr: CSRIndex, root: jax.Array,
               *, caps: EngineCaps, max_depth: int,
               out_cols: tuple[str, ...], switch_frac: float = 0.05
               ) -> BFSResult:
    """Direction-optimizing BFS: positional expansion for small frontiers,
    dense push for large ones.  State carries both representations; each
    level converts the cheap way (positions->bitmap is a scatter;
    bitmap->positions is a bounded compact)."""
    e = table.num_rows
    nv = csr.num_vertices
    from_col, to_col = table.column("from"), table.column("to")
    threshold = max(1, int(nv * switch_frac))

    seed = compact_mask(from_col == root, caps.frontier, e)
    emitted = jnp.zeros((e,), bool).at[
        jnp.where(seed.valid_mask(), seed.positions, e)].set(
            seed.valid_mask(), mode="drop")
    visited = jnp.zeros((nv,), bool).at[jnp.clip(root, 0, nv - 1)].set(True)

    def cond(state):
        frontier, _, _, depth, _ = state
        return (frontier.count > 0) & (depth < max_depth)

    def sparse_step(frontier, visited):
        fvalid = frontier.valid_mask()
        targets = jnp.where(fvalid,
                            to_col[jnp.minimum(frontier.positions, e - 1)], -1)
        keep, visited = dedup_targets(targets, fvalid, visited)
        targets = jnp.where(keep, targets, -1)
        epos, total, ovf = expand_frontier(csr, targets, keep, caps.frontier)
        return PosBlock(epos, total), visited, ovf

    def dense_step(frontier, visited):
        fvalid = frontier.valid_mask()
        targets = to_col[jnp.minimum(frontier.positions, e - 1)]
        tgt_v = jnp.zeros((nv,), bool).at[jnp.clip(targets, 0, nv - 1)].set(
            fvalid, mode="drop")
        tgt_v = tgt_v & ~visited
        visited = visited | tgt_v
        hit = tgt_v[jnp.clip(from_col, 0, nv - 1)]
        nxt = compact_mask(hit, caps.frontier, e)
        ovf = jnp.sum(hit, dtype=jnp.int32) > caps.frontier
        return nxt, visited, ovf

    def body(state):
        frontier, visited, emitted, depth, overflow = state
        nxt, visited, ovf = jax.lax.cond(
            frontier.count < threshold, sparse_step, dense_step,
            frontier, visited)
        emitted = emitted.at[jnp.where(nxt.valid_mask(), nxt.positions, e)
                             ].set(nxt.valid_mask(), mode="drop")
        return nxt, visited, emitted, depth + 1, overflow | ovf

    state = (seed, visited, emitted, jnp.zeros((), jnp.int32),
             jnp.zeros((), bool))
    frontier, visited, emitted, depth, overflow = jax.lax.while_loop(
        cond, body, state)

    block = compact_mask(emitted, caps.result, e)
    values = table.take(block.positions, out_cols)
    overflow = overflow | (jnp.sum(emitted, dtype=jnp.int32) > caps.result)
    return BFSResult(values, block.positions, block.count, depth, overflow)
