"""Beyond-paper engines: dense-frontier (bitmap) and direction-optimizing
BFS, as operator-pipeline compositions.

The paper's engines keep the frontier *sparse* (position lists).  On TPU a
dense boolean frontier over vertices is often better: one level becomes a
masked scatter over the full edge list — a boolean-semiring SpMV with no
data-dependent shapes, perfectly vectorizable on the VPU and trivially
shardable.  Both engines below run through the same
:func:`~repro.core.operators.fixed_point` driver as the paper's pipelines:

* ``bitmap``  — Seed(dense) → DenseBitmapStep, finished by CompactEmitted
  (the emitted-edge mask is compacted to positions and late-materialized, so
  the dense plan keeps the paper's positional contract);
* ``hybrid``  — Seed(pos) → HybridStep: positional CSRIndexJoin while the
  frontier is small, dense push once it covers > ``switch_frac`` of the
  vertices (direction-optimizing BFS).
"""
from __future__ import annotations

from .csr import CSRIndex
from .operators import (WORD_LANES, BFSResult, CompactEmitted, Context,
                        DeferredEmit, DenseBitmapStep, DirectionSwitch,
                        EngineCaps, HybridPullStep, HybridStep,
                        MultiQueryEmit, MultiQuerySeed, MultiQueryWordSweep,
                        Pipeline, PullStep, Seed, WeightedDenseStep,
                        bitmap_level, check_direction, execute)
from .table import ColumnTable

__all__ = ["bitmap_bfs", "hybrid_bfs", "bitmap_level", "bitmap_plan",
           "hybrid_plan", "diropt_plan", "diropt_hybrid_plan",
           "weighted_bitmap_plan", "multiquery_plan"]


def bitmap_plan(caps: EngineCaps, max_depth: int,
                out_cols: tuple[str, ...],
                direction: str = "outbound") -> Pipeline:
    """Dense-frontier BFS (always-push): O(E) work per level, state is two
    bitmaps + one edge mask; ``inclusive`` matches the dense loop's
    emit-inside-the-body level accounting."""
    check_direction(direction)
    return Pipeline(
        name="BitmapBFS", rep="dense",
        seed=Seed(kind="dense"),
        ops=(DenseBitmapStep(),),
        finisher=CompactEmitted(tuple(out_cols)),
        caps=caps, max_depth=max_depth, inclusive=True, tracks_emitted=True)


def weighted_bitmap_plan(caps: EngineCaps, max_depth: int,
                         out_cols: tuple[str, ...], semiring: str,
                         direction: str = "outbound",
                         use_kernel: bool = False) -> Pipeline:
    """Dense-frontier traversal under a value semiring: per level one ⊗
    over the full edge list and one ⊕-scatter into the (V,) value plane
    (:class:`WeightedDenseStep`; ``use_kernel`` routes the (sum, ×)
    combine through the ``spmm_segment`` Pallas kernel).  Single-direction
    views only — the fused bidirectional join space has no dense weighted
    step."""
    check_direction(direction)
    if direction == "both":
        raise ValueError("the dense weighted step is single-direction; "
                         "use the positional weighted engine for 'both'")
    return Pipeline(
        name="BitmapWeighted", rep="dense",
        seed=Seed(kind="dense", semiring=semiring),
        ops=(WeightedDenseStep(semiring=semiring, use_kernel=use_kernel),),
        finisher=CompactEmitted(tuple(out_cols)),
        caps=caps, max_depth=max_depth, inclusive=True, tracks_emitted=True,
        semiring=semiring)


def hybrid_plan(caps: EngineCaps, max_depth: int,
                out_cols: tuple[str, ...], switch_frac: float = 0.05,
                direction: str = "outbound") -> Pipeline:
    """Direction-optimizing BFS: the per-level operator flips between the
    paper's positional expansion and the dense push."""
    check_direction(direction)
    return Pipeline(
        name="HybridBFS", rep="pos",
        seed=Seed(mark_emitted=True),
        ops=(HybridStep(switch_frac=switch_frac),),
        finisher=CompactEmitted(tuple(out_cols)),
        caps=caps, max_depth=max_depth, tracks_emitted=True)


def diropt_plan(caps: EngineCaps, max_depth: int,
                out_cols: tuple[str, ...], direction: str = "outbound",
                alpha: float = 1.0, beta: float = 64.0,
                pull_fn=None) -> Pipeline:
    """Direction-optimizing dense BFS: per level a :class:`DirectionSwitch`
    picks the push bitmap step or the Beamer bottom-up :class:`PullStep`
    (gather over the reverse CSR from unvisited vertices); emission is
    DEFERRED — the loop carries only per-vertex depths and the emitted
    mask is derived in one pass by :class:`DeferredEmit`.  Row-for-row
    equal to ``bitmap`` (same rows, order, depths, loop accounting).

    ``alpha``/``beta`` are the switch thresholds
    (``CostConstants.pull_alpha``/``pull_beta`` — the planner stamps its
    refittable constants here); ``pull_fn`` plugs the Pallas
    ``frontier_pull`` kernel into the pull side."""
    check_direction(direction)
    return Pipeline(
        name="DirOptBFS", rep="dense",
        seed=Seed(kind="dense"),
        ops=(DirectionSwitch(push=DenseBitmapStep(deferred=True),
                             pull=PullStep(deferred=True,
                                           expand_fn=pull_fn),
                             alpha=alpha, beta=beta),),
        finisher=DeferredEmit(tuple(out_cols)),
        caps=caps, max_depth=max_depth, inclusive=True,
        tracks_vertex_depth=True, tracks_switch=True)


def diropt_hybrid_plan(caps: EngineCaps, max_depth: int,
                       out_cols: tuple[str, ...], switch_frac: float = 0.05,
                       direction: str = "outbound", alpha: float = 1.0,
                       beta: float = 64.0) -> Pipeline:
    """Direction-optimizing hybrid BFS: the positional-frontier
    :class:`HybridStep` (sparse IndexJoin / dense push) on the push side,
    its bottom-up twin :class:`HybridPullStep` on the pull side.
    Level-for-level state-identical to ``hybrid``."""
    check_direction(direction)
    return Pipeline(
        name="DirOptHybridBFS", rep="pos",
        seed=Seed(mark_emitted=True),
        ops=(DirectionSwitch(push=HybridStep(switch_frac=switch_frac),
                             pull=HybridPullStep(),
                             alpha=alpha, beta=beta),),
        finisher=CompactEmitted(tuple(out_cols)),
        caps=caps, max_depth=max_depth, tracks_emitted=True,
        tracks_switch=True)


def multiquery_plan(caps: EngineCaps, max_depth: int,
                    out_cols: tuple[str, ...], direction: str = "outbound",
                    lanes: int = WORD_LANES) -> Pipeline:
    """Bit-parallel multi-query BFS (MS-BFS): the dense frontier/visited
    planes widen from boolean to a uint32 word whose bits are up to 32
    concurrent roots — ONE segment-OR sweep per level advances every lane
    at once, with per-lane convergence freezing and per-lane depth caps.
    Emission is deferred per lane and row-for-row equal to the sequential
    deferred-emission engines; runs through
    :func:`~repro.core.operators.execute_multiquery`, not the scalar
    driver."""
    check_direction(direction)
    lanes = int(lanes)
    if not 1 <= lanes <= WORD_LANES:
        raise ValueError(f"multiquery lanes must be in 1..{WORD_LANES}, "
                         f"got {lanes}")
    return Pipeline(
        name="MultiQueryBFS", rep="dense",
        seed=MultiQuerySeed(lanes=lanes),
        ops=(MultiQueryWordSweep(lanes=lanes),),
        finisher=MultiQueryEmit(tuple(out_cols), lanes=lanes),
        caps=caps, max_depth=max_depth, inclusive=True,
        tracks_vertex_depth=True)


def bitmap_bfs(table: ColumnTable, num_vertices: int, root,
               *, caps: EngineCaps, max_depth: int,
               out_cols: tuple[str, ...]) -> BFSResult:
    """Dense-frontier BFS over the raw edge columns (no index needed)."""
    ctx = Context(table=table, rows=None, csr=None,
                  join_src=table.column("from"),
                  join_dst=table.column("to"))
    plan = bitmap_plan(caps, max_depth, out_cols)
    return execute(plan, ctx, root, num_vertices)


def hybrid_bfs(table: ColumnTable, csr: CSRIndex, root,
               *, caps: EngineCaps, max_depth: int,
               out_cols: tuple[str, ...], switch_frac: float = 0.05
               ) -> BFSResult:
    """Direction-optimizing BFS (positional below the switch threshold,
    dense push above it)."""
    ctx = Context(table=table, rows=None, csr=csr,
                  join_src=table.column("from"),
                  join_dst=table.column("to"))
    plan = hybrid_plan(caps, max_depth, out_cols, switch_frac)
    return execute(plan, ctx, root, num_vertices=csr.num_vertices)
