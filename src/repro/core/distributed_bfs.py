"""Distributed positional BFS — PRecursive over a device mesh, expressed as
an operator pipeline on the SAME :func:`~repro.core.operators.fixed_point`
driver as the single-device engines.

PosDB is "a disk-based *distributed* column-store"; the paper evaluates a
single node.  This module supplies the distributed engine the paper implies,
mapped onto JAX collectives:

* every column of the edge table is row-sharded over the BFS axes
  (``('pod','data')`` on the production mesh) — each device owns a slab of
  edges and builds a *local* CSR join index over them;
* the per-level pipeline is ``CSRIndexJoin`` (shard-local positional
  expansion of the replicated vertex frontier) → ``AppendUnionAll``
  (shard-local result positions) → ``ShardTargetExchange`` (the shard-aware
  operator: ONE tiled ``all_gather`` of vertex ids per level — the only
  collective, O(frontier) bytes, *never* values — followed by replicated
  dedup so every shard derives the identical next frontier);
* result positions stay shard-local; the final late materialization is a
  shard-local gather, so payload bytes cross no link at any point.

This is the paper's late-materialization win restated for a cluster: the
wire carries positions, values move zero times.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .csr import build_csr
from .operators import (AppendUnionAll, Context, CSRIndexJoin, EngineCaps,
                        Pipeline, RawPositions, Seed, ShardTargetExchange,
                        fixed_point)

__all__ = ["make_distributed_pbfs", "distributed_plan", "shard_map_compat"]


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """Version-portable shard_map (jax.shard_map landed after 0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def distributed_plan(axis, caps: EngineCaps, max_depth: int) -> Pipeline:
    """The distributed PRecursive pipeline: vertex-seeded (the frontier is
    the replicated target block, not edge positions), emit-inside-the-body
    (``inclusive`` + ``step_tag_offset=0``), shard-aware target union."""
    return Pipeline(
        name="DistributedPRecursive", rep="pos",
        seed=Seed(kind="vertices"),
        ops=(CSRIndexJoin(),
             AppendUnionAll("pos", step_tag_offset=0, append_seed=False),
             ShardTargetExchange(axis)),
        finisher=RawPositions(),
        caps=caps, max_depth=max_depth, inclusive=True)


def make_distributed_pbfs(mesh, axes: Sequence[str], num_vertices: int,
                          *, caps: EngineCaps, max_depth: int,
                          num_payload_cols: int):
    """Build a jitted distributed PRecursive BFS.

    Returns ``fn(from_col, to_col, payload, root) ->
    (positions, values, count, depth, overflow)`` where ``from_col``/
    ``to_col``/(rows of) ``payload`` are sharded over ``axes`` and outputs
    are sharded the same way (shard-local result blocks).
    """
    axes = tuple(axes)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    ax = axes if len(axes) > 1 else axes[0]
    plan = distributed_plan(ax, caps, max_depth)

    def bfs_local(from_loc, to_loc, payload_loc, root, shard_base):
        e_loc = from_loc.shape[0]
        ctx = Context(table=None, rows=None,
                      csr=build_csr(from_loc, num_vertices),
                      join_src=from_loc, join_dst=to_loc)
        r = fixed_point(plan, ctx, root, num_vertices)

        # shard-local late materialization: payload bytes never leave the
        # shard
        live = jnp.arange(caps.result, dtype=jnp.int32) < r.count
        safe = jnp.minimum(r.positions, e_loc - 1)
        vals = jnp.where(live[:, None], payload_loc[safe], 0.0)
        gpos = jnp.where(live, r.positions + shard_base, -1)
        return gpos, vals, r.count[None], (r.depth - 1)[None], \
            r.overflow[None]

    pspec = P(ax)
    fn = shard_map_compat(bfs_local, mesh,
                          (pspec, pspec, pspec, P(), pspec),
                          (pspec, pspec, pspec, pspec, pspec))

    @jax.jit
    def run(from_col, to_col, payload, root):
        e = from_col.shape[0]
        shard_base = (jnp.arange(nshards, dtype=jnp.int32) * (e // nshards))
        gpos, vals, counts, depths, ovfs = fn(from_col, to_col, payload, root,
                                              shard_base)
        return gpos, vals, counts, depths, ovfs

    return run
