"""Distributed positional BFS — PRecursive over a device mesh.

PosDB is "a disk-based *distributed* column-store"; the paper evaluates a
single node.  This module supplies the distributed engine the paper implies,
mapped onto JAX collectives:

* every column of the edge table is row-sharded over the BFS axes
  (``('pod','data')`` on the production mesh) — each device owns a slab of
  edges and builds a *local* CSR join index over them;
* the frontier is a replicated block of target **vertices** (small); each
  level every shard expands it through its local CSR into local edge
  positions — pure shard-local positional work;
* next-level targets are unioned with one ``all_gather`` of vertex ids per
  level — the only collective, O(frontier) bytes, *never* values;
* result positions stay shard-local; the final late materialization is a
  shard-local gather, so payload bytes cross no link at any point.

This is the paper's late-materialization win restated for a cluster: the
wire carries positions, values move zero times.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .csr import build_csr, expand_frontier
from .positions import PosBlock, append_block, block_from_mask
from .recursive import EngineCaps, dedup_targets

__all__ = ["make_distributed_pbfs"]


def make_distributed_pbfs(mesh, axes: Sequence[str], num_vertices: int,
                          *, caps: EngineCaps, max_depth: int,
                          num_payload_cols: int):
    """Build a jitted distributed PRecursive BFS.

    Returns ``fn(from_col, to_col, payload, root) ->
    (positions, values, count, depth, overflow)`` where ``from_col``/
    ``to_col``/(rows of) ``payload`` are sharded over ``axes`` and outputs
    are sharded the same way (shard-local result blocks).
    """
    axes = tuple(axes)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    ax = axes if len(axes) > 1 else axes[0]

    def bfs_local(from_loc, to_loc, payload_loc, root, shard_base):
        e_loc = from_loc.shape[0]
        csr = build_csr(from_loc, num_vertices)

        targets = jnp.full((caps.frontier,), -1, jnp.int32).at[0].set(root)
        tcount = jnp.ones((), jnp.int32)
        visited = jnp.zeros((num_vertices,), bool).at[
            jnp.clip(root, 0, num_vertices - 1)].set(True)
        result = jnp.full((caps.result,), e_loc, jnp.int32)
        rcount = jnp.zeros((), jnp.int32)

        def cond(state):
            _, tcount, _, _, _, depth, _ = state
            return (tcount > 0) & (depth <= max_depth)

        def body(state):
            targets, tcount, visited, result, rcount, depth, ovf = state
            valid = jnp.arange(caps.frontier, dtype=jnp.int32) < tcount
            # local positional expansion (replicated targets -> local epos)
            epos, total, o1 = expand_frontier(csr, targets, valid,
                                              caps.frontier)
            result, rcount, o2 = append_block(result, rcount,
                                              PosBlock(epos, total))
            # local targets of the newly reached edges
            live = jnp.arange(caps.frontier, dtype=jnp.int32) < total
            tloc = jnp.where(live, to_loc[jnp.minimum(epos, e_loc - 1)], -1)
            # the one collective: union candidate targets across shards
            gathered = jax.lax.all_gather(tloc, ax, tiled=True)  # (S*cap,)
            gvalid = gathered >= 0
            # replicated dedup -> identical next frontier on every shard
            keep, visited2 = dedup_targets(gathered, gvalid, visited)
            nxt, o3 = block_from_mask(gathered, keep, caps.frontier, -1)
            return (nxt.positions, nxt.count, visited2, result, rcount,
                    depth + 1, ovf | o1 | o2 | o3)

        state = (targets, tcount, visited, result, rcount,
                 jnp.zeros((), jnp.int32), jnp.zeros((), bool))
        targets, tcount, visited, result, rcount, depth, ovf = \
            jax.lax.while_loop(cond, body, state)

        # shard-local late materialization: payload bytes never leave the shard
        live = jnp.arange(caps.result, dtype=jnp.int32) < rcount
        safe = jnp.minimum(result, e_loc - 1)
        vals = jnp.where(live[:, None], payload_loc[safe], 0.0)
        gpos = jnp.where(live, result + shard_base, -1)
        return gpos, vals, rcount[None], (depth - 1)[None], ovf[None]

    pspec = P(ax)
    fn = jax.shard_map(
        bfs_local, mesh=mesh,
        in_specs=(pspec, pspec, pspec, P(), pspec),
        out_specs=(pspec, pspec, pspec, pspec, pspec),
        check_vma=False,
    )

    @jax.jit
    def run(from_col, to_col, payload, root):
        e = from_col.shape[0]
        shard_base = (jnp.arange(nshards, dtype=jnp.int32) * (e // nshards))
        gpos, vals, counts, depths, ovfs = fn(from_col, to_col, payload, root,
                                              shard_base)
        return gpos, vals, counts, depths, ovfs

    return run
