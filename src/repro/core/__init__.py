"""Core: the paper's positional recursive-query engine, in JAX."""
from .table import ColumnTable, RowTable, payload_names            # noqa: F401
from .positions import (PosBlock, empty_block, compact_mask,       # noqa: F401
                        append_block, take_late, sort_positions_by_key)
from .csr import CSRIndex, build_csr, expand_frontier              # noqa: F401
from .operators import (Context, Pipeline, TraversalState,         # noqa: F401
                        fixed_point, fixed_point_batch, execute,
                        execute_batch)
from .recursive import (EngineCaps, BFSResult, precursive_bfs,     # noqa: F401
                        trecursive_bfs, rowstore_bfs,
                        trecursive_rewrite_bfs, rowstore_rewrite_bfs)
from .bitmap import bitmap_bfs, hybrid_bfs                         # noqa: F401
