"""Composable positional operator algebra + the unified fixed-point driver.

The paper describes its engines as *Volcano operator trees* (Fig. 3 for the
tuple-based TRecursive plan, Fig. 4 for the positional PRecursive plan).
This module is that algebra for the TPU port: every recursive engine is a
:class:`Pipeline` — a seed operator, a tuple of per-level operators, and a
finisher — executed by ONE shared :func:`fixed_point` driver (a single
``jax.lax.while_loop``).  The engines in :mod:`repro.core.recursive`,
:mod:`repro.core.bitmap` and :mod:`repro.core.distributed_bfs` are thin
compositions of these operators; ``plan_repr`` in :mod:`repro.core.engine`
renders the *actual* composition, so the paper-figure mapping is auditable.

Operator → paper mapping
------------------------

===================  ======================================================
``Seed``             the non-recursive CTE child (Filter on the root; the
                     row-store variant is a SeqScan over interleaved rows)
``ReadTargets``      per-level read of the join column out of the frontier
                     (positions → one column gather; tuples/rows → free)
``VisitedDedup``     BFS vertex dedup (visited bitmap + scatter-argmin)
``CSRIndexJoin``     Fig. 4's IndexJoin: frontier vertices → edge positions
                     through the CSR join index (positions in, positions out)
``ScanHashJoin``     Fig. 3's HashJoin realized as PostgreSQL does it on a
                     heap table: full SeqScan probing the frontier hash
``DenseBitmapStep``  beyond-paper dense-frontier level (boolean SpMV push)
``EarlyMaterialize`` Fig. 3's per-level Materialize (tuple/row pipelines)
``AppendUnionAll``   the recursive UNION ALL: append the level block to the
                     working result, tagging each row with its BFS level
``LateMaterialize``  Fig. 4's single post-fixed-point Materialize
===================  ======================================================

State contract
--------------

All operators act on one :class:`TraversalState` pytree.  The *frontier
representation* is the axis the paper studies and is explicit per pipeline:

* ``rep='pos'``   — the frontier is a block of edge positions (PRecursive);
* ``rep='vals'``  — a block of materialized column values (TRecursive);
* ``rep='rows'``  — a block of full interleaved rows (row-store emulation);
* ``rep='dense'`` — a boolean vertex bitmap (beyond-paper bitmap engine).

Positions contract: pipelines whose representation carries positions
(``'pos'``/``'dense'``, and any pipeline finished by :class:`TopLevelJoin`)
return real edge positions in ``BFSResult.positions``; pure tuple/row
pipelines return all ``-1`` — positions are *unavailable* after early
materialization, exactly the information loss the paper's Fig. 3 plan pays.

Direction support: the join view (``ctx.join_src``/``ctx.join_dst`` and the
CSR over ``join_src``) decides traversal direction.  ``outbound`` uses
(from, to); ``inbound`` the reverse; ``both`` the FUSED bidirectional view
(``ctx.bidir``): the out- and in-CSRs plus one merged indptr, with a
VIRTUAL 2E join space (position ``p < E`` is edge ``p`` forward,
``p >= E`` backward) whose positions fold back onto real edges at
append/materialize time — same layout the old doubled view materialized,
at E-scale memory.

Direction-optimizing traversal: :class:`PullStep` is the Beamer bottom-up
dual of the push steps (gather over the reverse CSR from unvisited
vertices, testing in-neighbor membership in the frontier bitmap), and
:class:`DirectionSwitch` picks push or pull per level from exact work
terms, with thresholds owned by the planner's refittable cost constants.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .csr import CSRIndex, expand_frontier, expand_frontier_both
from .positions import PosBlock, append_block, block_from_mask, compact_mask
from .semiring import (Semiring, elem_combine, get_semiring, or_combine,
                       scatter_combine)
from .semiring import propagate as sr_propagate
from .table import ColumnTable, RowTable

__all__ = [
    "DIRECTIONS", "check_direction",
    "EngineCaps", "CostEnv", "OpCost",
    "BFSResult", "Context", "TraversalState", "Operator",
    "Seed", "ReadTargets", "VisitedDedup", "CSRIndexJoin", "ScanHashJoin",
    "DenseBitmapStep", "PullStep", "DirectionSwitch", "HybridStep",
    "HybridPullStep", "EarlyMaterialize", "AppendUnionAll",
    "ShardTargetExchange", "LateMaterialize", "EmitTuples", "ProjectRows",
    "CompactEmitted", "DeferredEmit", "TopLevelJoin", "RawPositions",
    "Pipeline", "fixed_point", "fixed_point_batch", "execute",
    "execute_batch", "dedup_targets", "bitmap_level",
    "Semiring", "or_combine", "WeightedExpand", "WeightedDenseStep",
    "MultiQuerySeed", "MultiQueryWordSweep", "MultiQueryEmit",
    "execute_multiquery", "WORD_LANES",
]


DIRECTIONS = ("outbound", "inbound", "both")


def check_direction(direction: str) -> None:
    if direction not in DIRECTIONS:
        raise ValueError(f"unknown direction {direction!r}; "
                         f"expected one of {DIRECTIONS}")


class EngineCaps(NamedTuple):
    """Static buffer capacities (the Volcano block sizes of the TPU port)."""

    frontier: int   # max edges emitted by a single BFS level
    result: int     # max edges in the full result


class CostEnv(NamedTuple):
    """One level's cardinalities + storage widths, fed to each operator's
    :meth:`Operator.estimate` by the planner's cost model.  Cardinalities
    come from sampled graph statistics (:mod:`repro.planner.stats`); widths
    from the dataset's actual column layout.  For finishers the planner sets
    ``frontier_rows``/``emitted_rows`` to the *total* result cardinality.

    The live cardinalities drive output-row estimates; the BYTE estimates of
    block operators are driven by ``frontier_cap``/``result_cap`` instead —
    under the static-shape padding convention every per-level op touches its
    whole fixed-capacity buffer, so capacity (not the live count) is what
    the memory system pays.  That asymmetry is exactly why a dense O(E)
    level can beat a "cheaper" positional level on small graphs with
    generous block sizes."""

    frontier_rows: float       # F: live frontier entries entering the level
    unique_rows: float         # U: frontier rows surviving vertex dedup
    emitted_rows: float        # M: edge rows the level's join emits
    num_vertices: int          # V
    num_edges: int             # EJ: join-space edge count (2E for 'both')
    frontier_cap: int          # static per-level block capacity
    result_cap: int            # static result buffer capacity
    row_bytes: int             # full interleaved row width (bytes/row)
    col_bytes: Any             # Mapping[str, int]: bytes/row per column
    kernel_factor: float = 1.0  # relative cost of a plugged expand kernel
    visited_rows: float = 0.0  # vertices discovered BEFORE this level (the
    #   pull-side work term: unvisited = V - visited_rows)


class OpCost(NamedTuple):
    """One operator's per-level estimate: output cardinality + bytes moved
    through the memory system (the ranking currency of the cost model)."""

    rows: float
    bytes: float


def _cols_bytes(env: CostEnv, cols) -> float:
    """Bytes/row of a materialized tuple over ``cols`` (unknown synthetic
    columns such as ``__next__`` count as one int32)."""
    return float(sum(env.col_bytes.get(c, 4) for c in cols))


class BFSResult(NamedTuple):
    values: Dict[str, jax.Array]   # (result_cap, ...) materialized outputs
    positions: jax.Array           # (result_cap,) edge positions (or -1s)
    count: jax.Array               # () live rows
    depth: jax.Array               # () levels actually executed
    overflow: jax.Array            # () any capacity overflow observed
    row_depths: Optional[jax.Array] = None   # (result_cap,) BFS level per row
    level_dirs: Optional[jax.Array] = None   # (L,) int8 per-level direction
    #   decision of a DirectionSwitch pipeline (-1 unused, 0 push, 1 pull)
    vertex_values: Optional[jax.Array] = None  # (V,) float32 semiring value
    #   plane of a weighted pipeline (None for the boolean reach workload)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Context:
    """Runtime inputs of a pipeline: storage + the direction-resolved join
    view.  ``join_src`` is the column the CSR indexes; ``join_dst`` holds the
    next vertex reached by each join-space edge.

    ``rcsr`` is the REVERSE CSR of the join view (groups join edges by
    ``join_dst``) — the pull-mode operators and the direction-switch
    predicate read it.  ``bidir=True`` selects the FUSED bidirectional view
    for ``direction='both'``: ``join_src``/``join_dst`` stay the E-sized
    base columns and the 2E join space is VIRTUAL (position ``p < E`` is
    edge ``p`` forward, ``p >= E`` is edge ``p-E`` backward), with
    ``both_indptr`` the merged out+in indptr — no 2E array is ever
    materialized.  ``bidir`` is pytree aux data (static under jit)."""

    table: Optional[ColumnTable]
    rows: Optional[RowTable]
    csr: Optional[CSRIndex]
    join_src: jax.Array
    join_dst: jax.Array
    rcsr: Optional[CSRIndex] = None
    both_indptr: Optional[jax.Array] = None
    bidir: bool = False
    edge_weights: Optional[jax.Array] = None   # (E,) float32 per-edge ⊗
    #   weight in REAL position order (shared by both orientations of the
    #   fused bidirectional view); None for unweighted traffic

    def tree_flatten(self):
        return ((self.table, self.rows, self.csr, self.join_src,
                 self.join_dst, self.rcsr, self.both_indptr,
                 self.edge_weights), self.bidir)

    @classmethod
    def tree_unflatten(cls, bidir, children):
        (table, rows, csr, join_src, join_dst, rcsr, both_indptr,
         edge_weights) = children
        return cls(table=table, rows=rows, csr=csr, join_src=join_src,
                   join_dst=join_dst, rcsr=rcsr, both_indptr=both_indptr,
                   bidir=bidir, edge_weights=edge_weights)


class TraversalState(NamedTuple):
    """The shared operator state.  One frontier representation is active per
    pipeline; the others hold zero-size placeholders so every pipeline runs
    through the identical ``while_loop`` structure."""

    frontier_pos: jax.Array            # (F,) int32 join-space edge positions
    frontier_vals: Dict[str, jax.Array]  # tuple rep: name -> (F, ...)
    frontier_rows: jax.Array           # (F, W) row-store rep
    frontier_count: jax.Array          # () int32 live frontier entries
    targets: jax.Array                 # (F,) int32 target vertices
    keep: jax.Array                    # (F,) bool survivors of dedup
    frontier_bits: jax.Array           # (V,) bool dense frontier
    emitted: jax.Array                 # (EJ,) bool emitted-edge mask
    emit_depth: jax.Array              # (EJ,) int32 level of first emission
    visited: jax.Array                 # (V,) bool BFS visited set
    result_pos: jax.Array              # (R,) int32 real result positions
    result_vals: Dict[str, jax.Array]  # materialized result buffers
    result_depth: jax.Array            # (R,) int32 BFS level per result row
    result_count: jax.Array            # () int32
    depth: jax.Array                   # () int32 levels executed
    overflow: jax.Array                # () bool
    vertex_depth: jax.Array            # (V,) int32 BFS depth per vertex
    #   (-1 = undiscovered; deferred-emission pipelines derive the emitted
    #   mask from it ONCE, after the fixed point)
    visited_count: jax.Array           # () int32 discovered vertices so far
    #   (maintained by the deferred dense steps so the switch predicate
    #   reads the unvisited count without a per-level popcount)
    level_dirs: jax.Array              # (L,) int8 per-level switch decision
    #   (-1 = level not executed, 0 = push, 1 = pull)
    frontier_val: jax.Array            # weighted value plane of the frontier:
    #   (F,) value arriving along each frontier edge (positional rep) or
    #   (V,) per-vertex level values (dense rep); zero-size for 'reach'
    vertex_val: jax.Array              # (V,) float32 ⊕-accumulated value per
    #   vertex (semiring identity = unreached); zero-size for 'reach'


# ---------------------------------------------------------------------------
# shared primitives
# ---------------------------------------------------------------------------

def dedup_targets(targets: jax.Array, valid: jax.Array, visited: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """BFS vertex dedup: drop already-visited targets and, within the level,
    keep only the first occurrence of each vertex (scatter-argmin ticket).

    Returns (keep_mask, new_visited)."""
    cap = targets.shape[0]
    nv = visited.shape[0]
    safe = jnp.clip(targets, 0, nv - 1)
    fresh = valid & ~visited[safe]
    slots = jnp.arange(cap, dtype=jnp.int32)
    ticket = jnp.full((nv,), cap, jnp.int32).at[safe].min(
        jnp.where(fresh, slots, cap), mode="drop")
    keep = fresh & (ticket[safe] == slots)
    # boolean ⊕ (scatter-max): dropped duplicates must not race the
    # winner's True write; weighted pipelines use scatter_combine instead
    new_visited = or_combine(visited, safe, keep)
    return keep, new_visited


def bitmap_level(from_col: jax.Array, to_col: jax.Array,
                 frontier_v: jax.Array, visited: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One dense push step.  Returns (edge_hit_mask, next_frontier, visited).

    edge_hit_mask marks edges whose source is in the frontier (these are the
    rows the CTE emits this level)."""
    nv = frontier_v.shape[0]
    hit = frontier_v[jnp.clip(from_col, 0, nv - 1)]
    tgt = jnp.clip(to_col, 0, nv - 1)
    nxt = or_combine(jnp.zeros((nv,), bool), tgt, hit)
    nxt = nxt & ~visited
    visited = visited | nxt
    return hit, nxt, visited


def append_values(bufs, count, vals, block_count, cap_r):
    """Append a value block into larger result buffers (the tuple/row-store
    UNION ALL).  Returns (new_bufs, new_count, overflowed)."""
    cap_f = next(iter(vals.values())).shape[0]
    slots = count + jnp.arange(cap_f, dtype=jnp.int32)
    live = (jnp.arange(cap_f, dtype=jnp.int32) < block_count) & (slots < cap_r)
    safe = jnp.where(live, slots, cap_r)
    out = {}
    for k, buf in bufs.items():
        v = vals[k]
        mask = live.reshape(live.shape + (1,) * (v.ndim - 1))
        out[k] = buf.at[safe].set(jnp.where(mask, v, 0), mode="drop")
    new_count = jnp.minimum(count + block_count, cap_r)
    return out, new_count, (count + block_count) > cap_r


def _num_real_rows(ctx: Context) -> int:
    if ctx.table is not None:
        return ctx.table.num_rows
    if ctx.rows is not None:
        return ctx.rows.num_rows
    return ctx.join_src.shape[0]


def _num_join(ctx: Context) -> int:
    """Join-space edge count EJ (2E under the fused bidirectional view —
    virtual: no 2E array backs it)."""
    n = ctx.join_src.shape[0]
    return 2 * n if ctx.bidir else n


def _to_real(ctx: Context, pos: jax.Array) -> jax.Array:
    """Fold join-space positions back to real edge positions.  Identity for
    outbound/inbound views; a 'both' view (fused-virtual, or a legacy
    materialized doubled view) maps the backward copy of edge ``p`` to
    ``e + p`` (the join-space sentinel ``2e`` folds to ``e``, the
    real-space sentinel)."""
    e = _num_real_rows(ctx)
    if not ctx.bidir and ctx.join_src.shape[0] == e:
        return pos
    return jnp.where(pos < e, pos, pos - e)


def _join_dst_at(ctx: Context, pos: jax.Array) -> jax.Array:
    """The next-vertex column of the join view, gathered at join-space
    positions (callers mask invalid lanes themselves).  Under the fused
    view the gather resolves forward positions through ``to`` and backward
    positions through ``from`` — two E-array gathers, no 2E column."""
    if not ctx.bidir:
        ej = ctx.join_src.shape[0]
        return ctx.join_dst[jnp.minimum(pos, ej - 1)]
    e = ctx.join_src.shape[0]
    fwd = pos < e
    p = jnp.clip(jnp.where(fwd, pos, pos - e), 0, e - 1)
    return jnp.where(fwd, ctx.join_dst[p], ctx.join_src[p])


def _join_src_at(ctx: Context, pos: jax.Array) -> jax.Array:
    """The source-vertex column of the join view at join-space positions."""
    if not ctx.bidir:
        ej = ctx.join_src.shape[0]
        return ctx.join_src[jnp.minimum(pos, ej - 1)]
    e = ctx.join_src.shape[0]
    fwd = pos < e
    p = jnp.clip(jnp.where(fwd, pos, pos - e), 0, e - 1)
    return jnp.where(fwd, ctx.join_src[p], ctx.join_dst[p])


def _seed_mask(ctx: Context, root: jax.Array) -> jax.Array:
    """(EJ,) mask of join edges whose source is the root (the seed
    filter).  Fused view: forward matches on ``from``, backward on ``to``,
    concatenated in join-space order."""
    if not ctx.bidir:
        return ctx.join_src == root
    return jnp.concatenate([ctx.join_src == root, ctx.join_dst == root])


def _hit_mask(ctx: Context, frontier_v: jax.Array) -> jax.Array:
    """(EJ,) mask of join edges whose SOURCE vertex is in ``frontier_v`` —
    the rows one CTE level emits (push-side emission test)."""
    nv = frontier_v.shape[0]
    if not ctx.bidir:
        return frontier_v[jnp.clip(ctx.join_src, 0, nv - 1)]
    return jnp.concatenate([
        frontier_v[jnp.clip(ctx.join_src, 0, nv - 1)],
        frontier_v[jnp.clip(ctx.join_dst, 0, nv - 1)]])


def _edge_weight_at(ctx: Context, pos: jax.Array) -> jax.Array:
    """Per-edge ⊗ weight gathered at JOIN-SPACE positions (callers mask
    invalid lanes themselves).  Weights live in real position order, so the
    fused bidirectional view folds the backward copy onto the same weight;
    a weightless context traverses with all-ones (reach-compatible)."""
    if ctx.edge_weights is None:
        return jnp.ones(pos.shape, jnp.float32)
    e = _num_real_rows(ctx)
    real = _to_real(ctx, pos)
    return ctx.edge_weights[jnp.clip(real, 0, e - 1)]


def _expand_join(ctx: Context, targets: jax.Array, keep: jax.Array,
                 capacity: int, expand_fn=None):
    """CSR expansion over the join view: the plain/Pallas kernel over the
    direction CSR, or the fused bidirectional expansion (out-slice then
    in-slice, join-space positions) when ``bidir``."""
    if ctx.bidir:
        return expand_frontier_both(ctx.csr, ctx.rcsr, ctx.both_indptr,
                                    targets, keep, capacity)
    expand = expand_fn or expand_frontier
    return expand(ctx.csr, targets, keep, capacity)


def _dense_push(ctx: Context, frontier_v: jax.Array, visited: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One dense PUSH step over the join view.  Returns
    (edge_hit_mask (EJ,), next_frontier, visited)."""
    if not ctx.bidir:
        return bitmap_level(ctx.join_src, ctx.join_dst, frontier_v, visited)
    nv = frontier_v.shape[0]
    src = jnp.clip(ctx.join_src, 0, nv - 1)
    dst = jnp.clip(ctx.join_dst, 0, nv - 1)
    hit_f = frontier_v[src]
    hit_b = frontier_v[dst]
    nxt = or_combine(or_combine(jnp.zeros((nv,), bool), dst, hit_f),
                     src, hit_b)
    nxt = nxt & ~visited
    visited = visited | nxt
    return jnp.concatenate([hit_f, hit_b]), nxt, visited


def _dense_pull(ctx: Context, frontier_v: jax.Array, visited: jax.Array,
                pull_fn=None) -> jax.Array:
    """One dense PULL (Beamer bottom-up) step: the next frontier is every
    UNVISITED vertex with an in-neighbor (over the join view) in the
    frontier bitmap.  The default walks the reverse CSR — the candidate
    mask gates the membership gather per reverse-adjacency entry;
    ``pull_fn`` plugs the Pallas ``frontier_pull`` kernel in its place."""
    nv = frontier_v.shape[0]
    cand = ~visited
    if ctx.bidir:
        # fused view: both orientations contribute, natural edge order
        src = jnp.clip(ctx.join_src, 0, nv - 1)
        dst = jnp.clip(ctx.join_dst, 0, nv - 1)
        nxt = or_combine(
            or_combine(jnp.zeros((nv,), bool), dst,
                       cand[dst] & frontier_v[src]),
            src, cand[src] & frontier_v[dst])
        return nxt & cand
    if pull_fn is not None:
        if ctx.rcsr is None:
            raise ValueError(
                "the frontier_pull kernel walks the reverse CSR; call "
                "Dataset.ensure_reverse() (inbound/both views build it "
                "automatically) before plugging PullStep(expand_fn=)")
        nxt = pull_fn(ctx.rcsr, ctx.join_src, ctx.join_dst, frontier_v,
                      visited)
        return nxt & cand
    if ctx.rcsr is not None:
        perm = ctx.rcsr.perm                   # join edges grouped by dst
        nbr = jnp.clip(ctx.join_src[perm], 0, nv - 1)   # in-neighbor
        vtx = jnp.clip(ctx.join_dst[perm], 0, nv - 1)   # owning vertex
        contrib = cand[vtx] & frontier_v[nbr]
        nxt = or_combine(jnp.zeros((nv,), bool), vtx, contrib)
        return nxt & cand
    # no reverse CSR built (outbound-only dataset): the same bottom-up
    # test evaluated in natural edge order — identical result, and plain
    # outbound traffic never pays the reverse-CSR build
    src = jnp.clip(ctx.join_src, 0, nv - 1)
    dst = jnp.clip(ctx.join_dst, 0, nv - 1)
    contrib = cand[dst] & frontier_v[src]
    nxt = or_combine(jnp.zeros((nv,), bool), dst, contrib)
    return nxt & cand


def _tag_depths(result_depth: jax.Array, count: jax.Array, block_cap: int,
                block_count: jax.Array, tag: jax.Array) -> jax.Array:
    """Record the BFS level of every row the current append makes live."""
    cap_r = result_depth.shape[0]
    slots = count + jnp.arange(block_cap, dtype=jnp.int32)
    live = (jnp.arange(block_cap, dtype=jnp.int32) < block_count) & \
           (slots < cap_r)
    return result_depth.at[jnp.where(live, slots, cap_r)].set(
        jnp.broadcast_to(tag, (block_cap,)), mode="drop")


# ---------------------------------------------------------------------------
# operators
# ---------------------------------------------------------------------------

class Operator:
    """Base operator: ``init`` runs once before the fixed point (seed-block
    handling), ``step`` once per level inside the ``while_loop``."""

    def init(self, ctx: Context, state: TraversalState, root: jax.Array
             ) -> TraversalState:
        return state

    def step(self, ctx: Context, state: TraversalState) -> TraversalState:
        return state

    def describe(self) -> str:
        return type(self).__name__

    def estimate(self, env: CostEnv) -> OpCost:
        """Per-level cost annotation: rows flowing out of this operator and
        bytes it drags through the memory system (overridden per class)."""
        return OpCost(env.frontier_rows, 0.0)


@dataclasses.dataclass(frozen=True)
class Seed(Operator):
    """The non-recursive child of the CTE.

    kind='edges'    — Filter[join_src = root] compacted to a position block;
    kind='vertices' — the frontier starts as the root vertex itself
                      (distributed engine: targets are exchanged, not edges);
    kind='dense'    — the root bit in a dense vertex bitmap.
    scan='rows' emulates the PostgreSQL SeqScan (strided read over the
    interleaved row table).  mark_emitted seeds the emitted-edge mask used by
    bitmap-style pipelines.  ``semiring != 'reach'`` additionally seeds the
    value plane: the root's vertex value is the semiring's seed value and
    (edge kind) each seed edge carries seed ⊗ weight."""

    kind: str = "edges"
    scan: str = "columnar"
    label: str = "from"
    mark_emitted: bool = False
    semiring: str = "reach"

    def _init_weighted(self, ctx, state, root):
        sr = get_semiring(self.semiring)
        nv = state.visited.shape[0]
        r = jnp.clip(root, 0, nv - 1)
        visited = state.visited.at[r].set(True)
        vertex_val = state.vertex_val.at[r].set(sr.seed_value)
        if self.kind == "dense":
            bits = jnp.zeros((nv,), bool).at[r].set(True)
            fval = jnp.full((nv,), sr.identity, jnp.float32).at[r].set(
                sr.seed_value)
            return state._replace(frontier_bits=bits, visited=visited,
                                  vertex_val=vertex_val, frontier_val=fval,
                                  frontier_count=jnp.ones((), jnp.int32))
        ej = _num_join(ctx)
        cap = state.frontier_pos.shape[0]
        blk = compact_mask(_seed_mask(ctx, root), cap, ej)
        w = _edge_weight_at(ctx, blk.positions)
        fval = jnp.where(
            blk.valid_mask(),
            sr_propagate(sr, jnp.float32(sr.seed_value), w), sr.identity)
        return state._replace(frontier_pos=blk.positions,
                              frontier_count=blk.count, visited=visited,
                              vertex_val=vertex_val, frontier_val=fval)

    def init(self, ctx, state, root):
        if self.semiring != "reach":
            return self._init_weighted(ctx, state, root)
        if state.vertex_depth.shape[0]:
            # deferred-emission pipeline: the per-vertex depth array IS
            # the visited set and the frontier (no separate bitmaps)
            nvd = state.vertex_depth.shape[0]
            return state._replace(
                vertex_depth=state.vertex_depth.at[
                    jnp.clip(root, 0, nvd - 1)].set(0),
                visited_count=jnp.ones((), jnp.int32),
                frontier_count=jnp.ones((), jnp.int32))
        nv = state.visited.shape[0]
        visited = state.visited.at[jnp.clip(root, 0, nv - 1)].set(True)
        if self.kind == "dense":
            bits = jnp.zeros((nv,), bool).at[jnp.clip(root, 0, nv - 1)
                                             ].set(True)
            return state._replace(frontier_bits=bits, visited=visited,
                                  frontier_count=jnp.ones((), jnp.int32))
        if self.kind == "vertices":
            cap = state.targets.shape[0]
            targets = jnp.full((cap,), -1, jnp.int32).at[0].set(root)
            keep = jnp.zeros((cap,), bool).at[0].set(True)
            return state._replace(targets=targets, keep=keep, visited=visited,
                                  frontier_count=jnp.ones((), jnp.int32))
        ej = _num_join(ctx)
        mask = (ctx.rows.column(self.label).astype(jnp.int32) == root
                if self.scan == "rows" else _seed_mask(ctx, root))
        cap = state.frontier_pos.shape[0]
        blk = compact_mask(mask, cap, ej)
        state = state._replace(frontier_pos=blk.positions,
                               frontier_count=blk.count, visited=visited)
        if self.mark_emitted:
            valid = blk.valid_mask()
            idx = jnp.where(valid, blk.positions, ej)
            emitted = state.emitted.at[idx].set(valid, mode="drop")
            emit_depth = state.emit_depth.at[idx].set(
                jnp.zeros((cap,), jnp.int32), mode="drop")
            state = state._replace(emitted=emitted, emit_depth=emit_depth)
        return state

    def describe(self):
        if self.scan == "rows":
            return f"SeqScan[{self.label} = $root] -> full rows"
        if self.kind == "vertices":
            return "SeedVertices[$root]"
        if self.kind == "dense":
            return "SeedBitmap[$root]"
        return f"Filter[{self.label} = $root] -> PosBlock"

    def estimate(self, env):
        if self.kind == "dense":             # set one bit in a (V,) bitmap
            return OpCost(env.frontier_rows, float(env.num_vertices))
        if self.kind == "vertices":
            return OpCost(env.frontier_rows, 4.0)
        if self.scan == "rows":              # strided scan drags full rows
            return OpCost(env.frontier_rows,
                          float(env.num_edges) * env.row_bytes)
        # columnar filter scan + compaction into the position block
        return OpCost(env.frontier_rows,
                      float(env.num_edges) * 4 + env.frontier_cap * 4.0)


@dataclasses.dataclass(frozen=True)
class ReadTargets(Operator):
    """Per-level read of the join column out of the frontier.  For the
    positional rep this is the ONLY per-level value gather (one column);
    tuple/row reps already paid for it at materialization time."""

    source: str = "pos"     # 'pos' | 'vals' | 'rows'
    col: str = "to"

    def step(self, ctx, state):
        cap = state.targets.shape[0]
        valid = jnp.arange(cap, dtype=jnp.int32) < state.frontier_count
        if self.source == "pos":
            t = _join_dst_at(ctx, state.frontier_pos)
        elif self.source == "vals":
            t = state.frontier_vals[self.col].astype(jnp.int32)
        else:
            t = state.frontier_rows[:, ctx.rows.slot(self.col)
                                    ].astype(jnp.int32)
        return state._replace(targets=jnp.where(valid, t, -1), keep=valid)

    def describe(self):
        what = {"pos": "positions", "vals": "tuple block",
                "rows": "row block"}[self.source]
        return f"ReadCol[{self.col}]({what})"

    def estimate(self, env):
        cap = float(env.frontier_cap)
        if self.source == "pos":     # positions + ONE column gather
            return OpCost(env.frontier_rows, cap * 8.0)
        if self.source == "vals":    # the column is already materialized
            return OpCost(env.frontier_rows, cap * 4.0)
        # strided read over the padded row block
        return OpCost(env.frontier_rows, cap * env.row_bytes)


@dataclasses.dataclass(frozen=True)
class VisitedDedup(Operator):
    """BFS semantics: a vertex expands at most once (visited bitmap +
    within-level scatter-argmin).  Omitted for raw UNION ALL walks."""

    def step(self, ctx, state):
        keep, visited = dedup_targets(state.targets, state.keep,
                                      state.visited)
        return state._replace(targets=jnp.where(keep, state.targets, -1),
                              keep=keep, visited=visited)

    def describe(self):
        return "VisitedDedup[bitmap]"

    def estimate(self, env):
        # scatter-argmin ticket over the padded block + the (V,) ticket /
        # visited arrays rebuilt-or-updated every level
        return OpCost(env.unique_rows,
                      env.frontier_cap * 12.0 + env.num_vertices * 5.0)


@dataclasses.dataclass(frozen=True)
class CSRIndexJoin(Operator):
    """Fig. 4's IndexJoin: expand frontier vertices into the positions of
    their out-edges through the CSR join index — positions in, positions
    out, no values touched.  ``expand_fn`` plugs in the Pallas kernel."""

    expand_fn: Optional[Callable] = None

    def step(self, ctx, state):
        cap = state.frontier_pos.shape[0]
        epos, total, ovf = _expand_join(ctx, state.targets, state.keep, cap,
                                        self.expand_fn)
        return state._replace(frontier_pos=epos, frontier_count=total,
                              overflow=state.overflow | ovf)

    def describe(self):
        return "IndexJoin[CSR(join_src)](CTE, edges)"

    def estimate(self, env):
        # two-phase expansion over the padded block: degrees + cumsum +
        # searchsorted inversion + perm gather, all at capacity
        b = env.frontier_cap * 16.0 + env.unique_rows * 8.0
        if self.expand_fn is not None:
            b *= env.kernel_factor
        return OpCost(env.emitted_rows, b)


@dataclasses.dataclass(frozen=True)
class ScanHashJoin(Operator):
    """Fig. 3's HashJoin as PostgreSQL executes it without an index: build a
    hash of the frontier's vertex set, then SeqScan the WHOLE table probing
    it.  On the row table the scan touches every byte of every row."""

    def step(self, ctx, state):
        nv = state.visited.shape[0]
        e = ctx.rows.num_rows
        cap = state.frontier_pos.shape[0]
        probe = or_combine(jnp.zeros((nv,), bool),
                           jnp.clip(state.targets, 0, nv - 1), state.keep)
        scan_from = ctx.rows.column("from").astype(jnp.int32)  # full scan
        hit = probe[jnp.clip(scan_from, 0, nv - 1)] & (scan_from >= 0)
        blk = compact_mask(hit, cap, e)
        ovf = jnp.sum(hit, dtype=jnp.int32) > cap
        return state._replace(frontier_pos=blk.positions,
                              frontier_count=blk.count,
                              overflow=state.overflow | ovf)

    def describe(self):
        return "HashJoin[from = cte.to](Hash(cte), SeqScan(edges))"

    def estimate(self, env):
        # frontier hash build + a FULL heap scan probing it every level
        return OpCost(env.emitted_rows,
                      env.num_vertices * 1.0 + env.frontier_cap * 4.0
                      + float(env.num_edges) * (env.row_bytes + 1.0))


@dataclasses.dataclass(frozen=True)
class WeightedExpand(Operator):
    """The positional weighted level: one fused ⊗-propagate / ⊕-combine /
    winner-select / IndexJoin step.

    Each frontier entry is a join-space edge position carrying the value
    that arrives along it (``frontier_val``).  The step ⊕-combines the
    arrivals per target vertex into the level plane ``lvl``, folds ``lvl``
    into the per-vertex accumulator, picks ONE expansion slot per active
    vertex with the same scatter-argmin ticket :func:`dedup_targets` uses
    (⊗ distributes over ⊕, so expanding the COMBINED per-vertex value once
    equals expanding every path separately — the UNION-ALL fold), and
    expands the winners through the CSR join index.

    Improving semirings (``shortest_path``) re-expand only vertices whose
    value STRICTLY improved — label-correcting Bellman-Ford whose fixed
    point (empty improved set) is exactly the driver's existing
    ``frontier_count > 0`` convergence test, i.e. value stabilization.
    Walk semirings (the aggregates) re-expand every vertex that received a
    value this level and rely on the pipeline depth bound."""

    semiring: str

    def step(self, ctx, state):
        sr = get_semiring(self.semiring)
        cap = state.frontier_pos.shape[0]
        nv = state.vertex_val.shape[0]
        slots = jnp.arange(cap, dtype=jnp.int32)
        valid = slots < state.frontier_count
        t = _join_dst_at(ctx, state.frontier_pos)
        safe = jnp.clip(t, 0, nv - 1)
        idx = jnp.where(valid, safe, nv)
        prop = state.frontier_val            # ⊗ was applied at expansion
        lvl = scatter_combine(sr, jnp.full((nv,), sr.identity, jnp.float32),
                              idx, prop)
        received = or_combine(jnp.zeros((nv,), bool), idx, valid)
        new_vv = jnp.where(received, elem_combine(sr, state.vertex_val, lvl),
                           state.vertex_val)
        if sr.improving:                     # frontier = strictly improved
            eligible = valid & (lvl < state.vertex_val)[safe]
        else:                                # frontier = all receivers
            eligible = valid
        eidx = jnp.where(eligible, safe, nv)
        ticket = jnp.full((nv,), cap, jnp.int32).at[eidx].min(
            jnp.where(eligible, slots, cap), mode="drop")
        winner = eligible & (ticket[safe] == slots)
        targets = jnp.where(winner, t, -1)
        epos, total, ovf = _expand_join(ctx, targets, winner, cap)
        evalid = jnp.arange(cap, dtype=jnp.int32) < total
        sval = lvl[jnp.clip(_join_src_at(ctx, epos), 0, nv - 1)]
        w = _edge_weight_at(ctx, epos)
        fval = jnp.where(evalid, sr_propagate(sr, sval, w), sr.identity)
        return state._replace(frontier_pos=epos, frontier_count=total,
                              frontier_val=fval, vertex_val=new_vv,
                              targets=targets, keep=winner,
                              overflow=state.overflow | ovf)

    def describe(self):
        return (f"WeightedExpand[{self.semiring}: combine(+)=per-vertex, "
                "winner -> IndexJoin[CSR(join_src)]]")

    def estimate(self, env):
        # the boolean ReadCol+Dedup+IndexJoin work at capacity, plus the
        # value plane: frontier values r/w (8B/slot) and the (V,) level +
        # accumulator planes (two f32 r/w passes)
        b = (env.frontier_cap * 36.0 + env.num_vertices * 5.0
             + env.frontier_cap * 8.0 + env.num_vertices * 16.0)
        return OpCost(env.emitted_rows, b)


@dataclasses.dataclass(frozen=True)
class WeightedDenseStep(Operator):
    """The dense weighted level: ⊗ over the full edge list then one
    ⊕-scatter into the (V,) level plane — the weighted generalization of
    :class:`DenseBitmapStep`'s boolean SpMV.

    For the (sum, ×) semiring the ⊕-scatter IS the fused
    gather-scale-segment-sum the idle ``kernels/spmm_segment`` implements,
    so ``use_kernel=True`` routes the combine through it (inactive edges
    are disabled with the kernel's own ``src >= N`` padding contract);
    every other ⊕ uses the jnp scatter.  Single-direction views only: the
    planner never offers the dense engine for ``direction='both'`` under a
    weighted workload."""

    semiring: str
    use_kernel: bool = False
    interpret: bool = True       # Pallas interpret mode (CPU-safe default)

    def step(self, ctx, state):
        sr = get_semiring(self.semiring)
        nv = state.vertex_val.shape[0]
        src = jnp.clip(ctx.join_src, 0, nv - 1)
        dst = jnp.clip(ctx.join_dst, 0, nv - 1)
        hit = state.frontier_bits[src]
        w = _edge_weight_at(ctx, jnp.arange(ctx.join_src.shape[0],
                                            dtype=jnp.int32))
        if self.use_kernel and sr.combine == "add" and sr.propagate == "mul":
            from ..kernels.spmm_segment import spmm_segment
            lvl = spmm_segment(state.frontier_val[:, None],
                               jnp.where(hit, src, nv), dst, w, nv,
                               use_pallas=True, interpret=self.interpret
                               )[:, 0]
        else:
            prop = sr_propagate(sr, state.frontier_val[src], w)
            lvl = scatter_combine(
                sr, jnp.full((nv,), sr.identity, jnp.float32),
                jnp.where(hit, dst, nv), prop)
        received = or_combine(jnp.zeros((nv,), bool),
                              jnp.where(hit, dst, nv), hit)
        new_vv = jnp.where(received, elem_combine(sr, state.vertex_val, lvl),
                           state.vertex_val)
        if sr.improving:
            nxt = received & (lvl < state.vertex_val)
        else:
            nxt = received
        fval = jnp.where(nxt, lvl, sr.identity)
        new = hit & ~state.emitted
        emit_depth = jnp.where(new, state.depth, state.emit_depth)
        return state._replace(frontier_bits=nxt, frontier_val=fval,
                              vertex_val=new_vv,
                              visited=state.visited | nxt,
                              emitted=state.emitted | hit,
                              emit_depth=emit_depth,
                              frontier_count=jnp.sum(nxt, dtype=jnp.int32))

    def describe(self):
        how = "spmm_segment kernel" if self.use_kernel else "(+)-scatter"
        return f"BitmapStep[weighted {self.semiring}: {how}]"

    def estimate(self, env):
        # the boolean dense step's O(E) traffic, plus the value plane: one
        # f32 propagate per edge and the (V,) level + accumulator planes
        b = (float(env.num_edges) * (10.0 + 8.0)
             + float(env.num_vertices) * (3.0 + 16.0))
        if self.use_kernel:
            b *= env.kernel_factor
        return OpCost(env.emitted_rows, b)


def _record_deferred(state: TraversalState, new: jax.Array
                     ) -> TraversalState:
    """Deferred-emission bookkeeping: the loop carries ONLY the per-vertex
    depth array (frontier = ``vd == depth``, visited = ``vd >= 0`` — no
    separate bitmaps) plus the scalar visited count the switch predicate
    reads.  Newly discovered vertices emit at ``state.depth + 1``; the
    emitted mask is derived once, after the fixed point."""
    count = jnp.sum(new, dtype=jnp.int32)
    vd = jnp.where(new, state.depth + 1, state.vertex_depth)
    return state._replace(vertex_depth=vd, frontier_count=count,
                          visited_count=state.visited_count + count)


@dataclasses.dataclass(frozen=True)
class DenseBitmapStep(Operator):
    """Beyond-paper dense level: the frontier is a vertex bitmap and one
    level is a masked scatter over the full edge list (boolean-semiring
    SpMV) — O(E) work but zero data-dependent shapes.

    ``deferred=True`` (the direction-optimizing pipelines) skips the
    per-level emitted-mask/emit-depth upkeep — two O(E) writes per level —
    and records per-vertex depths instead; :class:`DeferredEmit` rebuilds
    the identical emitted set in ONE O(E) pass after the fixed point."""

    deferred: bool = False

    def deferred_new(self, ctx, state):
        """Narrow deferred protocol: the newly-discovered-vertex mask from
        the per-vertex depth array alone (DirectionSwitch conds over THIS,
        not the whole state, so the branch exchanges one (V,) mask)."""
        vd = state.vertex_depth
        nv = vd.shape[0]
        src = jnp.clip(ctx.join_src, 0, nv - 1)
        dst = jnp.clip(ctx.join_dst, 0, nv - 1)
        # frontier membership fused into the edge gather (vd[src] == depth)
        # — no (V,) frontier mask is ever materialized
        if ctx.bidir:
            tgt = or_combine(
                or_combine(jnp.zeros((nv,), bool), dst,
                           vd[src] == state.depth),
                src, vd[dst] == state.depth)
        else:
            tgt = or_combine(jnp.zeros((nv,), bool), dst,
                             vd[src] == state.depth)
        return tgt & (vd < 0)

    def step(self, ctx, state):
        if self.deferred:
            return _record_deferred(state, self.deferred_new(ctx, state))
        hit, nxt, visited = _dense_push(ctx, state.frontier_bits,
                                        state.visited)
        new = hit & ~state.emitted
        emit_depth = jnp.where(new, state.depth, state.emit_depth)
        return state._replace(frontier_bits=nxt, visited=visited,
                              emitted=state.emitted | hit,
                              emit_depth=emit_depth,
                              frontier_count=jnp.sum(nxt, dtype=jnp.int32))

    def describe(self):
        tag = ", deferred emit" if self.deferred else ""
        return f"BitmapStep[push: frontier bits -> edge mask{tag}]"

    def estimate(self, env):
        # O(E) masked scatter + bitmap updates, independent of frontier
        # size; the deferred variant drops the two per-level O(E) emitted
        # writes (paid once in the finisher instead)
        e_ops = 6.0 if self.deferred else 10.0
        v_ops = 4.0 if self.deferred else 3.0
        return OpCost(env.emitted_rows,
                      float(env.num_edges) * e_ops
                      + float(env.num_vertices) * v_ops)


@dataclasses.dataclass(frozen=True)
class PullStep(Operator):
    """Beamer-style bottom-up level: gather over the REVERSE CSR from
    unvisited vertices, testing membership of their in-neighbors in the
    frontier bitmap — the pull dual of :class:`DenseBitmapStep`'s push.
    ``expand_fn`` plugs the Pallas ``frontier_pull`` kernel
    (:func:`repro.kernels.frontier_pull.make_pull_fn`).

    In deferred mode (the diropt pipelines) a pull level touches no
    emitted-edge state at all; in emitted mode the push-side hit mask is
    still computed (emission is defined by the SQL join, not by how the
    next frontier was found), so pull only pays off with deferral."""

    deferred: bool = False
    expand_fn: Optional[Callable] = None

    def deferred_new(self, ctx, state):
        """Narrow deferred protocol (see DenseBitmapStep.deferred_new)."""
        vd = state.vertex_depth
        frontier = vd == state.depth
        return _dense_pull(ctx, frontier, vd >= 0, self.expand_fn)

    def step(self, ctx, state):
        if self.deferred:
            return _record_deferred(state, self.deferred_new(ctx, state))
        nxt = _dense_pull(ctx, state.frontier_bits, state.visited,
                          self.expand_fn)
        visited = state.visited | nxt
        hit = _hit_mask(ctx, state.frontier_bits)
        new = hit & ~state.emitted
        emit_depth = jnp.where(new, state.depth, state.emit_depth)
        return state._replace(frontier_bits=nxt, visited=visited,
                              emitted=state.emitted | hit,
                              emit_depth=emit_depth,
                              frontier_count=jnp.sum(nxt, dtype=jnp.int32))

    def describe(self):
        how = "kernel" if self.expand_fn is not None else "reverse CSR"
        return f"PullStep[bottom-up: unvisited <- frontier bits ({how})]"

    def estimate(self, env):
        # the pull side reads the reverse adjacency of the UNVISITED set:
        # work shrinks as the traversal saturates the graph — exactly the
        # deep/wide regime where push degenerates
        unvis = max(float(env.num_vertices) - env.visited_rows, 0.0)
        frac = unvis / max(float(env.num_vertices), 1.0)
        b = frac * float(env.num_edges) * 8.0 + float(env.num_vertices) * 4.0
        if not self.deferred:
            b += float(env.num_edges) * 4.0       # emitted upkeep anyway
        if self.expand_fn is not None:
            b *= env.kernel_factor
        return OpCost(env.emitted_rows, b)


@dataclasses.dataclass(frozen=True)
class DirectionSwitch(Operator):
    """The direction-optimizing combinator: per level, a ``lax.cond`` picks
    the push or the pull operator by comparing the estimated work terms —
    frontier occupancy x avg out-degree (the push side's emitted edges)
    vs unvisited count x avg in-degree (the pull side's reverse-adjacency
    reads):

        pull  iff  alpha * n_f * avg_out > (V - visited) * avg_in
              and  beta * n_f >= V

    (Beamer's two thresholds; the second keeps shrunk tail frontiers on
    the push side.)  The average degrees are trace-time constants off the
    join view's shapes, so the whole predicate costs one popcount of the
    visited bitmap per level.  ``alpha``/``beta`` are owned by
    :class:`repro.planner.cost.CostConstants` (``pull_alpha`` /
    ``pull_beta``) so the calibrator can refit them; the planner stamps its
    constants' values onto the pipeline it prices.  The decision taken at
    every level is recorded in ``TraversalState.level_dirs`` and surfaces
    in ``BFSResult.level_dirs`` / the plan-store schema."""

    push: Operator
    pull: Operator
    alpha: float = 1.0
    beta: float = 64.0

    def _predicate(self, ctx, state):
        nv = state.vertex_depth.shape[0] or state.visited.shape[0]
        ej = float(_num_join(ctx))
        avg = ej / max(float(nv), 1.0)     # avg out == avg in over the view
        n_f = state.frontier_count
        if state.frontier_bits.shape[0] or state.vertex_depth.shape[0]:
            # dense/deferred frontier: the count is VERTICES — scale by
            # the average out-degree to get the push-side edge work
            m_f = n_f.astype(jnp.float32) * avg
        else:                              # positional frontier: the edge
            m_f = n_f.astype(jnp.float32)  # block IS m_f
        if state.vertex_depth.shape[0]:    # deferred steps keep the scalar
            unvisited = nv - state.visited_count
        else:
            unvisited = nv - jnp.sum(state.visited, dtype=jnp.int32)
        m_u = unvisited.astype(jnp.float32) * avg
        use_pull = self.alpha * m_f > m_u
        use_pull &= self.beta * n_f.astype(jnp.float32) >= float(nv)
        return use_pull

    def step(self, ctx, state):
        use_pull = self._predicate(ctx, state)
        if state.level_dirs.shape[0]:
            idx = jnp.minimum(state.depth, state.level_dirs.shape[0] - 1)
            state = state._replace(level_dirs=state.level_dirs.at[idx].set(
                use_pull.astype(jnp.int8)))
        narrow = (state.vertex_depth.shape[0]
                  and hasattr(self.push, "deferred_new")
                  and hasattr(self.pull, "deferred_new"))
        if narrow:
            # deferred dense steps: the cond exchanges ONE (V,) mask
            # instead of threading the whole traversal state through the
            # branch boundary
            new = jax.lax.cond(
                use_pull,
                lambda: self.pull.deferred_new(ctx, state),
                lambda: self.push.deferred_new(ctx, state))
            return _record_deferred(state, new)
        return jax.lax.cond(use_pull,
                            lambda s: self.pull.step(ctx, s),
                            lambda s: self.push.step(ctx, s), state)

    def describe(self):
        return (f"DirectionSwitch[a={self.alpha:g} b={self.beta:g}: "
                f"{self.push.describe()} | {self.pull.describe()}]")

    def predict(self, env: CostEnv) -> str:
        """The cost model's per-level decision (mirrors the runtime
        predicate on the sampled cardinalities): 'push' or 'pull'."""
        avg = float(env.num_edges) / max(float(env.num_vertices), 1.0)
        unvis = max(float(env.num_vertices) - env.visited_rows, 0.0)
        m_f = env.emitted_rows                 # edges out of the frontier
        m_u = unvis * avg
        n_f = env.frontier_rows
        if self.alpha * m_f > m_u and self.beta * n_f >= env.num_vertices:
            return "pull"
        return "push"

    def estimate(self, env):
        chosen = (self.pull if self.predict(env) == "pull"
                  else self.push).estimate(env)
        # the predicate itself: two degree reductions over (V,)
        return OpCost(chosen.rows,
                      chosen.bytes + float(env.num_vertices) * 2.0)


def _install_edge_frontier(ctx: Context, state: TraversalState,
                           nxt: PosBlock, visited: jax.Array,
                           ovf: jax.Array) -> TraversalState:
    """Shared positional-frontier bookkeeping (HybridStep and its pull
    twin): install the next edge block and mark its positions emitted at
    ``depth + 1``."""
    ej = _num_join(ctx)
    cap = state.frontier_pos.shape[0]
    valid = nxt.valid_mask()
    idx = jnp.where(valid, nxt.positions, ej)
    new = valid & ~state.emitted[jnp.minimum(nxt.positions, ej - 1)]
    emitted = state.emitted.at[idx].set(valid, mode="drop")
    emit_depth = state.emit_depth.at[jnp.where(new, nxt.positions, ej)].set(
        jnp.broadcast_to(state.depth + 1, (cap,)), mode="drop")
    return state._replace(frontier_pos=nxt.positions,
                          frontier_count=nxt.count, visited=visited,
                          emitted=emitted, emit_depth=emit_depth,
                          overflow=state.overflow | ovf)


@dataclasses.dataclass(frozen=True)
class HybridStep(Operator):
    """Direction-optimizing level: positional IndexJoin while the frontier
    is small, dense push once it covers > switch_frac of the vertices."""

    switch_frac: float = 0.05

    def step(self, ctx, state):
        ej = _num_join(ctx)
        nv = state.visited.shape[0]
        cap = state.frontier_pos.shape[0]
        threshold = max(1, int(nv * self.switch_frac))

        def sparse_step(frontier, visited):
            fvalid = frontier.valid_mask()
            targets = jnp.where(fvalid,
                                _join_dst_at(ctx, frontier.positions), -1)
            keep, visited = dedup_targets(targets, fvalid, visited)
            targets = jnp.where(keep, targets, -1)
            epos, total, ovf = _expand_join(ctx, targets, keep, cap)
            return PosBlock(epos, total), visited, ovf

        def dense_step(frontier, visited):
            fvalid = frontier.valid_mask()
            targets = _join_dst_at(ctx, frontier.positions)
            # boolean ⊕ (scatter-max): padded slots (clipped onto a real
            # vertex) must never UNSET a vertex another slot reached
            tgt_v = or_combine(jnp.zeros((nv,), bool),
                               jnp.clip(targets, 0, nv - 1), fvalid)
            tgt_v = tgt_v & ~visited
            visited = visited | tgt_v
            hit = _hit_mask(ctx, tgt_v)
            nxt = compact_mask(hit, cap, ej)
            ovf = jnp.sum(hit, dtype=jnp.int32) > cap
            return nxt, visited, ovf

        frontier = PosBlock(state.frontier_pos, state.frontier_count)
        nxt, visited, ovf = jax.lax.cond(
            state.frontier_count < threshold, sparse_step, dense_step,
            frontier, state.visited)
        return _install_edge_frontier(ctx, state, nxt, visited, ovf)

    def describe(self):
        return (f"DirectionOpt[<{self.switch_frac:g}V: IndexJoin[CSR] | "
                f"else BitmapStep]")

    def estimate(self, env):
        # the sparse branch is the positional loop body at capacity; the
        # dense branch is one bitmap push; emitted-mask upkeep either way
        sparse = env.frontier_cap * 36.0 + env.num_vertices * 5.0
        dense = float(env.num_edges) * 10.0 + float(env.num_vertices) * 3.0
        threshold = max(1.0, env.num_vertices * self.switch_frac)
        chosen = sparse if env.frontier_rows < threshold else dense
        return OpCost(env.emitted_rows, chosen + env.frontier_cap * 5.0)


@dataclasses.dataclass(frozen=True)
class HybridPullStep(Operator):
    """The pull twin of :class:`HybridStep`'s dense branch, for positional
    (edge-block) frontiers: rebuild the previous level's VERTEX set from
    the frontier edges' join sources, bottom-up test the unvisited set
    against it, then emit and compact exactly like the push branch — so a
    :class:`DirectionSwitch` over (HybridStep, HybridPullStep) is
    level-for-level state-identical to plain HybridStep."""

    def step(self, ctx, state):
        ej = _num_join(ctx)
        nv = state.visited.shape[0]
        cap = state.frontier_pos.shape[0]
        fvalid = (jnp.arange(cap, dtype=jnp.int32) < state.frontier_count)
        srcs = _join_src_at(ctx, state.frontier_pos)
        prev_v = or_combine(jnp.zeros((nv,), bool),
                            jnp.clip(srcs, 0, nv - 1), fvalid)
        tgt_v = _dense_pull(ctx, prev_v, state.visited)
        visited = state.visited | tgt_v
        hit = _hit_mask(ctx, tgt_v)
        nxt = compact_mask(hit, cap, ej)
        ovf = jnp.sum(hit, dtype=jnp.int32) > cap
        return _install_edge_frontier(ctx, state, nxt, visited, ovf)

    def describe(self):
        return "PullStep[bottom-up over reverse CSR -> edge block]"

    def estimate(self, env):
        # Only the bottom-up gather shrinks with the unvisited fraction.
        # Everything else is paid IN FULL every pull level: the positional
        # frontier keeps no vertex set between levels, so this step rebuilds
        # the previous-vertex set from scratch (a (V,) plane + a
        # frontier_cap scatter — the same per-row scatter factor as the
        # sparse positional branch), then runs the full-edge hit mask and
        # compaction exactly like the dense push.  The old estimate omitted
        # the rebuild and half the hit/compact work, pricing pull levels
        # ~2.5x under the push branch they replace — which kept
        # diropt_hybrid a near-tied candidate while the paired bench
        # measured it at 0.33-0.37x of its push-only counterpart.
        unvis = max(float(env.num_vertices) - env.visited_rows, 0.0)
        frac = unvis / max(float(env.num_vertices), 1.0)
        return OpCost(env.emitted_rows,
                      frac * float(env.num_edges) * 8.0
                      + env.frontier_cap * 36.0          # prev-set rebuild
                      + float(env.num_edges) * 10.0      # hit + compact
                      + float(env.num_vertices) * 6.0
                      + env.frontier_cap * 5.0)


@dataclasses.dataclass(frozen=True)
class EarlyMaterialize(Operator):
    """Fig. 3's per-level Materialize: turn the positional join output into
    value tuples (or full interleaved rows) IMMEDIATELY — the (3+N) gathers
    per level that the positional plan avoids.  ``with_next`` additionally
    carries the join-space next-vertex column (needed when direction='both'
    makes the next vertex ambiguous after folding to real positions)."""

    cols: Tuple[str, ...] = ()
    rows: bool = False
    with_next: bool = False

    def init(self, ctx, state, root):
        return self._materialize(ctx, state)

    def step(self, ctx, state):
        return self._materialize(ctx, state)

    def _materialize(self, ctx, state):
        pos_real = _to_real(ctx, state.frontier_pos)
        if self.rows:
            return state._replace(frontier_rows=ctx.rows.take_rows(pos_real))
        vals = ctx.table.take(pos_real, self.cols)
        if self.with_next:
            valid = state.frontier_pos < _num_join(ctx)
            vals["__next__"] = jnp.where(
                valid, _join_dst_at(ctx, state.frontier_pos), -1)
        return state._replace(frontier_vals=vals)

    def describe(self):
        if self.rows:
            return "Materialize[* full rows](heap read)"
        return f"Materialize[{', '.join(self.cols)}](EVERY level)"

    def estimate(self, env):
        width = (env.row_bytes if self.rows
                 else _cols_bytes(env, self.cols) + (4.0 if self.with_next
                                                    else 0.0))
        return OpCost(env.emitted_rows, env.frontier_cap * width)


@dataclasses.dataclass(frozen=True)
class AppendUnionAll(Operator):
    """The recursive UNION ALL: append the level's block to the working
    result, tagging every appended row with its BFS level.  ``init`` appends
    the seed block (level 0) when the pipeline is edge-seeded; ``step``
    appends level ``depth + step_tag_offset`` (offset 0 — and no seed append
    — for vertex-seeded pipelines that emit the current level inside the
    loop body)."""

    rep: str = "pos"            # 'pos' | 'vals' | 'rows'
    cols: Tuple[str, ...] = ()  # result columns for rep='vals'
    step_tag_offset: int = 1
    append_seed: bool = True

    def init(self, ctx, state, root):
        if not self.append_seed:
            return state
        return self._append(ctx, state, state.depth)

    def step(self, ctx, state):
        return self._append(ctx, state, state.depth + self.step_tag_offset)

    def _append(self, ctx, state, tag):
        if self.rep == "pos":
            block = PosBlock(_to_real(ctx, state.frontier_pos),
                             state.frontier_count)
            rpos, rcount, ovf = append_block(state.result_pos,
                                             state.result_count, block)
            rdepth = _tag_depths(state.result_depth, state.result_count,
                                 block.capacity, block.count, tag)
            return state._replace(result_pos=rpos, result_count=rcount,
                                  result_depth=rdepth,
                                  overflow=state.overflow | ovf)
        if self.rep == "vals":
            vals = {k: state.frontier_vals[k] for k in self.cols}
        else:
            vals = {"rows": state.frontier_rows}
        cap_r = state.result_depth.shape[0]
        bufs = state.result_vals
        if not bufs:     # first append allocates the result buffers
            bufs = {k: jnp.zeros((cap_r,) + v.shape[1:], v.dtype)
                    for k, v in vals.items()}
        bufs, rcount, ovf = append_values(bufs, state.result_count, vals,
                                          state.frontier_count, cap_r)
        block_cap = next(iter(vals.values())).shape[0]
        rdepth = _tag_depths(state.result_depth, state.result_count,
                             block_cap, state.frontier_count, tag)
        return state._replace(result_vals=bufs, result_count=rcount,
                              result_depth=rdepth,
                              overflow=state.overflow | ovf)

    def describe(self):
        return "UnionAll[append working table]"

    def estimate(self, env):
        width = {"pos": 4.0, "rows": float(env.row_bytes)}.get(
            self.rep, _cols_bytes(env, self.cols))
        # appended block + the per-row depth tag, at block capacity
        return OpCost(env.emitted_rows, env.frontier_cap * (width + 4.0))


@dataclasses.dataclass(frozen=True)
class ShardTargetExchange(Operator):
    """The distributed engine's shard-aware operator: union next-level
    target vertices across shards with ONE tiled ``all_gather`` per level
    (O(frontier) vertex ids — never values), then dedup replicated so every
    shard derives the identical next frontier."""

    axis: Any

    def step(self, ctx, state):
        cap = state.frontier_pos.shape[0]
        live = jnp.arange(cap, dtype=jnp.int32) < state.frontier_count
        tloc = jnp.where(
            live, _join_dst_at(ctx, state.frontier_pos), -1)
        gathered = jax.lax.all_gather(tloc, self.axis, tiled=True)
        gvalid = gathered >= 0
        keep, visited = dedup_targets(gathered, gvalid, state.visited)
        nxt, ovf = block_from_mask(gathered, keep, cap, -1)
        kmask = jnp.arange(cap, dtype=jnp.int32) < nxt.count
        return state._replace(targets=nxt.positions, keep=kmask,
                              frontier_count=nxt.count, visited=visited,
                              overflow=state.overflow | ovf)

    def describe(self):
        return f"AllGatherTargets[axis={self.axis!r}] -> VisitedDedup"

    def estimate(self, env):
        # one tiled all_gather of vertex ids + replicated dedup
        return OpCost(env.unique_rows,
                      env.frontier_cap * 18.0 + env.num_vertices * 5.0)


# ---------------------------------------------------------------------------
# finishers
# ---------------------------------------------------------------------------

def _drain_value_frontier(ctx, pipeline, state):
    """Fold the FINAL frontier's arrivals into the vertex accumulator.

    :class:`WeightedExpand` ⊕-combines the arrivals produced by the
    PREVIOUS expansion at the start of each step, so when the depth bound
    (rather than convergence) stops the loop, the last expansion's rows
    are in the result but their values are still sitting in
    ``frontier_val``.  The dense step combines in the same iteration it
    emits, so only the positional finisher needs this drain; it is a
    no-op on a converged (empty) frontier."""
    cap = state.frontier_pos.shape[0]
    nv = state.vertex_val.shape[0]
    sr = get_semiring(pipeline.semiring)
    slots = jnp.arange(cap, dtype=jnp.int32)
    valid = slots < state.frontier_count
    safe = jnp.clip(_join_dst_at(ctx, state.frontier_pos), 0, nv - 1)
    idx = jnp.where(valid, safe, nv)
    lvl = scatter_combine(sr, jnp.full((nv,), sr.identity, jnp.float32),
                          idx, state.frontier_val)
    received = or_combine(jnp.zeros((nv,), bool), idx, valid)
    return jnp.where(received, elem_combine(sr, state.vertex_val, lvl),
                     state.vertex_val)


@dataclasses.dataclass(frozen=True)
class LateMaterialize:
    """Fig. 4's single Materialize after the fixed point — the paper's core
    win: ALL output columns gathered exactly once, from positions."""

    cols: Tuple[str, ...]

    def finish(self, ctx, pipeline, state):
        values = ctx.table.take(state.result_pos, self.cols)
        vv = (_drain_value_frontier(ctx, pipeline, state)
              if pipeline.semiring != "reach" else None)
        return BFSResult(values, state.result_pos, state.result_count,
                         state.depth, state.overflow, state.result_depth,
                         vertex_values=vv)

    def describe(self):
        return (f"Materialize[{', '.join(self.cols)}]"
                "  <- ONE late gather, after the fixed point")

    def estimate(self, env):
        return OpCost(env.frontier_rows,
                      env.result_cap * (_cols_bytes(env, self.cols) + 4.0))


@dataclasses.dataclass(frozen=True)
class EmitTuples:
    """Tuple-pipeline finisher: the result was materialized level by level;
    positions are unavailable (all -1) — the Fig. 3 contract."""

    cols: Tuple[str, ...]

    def finish(self, ctx, pipeline, state):
        cap_r = state.result_depth.shape[0]
        values = {k: state.result_vals[k] for k in self.cols}
        return BFSResult(values, jnp.full((cap_r,), -1, jnp.int32),
                         state.result_count, state.depth, state.overflow,
                         state.result_depth)

    def describe(self):
        return f"Emit[{', '.join(self.cols)}](pre-materialized; positions=-1)"

    def estimate(self, env):
        return OpCost(env.frontier_rows, 0.0)   # already paid per level


@dataclasses.dataclass(frozen=True)
class ProjectRows:
    """Row-store finisher: project output columns back out of the gathered
    full rows; positions are unavailable (all -1)."""

    cols: Tuple[str, ...]

    def finish(self, ctx, pipeline, state):
        cap_r = state.result_depth.shape[0]
        values = ctx.rows.project(state.result_vals["rows"], self.cols)
        return BFSResult(values, jnp.full((cap_r,), -1, jnp.int32),
                         state.result_count, state.depth, state.overflow,
                         state.result_depth)

    def describe(self):
        return f"Project[{', '.join(self.cols)}](full rows)"

    def estimate(self, env):
        return OpCost(env.frontier_rows, env.result_cap * env.row_bytes)


@dataclasses.dataclass(frozen=True)
class CompactEmitted:
    """Bitmap-pipeline finisher: compact the emitted-edge mask into a
    position block, then late-materialize — the dense plan keeps the
    positional contract."""

    cols: Tuple[str, ...]

    def finish(self, ctx, pipeline, state):
        ej = _num_join(ctx)
        cap_r = pipeline.caps.result
        blk = compact_mask(state.emitted, cap_r, ej)
        pos_real = _to_real(ctx, blk.positions)
        values = ctx.table.take(pos_real, self.cols)
        overflow = state.overflow | (
            jnp.sum(state.emitted, dtype=jnp.int32) > cap_r)
        row_depths = jnp.where(
            blk.valid_mask(),
            state.emit_depth[jnp.minimum(blk.positions, ej - 1)], -1)
        dirs = state.level_dirs if state.level_dirs.shape[0] else None
        vv = state.vertex_val if pipeline.semiring != "reach" else None
        return BFSResult(values, pos_real, blk.count, state.depth, overflow,
                         row_depths, dirs, vertex_values=vv)

    def describe(self):
        return (f"Materialize[{', '.join(self.cols)}](Compact(emitted mask))"
                "  <- ONE late gather")

    def estimate(self, env):
        return OpCost(env.frontier_rows,
                      float(env.num_edges) * 2.0
                      + env.result_cap * (_cols_bytes(env, self.cols)
                                          + 4.0))


@dataclasses.dataclass(frozen=True)
class DeferredEmit:
    """Deferred-emission finisher (the diropt pipelines): the loop carried
    only per-vertex depths, so the emitted-edge mask is DERIVED here in one
    O(EJ) pass — a join edge is emitted iff its source vertex was
    discovered strictly before the last executed level — then compacted
    and late-materialized exactly like :class:`CompactEmitted` (identical
    row set, order and depths)."""

    cols: Tuple[str, ...]

    def finish(self, ctx, pipeline, state):
        ej = _num_join(ctx)
        cap_r = pipeline.caps.result
        vd = state.vertex_depth
        nv = vd.shape[0]
        if ctx.bidir:
            src_depth = jnp.concatenate([
                vd[jnp.clip(ctx.join_src, 0, nv - 1)],
                vd[jnp.clip(ctx.join_dst, 0, nv - 1)]])
        else:
            src_depth = vd[jnp.clip(ctx.join_src, 0, nv - 1)]
        emitted = (src_depth >= 0) & (src_depth < state.depth)
        blk = compact_mask(emitted, cap_r, ej)
        pos_real = _to_real(ctx, blk.positions)
        values = ctx.table.take(pos_real, self.cols)
        overflow = state.overflow | (
            jnp.sum(emitted, dtype=jnp.int32) > cap_r)
        row_depths = jnp.where(
            blk.valid_mask(),
            src_depth[jnp.minimum(blk.positions, ej - 1)], -1)
        dirs = state.level_dirs if state.level_dirs.shape[0] else None
        return BFSResult(values, pos_real, blk.count, state.depth, overflow,
                         row_depths, dirs)

    def describe(self):
        return (f"Materialize[{', '.join(self.cols)}]"
                "(Compact(vertex depths -> emitted))  <- ONE deferred pass")

    def estimate(self, env):
        # one (EJ,) depth gather + mask + compact, then the late gather
        return OpCost(env.frontier_rows,
                      float(env.num_edges) * 3.0
                      + env.result_cap * (_cols_bytes(env, self.cols)
                                          + 4.0))


@dataclasses.dataclass(frozen=True)
class TopLevelJoin:
    """The paper's Exp-3 rewriting: the recursion carried only (id, to); the
    payload columns come back through ONE top-level hash join on ``id``
    (realized as an inverse-permutation probe array).  On the row store the
    join re-gathers full rows — the rewrite cannot rescue a heap table."""

    cols: Tuple[str, ...]
    inner: Any
    use_rows: bool = False

    def finish(self, ctx, pipeline, state):
        slim = self.inner.finish(ctx, pipeline, state)
        if self.use_rows:
            e = ctx.rows.num_rows
            id_col = ctx.rows.column("id").astype(jnp.int32)  # strided scan
            probe = jnp.zeros((e,), jnp.int32).at[
                jnp.clip(id_col, 0, e - 1)].set(
                jnp.arange(e, dtype=jnp.int32), mode="drop")
        else:
            e = ctx.table.num_rows
            id_col = ctx.table.column("id")
            probe = jnp.zeros((e,), jnp.int32).at[id_col].set(
                jnp.arange(e, dtype=jnp.int32), mode="drop")
        cap_r = slim.positions.shape[0]
        live = jnp.arange(cap_r, dtype=jnp.int32) < slim.count
        ids = jnp.where(live, slim.values["id"].astype(jnp.int32), -1)
        pos = jnp.where(live, probe[jnp.clip(ids, 0, e - 1)], e)
        if self.use_rows:
            values = ctx.rows.project(ctx.rows.take_rows(pos), self.cols)
        else:
            values = ctx.table.take(pos, self.cols)
        return BFSResult(values, pos, slim.count, slim.depth, slim.overflow,
                         slim.row_depths, vertex_values=slim.vertex_values)

    def describe(self):
        return (f"HashJoin[id = cte.id](Hash(id -> pos), "
                f"{self.inner.describe()})")

    def estimate(self, env):
        inner = self.inner.estimate(env)
        cap_r = env.result_cap
        if self.use_rows:     # strided id scan + full-row re-gather
            b = float(env.num_edges) * env.row_bytes + cap_r * env.row_bytes
        else:                 # probe-array build + ONE late gather
            b = (float(env.num_edges) * 8.0
                 + cap_r * (_cols_bytes(env, self.cols) + 4.0))
        return OpCost(env.frontier_rows, inner.bytes + b)


@dataclasses.dataclass(frozen=True)
class RawPositions:
    """Return bare result positions (the distributed engine materializes
    shard-locally outside the driver)."""

    def finish(self, ctx, pipeline, state):
        return BFSResult({}, state.result_pos, state.result_count,
                         state.depth, state.overflow, state.result_depth)

    def describe(self):
        return "RawPositions[] (caller materializes shard-locally)"

    def estimate(self, env):
        return OpCost(env.frontier_rows, 0.0)


# ---------------------------------------------------------------------------
# the pipeline + the ONE fixed-point driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Pipeline:
    """A declarative recursive plan: seed, per-level operators, finisher.
    Hashable (all-static) so it can be a jit static argument."""

    name: str
    rep: str                 # 'pos' | 'vals' | 'rows' | 'dense'
    seed: Seed
    ops: Tuple[Operator, ...]
    finisher: Any
    caps: EngineCaps
    max_depth: int
    inclusive: bool = False        # cond: depth <= max_depth (dense engines)
    tracks_emitted: bool = False   # carries the (EJ,) emitted-edge mask
    tracks_vertex_depth: bool = False  # deferred emission: (V,) vertex depths
    tracks_switch: bool = False    # records per-level push/pull decisions
    semiring: str = "reach"        # value-plane workload; 'reach' = boolean
    #   BFS with zero-size value placeholders (bit-identical fast path)

    @property
    def carries_positions(self) -> bool:
        """The positions contract: see the module docstring."""
        return (self.rep in ("pos", "dense")
                or isinstance(self.finisher, TopLevelJoin))

    def render(self, root=0) -> str:
        """The Volcano tree of the ACTUAL composition (Fig. 3/4 audit)."""
        loop = "\n".join(f"    {op.describe()}" for op in self.ops)
        seed = self.seed.describe().replace("$root", str(root))
        return (f"{self.finisher.describe()}\n"
                f"  {self.name}(maxrec={self.max_depth})\n"
                f"    {seed}            (non-recursive child)\n"
                f"{loop}")


def _initial_state(pipeline: Pipeline, ctx: Context, num_vertices: int
                   ) -> TraversalState:
    cap_f, cap_r = pipeline.caps.frontier, pipeline.caps.result
    ej = _num_join(ctx)
    e = _num_real_rows(ctx)
    dense = pipeline.rep == "dense"
    track = pipeline.tracks_emitted
    deferred = pipeline.tracks_vertex_depth
    weighted = pipeline.semiring != "reach"
    sr = get_semiring(pipeline.semiring) if weighted else None
    use_result_pos = pipeline.rep == "pos" and not track
    n_levels = pipeline.max_depth + 2          # >= executed iterations
    i32z = jnp.zeros((), jnp.int32)
    return TraversalState(
        frontier_pos=(jnp.zeros((0,), jnp.int32) if dense
                      else jnp.full((cap_f,), ej, jnp.int32)),
        frontier_vals={},
        frontier_rows=jnp.zeros((0, 0), jnp.float32),
        frontier_count=i32z,
        # deferred pipelines carry ONLY the vertex-depth array: no target
        # block, no dedup mask, no per-row result buffers in the loop
        targets=(jnp.zeros((0,), jnp.int32) if deferred
                 else jnp.full((cap_f,), -1, jnp.int32)),
        keep=(jnp.zeros((0,), bool) if deferred
              else jnp.zeros((cap_f,), bool)),
        frontier_bits=(jnp.zeros((num_vertices,), bool)
                       if dense and not pipeline.tracks_vertex_depth
                       else jnp.zeros((0,), bool)),
        emitted=(jnp.zeros((ej,), bool) if track
                 else jnp.zeros((0,), bool)),
        emit_depth=(jnp.full((ej,), -1, jnp.int32) if track
                    else jnp.zeros((0,), jnp.int32)),
        visited=(jnp.zeros((0,), bool) if pipeline.tracks_vertex_depth
                 else jnp.zeros((num_vertices,), bool)),
        result_pos=(jnp.full((cap_r,), e, jnp.int32) if use_result_pos
                    else jnp.zeros((0,), jnp.int32)),
        result_vals={},
        result_depth=(jnp.zeros((0,), jnp.int32) if track or deferred
                      else jnp.full((cap_r,), -1, jnp.int32)),
        result_count=i32z,
        depth=i32z,
        overflow=jnp.zeros((), bool),
        vertex_depth=(jnp.full((num_vertices,), -1, jnp.int32)
                      if pipeline.tracks_vertex_depth
                      else jnp.zeros((0,), jnp.int32)),
        visited_count=i32z,
        level_dirs=(jnp.full((n_levels,), -1, jnp.int8)
                    if pipeline.tracks_switch
                    else jnp.zeros((0,), jnp.int8)),
        # the semiring value plane: zero-size placeholders for 'reach' keep
        # the boolean pipelines' loop state bit-identical to pre-value-plane
        frontier_val=(jnp.zeros((0,), jnp.float32) if not weighted
                      else jnp.full((num_vertices if dense else cap_f,),
                                    sr.identity, jnp.float32)),
        vertex_val=(jnp.full((num_vertices,), sr.identity, jnp.float32)
                    if weighted else jnp.zeros((0,), jnp.float32)),
    )


def fixed_point(pipeline: Pipeline, ctx: Context, root: jax.Array,
                num_vertices: int) -> BFSResult:
    """Run ANY pipeline to its fixed point: one ``jax.lax.while_loop``, the
    operator steps composed in order inside the body.  This is the single
    recursion driver behind every engine variant."""
    root = jnp.asarray(root, jnp.int32)
    state = _initial_state(pipeline, ctx, num_vertices)
    state = pipeline.seed.init(ctx, state, root)
    for op in pipeline.ops:
        state = op.init(ctx, state, root)

    limit = pipeline.max_depth + (1 if pipeline.inclusive else 0)

    def cond(s):
        return (s.frontier_count > 0) & (s.depth < limit)

    def body(s):
        for op in pipeline.ops:
            s = op.step(ctx, s)
        return s._replace(depth=s.depth + 1)

    state = jax.lax.while_loop(cond, body, state)
    return pipeline.finisher.finish(ctx, pipeline, state)


def fixed_point_batch(pipeline: Pipeline, ctx: Context, roots: jax.Array,
                      num_vertices: int) -> BFSResult:
    """Batched fixed point: the per-level operator steps are vmapped over a
    vector of roots inside ONE ``jax.lax.while_loop`` whose predicate is the
    explicit all-lanes-converged test — the loop exits as soon as EVERY
    lane's frontier has died (or hit its depth bound), so a reach-bucketed
    batch stops when its deepest root finishes instead of running to the
    global depth bound.  Lanes that converge early are frozen (their carry
    is masked), so lane ``i`` of the result is bit-identical to
    :func:`fixed_point` on ``roots[i]``."""
    roots = jnp.asarray(roots, jnp.int32)

    def init_one(root):
        state = _initial_state(pipeline, ctx, num_vertices)
        state = pipeline.seed.init(ctx, state, root)
        for op in pipeline.ops:
            state = op.init(ctx, state, root)
        return state

    state = jax.vmap(init_one)(roots)
    limit = pipeline.max_depth + (1 if pipeline.inclusive else 0)

    def lane_active(s):
        return (s.frontier_count > 0) & (s.depth < limit)

    def cond(s):
        return jnp.any(lane_active(s))      # all-lanes-converged early exit

    def step_one(s):
        for op in pipeline.ops:
            s = op.step(ctx, s)
        return s._replace(depth=s.depth + 1)

    def body(s):
        active = lane_active(s)             # (B,)
        nxt = jax.vmap(step_one)(s)

        def freeze(new, old):
            mask = active.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(mask, new, old)

        return jax.tree_util.tree_map(freeze, nxt, s)

    state = jax.lax.while_loop(cond, body, state)
    return jax.vmap(lambda s: pipeline.finisher.finish(ctx, pipeline, s)
                    )(state)


_execute_impl = jax.jit(fixed_point,
                        static_argnames=("pipeline", "num_vertices"))


def execute(pipeline: Pipeline, ctx: Context, root, num_vertices: int
            ) -> BFSResult:
    """Jitted single-root pipeline execution."""
    return _execute_impl(pipeline, ctx, jnp.asarray(root, jnp.int32),
                         num_vertices)


_batch_impl = jax.jit(fixed_point_batch,
                      static_argnames=("pipeline", "num_vertices"))


def execute_batch(pipeline: Pipeline, ctx: Context, roots,
                  num_vertices: int) -> BFSResult:
    """vmap-batched multi-root execution: ONE jitted XLA dispatch runs the
    whole batch (the serving path — many users' roots per call), through
    :func:`fixed_point_batch` so the dispatch stops when all lanes have
    converged.  Returns a BFSResult whose arrays carry a leading batch
    dimension."""
    roots = jnp.asarray(roots, jnp.int32)
    return _batch_impl(pipeline, ctx, roots, num_vertices)


# ---------------------------------------------------------------------------
# bit-parallel multi-query traversal (MS-BFS)
# ---------------------------------------------------------------------------

# The dense engines carry (V,)-sized boolean planes; the multiquery engine
# widens the ELEMENT TYPE instead of vmapping — one uint32 word per vertex
# packs up to 32 concurrent roots, and a single dense sweep advances every
# lane at once (Then et al., "The More the Merrier").  jnp is x32 by
# default, so the word is uint32; enable x64 before asking for wider words.
_WORD_DTYPE = jnp.uint32
WORD_LANES = 32


class MultiQueryState(NamedTuple):
    """The word-sweep loop carry.  No (lanes, V) plane lives in the loop:
    per-lane vertex depths are reconstructed AFTER the fixed point from the
    per-level new-bits snapshots (``level_words[d]`` holds the word of
    lanes that discovered each vertex at depth ``d`` — bits are set at most
    once per (lane, vertex), so the first set level IS the BFS depth)."""

    frontier_word: jax.Array   # (V,) uint32: lane bits in the frontier
    visited_word: jax.Array    # (V,) uint32: lane bits ever discovered
    level_words: jax.Array     # (max_levels, V) uint32: new bits per level
    lane_depth: jax.Array      # (lanes,) int32: levels executed per lane
    active: jax.Array          # () uint32: lanes still traversing
    depth: jax.Array           # () int32: levels executed (max over lanes)


def _segment_or(words: jax.Array, indptr: jax.Array,
                num_seg: int) -> jax.Array:
    """Per-segment bitwise OR of ``words`` (grouped by segment, boundaries
    in ``indptr``).  JAX scatters have no OR mode, so the dst-grouped
    reduce runs as ONE log-depth segmented associative scan over
    (segment-start flag, word) pairs — the classic segmented-scan combine:
    a start flag on the right operand resets the accumulation."""
    e = words.shape[0]
    if e == 0:
        return jnp.zeros((num_seg,), words.dtype)
    starts = indptr[:-1]
    # a start at position e (empty trailing segments) must not flag e-1
    flags = jnp.zeros((e,), bool).at[
        jnp.where(starts < e, starts, e)].set(True, mode="drop")

    def comb(a, b):
        af, av = a
        bf, bv = b
        return af | bf, jnp.where(bf, bv, av | bv)

    _, acc = jax.lax.associative_scan(comb, (flags, words))
    seg = acc[jnp.clip(indptr[1:] - 1, 0, e - 1)]
    return jnp.where(indptr[1:] > indptr[:-1], seg,
                     jnp.zeros((), words.dtype))


def _word_gather(ctx: Context, frontier_word: jax.Array, nv: int
                 ) -> jax.Array:
    """One packed-word level: for every vertex, the OR of its in-neighbors'
    frontier words (the MS-BFS analogue of :func:`_dense_pull`'s membership
    test, over all 32 lanes at once).  Needs dst-grouped edge orders:
    ``ctx.rcsr`` groups the join edges by ``join_dst`` in every direction
    view; the fused bidirectional view adds the backward orientation
    (grouped by ``join_src``) through ``ctx.csr``."""
    src = jnp.clip(ctx.join_src, 0, nv - 1)
    dst = jnp.clip(ctx.join_dst, 0, nv - 1)
    if ctx.bidir:
        fwd = _segment_or(frontier_word[src[ctx.rcsr.perm]],
                          ctx.rcsr.indptr, nv)
        bwd = _segment_or(frontier_word[dst[ctx.csr.perm]],
                          ctx.csr.indptr, nv)
        return fwd | bwd
    if ctx.rcsr is None:
        raise ValueError(
            "the multiquery word sweep needs dst-grouped edges (the "
            "reverse CSR); call Dataset.ensure_reverse() before dispatch")
    return _segment_or(frontier_word[src[ctx.rcsr.perm]],
                       ctx.rcsr.indptr, nv)


def _or_reduce(words: jax.Array) -> jax.Array:
    return jnp.bitwise_or.reduce(words)


@dataclasses.dataclass(frozen=True)
class MultiQuerySeed(Operator):
    """Scatter each root's lane bit into the packed frontier/visited words
    (lane bits are distinct, so a scatter-ADD of colliding roots IS the
    OR).  ``kind='dense'`` so the cost model prices levels with the dense
    engines' vertex-frontier accounting."""

    lanes: int = WORD_LANES
    kind: str = "dense"

    def describe(self):
        return f"MultiQuerySeed[{self.lanes} lane bits -> (V,) word]"

    def estimate(self, env):
        # two (V,) word planes + the snapshot row + the lane-bit scatter
        return OpCost(float(self.lanes),
                      float(env.num_vertices) * 12.0 + self.lanes * 8.0)


@dataclasses.dataclass(frozen=True)
class MultiQueryWordSweep(Operator):
    """One bit-parallel level: gather every in-neighbor's frontier word,
    segment-OR by destination, mask by ``~visited`` and the active-lane
    word.  Per-level cost is lane-count-INDEPENDENT (that is the whole
    point): E word gathers + the log-depth segmented scan + three (V,)
    word-plane updates, where the vmapped alternative pays its full
    per-level cost once per lane."""

    lanes: int = WORD_LANES

    def describe(self):
        return (f"MultiQueryWordSweep[{self.lanes} lanes/word: "
                "segment-OR pull, per-lane freeze]")

    def estimate(self, env):
        # (E,) word gather + segmented-scan passes (log-depth, priced as a
        # small linear factor) + frontier/visited/snapshot word planes
        return OpCost(env.emitted_rows,
                      float(env.num_edges) * 16.0
                      + float(env.num_vertices) * 16.0)


@dataclasses.dataclass(frozen=True)
class MultiQueryEmit:
    """Per-lane deferred emission: reconstruct each lane's (V,) vertex
    depths from the level snapshots, then derive/compact/materialize the
    emitted edge set exactly like :class:`DeferredEmit` — lane ``l`` of the
    result is row-for-row identical (rows, order, ``row_depths``) to the
    sequential deferred-emission engines on ``roots[l]``."""

    cols: Tuple[str, ...]
    lanes: int = WORD_LANES

    def finish(self, ctx, pipeline, state):
        raise NotImplementedError(
            "multiquery pipelines run through execute_multiquery, not the "
            "scalar fixed_point driver")

    def describe(self):
        return (f"Materialize[{', '.join(self.cols)}]"
                f"(Compact(lane depths -> emitted)) x{self.lanes} lanes")

    def estimate(self, env):
        # per lane: the level->depth reconstruction, one (EJ,) depth
        # gather + mask + compact, and the late materialize
        per_lane = (float(env.num_edges) * 3.0
                    + float(env.num_vertices) * 2.0
                    + env.result_cap * (_cols_bytes(env, self.cols) + 4.0))
        return OpCost(env.frontier_rows, self.lanes * per_lane)


def _multiquery_finish(ctx: Context, pipeline: Pipeline,
                       state: "MultiQueryState", lane_ids: jax.Array,
                       nv: int) -> BFSResult:
    """All-lanes deferred emission in ONE batched pass.

    The emitted-edge test stays bit-parallel: per level, mask the new-bits
    snapshot by the word of lanes whose executed depth exceeds that level,
    OR the levels together into one (V,) emit word, and gather it through
    the join sources — ``emitted_word[j]``'s bits are exactly the lanes
    for which :class:`DeferredEmit` would emit edge ``j``.

    Compaction is the part that cannot stay packed (each lane compacts to
    its own slots).  A vmapped :func:`compact_mask` lowers to per-lane
    ``nonzero`` scatters that dominate the whole dispatch on CPU, so
    instead: one (EJ, lanes) prefix-count cumsum, then the i-th set
    position per lane is recovered by a shared binary search over the
    prefix column — all gathers, no scatters.  Positions come out
    ascending per lane with the join-space sentinel in padding slots, the
    exact :func:`compact_mask` layout."""
    ej = _num_join(ctx)
    cap_r = pipeline.caps.result
    lanes = lane_ids.shape[0]
    n_levels = state.level_words.shape[0]
    # word of lanes for which a vertex discovered at level d is a frontier
    # vertex (d < that lane's executed depth)
    lane_bits = jnp.left_shift(_WORD_DTYPE(1), lane_ids)
    level_mask = jnp.sum(
        jnp.where(jnp.arange(n_levels, dtype=jnp.int32)[:, None]
                  < state.lane_depth[None, :],
                  lane_bits[None, :], 0),
        axis=1, dtype=_WORD_DTYPE)                           # (NL,)
    emit_v = jnp.bitwise_or.reduce(
        state.level_words & level_mask[:, None], axis=0)     # (V,)
    src = jnp.clip(ctx.join_src, 0, nv - 1)
    if ctx.bidir:
        join_v = jnp.concatenate(
            [src, jnp.clip(ctx.join_dst, 0, nv - 1)])        # (EJ,)
    else:
        join_v = src
    emitted_word = emit_v[join_v]                            # (EJ,)
    # per-lane prefix counts, lanes as the vector axis
    bits = ((emitted_word[:, None] >> lane_ids[None, :])
            & _WORD_DTYPE(1)).astype(jnp.int32)              # (EJ, lanes)
    prefix = jnp.cumsum(bits, axis=0)                        # (EJ, lanes)
    total = prefix[-1]                                       # (lanes,)
    count = jnp.minimum(total, cap_r)
    overflow = total > cap_r
    # i-th emitted position per lane = first j with prefix[j] == i+1:
    # one vectorized binary search over the (cap_r, lanes) grid
    want = jnp.arange(1, cap_r + 1, dtype=jnp.int32)[:, None]
    lane_cols = jnp.arange(lanes, dtype=jnp.int32)[None, :]
    lo = jnp.zeros((cap_r, lanes), jnp.int32)
    hi = jnp.full((cap_r, lanes), ej, jnp.int32)
    for _ in range(max(ej, 1).bit_length()):
        mid = (lo + hi) // 2
        val = jnp.where(mid < ej,
                        prefix[jnp.minimum(mid, ej - 1), lane_cols],
                        jnp.int32(1 << 30))
        ge = val >= want
        lo = jnp.where(ge, lo, mid + 1)
        hi = jnp.where(ge, mid, hi)
    positions = lo.T                                         # (lanes, cap_r)
    pos_real = _to_real(ctx, positions)
    values = ctx.table.take(pos_real, pipeline.finisher.cols)
    valid = (jnp.arange(cap_r, dtype=jnp.int32)[None, :] < count[:, None])
    # row depth = the source vertex's per-lane BFS level; recover it from
    # the level snapshots at just the compacted positions (each (lane,
    # vertex) bit is set in at most ONE level, so the overwrite is exact)
    v_at = join_v[jnp.minimum(positions, ej - 1)]            # (lanes, cap_r)
    row_depths = jnp.full((lanes, cap_r), -1, jnp.int32)
    for d in range(n_levels):
        hit = ((state.level_words[d][v_at] >> lane_ids[:, None])
               & _WORD_DTYPE(1)).astype(bool)
        row_depths = jnp.where(hit, jnp.int32(d), row_depths)
    row_depths = jnp.where(valid, row_depths, -1)
    return BFSResult(values, pos_real, count, state.lane_depth, overflow,
                     row_depths)


def multiquery_fixed_point(pipeline: Pipeline, ctx: Context,
                           roots: jax.Array, num_vertices: int,
                           lane_limits: jax.Array) -> BFSResult:
    """The MS-BFS driver: ONE ``jax.lax.while_loop`` advances up to 32
    packed lanes per level.

    Per-lane convergence freezing and depth caps live in the ``active``
    word: a lane leaves it when its frontier bits die or its depth cap
    binds, its bits stop propagating, and its executed-level counter
    freezes — so lane ``l`` of the result is row-identical to the scalar
    driver on ``roots[l]`` with ``max_depth=lane_limits[l]``.
    ``lane_limits`` come from the serving layer's reach buckets (clamped
    to the query's ``max_depth``; estimates never bind below a lane's
    natural convergence depth, so capping is semantics-preserving)."""
    nv = num_vertices
    lanes = roots.shape[0]
    if lanes > WORD_LANES:
        raise ValueError(f"multiquery packs at most {WORD_LANES} roots per "
                         f"{_WORD_DTYPE.dtype.name} word, got {lanes}")
    roots = jnp.clip(jnp.asarray(roots, jnp.int32), 0, nv - 1)
    lane_ids = jnp.arange(lanes, dtype=_WORD_DTYPE)
    lane_bits = jnp.left_shift(_WORD_DTYPE(1), lane_ids)
    # distinct bits per lane: scatter-ADD of colliding roots == OR
    root_word = jnp.zeros((nv,), _WORD_DTYPE).at[roots].add(lane_bits)
    limit = pipeline.max_depth + (1 if pipeline.inclusive else 0)
    bonus = 1 if pipeline.inclusive else 0
    lane_limit = (jnp.minimum(jnp.asarray(lane_limits, jnp.int32),
                              pipeline.max_depth) + bonus)
    n_levels = limit + 1                      # snapshot rows: seed + levels
    level_words = jnp.zeros((n_levels, nv), _WORD_DTYPE).at[0].set(root_word)
    active0 = jnp.sum(jnp.where(lane_limit > 0, lane_bits, 0),
                      dtype=_WORD_DTYPE)
    state = MultiQueryState(
        frontier_word=root_word, visited_word=root_word,
        level_words=level_words,
        lane_depth=jnp.zeros((lanes,), jnp.int32),
        active=active0, depth=jnp.zeros((), jnp.int32))

    def cond(s):
        return (s.active != 0) & (s.depth < limit)

    def body(s):
        gathered = _word_gather(ctx, s.frontier_word, nv)
        new = gathered & ~s.visited_word & s.active
        visited = s.visited_word | new
        depth = s.depth + 1
        # lanes in the active word executed this level
        ran = ((s.active >> lane_ids) & _WORD_DTYPE(1)).astype(jnp.int32)
        lane_depth = s.lane_depth + ran
        # freeze: frontier died (no new bits anywhere) or depth cap bound
        alive = _or_reduce(new)
        within = jnp.sum(jnp.where(lane_depth < lane_limit, lane_bits, 0),
                         dtype=_WORD_DTYPE)
        return MultiQueryState(
            frontier_word=new, visited_word=visited,
            level_words=s.level_words.at[depth].set(new),
            lane_depth=lane_depth, active=s.active & alive & within,
            depth=depth)

    state = jax.lax.while_loop(cond, body, state)
    return _multiquery_finish(ctx, pipeline, state, lane_ids, nv)


_multiquery_impl = jax.jit(multiquery_fixed_point,
                           static_argnames=("pipeline", "num_vertices"))


def execute_multiquery(pipeline: Pipeline, ctx: Context, roots,
                       num_vertices: int,
                       lane_limits=None) -> BFSResult:
    """Jitted bit-parallel multi-root execution: ONE dense word sweep
    answers up to 32 roots.  Returns a BFSResult with a leading
    ``len(roots)`` lane dimension, row-for-row equal per lane to the
    sequential deferred-emission engines.  ``lane_limits`` (optional,
    (lanes,) int32) caps each lane's executed depth — the serving layer
    passes per-lane reach-bucket depth estimates; ``None`` means every
    lane runs to the query's ``max_depth``."""
    roots = jnp.asarray(roots, jnp.int32)
    if lane_limits is None:
        lane_limits = jnp.full((roots.shape[0],), pipeline.max_depth,
                               jnp.int32)
    return _multiquery_impl(pipeline, ctx, roots, num_vertices,
                            jnp.asarray(lane_limits, jnp.int32))
