"""Position blocks — the paper's core intermediate representation.

PosDB's positional operators exchange blocks of row ids instead of value
tuples.  Under XLA every buffer is static-shaped, so a position block is a
fixed-capacity ``int32`` vector plus a live count; dead slots hold an
out-of-range sentinel so downstream gathers mask to zero (see
``ColumnTable.take``).

This module also exposes the *positional processing* primitives reused across
the framework (MoE dispatch, embedding lookup, neighbor sampling): they are
the paper's late-materialization discipline packaged as a library.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "PosBlock", "empty_block", "block_from_mask", "append_block",
    "compact_mask", "take_late", "sort_positions_by_key",
]


class PosBlock(NamedTuple):
    """Fixed-capacity block of row positions.

    positions : (cap,) int32 — valid entries first, sentinel padding after
    count     : ()     int32 — number of live entries
    """

    positions: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.positions.shape[0]

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.count


def empty_block(capacity: int, sentinel: int) -> PosBlock:
    return PosBlock(
        positions=jnp.full((capacity,), sentinel, dtype=jnp.int32),
        count=jnp.zeros((), jnp.int32),
    )


def compact_mask(mask: jax.Array, capacity: int, sentinel: int) -> PosBlock:
    """Turn a boolean row mask into a compacted position block.

    The columnar Filter operator: emits the positions of matching rows.
    Deterministic (ascending) order; overflow beyond ``capacity`` is dropped
    (callers check ``count`` vs capacity to detect it).
    """
    count = jnp.sum(mask, dtype=jnp.int32)
    idx = jnp.nonzero(mask, size=capacity, fill_value=sentinel)[0].astype(jnp.int32)
    return PosBlock(idx, jnp.minimum(count, capacity))


def block_from_mask(values: jax.Array, mask: jax.Array, capacity: int,
                    sentinel: int) -> tuple[PosBlock, jax.Array]:
    """Compact ``values[mask]`` into a block; returns (block, overflow)."""
    n = values.shape[0]
    count = jnp.sum(mask, dtype=jnp.int32)
    order = jnp.argsort(~mask, stable=True)            # valid slots first
    gathered = jnp.take(values, order[:min(capacity, n)], axis=0)
    if capacity > n:
        gathered = jnp.pad(gathered, (0, capacity - n))
    live = jnp.arange(capacity, dtype=jnp.int32) < jnp.minimum(count, capacity)
    out = jnp.where(live, gathered, sentinel)
    return PosBlock(out.astype(jnp.int32), jnp.minimum(count, capacity)), count > capacity


def append_block(buf: jax.Array, buf_count: jax.Array, block: PosBlock
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Append a block's live entries into a larger result buffer.

    Returns (new_buffer, new_count, overflowed).  Entries past the buffer
    capacity are dropped (and flagged) rather than wrapped.
    """
    cap_r = buf.shape[0]
    slots = buf_count + jnp.arange(block.capacity, dtype=jnp.int32)
    live = block.valid_mask() & (slots < cap_r)
    safe_slots = jnp.where(live, slots, cap_r)          # scatter-drop padding
    buf = buf.at[safe_slots].set(jnp.where(live, block.positions, 0),
                                 mode="drop")
    new_count = jnp.minimum(buf_count + block.count, cap_r)
    return buf, new_count, (buf_count + block.count) > cap_r


# ---------------------------------------------------------------------------
# Late materialization + positional processing primitives (framework-wide API)
# ---------------------------------------------------------------------------

def take_late(table, block: PosBlock, names=None):
    """The Materialize operator: one gather at the very end of a positional
    plan.  ``table`` is a ColumnTable; returns dict of (cap, ...) arrays with
    dead slots zeroed."""
    return table.take(block.positions, names)


def sort_positions_by_key(keys: jax.Array, num_buckets: int
                          ) -> tuple[jax.Array, jax.Array]:
    """Stable-sort positions by an integer bucket key.

    The positional MoE-dispatch primitive: returns (order, bucket_counts)
    where ``order`` lists original positions grouped by bucket.  Tokens are
    *gathered once* along ``order``, processed per contiguous bucket, and
    scattered back — values move twice, positions do all the routing.
    """
    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    counts = jnp.zeros((num_buckets,), jnp.int32).at[keys].add(1, mode="drop")
    return order, counts
