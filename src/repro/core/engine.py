"""Query layer: the paper's three experiment queries as engine-dispatched
plans.

A ``RecursiveQuery`` describes the SQL of §5.1 (Listings 1.1/1.2/1.3):
which payload columns exist, what the recursion carries, whether the Exp-3
rewrite is applied, and which engine executes it.  ``plan_repr`` renders the
Volcano tree of Fig. 3/4 for the chosen engine so the operator mapping is
auditable.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

from .bitmap import bitmap_bfs, hybrid_bfs
from .csr import CSRIndex, build_csr
from .recursive import (BFSResult, EngineCaps, precursive_bfs, rowstore_bfs,
                        rowstore_rewrite_bfs, trecursive_bfs,
                        trecursive_rewrite_bfs)
from .table import ColumnTable, RowTable, payload_names

EngineName = Literal["precursive", "trecursive", "rowstore", "rowstore_index",
                     "bitmap", "hybrid", "trecursive_rewrite",
                     "rowstore_rewrite", "rowstore_index_rewrite"]

ENGINE_NAMES: tuple[str, ...] = (
    "precursive", "trecursive", "rowstore", "rowstore_index", "bitmap",
    "hybrid", "trecursive_rewrite", "rowstore_rewrite",
    "rowstore_index_rewrite")


@dataclasses.dataclass(frozen=True)
class RecursiveQuery:
    """One recursive CTE query instance (a paper experiment cell)."""

    engine: EngineName
    max_depth: int
    payload_cols: int                 # the paper's N
    caps: EngineCaps
    dedup: bool = True                # BFS semantics (UNION ALL if False)

    @property
    def out_cols(self) -> tuple[str, ...]:
        return ("id", "from", "to", "name",
                *payload_names(self.payload_cols))


@dataclasses.dataclass(frozen=True)
class Dataset:
    """A prepared graph: columnar + row layouts + the join index."""

    table: ColumnTable
    rows: RowTable
    csr: CSRIndex
    num_vertices: int

    @classmethod
    def prepare(cls, table: ColumnTable, num_vertices: int) -> "Dataset":
        return cls(table=table, rows=RowTable.from_column_table(table),
                   csr=build_csr(table.column("from"), num_vertices),
                   num_vertices=num_vertices)


def run_query(q: RecursiveQuery, ds: Dataset, root: int) -> BFSResult:
    rt = jnp.int32(root)
    kw = dict(caps=q.caps, max_depth=q.max_depth, out_cols=q.out_cols,
              dedup=q.dedup)
    if q.engine == "precursive":
        return precursive_bfs(ds.table, ds.csr, rt, **kw)
    if q.engine == "trecursive":
        return trecursive_bfs(ds.table, ds.csr, rt, **kw)
    if q.engine == "rowstore":
        return rowstore_bfs(ds.rows, ds.csr, rt, use_index=False, **kw)
    if q.engine == "rowstore_index":
        return rowstore_bfs(ds.rows, ds.csr, rt, use_index=True, **kw)
    if q.engine == "bitmap":
        kw.pop("dedup")
        return bitmap_bfs(ds.table, ds.num_vertices, rt, **kw)
    if q.engine == "hybrid":
        kw.pop("dedup")
        return hybrid_bfs(ds.table, ds.csr, rt, **kw)
    if q.engine == "trecursive_rewrite":
        return trecursive_rewrite_bfs(ds.table, ds.csr, rt, **kw)
    if q.engine == "rowstore_rewrite":
        return rowstore_rewrite_bfs(ds.rows, ds.csr, rt, use_index=False, **kw)
    if q.engine == "rowstore_index_rewrite":
        return rowstore_rewrite_bfs(ds.rows, ds.csr, rt, use_index=True, **kw)
    raise ValueError(f"unknown engine {q.engine!r}")


_PLANS = {
    "precursive": """\
Materialize[{cols}]                <- ONE late gather, after the fixed point
  PRecursive(maxrec={d})
    Filter[from = {root}] -> PosBlock            (non-recursive child)
    IndexJoin[CSR(from)](PRecursiveCTE, edges)   (recursive child: pos -> pos)""",
    "trecursive": """\
TRecursive(maxrec={d})
  Materialize[{cols}](Filter[from = {root}])    (non-recursive child)
  Join[from = cte.to]                            (recursive child)
    TRecursiveCTE
    Materialize[{cols}](edges)                  <- (3+N) gathers EVERY level""",
    "rowstore": """\
Recursive(maxrec={d})                            (PostgreSQL emulation)
  SeqScan[from = {root}] -> full rows
  HashJoin[from = cte.to]
    Hash(cte)
    SeqScan(edges)                              <- full-width scan EVERY level""",
}


def plan_repr(engine: str, max_depth: int, payload_cols: int,
              root: int = 0) -> str:
    base = {"rowstore_index": "rowstore", "hybrid": "precursive",
            "bitmap": "precursive", "trecursive_rewrite": "trecursive",
            "rowstore_rewrite": "rowstore",
            "rowstore_index_rewrite": "rowstore"}.get(engine, engine)
    cols = ", ".join(("id", "from", "to", "name",
                      *payload_names(payload_cols)))
    return _PLANS[base].format(d=max_depth, cols=cols, root=root)
