"""Query layer: paper experiment queries dispatched onto operator pipelines.

A ``RecursiveQuery`` describes the SQL of §5.1 (Listings 1.1/1.2/1.3):
which payload columns exist, what the recursion carries, whether the Exp-3
rewrite is applied, which engine executes it, and the traversal
``direction``.  Engine dispatch is a *plan-builder registry*
(:data:`PLAN_BUILDERS`): every engine name maps to a function producing a
declarative :class:`~repro.core.operators.Pipeline`, and every pipeline runs
through the single shared :func:`~repro.core.operators.fixed_point` driver.

``plan_repr`` renders the Volcano tree *derived from the actual operator
composition* (``Pipeline.render``), so the mapping onto the paper's
Fig. 3/4 operator trees is auditable rather than hand-maintained:

* Fig. 4 (PRecursive)  → Seed → ReadCol → VisitedDedup → CSRIndexJoin →
  AppendUnionAll, finished by one LateMaterialize;
* Fig. 3 (TRecursive)  → the same loop + EarlyMaterialize every level,
  finished by EmitTuples;
* PostgreSQL baseline  → SeqScan seed + ScanHashJoin + full-row gathers.

Serving path: :func:`run_query_batch` vmaps the driver over a vector of
roots — ONE jitted XLA dispatch answers a whole batch of users' traversal
queries (the multi-tenant fan-out the ROADMAP targets).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, Literal, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import faultinject as _fault
from repro.obs import trace as _trace

from .bitmap import (bitmap_plan, diropt_hybrid_plan, diropt_plan,
                     hybrid_plan, multiquery_plan, weighted_bitmap_plan)
from .csr import CSRIndex, build_csr, merged_indptr
from .operators import WORD_LANES, BFSResult, Context, EngineCaps, \
    Pipeline, execute, execute_batch, execute_multiquery
from .recursive import (DIRECTIONS, precursive_plan, rowstore_plan,
                        rowstore_rewrite_plan, trecursive_plan,
                        trecursive_rewrite_plan, weighted_precursive_plan)
from .semiring import WORKLOADS
from .table import ColumnTable, RowTable, payload_names

EngineName = Literal["precursive", "trecursive", "rowstore", "rowstore_index",
                     "bitmap", "hybrid", "trecursive_rewrite",
                     "rowstore_rewrite", "rowstore_index_rewrite",
                     "diropt", "diropt_hybrid", "multiquery"]

ENGINE_NAMES: tuple[str, ...] = (
    "precursive", "trecursive", "rowstore", "rowstore_index", "bitmap",
    "hybrid", "trecursive_rewrite", "rowstore_rewrite",
    "rowstore_index_rewrite", "diropt", "diropt_hybrid")

# the bit-parallel MS-BFS engine is a BATCH engine: one dispatch answers up
# to 32 roots, so it is priced per coalesced batch and only becomes a
# candidate when the planner is handed a lane count (> 1).  It deliberately
# stays OUT of ENGINE_NAMES — the single-root enumeration suites, parity
# loops and EXPLAIN listings iterate that tuple.
MULTIQUERY_ENGINE = "multiquery"

# the direction-optimizing engines (per-level push/pull switch) and their
# push-only counterparts — parity suites assert row-for-row equality along
# these pairs, and the perf gate compares diropt cells against the best
# PUSH_ENGINE cell
DIROPT_ENGINE_NAMES: tuple[str, ...] = ("diropt", "diropt_hybrid")
PUSH_COUNTERPART = {"diropt": "bitmap", "diropt_hybrid": "hybrid"}

Direction = Literal["outbound", "inbound", "both"]


@dataclasses.dataclass(frozen=True)
class RecursiveQuery:
    """One recursive CTE query instance (a paper experiment cell)."""

    engine: EngineName
    max_depth: int
    payload_cols: int                 # the paper's N
    caps: EngineCaps
    dedup: bool = True                # BFS semantics (UNION ALL if False)
    direction: Direction = "outbound"
    workload: str = "reach"           # semiring name ('reach' = boolean BFS)
    weight_col: Optional[str] = None  # edge-weight column (weighted only)
    lanes: int = 1                    # coalesced roots per dispatch
    #   (> 1 only for the bit-parallel `multiquery` engine: the planner
    #   prices that engine per coalesced batch, and the serving layer packs
    #   up to WORD_LANES in-flight roots into one word-sweep dispatch)

    @property
    def out_cols(self) -> tuple[str, ...]:
        return ("id", "from", "to", "name",
                *payload_names(self.payload_cols))


# the engines that can carry the semiring value plane; every other engine
# is skipped by the planner for weighted workloads (with a recorded reason)
WEIGHTED_ENGINE_NAMES: tuple[str, ...] = ("precursive", "bitmap")


# ---------------------------------------------------------------------------
# plan-builder registry: engine name -> RecursiveQuery -> Pipeline
# ---------------------------------------------------------------------------

PLAN_BUILDERS: Dict[str, Callable[[RecursiveQuery], Pipeline]] = {
    "precursive": lambda q: precursive_plan(
        q.caps, q.max_depth, q.out_cols, q.dedup, q.direction),
    "trecursive": lambda q: trecursive_plan(
        q.caps, q.max_depth, q.out_cols, q.dedup, q.direction),
    "rowstore": lambda q: rowstore_plan(
        q.caps, q.max_depth, q.out_cols, q.dedup, use_index=False,
        direction=q.direction),
    "rowstore_index": lambda q: rowstore_plan(
        q.caps, q.max_depth, q.out_cols, q.dedup, use_index=True,
        direction=q.direction),
    "bitmap": lambda q: bitmap_plan(
        q.caps, q.max_depth, q.out_cols, q.direction),
    "hybrid": lambda q: hybrid_plan(
        q.caps, q.max_depth, q.out_cols, direction=q.direction),
    "trecursive_rewrite": lambda q: trecursive_rewrite_plan(
        q.caps, q.max_depth, q.out_cols, q.dedup, q.direction),
    "rowstore_rewrite": lambda q: rowstore_rewrite_plan(
        q.caps, q.max_depth, q.out_cols, q.dedup, use_index=False,
        direction=q.direction),
    "rowstore_index_rewrite": lambda q: rowstore_rewrite_plan(
        q.caps, q.max_depth, q.out_cols, q.dedup, use_index=True,
        direction=q.direction),
    "diropt": lambda q: diropt_plan(
        q.caps, q.max_depth, q.out_cols, q.direction),
    "diropt_hybrid": lambda q: diropt_hybrid_plan(
        q.caps, q.max_depth, q.out_cols, direction=q.direction),
    "multiquery": lambda q: multiquery_plan(
        q.caps, q.max_depth, q.out_cols, q.direction,
        lanes=max(getattr(q, "lanes", 1), 1)),
}


def build_plan(q: RecursiveQuery) -> Pipeline:
    workload = getattr(q, "workload", "reach")
    if workload != "reach":
        if workload not in WORKLOADS:
            raise ValueError(f"unknown workload {workload!r}; "
                             f"known: {WORKLOADS}")
        if q.engine == "precursive":
            return weighted_precursive_plan(q.caps, q.max_depth, q.out_cols,
                                            workload, q.direction)
        if q.engine == "bitmap":
            return weighted_bitmap_plan(q.caps, q.max_depth, q.out_cols,
                                        workload, q.direction)
        raise ValueError(
            f"engine {q.engine!r} has no value plane; weighted workloads "
            f"run on {WEIGHTED_ENGINE_NAMES}")
    try:
        builder = PLAN_BUILDERS[q.engine]
    except KeyError:
        raise ValueError(f"unknown engine {q.engine!r}; "
                         f"known: {ENGINE_NAMES}") from None
    return builder(q)


def positions_available(engine: str) -> bool:
    """The positions contract, derived from the engine's actual pipeline:
    True iff ``BFSResult.positions`` holds real edge positions."""
    q = RecursiveQuery(engine=engine, max_depth=1, payload_cols=0,
                       caps=EngineCaps(1, 1))
    return build_plan(q).carries_positions


@dataclasses.dataclass(frozen=True)
class Dataset:
    """A prepared graph: columnar + row layouts + the join index.

    Direction views are built on first use and cached on the instance.
    The reverse CSR (over ``to``) serves THREE consumers — ``inbound``
    traversal, the pull-mode operators' bottom-up gathers, and the fused
    ``both`` view — so ``direction='both'`` adds only one merged (V+1)
    indptr on top of it: E-scale memory, not the old doubled-2E edge
    view (see :func:`~repro.core.csr.expand_frontier_both`)."""

    table: ColumnTable
    rows: RowTable
    csr: CSRIndex
    num_vertices: int
    rcsr: CSRIndex | None = None           # reverse CSR (over `to`)
    both_indptr: object = None             # (V+1,) merged out+in indptr
    stats_cache: dict | None = None        # direction -> GraphStats
    weights_cache: dict | None = None      # weight_col -> (E,) f32 weights

    @classmethod
    def prepare(cls, table: ColumnTable, num_vertices: int) -> "Dataset":
        return cls(table=table, rows=RowTable.from_column_table(table),
                   csr=build_csr(table.column("from"), num_vertices),
                   num_vertices=num_vertices)

    def ensure_reverse(self) -> None:
        """Build + cache the reverse CSR.  ``inbound``/``both`` call this
        automatically; pull-KERNEL users on an outbound-only dataset opt
        in explicitly (the default XLA pull falls back to a natural-order
        formulation when the reverse CSR is absent, so plain outbound
        traffic never pays the extra O(E log E) build)."""
        if self.rcsr is None:
            object.__setattr__(self, "rcsr", build_csr(
                self.table.column("to"), self.num_vertices))

    def ensure_direction(self, direction: str) -> None:
        if direction not in DIRECTIONS:
            raise ValueError(f"unknown direction {direction!r}")
        if direction in ("inbound", "both"):
            self.ensure_reverse()
        if direction == "both" and self.both_indptr is None:
            object.__setattr__(self, "both_indptr",
                               merged_indptr(self.csr, self.rcsr))

    def edge_weights(self, weight_col: str) -> jax.Array:
        """The (E,) float32 ⊗-weight column in real position order — the
        edge-weight positional column of the weighted workloads.  Converted
        once per column and cached on the instance (same array object every
        call, so jitted dispatches keep hitting their compile cache)."""
        cache = self.weights_cache
        if cache is None:
            cache = {}
            object.__setattr__(self, "weights_cache", cache)
        if weight_col not in cache:
            if weight_col not in self.table.names:
                raise ValueError(f"unknown weight column {weight_col!r}; "
                                 f"table has {self.table.names}")
            col = self.table.column(weight_col)
            if col.ndim != 1:
                raise ValueError(
                    f"weight column {weight_col!r} must be 1-D, "
                    f"got shape {tuple(col.shape)}")
            cache[weight_col] = jnp.asarray(col, jnp.float32)
        return cache[weight_col]

    def context(self, direction: str = "outbound",
                weight_col: Optional[str] = None) -> Context:
        """The direction-resolved join view the operators run against.
        ``weight_col`` attaches the edge-weight positional column (weighted
        workloads; None for all-ones weights is expressed by the operators
        themselves, so reach contexts carry no weight array at all)."""
        self.ensure_direction(direction)
        w = self.edge_weights(weight_col) if weight_col is not None else None
        if direction == "inbound":
            return Context(table=self.table, rows=self.rows, csr=self.rcsr,
                           join_src=self.table.column("to"),
                           join_dst=self.table.column("from"),
                           rcsr=self.csr, edge_weights=w)
        if direction == "both":
            return Context(table=self.table, rows=self.rows, csr=self.csr,
                           join_src=self.table.column("from"),
                           join_dst=self.table.column("to"),
                           rcsr=self.rcsr, both_indptr=self.both_indptr,
                           bidir=True, edge_weights=w)
        return Context(table=self.table, rows=self.rows, csr=self.csr,
                       join_src=self.table.column("from"),
                       join_dst=self.table.column("to"), rcsr=self.rcsr,
                       edge_weights=w)

    def edge_view_bytes(self, direction: str = "outbound") -> int:
        """Bytes of the index arrays one direction's join view ADDS beyond
        the always-built outbound CSR (the benchmark's fused-CSR memory
        audit).  ``both`` must come out E-scale: the reverse CSR (shared
        with ``inbound`` and the pull path) plus ONE merged (V+1) indptr —
        the old doubled view added three 2E arrays on top of the same
        baseline."""
        self.ensure_direction(direction)

        def nbytes(a):
            return int(np.asarray(a).size * 4)

        if direction == "outbound":
            return nbytes(self.csr.perm) + nbytes(self.csr.indptr)
        rev = nbytes(self.rcsr.perm) + nbytes(self.rcsr.indptr)
        if direction == "inbound":
            return rev
        return rev + nbytes(self.both_indptr)

    def stats(self, direction: str = "outbound"):
        """Planner statistics hook: per-direction
        :class:`~repro.planner.stats.GraphStats` (degree histogram, sampled
        frontier-growth profile, density/shape flags), computed once and
        cached on the instance like the direction views."""
        cache = self.stats_cache
        if cache is None:
            cache = {}
            object.__setattr__(self, "stats_cache", cache)
        if direction not in cache:
            from repro.planner.stats import compute_stats
            with _trace.trace_span("stats", direction=direction):
                cache[direction] = compute_stats(self, direction)
        return cache[direction]


def query_context(q: RecursiveQuery, ds: Dataset) -> Context:
    """The join view a query runs against: direction-resolved, with the
    edge-weight column attached for weighted workloads."""
    wc = q.weight_col if getattr(q, "workload", "reach") != "reach" else None
    return ds.context(q.direction, weight_col=wc)


def run_query(q: RecursiveQuery, ds: Dataset, root: int) -> BFSResult:
    """Execute one query through the shared fixed-point driver.

    With a tracer installed (:func:`repro.obs.trace.set_tracer`) the
    dispatch is wrapped in a span and per-level traversal events are
    derived from the result — the traced path synchronizes (tracing is an
    enabled-only cost); the untraced path stays fully async."""
    plan = build_plan(q)
    t = _trace.current_tracer()
    if t is None:
        return execute(plan, query_context(q, ds), jnp.int32(root),
                       ds.num_vertices)
    with t.span("dispatch", engine=q.engine, direction=q.direction,
                lanes=1):
        r = execute(plan, query_context(q, ds), jnp.int32(root),
                    ds.num_vertices)
        jax.block_until_ready(r)
    _trace.emit_level_events(t, r, engine=q.engine)
    return r


def run_query_batch(q: RecursiveQuery, ds: Dataset, roots) -> BFSResult:
    """Execute one query for MANY roots in a single jitted XLA dispatch
    (vmap over the fixed-point driver).  Every array in the returned
    ``BFSResult`` gains a leading ``len(roots)`` batch dimension; row i is
    bit-identical to ``run_query(q, ds, roots[i])``."""
    plan = build_plan(q)
    roots = jnp.asarray(roots, jnp.int32)
    t = _trace.current_tracer()
    if t is None:
        return execute_batch(plan, query_context(q, ds), roots,
                             ds.num_vertices)
    with t.span("dispatch", engine=q.engine, direction=q.direction,
                lanes=int(roots.shape[0])):
        r = execute_batch(plan, query_context(q, ds), roots,
                          ds.num_vertices)
        jax.block_until_ready(r)
    _trace.emit_level_events(t, r, engine=q.engine)
    return r


def run_query_multi(q: RecursiveQuery, ds: Dataset, roots,
                    lane_limits=None) -> BFSResult:
    """Execute one query for up to :data:`WORD_LANES` roots in a single
    BIT-PARALLEL dispatch: every root is a bit lane of one packed dense
    frontier word, and one MS-BFS sweep per level advances all of them
    (``q.engine`` must be ``'multiquery'``).  The returned ``BFSResult``
    carries a leading ``len(roots)`` lane dimension; lane i is row-for-row
    identical to ``run_query`` on ``roots[i]`` through a deferred-emission
    engine.  ``lane_limits`` (optional, per-lane depth caps from the reach
    buckets) must never be below a lane's natural convergence depth —
    callers pass estimates only when they are exact."""
    if len(roots) > WORD_LANES:
        raise ValueError(f"multiquery packs at most {WORD_LANES} roots "
                         f"per dispatch, got {len(roots)}")
    mq = q if q.engine == "multiquery" and q.lanes == len(roots) else \
        dataclasses.replace(q, engine="multiquery", lanes=len(roots))
    plan = build_plan(mq)
    ds.ensure_reverse()          # the word sweep gathers dst-grouped edges
    ds.ensure_direction(mq.direction)
    t = _trace.current_tracer()
    if t is None:
        return execute_multiquery(plan, query_context(mq, ds), roots,
                                  ds.num_vertices, lane_limits)
    with t.span("dispatch", engine="multiquery", direction=mq.direction,
                lanes=int(len(roots))):
        r = execute_multiquery(plan, query_context(mq, ds), roots,
                               ds.num_vertices, lane_limits)
        jax.block_until_ready(r)
    _trace.emit_level_events(t, r, engine="multiquery")
    return r


def result_lane(r: BFSResult, lane: int) -> BFSResult:
    """Slice one lane out of a batched BFSResult."""
    return jax.tree_util.tree_map(lambda a: a[lane], r)


@dataclasses.dataclass(frozen=True)
class BucketTiming:
    """One bucket's measured dispatch, reported by
    :func:`dispatch_buckets` to its observer — the planner's calibration
    feedback loop consumes these.

    ``elapsed_us`` attributes DEVICE time to this bucket: the interval from
    max(this bucket's launch, the previous bucket's completion) to this
    bucket's results being materialized.  Buckets are launched back-to-back
    and executed in order on one stream, so without the max() every
    bucket's wait on its predecessors would be double-counted."""

    index: int                 # position in the buckets sequence
    lanes: int                 # real lanes (len(bucket.indices))
    padded_lanes: int          # dispatched lanes (len(bucket.roots))
    caps: EngineCaps           # the caps the MEASURED dispatch ran with
    retried: bool              # True when the fallback-caps retry ran
    elapsed_us: float
    predicted_caps: Optional[EngineCaps] = None
    #   the caps bucketing PREDICTED for this bucket — when ``retried`` is
    #   True these are the caps that overflowed (the measured dispatch ran
    #   at ``caps`` == the fallback), making the silent 2x-dispatch cliff
    #   visible to observers instead of only to the retry branch
    evicted_lanes: int = 0
    #   lanes evicted to SOLO fallback-caps re-dispatches because only they
    #   overflowed the bucket caps — the rest of the bucket kept its caps
    #   (with coalesced lanes, one pathological root must not force the
    #   whole 32-lane word onto fallback caps)


# process-wide visibility for the overflow-retry path: every retry is a
# hidden 2x-dispatch perf cliff (the bucket ran once at its predicted caps,
# overflowed, and ran again at the fallback caps), so it is counted here,
# surfaced on the BucketTiming, traced, and warned about once per process
# (serving sessions additionally warn once per session and count it in
# their metrics registry)
_overflow_state = {"retries": 0, "warned": False, "lane_evictions": 0}


def overflow_retry_count() -> int:
    """Process-wide count of fallback-caps overflow retries."""
    return _overflow_state["retries"]


def lane_eviction_count() -> int:
    """Process-wide count of lanes evicted to solo fallback re-dispatches
    (per-lane overflow handling — the rest of the bucket kept its caps)."""
    return _overflow_state["lane_evictions"]


def _note_overflow_retry(index: int, predicted: EngineCaps,
                         fallback: EngineCaps, tracer) -> None:
    _overflow_state["retries"] += 1
    if tracer is not None:
        tracer.event("overflow_retry", bucket=index,
                     predicted_caps=[predicted.frontier, predicted.result],
                     fallback_caps=[fallback.frontier, fallback.result])
    if not _overflow_state["warned"]:
        _overflow_state["warned"] = True
        warnings.warn(
            f"bucket {index} overflowed its predicted caps "
            f"(frontier={predicted.frontier}, result={predicted.result}) "
            f"and was re-dispatched at the fallback caps "
            f"(frontier={fallback.frontier}, result={fallback.result}) — "
            "a transparent retry that doubles that bucket's dispatch "
            "cost; consider larger caps or fewer buckets "
            "(warned once per process; see ServingSession.metrics() for "
            "counts)", RuntimeWarning, stacklevel=3)


def _note_lane_eviction(index: int, lanes: Sequence[int],
                        predicted: EngineCaps, fallback: EngineCaps,
                        tracer) -> None:
    _overflow_state["lane_evictions"] += len(lanes)
    if tracer is not None:
        tracer.event("overflow_lane_eviction", bucket=index,
                     lanes=list(lanes),
                     predicted_caps=[predicted.frontier, predicted.result],
                     fallback_caps=[fallback.frontier, fallback.result])


def _evict_bucket(b, lane: int, caps: EngineCaps):
    """A single-lane bucket for one evicted root, dispatched solo at the
    fallback caps (the original bucket keeps its caps for every other
    lane)."""
    indices = (b.indices[lane],)
    roots = (b.roots[lane],)
    if dataclasses.is_dataclass(b):
        try:
            return dataclasses.replace(b, indices=indices, roots=roots,
                                       caps=caps)
        except TypeError:
            pass
    import types
    return types.SimpleNamespace(indices=indices, roots=roots, caps=caps)


class _SkippedLane:
    """Sentinel filling a lane whose bucket was skipped by the deadline
    budget — callers that passed ``deadline_us`` replace it with a
    classified degraded answer; callers that didn't never see it."""

    def __repr__(self) -> str:           # pragma: no cover - debug aid
        return "<skipped lane>"


SKIPPED = _SkippedLane()


@dataclasses.dataclass
class RetryPolicy:
    """THE retry policy: full-bucket overflow retries, per-lane evictions,
    and guard-degraded re-dispatches all spend from this one bounded
    budget, replacing the former ad-hoc one-retry branches.

    ``max_attempts`` counts dispatches per bucket (initial + retries);
    ``growth`` grows caps geometrically toward the fallback on each retry
    (``None`` jumps straight to fallback caps — the historical behavior);
    ``budget`` bounds TOTAL retries across the policy's lifetime (a
    serving session shares one policy across requests).  When the budget
    is exhausted the executor stops re-dispatching and reports the bucket
    in :attr:`DispatchReport.denied_buckets` — the serving layer then
    degrades that answer (truncated rows, flagged) instead of raising
    mid-request."""

    max_attempts: int = 2
    growth: Optional[float] = None
    budget: Optional[int] = None
    spent: int = 0

    def spend(self) -> bool:
        """Consume one retry if the budget allows it."""
        if self.budget is not None and self.spent >= self.budget:
            return False
        self.spent += 1
        return True

    def next_caps(self, attempt: int, current: EngineCaps,
                  fallback: EngineCaps) -> EngineCaps:
        """Caps for retry number ``attempt`` (1-based): geometric growth
        toward the fallback, or straight to it when ``growth`` is None or
        this is the last allowed attempt."""
        if self.growth is None or attempt + 1 >= self.max_attempts:
            return fallback
        return EngineCaps(
            frontier=min(int(current.frontier * self.growth),
                         fallback.frontier),
            result=min(int(current.result * self.growth), fallback.result))


@dataclasses.dataclass
class DispatchReport:
    """What :func:`dispatch_buckets` did beyond returning rows: which
    buckets were skipped (deadline), straggled, or were denied a retry —
    the explicit flags that replace silent blocking/truncation."""

    skipped_buckets: list = dataclasses.field(default_factory=list)
    skipped_lanes: list = dataclasses.field(default_factory=list)
    #   ORIGINAL root-vector indices whose bucket was never launched
    straggler_buckets: list = dataclasses.field(default_factory=list)
    denied_buckets: list = dataclasses.field(default_factory=list)
    #   overflowed buckets the retry budget refused to re-dispatch: their
    #   rows are TRUNCATED at bucket caps (callers must not overflow-check)
    denied_lanes: list = dataclasses.field(default_factory=list)
    retries: int = 0
    evictions: int = 0

    @property
    def truncated(self) -> bool:
        """True iff any lane's answer is incomplete (skipped or denied)."""
        return bool(self.skipped_buckets or self.denied_buckets)


def dispatch_buckets(buckets: Sequence, dispatch: Callable, *,
                     fallback_caps: EngineCaps,
                     finish: Optional[Callable] = None,
                     observer: Optional[Callable] = None,
                     to_host: bool = False,
                     retry: Optional[RetryPolicy] = None,
                     deadline_us: Optional[float] = None,
                     straggler=None,
                     report: Optional[DispatchReport] = None) -> list:
    """THE bucket-dispatch executor: every reach-bucketed execution path
    (:func:`run_query_buckets`, ``PhysicalChoice.run_bucketed``'s kernel
    branch, ``ServingSession._execute``) delegates here, so the shared
    launch -> overflow-retry -> scatter-by-indices shape exists exactly
    once and cannot drift.

    ``dispatch(index, bucket, caps)`` runs one batched dispatch for a
    bucket at the given caps and returns a batched ``BFSResult`` (leading
    lane dimension).  The executor:

    * launches EVERY bucket before touching any result — dispatches are
      async, and the host-side overflow check must not serialize them.
      EXCEPT under a ``deadline_us`` budget: then buckets launch lazily,
      one at a time, and a bucket is SKIPPED (its lanes filled with the
      :data:`SKIPPED` sentinel, recorded on the ``report``) when the
      budget is already exhausted or the straggler monitor's predicted
      wall time (``straggler.expected``) no longer fits the remainder —
      skip-vs-launch is decided BEFORE paying the dispatch cost.  The
      first bucket always launches: a request makes progress, the budget
      only stops FURTHER work;
    * retries on overflow through the :class:`RetryPolicy` (bucket caps
      are predictions; bucketing must never turn a valid query into a
      truncated result).  When overflow is PER LANE and only some real
      lanes overflowed, just those lanes are EVICTED to solo fallback
      re-dispatches and the rest of the bucket keeps its result at bucket
      caps — with coalesced lanes one pathological root must not force
      the whole word onto worst-case caps.  Only a full-bucket (or
      scalar) overflow still re-dispatches the whole bucket.  A policy
      whose budget is exhausted DENIES the retry: the bucket is recorded
      in ``report.denied_buckets`` and its truncated-at-caps rows stand
      (callers degrade the answer instead of raising mid-request);
    * applies the optional ``finish(index, bucket, result)`` hook to the
      batched result (the serving layer dresses per-bucket results here;
      the report is filled for bucket ``i`` before ``finish(i, ...)``
      runs, so the hook can consult it);
    * scatters lanes back to the ORIGINAL root order via each bucket's
      ``indices`` (``to_host=True`` converts each bucket's result to host
      numpy first — one transfer per bucket, lanes become free views);
    * measures per-bucket wall-clock ONCE, consistently, and reports it to
      ``observer(timing)`` as a :class:`BucketTiming` — this is the single
      measurement point the cost-model calibrator trusts.  When a
      ``straggler`` monitor is passed, every measured bucket feeds its
      EMA and buckets exceeding the straggler deadline are recorded in
      ``report.straggler_buckets``.
    """
    buckets = tuple(buckets)
    total = sum(len(b.indices) for b in buckets)
    out: list = [None] * total
    policy = retry if retry is not None else RetryPolicy()
    rep = report if report is not None else DispatchReport()
    # the executor owns bucket-granular tracing: suppress the global
    # tracer around nested dispatches so per-root instrumentation inside
    # run_query_batch cannot serialize the async launch loop, and emit
    # per-bucket spans/events from the one measurement point instead
    tracer = _trace.current_tracer()
    prev_tracer = _trace.set_tracer(None) if tracer is not None else None
    try:
        lazy = deadline_us is not None
        t_start = time.perf_counter()
        launched = []
        if not lazy:
            for i, b in enumerate(buckets):
                t0 = time.perf_counter()
                launched.append((i, b, t0, dispatch(i, b, b.caps)))
        prev_done = None
        timings = []
        for k in range(len(buckets)):
            if lazy:
                i, b = k, buckets[k]
                elapsed_us = (time.perf_counter() - t_start) * 1e6
                predicted_us = (straggler.expected
                                if straggler is not None else 0.0)
                if timings and elapsed_us + predicted_us >= deadline_us:
                    rep.skipped_buckets.append(i)
                    if tracer is not None:
                        tracer.event("deadline_skip", bucket=i,
                                     lanes=len(b.indices),
                                     elapsed_us=elapsed_us,
                                     predicted_us=predicted_us,
                                     deadline_us=deadline_us)
                    for idx in b.indices:
                        rep.skipped_lanes.append(idx)
                        out[idx] = SKIPPED
                    continue
                t0 = time.perf_counter()
                r = dispatch(i, b, b.caps)
            else:
                i, b, t0, r = launched[k]
            if _fault._ACTIVE:
                d = _fault.consume("straggler_sleep")
                if d:
                    time.sleep(float(d))
            retried = False
            evicted: dict = {}
            if b.caps != fallback_caps:
                ov = np.asarray(r.overflow).reshape(-1)
                n_real = len(b.indices)
                real_ov = ov[:n_real] if ov.size >= n_real else \
                    np.broadcast_to(ov, (n_real,))
                if _fault._ACTIVE and _fault.consume("bucket_overflow"):
                    real_ov = np.ones(n_real, dtype=bool)
                if real_ov.any():
                    if n_real == 1 or real_ov.all():
                        caps_now = b.caps
                        attempt = 1
                        while attempt < policy.max_attempts:
                            if not policy.spend():
                                break
                            caps_now = policy.next_caps(
                                attempt, caps_now, fallback_caps)
                            r = dispatch(i, b, caps_now)
                            retried = True
                            rep.retries += 1
                            _note_overflow_retry(i, b.caps, caps_now,
                                                 tracer)
                            ov = np.asarray(r.overflow).reshape(-1)
                            real_ov = ov[:n_real] if ov.size >= n_real \
                                else np.broadcast_to(ov, (n_real,))
                            attempt += 1
                            if not real_ov.any() \
                                    or caps_now == fallback_caps:
                                break
                        if real_ov.any() and not retried:
                            rep.denied_buckets.append(i)
                            rep.denied_lanes.extend(b.indices)
                    else:
                        # per-lane eviction: solo fallback re-dispatch for
                        # just the overflowing lanes
                        hit = np.nonzero(real_ov)[0].tolist()
                        done = []
                        for lane in hit:
                            if not policy.spend():
                                rep.denied_lanes.append(b.indices[lane])
                                continue
                            sb = _evict_bucket(b, lane, fallback_caps)
                            evicted[lane] = (sb, dispatch(i, sb,
                                                          fallback_caps))
                            done.append(lane)
                            rep.evictions += 1
                        if done:
                            _note_lane_eviction(i, done, b.caps,
                                                fallback_caps, tracer)
                        if len(done) < len(hit):
                            rep.denied_buckets.append(i)
            if finish is not None:
                r = finish(i, b, r)
                evicted = {lane: (sb, finish(i, sb, rr))
                           for lane, (sb, rr) in evicted.items()}
            if to_host:
                # one device->host transfer per bucket (also synchronizes)
                if tracer is not None:
                    with tracer.span("transfer", bucket=i,
                                     lanes=len(b.indices)):
                        r = jax.tree_util.tree_map(np.asarray, r)
                else:
                    r = jax.tree_util.tree_map(np.asarray, r)
                evicted = {lane: (sb, jax.tree_util.tree_map(np.asarray,
                                                             rr))
                           for lane, (sb, rr) in evicted.items()}
            elif observer is not None or tracer is not None:
                jax.block_until_ready(r)  # timing needs a real completion
                for _, rr in evicted.values():
                    jax.block_until_ready(rr)
            t_done = time.perf_counter()
            for lane, idx in enumerate(b.indices):
                if lane in evicted:
                    out[idx] = jax.tree_util.tree_map(
                        lambda a: a[0], evicted[lane][1])
                else:
                    out[idx] = jax.tree_util.tree_map(
                        lambda a, lane=lane: a[lane], r)
            timing = BucketTiming(
                index=i, lanes=len(b.indices), padded_lanes=len(b.roots),
                caps=(fallback_caps if retried else b.caps),
                retried=retried,
                elapsed_us=(t_done - (t0 if prev_done is None
                                      else max(t0, prev_done))) * 1e6,
                predicted_caps=b.caps, evicted_lanes=len(evicted))
            if straggler is not None and straggler.record(timing.elapsed_us):
                rep.straggler_buckets.append(i)
                if tracer is not None:
                    tracer.event("straggler", bucket=i,
                                 elapsed_us=timing.elapsed_us,
                                 expected_us=straggler.expected)
            if observer is not None:
                observer(timing)
            timings.append((timing, r))
            prev_done = t_done
    finally:
        if tracer is not None:
            _trace.set_tracer(prev_tracer)
    if tracer is not None:
        # spans + level events AFTER the measurement loop, so enabled
        # tracing never sits inside a timed interval the calibrator trusts
        for timing, r in timings:
            with tracer.span("dispatch", bucket=timing.index,
                             lanes=timing.lanes,
                             padded_lanes=timing.padded_lanes,
                             retried=timing.retried,
                             elapsed_us=timing.elapsed_us):
                _trace.emit_level_events(tracer, r, bucket=timing.index)
    if any(x is None for x in out):
        raise ValueError("buckets do not cover lanes 0..%d exactly"
                         % (total - 1))
    return out  # deadline-skipped lanes hold the SKIPPED sentinel


def run_query_buckets(q: RecursiveQuery, ds: Dataset, buckets
                      ) -> list[BFSResult]:
    """Reach-bucketed serving execution: one jitted batched dispatch PER
    BUCKET, each with that bucket's (smaller) ``EngineCaps``, instead of one
    worst-case lockstep dispatch over the whole root vector.

    ``buckets`` is a sequence of bucket objects (see
    :func:`repro.planner.optimize.bucket_roots`) carrying ``roots``,
    ``indices`` (lanes in the original root vector) and ``caps``.  Results
    come back PER ROOT, in the original order; each entry is bit-identical
    to ``run_query(q, ds, root)`` on its root.  Launch ordering, the
    global-caps overflow retry, and the scatter live in
    :func:`dispatch_buckets` (the one shared executor)."""
    def _dispatch(i, b, caps):
        qb = dataclasses.replace(q, caps=caps) if caps != q.caps else q
        return run_query_batch(qb, ds, b.roots)

    return dispatch_buckets(buckets, _dispatch, fallback_caps=q.caps)


def plan_and_run(sql_or_ast, ds: Dataset, roots=None, **kwargs) -> BFSResult:
    """Answer a recursive query WITHOUT an engine name: parse the minimal
    ``WITH RECURSIVE`` dialect (or take a planner AST / LogicalQuery),
    price every legal engine against ``ds.stats()``, and execute the
    cheapest through the same ``PLAN_BUILDERS`` path ``run_query`` uses.

    ``roots`` is one root (scalar) or a sequence (one vmap-batched
    dispatch).  See :func:`repro.planner.plan_and_run` for keyword options
    (``caps``, ``include_kernel``, ``default_max_depth``)."""
    from repro.planner import plan_and_run as _impl
    return _impl(sql_or_ast, ds, roots, **kwargs)


def explain(sql_or_ast, ds: Dataset, **kwargs) -> str:
    """EXPLAIN the query: the ranked candidate engines with per-operator
    estimated rows/bytes (see :mod:`repro.planner.explain`)."""
    from repro.planner import explain as _impl
    return _impl(sql_or_ast, ds, **kwargs)


def explain_analyze(sql_or_ast, ds: Dataset, **kwargs) -> dict:
    """EXPLAIN ANALYZE: plan, EXECUTE, and reconcile predicted vs. actual
    per-operator rows/bytes and per-level push/pull directions (see
    :func:`repro.planner.explain.explain_analyze`)."""
    from repro.planner import explain_analyze as _impl
    return _impl(sql_or_ast, ds, **kwargs)


def plan_repr(engine: str, max_depth: int, payload_cols: int,
              root: int = 0) -> str:
    """Volcano-tree rendering DERIVED from the engine's actual operator
    composition (not a hand-written template)."""
    q = RecursiveQuery(engine=engine, max_depth=max_depth,
                       payload_cols=payload_cols,
                       caps=EngineCaps(frontier=0, result=0))
    return build_plan(q).render(root=root)
