"""Columnar tables — the storage layer of the position-enabled engine.

A ``ColumnTable`` is the JAX analogue of a PosDB table: a dict of equal-length
device arrays, one per column.  Positions (row ids) index into every column.

``RowTable`` is the row-store emulation used as the PostgreSQL baseline: all
columns are interleaved into a single row-major ``(rows, width)`` array so that
touching *any* attribute of a row drags the full row through the memory
system — the defining cost asymmetry the paper exploits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ColumnTable", "RowTable", "payload_names"]


def payload_names(n: int) -> list[str]:
    """Column names for the paper's N auxiliary payload columns."""
    return [f"column{i + 1}" for i in range(n)]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ColumnTable:
    """A columnar table: name -> (num_rows,) or (num_rows, k) array.

    All columns share the same leading dimension.  Gathers go through
    :meth:`take` which masks out-of-range positions (the static-shape padding
    convention used throughout the engine: padded position slots hold
    ``num_rows`` and gather a zero row).
    """

    columns: Dict[str, jax.Array]

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(dict(zip(names, children)))

    # -- construction ----------------------------------------------------
    @classmethod
    def from_numpy(cls, cols: Mapping[str, np.ndarray]) -> "ColumnTable":
        return cls({k: jnp.asarray(v) for k, v in cols.items()})

    # -- basic properties ------------------------------------------------
    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self.columns))

    def column(self, name: str) -> jax.Array:
        return self.columns[name]

    def select(self, names: Sequence[str]) -> "ColumnTable":
        return ColumnTable({n: self.columns[n] for n in names})

    # -- positional access (the late-materialization primitive) -----------
    def take(self, positions: jax.Array, names: Sequence[str] | None = None
             ) -> Dict[str, jax.Array]:
        """Gather ``positions`` from the requested columns.

        Out-of-range positions (the padding sentinel) yield zeros, so callers
        can carry fixed-capacity position buffers without branching.
        """
        names = self.names if names is None else tuple(names)
        n = self.num_rows
        safe = jnp.minimum(positions, n - 1)
        valid = positions < n
        out = {}
        for name in names:
            col = self.columns[name]
            g = jnp.take(col, safe, axis=0)
            mask = valid.reshape(valid.shape + (1,) * (g.ndim - valid.ndim))
            out[name] = jnp.where(mask, g, jnp.zeros((), g.dtype))
        return out

    def width_bytes(self, names: Sequence[str] | None = None) -> int:
        names = self.names if names is None else tuple(names)
        total = 0
        for name in names:
            col = self.columns[name]
            per_row = int(np.prod(col.shape[1:])) if col.ndim > 1 else 1
            total += per_row * col.dtype.itemsize
        return total


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RowTable:
    """Row-store emulation: one interleaved row-major ``(rows, width)`` array.

    Column access slices with stride ``width`` — on real hardware every
    element read drags its whole row's cache lines along, reproducing the
    row-store penalty the paper measures against PostgreSQL.  Row gathers
    read the full width and then project, exactly like a heap-page read.
    """

    data: jax.Array                      # (rows, width) float32
    layout: tuple[str, ...]              # column name per slot

    def tree_flatten(self):
        return (self.data,), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(children[0], layout)

    @classmethod
    def from_column_table(cls, table: ColumnTable) -> "RowTable":
        cols, layout = [], []
        for name in table.names:
            col = table.columns[name]
            if col.ndim == 1:
                cols.append(col.astype(jnp.float32)[:, None])
                layout.append(name)
            else:
                for j in range(col.shape[1]):
                    cols.append(col[:, j].astype(jnp.float32)[:, None])
                    layout.append(f"{name}.{j}")
        return cls(jnp.concatenate(cols, axis=1), tuple(layout))

    @property
    def num_rows(self) -> int:
        return self.data.shape[0]

    @property
    def width(self) -> int:
        return self.data.shape[1]

    def slot(self, name: str) -> int:
        return self.layout.index(name)

    def column(self, name: str) -> jax.Array:
        """Full-column read.  Strided over rows — the row-store scan cost."""
        return self.data[:, self.slot(name)]

    def take_rows(self, positions: jax.Array) -> jax.Array:
        """Gather whole rows (the heap-page read), masking padding slots."""
        n = self.num_rows
        safe = jnp.minimum(positions, n - 1)
        rows = jnp.take(self.data, safe, axis=0)
        return jnp.where((positions < n)[:, None], rows, 0.0)

    def project(self, rows: jax.Array, names: Sequence[str]) -> Dict[str, jax.Array]:
        """Project columns back out of gathered full rows; multi-slot
        (vector) columns are reassembled from their interleaved slots."""
        out = {}
        for n in names:
            if n in self.layout:
                out[n] = rows[:, self.slot(n)]
            else:
                slots = [i for i, nm in enumerate(self.layout)
                         if nm.startswith(n + ".")]
                if not slots:
                    raise KeyError(n)
                out[n] = rows[:, jnp.asarray(slots)]
        return out
