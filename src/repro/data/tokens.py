"""Synthetic LM token pipeline.

Stateless and seeded: batch ``i`` is a pure function of (seed, step), so a
restarted/elastically re-sharded job resumes the stream exactly by replaying
(seed, step) — the fault-tolerance contract used by launch/train.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def lm_batch(seed: int, step: int, batch: int, seq_len: int, vocab: int
             ) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.PCG64DXSM([seed, step]))
    # Zipfian-ish token draw (realistic skew, cheap to generate)
    z = rng.zipf(1.3, size=(batch, seq_len + 1))
    tok = (z % vocab).astype(np.int32)
    return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


def lm_batch_on_device(key: jax.Array, batch: int, seq_len: int, vocab: int
                       ) -> dict[str, jax.Array]:
    tok = jax.random.randint(key, (batch, seq_len + 1), 0, vocab, jnp.int32)
    return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}
