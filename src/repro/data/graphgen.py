"""Synthetic graph generators for the GNN architectures.

Real datasets (Cora, Reddit, ogbn-products) are not downloadable in this
environment; we generate graphs with matching statistics (node/edge counts,
degree distribution) for smoke tests and benchmarks, and use the exact
published shapes via ShapeDtypeStructs for the dry-run.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class GraphData(NamedTuple):
    src: np.ndarray          # (E,) int32
    dst: np.ndarray          # (E,) int32
    feats: np.ndarray        # (V, F) float32
    labels: np.ndarray       # (V,) int32
    num_vertices: int
    num_classes: int


def rmat_edges(num_vertices: int, num_edges: int, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19
               ) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT power-law generator (Chakrabarti et al.) — vectorized."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(2, num_vertices))))
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    for _ in range(scale):
        r = rng.random(num_edges)
        src = src * 2 + (r >= a + b)
        dst = dst * 2 + (((r >= a) & (r < a + b)) | (r >= a + b + c))
    src = (src % num_vertices).astype(np.int32)
    dst = (dst % num_vertices).astype(np.int32)
    return src, dst


def make_graph(num_vertices: int, num_edges: int, d_feat: int,
               num_classes: int = 16, seed: int = 0,
               undirected: bool = True) -> GraphData:
    src, dst = rmat_edges(num_vertices, num_edges // (2 if undirected else 1),
                          seed)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    rng = np.random.default_rng(seed + 1)
    feats = rng.standard_normal((num_vertices, d_feat)).astype(np.float32)
    labels = rng.integers(0, num_classes, num_vertices).astype(np.int32)
    return GraphData(src, dst, feats, labels, num_vertices, num_classes)


def make_molecule_batch(batch: int, nodes_per_graph: int,
                        edges_per_graph: int, d_feat: int, seed: int = 0
                        ) -> GraphData:
    """Batched small graphs (the `molecule` shape): disjoint union with a
    graph-id segment structure encoded by node offsets."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for g in range(batch):
        s = rng.integers(0, nodes_per_graph, edges_per_graph)
        d = rng.integers(0, nodes_per_graph, edges_per_graph)
        srcs.append(s + g * nodes_per_graph)
        dsts.append(d + g * nodes_per_graph)
    v = batch * nodes_per_graph
    feats = rng.standard_normal((v, d_feat)).astype(np.float32)
    labels = rng.integers(0, 2, batch).astype(np.int32)
    return GraphData(np.concatenate(srcs).astype(np.int32),
                     np.concatenate(dsts).astype(np.int32),
                     feats, labels, v, 2)
