from . import treegen, graphgen, tokens, recsys_stream  # noqa: F401
