"""Criteo-like synthetic stream for the DeepFM architecture.

39 fields (13 numeric + 26 categorical with heavy-tailed vocabularies).
Categorical ids ARE positions into the embedding tables — the recsys
workload is the framework's purest instance of the paper's positional /
late-materialization discipline.
"""
from __future__ import annotations

import numpy as np

N_DENSE = 13
N_SPARSE = 26

# Published Criteo-1TB per-field cardinalities (rounded), heavy-tailed.
CRITEO_VOCABS = [
    7912889, 33823, 17139, 7339, 20046, 4, 7105, 1382, 63, 5554114,
    582469, 245828, 11, 2209, 10667, 104, 4, 968, 15, 8165896,
    2675940, 7156453, 302516, 12022, 97, 35,
]


def vocab_sizes(scale: float = 1.0) -> list[int]:
    return [max(4, int(v * scale)) for v in CRITEO_VOCABS]


def recsys_batch(seed: int, step: int, batch: int,
                 vocabs: list[int] | None = None) -> dict[str, np.ndarray]:
    vocabs = vocabs or vocab_sizes()
    rng = np.random.default_rng(np.random.PCG64DXSM([seed, step, 7]))
    dense = rng.standard_normal((batch, N_DENSE)).astype(np.float32)
    sparse = np.stack(
        [(rng.zipf(1.2, batch) % v).astype(np.int32) for v in vocabs], axis=1)
    label = (rng.random(batch) < 0.25).astype(np.float32)
    return {"dense": dense, "sparse": sparse, "label": label}
