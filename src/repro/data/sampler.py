"""Neighbor sampler — the paper's PRecursive engine applied to GNN training.

The GraphSAGE fan-out sampler is literally a capacity-bounded BFS over
positions: per hop it expands node *positions* through the CSR index
(uniformly subsampling each vertex's CSR range to the fan-out) and only at
the very end materializes features for the sampled nodes — the engine's
late-materialization discipline verbatim.

Fully jit-compatible (static fan-outs); runs on device so the sampler can be
fused into the train step for the dry-run.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.csr import CSRIndex


@functools.partial(jax.jit, static_argnames=("fanouts",))
def sample_block(key: jax.Array, csr: CSRIndex, dst_of_edge: jax.Array,
                 seeds: jax.Array, fanouts: tuple[int, ...]):
    """seeds (B,) -> list of per-hop node-id arrays [seeds, hop1, hop2, ...]
    (hop l has B * prod(fanouts[:l]) entries; missing neighbors repeat via
    modular indexing, the standard with-replacement fallback)."""
    layers = [seeds]
    cur = seeds
    for li, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        n = cur.shape[0]
        v = jnp.clip(cur, 0, csr.num_vertices - 1)
        start = csr.indptr[v]                        # (n,)
        deg = csr.indptr[v + 1] - start
        r = jax.random.randint(sub, (n, f), 0, 1 << 30)
        off = r % jnp.maximum(deg, 1)[:, None]
        epos = csr.perm[jnp.minimum(start[:, None] + off,
                                    csr.num_edges - 1)]
        nbr = dst_of_edge[epos]                      # (n, f)
        # isolated vertices sample themselves (self-loop fallback)
        nbr = jnp.where((deg > 0)[:, None], nbr, cur[:, None])
        cur = nbr.reshape(-1)
        layers.append(cur)
    return layers


def gather_block_features(feats: jax.Array, layers: Sequence[jax.Array]):
    """The ONE late materialization: features for every sampled layer,
    deepest first (what ``sage_block_forward`` consumes)."""
    return [jnp.take(feats, l, axis=0) for l in reversed(layers)]
