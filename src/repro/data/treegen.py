"""Tree/graph dataset generator mirroring the paper's experiment setup.

The paper stores a generated tree as an edge list with columns
``id, from, to, name`` plus N auxiliary payload columns (§5.1).  ``id`` is a
*permutation* of row positions (so the Exp-3 top-level join is a real join,
not a no-op), ``name`` a 15-char varchar and payloads 20-char varchars —
emulated as fixed-width numeric columns of equivalent byte width.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.table import ColumnTable, RowTable, payload_names


class TreeSpec(NamedTuple):
    num_vertices: int
    height: int            # tree height (max BFS depth from root)
    payload_cols: int      # the paper's N
    seed: int = 0

    @property
    def num_edges(self) -> int:
        return self.num_vertices - 1


def random_tree_edges(spec: TreeSpec) -> tuple[np.ndarray, np.ndarray]:
    """Random tree with controlled height: vertices 1..V-1 attach to a parent
    drawn from the previous level (level widths split geometrically so the
    tree has exactly ``height`` levels when feasible)."""
    rng = np.random.default_rng(spec.seed)
    v, h = spec.num_vertices, max(1, spec.height)
    # carve v-1 non-root vertices into h level buckets (each >= 1)
    remaining = v - 1
    widths = []
    for lvl in range(h):
        levels_left = h - lvl
        if levels_left == 1:
            w = remaining
        else:
            lo = 1
            hi = max(1, remaining - (levels_left - 1))
            grow = min(hi, max(lo, int(remaining / levels_left * 1.5)))
            w = int(rng.integers(lo, grow + 1))
        widths.append(w)
        remaining -= w
    labels = np.concatenate([np.full(w, i) for i, w in enumerate(widths)])
    vid = np.arange(1, v)
    level_of = np.concatenate([[0], labels + 1])
    src = np.empty(v - 1, dtype=np.int64)
    prev = np.array([0])
    start = 1
    for w in widths:
        cur = vid[start - 1: start - 1 + w]
        src[start - 1: start - 1 + w] = rng.choice(prev, size=w)
        prev = cur
        start += w
    dst = vid
    del level_of
    return src.astype(np.int32), dst.astype(np.int32)


def make_edge_table(spec: TreeSpec) -> ColumnTable:
    rng = np.random.default_rng(spec.seed + 1)
    src, dst = random_tree_edges(spec)
    e = src.shape[0]
    ids = rng.permutation(e).astype(np.int32)
    cols = {
        "id": ids,
        "from": src,
        "to": dst,
        # name varchar(15) ~ 16 bytes -> 4 float32 slots
        "name": rng.standard_normal((e, 4)).astype(np.float32),
    }
    for pname in payload_names(spec.payload_cols):
        # varchar(20) ~ 20 bytes -> 5 float32 slots
        cols[pname] = rng.standard_normal((e, 5)).astype(np.float32)
    return ColumnTable.from_numpy(cols)


def make_row_table(table: ColumnTable) -> RowTable:
    return RowTable.from_column_table(table)


def bfs_reference(src: np.ndarray, dst: np.ndarray, root: int,
                  max_depth: int, num_vertices: int) -> list[set[int]]:
    """Pure-python oracle: per-level sets of emitted *edge positions* under
    BFS semantics (visited-vertex dedup), level 0 = edges out of root."""
    adj: list[list[int]] = [[] for _ in range(num_vertices)]
    for i, s in enumerate(src):
        adj[int(s)].append(i)
    visited = {int(root)}
    frontier = [int(root)]
    levels: list[set[int]] = []
    for _ in range(max_depth + 1):
        epos = [i for v in frontier for i in adj[v]]
        nxt = []
        emitted = set()
        for i in epos:
            t = int(dst[i])
            emitted.add(i)
            if t not in visited:
                visited.add(t)
                nxt.append(t)
        levels.append(emitted)
        frontier = nxt
        if not frontier:
            break
    return levels
