"""Oracle for the BFS frontier expansion — the PRecursive hot loop.

The reference is the engine's own vectorized expansion
(:func:`repro.core.csr.expand_frontier`), re-exported so the kernel test
sweeps compare against exactly what the production engine computes.
"""
from __future__ import annotations

from repro.core.csr import CSRIndex, expand_frontier


def frontier_expand_ref(csr: CSRIndex, targets, valid, capacity: int):
    return expand_frontier(csr, targets, valid, capacity)
