"""Jitted frontier-expansion wrapper with the engine's contract.

``frontier_expand_fused(csr, targets, valid, capacity)`` is drop-in for
:func:`repro.core.csr.expand_frontier` (same signature is accepted by
``precursive_bfs(expand_fn=...)``): phase A (rank inversion) runs as the
Pallas ``expand_index`` kernel, phase B (the perm gather) reuses the
``late_gather`` kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.csr import CSRIndex, csr_degrees
from repro.kernels.late_gather import late_gather_pallas

from .frontier_expand import expand_index_pallas


def frontier_expand_fused(csr: CSRIndex, targets: jax.Array,
                          valid: jax.Array, capacity: int,
                          *, interpret: bool = True
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    deg = csr_degrees(csr, targets, valid)
    ends = jnp.cumsum(deg, dtype=jnp.int32)
    total = ends[-1]
    v = jnp.clip(targets, 0, csr.num_vertices - 1)
    estart = jnp.where(deg > 0, csr.indptr[v], 0)

    gidx = expand_index_pallas(ends, estart, deg, csr.num_edges,
                               capacity=capacity, interpret=interpret)
    perm2d = csr.perm[:, None]
    epos = late_gather_pallas(perm2d, gidx, interpret=interpret)[:, 0]
    # sentinel rows gather as 0 -> restore the engine's sentinel value
    epos = jnp.where(gidx >= csr.num_edges, csr.num_edges, epos)
    return epos.astype(jnp.int32), jnp.minimum(total, capacity), \
        total > capacity


def make_expand_fn(interpret: bool = True):
    """Engine plug-in: ``precursive_bfs(..., expand_fn=make_expand_fn())``."""
    return functools.partial(frontier_expand_fused, interpret=interpret)
