from .ops import frontier_expand_fused, make_expand_fn   # noqa: F401
from .frontier_expand import expand_index_pallas          # noqa: F401
from .ref import frontier_expand_ref                      # noqa: F401
