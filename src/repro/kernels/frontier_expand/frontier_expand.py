"""Pallas TPU kernels: CSR frontier expansion (positions -> positions).

One BFS level of the paper's PRecursive operator: every frontier target
vertex emits the contiguous CSR range of its out-edges.  Two phases, both
VMEM-tiled:

* **Phase A (`expand_index`)** — rank inversion.  For each output slot ``j``
  find which frontier slot produced it (``srcslot = #{ends <= j}``) and the
  edge offset within that vertex's CSR range.  The frontier-sized arrays
  (cumulative ends, CSR range starts) live wholly in VMEM; the search is a
  *chunked compare-count* (no dynamic VMEM gather — TPU-safe) followed by a
  one-hot masked-sum select, which lowers onto the VPU as dense compares.
* **Phase B** — the positional gather ``perm[gidx]`` reusing the
  ``late_gather`` machinery (scalar-prefetched indices drive the BlockSpec
  index_map, so only reached CSR slots are DMA'd).

Output slots beyond the level's total carry the sentinel ``num_edges``
(gathers mask them to zero downstream, per the engine convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CHUNK = 512     # frontier chunk per compare-count step


def _expand_index_kernel(ends_ref, estart_ref, deg_ref, out_ref,
                         *, block_c: int, frontier: int, num_edges: int):
    jb = pl.program_id(0)
    j = jb * block_c + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
    ends = ends_ref[...]          # (1, F) cumulative level offsets
    estart = estart_ref[...]      # (1, F) CSR range starts (indptr[target])
    deg = deg_ref[...]            # (1, F) per-target degrees
    total = ends[0, frontier - 1]

    nchunk = (frontier + _CHUNK - 1) // _CHUNK

    # chunked compare-count + one-hot select, fully vectorized:
    srcslot = jnp.zeros((1, block_c), jnp.int32)
    start_sel = jnp.zeros((1, block_c), jnp.int32)
    end_sel = jnp.zeros((1, block_c), jnp.int32)
    deg_sel = jnp.zeros((1, block_c), jnp.int32)

    def chunk_body(c, srcslot):
        c0 = c * _CHUNK
        ends_c = jax.lax.dynamic_slice(ends, (0, c0), (1, _CHUNK))
        # rank: #{ends <= j} over this chunk  -> (1, block_c)
        le = (ends_c[0, :][None, :, None] <= j[0, :][None, None, :])
        cnt = jnp.sum(le.astype(jnp.int32), axis=1)
        return srcslot + cnt

    srcslot = jax.lax.fori_loop(0, nchunk, chunk_body, srcslot)
    srcslot = jnp.minimum(srcslot, frontier - 1)

    def sel_body(c, carry):
        start_sel, end_sel, deg_sel = carry
        c0 = c * _CHUNK
        start_c = jax.lax.dynamic_slice(estart, (0, c0), (1, _CHUNK))
        end_c = jax.lax.dynamic_slice(ends, (0, c0), (1, _CHUNK))
        deg_c = jax.lax.dynamic_slice(deg, (0, c0), (1, _CHUNK))
        onehot = (srcslot[0, :][None, :, None] ==
                  (jax.lax.broadcasted_iota(jnp.int32, (1, 1, _CHUNK), 2) + c0))
        pick = lambda v: jnp.sum(
            jnp.where(onehot, v[0, :][None, None, :], 0), axis=2)
        return (start_sel + pick(start_c), end_sel + pick(end_c),
                deg_sel + pick(deg_c))

    start_sel, end_sel, deg_sel = jax.lax.fori_loop(
        0, nchunk, sel_body, (start_sel, end_sel, deg_sel))

    within = j - (end_sel - deg_sel)
    gidx = start_sel + within
    live = j < total
    out_ref[...] = jnp.where(live, gidx, num_edges)


@functools.partial(jax.jit, static_argnames=("num_edges", "capacity",
                                             "block_c", "interpret"))
def expand_index_pallas(ends: jax.Array, estart: jax.Array, deg: jax.Array,
                        num_edges: int, *, capacity: int, block_c: int = 256,
                        interpret: bool = True) -> jax.Array:
    """Phase A: (F,) cumulative ends / CSR starts / degrees -> (capacity,)
    positions *into perm* (gidx), sentinel-padded."""
    f = ends.shape[0]
    pad_f = (-f) % _CHUNK
    big = jnp.iinfo(jnp.int32).max
    ends_p = jnp.pad(ends, (0, pad_f), constant_values=big)[None, :]
    estart_p = jnp.pad(estart, (0, pad_f))[None, :]
    deg_p = jnp.pad(deg, (0, pad_f))[None, :]
    fp = f + pad_f

    pad_c = (-capacity) % block_c
    cp = capacity + pad_c

    out = pl.pallas_call(
        functools.partial(_expand_index_kernel, block_c=block_c,
                          frontier=f, num_edges=num_edges),
        grid=(cp // block_c,),
        in_specs=[pl.BlockSpec((1, fp), lambda jb: (0, 0)),
                  pl.BlockSpec((1, fp), lambda jb: (0, 0)),
                  pl.BlockSpec((1, fp), lambda jb: (0, 0))],
        out_specs=pl.BlockSpec((1, block_c), lambda jb: (0, jb)),
        out_shape=jax.ShapeDtypeStruct((1, cp), jnp.int32),
        interpret=interpret,
    )(ends_p, estart_p, deg_p)
    return out[0, :capacity]
