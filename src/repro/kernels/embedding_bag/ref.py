"""Oracle for embedding-bag (ragged gather + segment-sum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, indices: jax.Array,
                      segment_ids: jax.Array, num_bags: int,
                      weights: jax.Array | None = None) -> jax.Array:
    """out[b] = sum_{i: seg[i]=b} w[i] * table[idx[i]].

    table (R, D); indices/segment_ids (I,) int32, seg non-decreasing;
    indices >= R are treated as padding (contribute zero).
    """
    r = table.shape[0]
    rows = jnp.take(table, jnp.minimum(indices, r - 1), axis=0)
    rows = jnp.where((indices < r)[:, None], rows, 0.0)
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
