from .ops import embedding_bag, fixed_hot_lookup       # noqa: F401
from .embedding_bag import embedding_bag_pallas        # noqa: F401
from .ref import embedding_bag_ref                     # noqa: F401
