"""Pallas TPU kernel: EmbeddingBag — ragged gather + segment reduce.

JAX has no native ``nn.EmbeddingBag``; this kernel IS the framework's one
(required for the recsys architecture, reused by GNN mean-aggregation).

It is the paper's positional discipline on the embedding path: categorical
ids are *positions* into a huge table; only hit rows cross HBM->VMEM.  The
scalar-prefetched ``indices`` drive the table BlockSpec (one row DMA per
grid step) and the scalar-prefetched ``segment_ids`` drive the *output*
BlockSpec, so consecutive grid steps of the same bag accumulate in the VMEM
output block without round-tripping to HBM.

Contract (enforced/arranged by ops.py):
  * ``segment_ids`` non-decreasing (bags contiguous) — gives consecutive
    output-block revisits, the only accumulation pattern TPU Pallas allows;
  * every bag non-empty (ops pads empty bags with a sentinel index >= R,
    which gathers a zero row);
  * weights are an ordinary VMEM operand blocked (1,) per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, seg_ref, tab_ref, w_ref, out_ref, *, num_rows: int):
    i = pl.program_id(0)
    first = (i == 0) | (seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])
    valid = idx_ref[i] < num_rows
    row = tab_ref[...] * w_ref[0]
    row = jnp.where(valid, row, jnp.zeros((), row.dtype))

    @pl.when(first)
    def _init():
        out_ref[...] = row

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[...] += row


@functools.partial(jax.jit, static_argnames=("num_bags", "interpret"))
def embedding_bag_pallas(table: jax.Array, indices: jax.Array,
                         segment_ids: jax.Array, num_bags: int,
                         weights: jax.Array | None = None,
                         *, interpret: bool = True) -> jax.Array:
    r, d = table.shape
    i_n = indices.shape[0]
    if weights is None:
        weights = jnp.ones((i_n,), table.dtype)
    pad_d = (-d) % 128
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    dp = d + pad_d

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(i_n,),
        in_specs=[
            pl.BlockSpec((1, dp),
                         lambda i, idx_ref, seg_ref:
                         (jnp.minimum(idx_ref[i], r - 1), 0)),
            pl.BlockSpec((1,), lambda i, idx_ref, seg_ref: (i,)),
        ],
        out_specs=pl.BlockSpec((1, dp),
                               lambda i, idx_ref, seg_ref:
                               (jnp.minimum(seg_ref[i], num_bags - 1), 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, num_rows=r),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((num_bags, dp), table.dtype),
        interpret=interpret,
    )(indices, segment_ids, table, weights)
    return out[:, :d]
