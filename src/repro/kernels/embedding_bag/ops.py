"""Jitted EmbeddingBag wrapper: normalizes ragged input to the kernel
contract (sorted segments, no empty bags) and exposes fixed-hotness and
per-field conveniences used by the recsys/GNN models."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .embedding_bag import embedding_bag_pallas
from .ref import embedding_bag_ref


def embedding_bag(table: jax.Array, indices: jax.Array,
                  segment_ids: jax.Array, num_bags: int,
                  weights: jax.Array | None = None,
                  *, combiner: str = "sum", use_pallas: bool = False,
                  interpret: bool = True) -> jax.Array:
    """General ragged bag lookup.  ``segment_ids`` need not be sorted and
    bags may be empty; normalization happens here, not in the kernel."""
    if use_pallas:
        order = jnp.argsort(segment_ids, stable=True)
        idx_s = indices[order]
        seg_s = segment_ids[order]
        w_s = None if weights is None else weights[order]
        # guarantee every bag visited: append one sentinel index per bag
        r = table.shape[0]
        pad_idx = jnp.full((num_bags,), r, jnp.int32)
        pad_seg = jnp.arange(num_bags, dtype=jnp.int32)
        idx2 = jnp.concatenate([idx_s, pad_idx])
        seg2 = jnp.concatenate([seg_s, pad_seg])
        order2 = jnp.argsort(seg2, stable=True)
        w2 = None if w_s is None else jnp.concatenate(
            [w_s, jnp.zeros((num_bags,), table.dtype)])[order2]
        out = embedding_bag_pallas(table, idx2[order2], seg2[order2],
                                   num_bags, w2, interpret=interpret)
    else:
        out = embedding_bag_ref(table, indices, segment_ids, num_bags,
                                weights)
    if combiner == "mean":
        sizes = jax.ops.segment_sum(
            (indices < table.shape[0]).astype(table.dtype), segment_ids,
            num_segments=num_bags)
        out = out / jnp.maximum(sizes, 1.0)[:, None]
    return out


def fixed_hot_lookup(table: jax.Array, ids: jax.Array,
                     *, use_pallas: bool = False, interpret: bool = True
                     ) -> jax.Array:
    """(B, K) ids -> (B, K, D): the DeepFM per-field lookup (hotness 1 per
    field, fields stacked).  Pure gather — the degenerate bag."""
    b, k = ids.shape
    flat = ids.reshape(-1)
    if use_pallas:
        from repro.kernels.late_gather import late_gather_pallas
        rows = late_gather_pallas(table, flat, interpret=interpret)
    else:
        rows = jnp.take(table, jnp.minimum(flat, table.shape[0] - 1), axis=0)
        rows = jnp.where((flat < table.shape[0])[:, None], rows, 0.0)
    return rows.reshape(b, k, table.shape[1])
