"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships three files per the repo convention:
``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py`` (jitted wrapper with
the public contract), ``ref.py`` (pure-jnp oracle).  All kernels validate in
``interpret=True`` on CPU; BlockSpecs are written for the TPU (8,128)/MXU
tiling target.
"""
from . import (late_gather, embedding_bag, spmm_segment,  # noqa: F401
               frontier_expand, frontier_pull)
