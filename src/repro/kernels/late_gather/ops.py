"""Jitted public wrapper for the Materialize gather.

``materialize`` fuses multiple columns into a single wide gather (one DMA
stream per position instead of one per column — the columnar analogue of a
heap-page read, but only for rows that survived the recursion).
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from .late_gather import late_gather_pallas
from .ref import late_gather_ref


def late_gather(table: jax.Array, positions: jax.Array,
                *, use_pallas: bool = False, interpret: bool = True
                ) -> jax.Array:
    if use_pallas:
        return late_gather_pallas(table, positions, interpret=interpret)
    return late_gather_ref(table, positions)


def materialize(columns: Dict[str, jax.Array], positions: jax.Array,
                names: Sequence[str], *, use_pallas: bool = False,
                interpret: bool = True) -> Dict[str, jax.Array]:
    """Gather ``names`` columns at ``positions`` via ONE fused wide gather."""
    parts, slices, off = [], {}, 0
    dtype = jnp.float32
    for n in names:
        col = columns[n]
        c2 = col[:, None] if col.ndim == 1 else col
        parts.append(c2.astype(dtype))
        slices[n] = (off, off + c2.shape[1], col.ndim == 1, col.dtype)
        off += c2.shape[1]
    fused = jnp.concatenate(parts, axis=1)
    g = late_gather(fused, positions, use_pallas=use_pallas,
                    interpret=interpret)
    out = {}
    for n, (a, b, was_1d, dt) in slices.items():
        v = g[:, a:b].astype(dt)
        out[n] = v[:, 0] if was_1d else v
    return out
