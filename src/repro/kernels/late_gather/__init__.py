from .ops import late_gather, materialize          # noqa: F401
from .late_gather import late_gather_pallas        # noqa: F401
from .ref import late_gather_ref                   # noqa: F401
