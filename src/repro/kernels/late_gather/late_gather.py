"""Pallas TPU kernel: positional row gather (the Materialize operator).

The paper's late materialization ends every positional plan with ONE gather
of the output columns at the surviving positions.  On TPU the gather is
expressed with a scalar-prefetched position vector driving the input
BlockSpec ``index_map``: grid step ``i`` DMAs exactly the row
``positions[i]`` from HBM into VMEM — rows that were never reached are never
touched, which is the whole point.

Blocking: ``(1, block_w)`` input/output blocks.  A 1-row block underuses the
(8, 128) sublane tile; the mitigation (documented in EXPERIMENTS.md §Perf)
is to sort positions so consecutive grid steps hit adjacent HBM pages, and
to fuse multiple columns into one wide gather (what ``ops.materialize``
does).  Width is padded to a multiple of 128 lanes by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pos_ref, tab_ref, out_ref, *, num_rows: int):
    i = pl.program_id(0)
    valid = pos_ref[i] < num_rows
    block = tab_ref[...]
    out_ref[...] = jnp.where(valid, block, jnp.zeros((), block.dtype))


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def late_gather_pallas(table: jax.Array, positions: jax.Array,
                       *, block_w: int = 128, interpret: bool = True
                       ) -> jax.Array:
    """(R, W) table, (P,) int32 positions -> (P, W) gathered rows."""
    r, w = table.shape
    p = positions.shape[0]
    bw = min(block_w, max(w, 1))
    pad_w = (-w) % bw
    if pad_w:
        table = jnp.pad(table, ((0, 0), (0, pad_w)))
    wp = w + pad_w

    grid = (p, wp // bw)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec(
            (1, bw), lambda i, j, pos_ref: (jnp.minimum(pos_ref[i], r - 1), j))],
        out_specs=pl.BlockSpec((1, bw), lambda i, j, pos_ref: (i, j)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, num_rows=r),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((p, wp), table.dtype),
        interpret=interpret,
    )(positions, table)
    return out[:, :w]
