"""Oracle for the positional Materialize gather."""
from __future__ import annotations

import jax.numpy as jnp


def late_gather_ref(table: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """out[i] = table[positions[i]]; rows with positions >= num_rows -> 0.

    table: (R, W) any dtype; positions: (P,) int32.  Returns (P, W).
    """
    r = table.shape[0]
    safe = jnp.minimum(positions, r - 1)
    out = jnp.take(table, safe, axis=0)
    return jnp.where((positions < r)[:, None], out, jnp.zeros((), table.dtype))
