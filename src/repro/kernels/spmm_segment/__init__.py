from .ops import spmm_segment, gcn_norm_spmm       # noqa: F401
from .spmm_segment import spmm_segment_pallas      # noqa: F401
from .ref import spmm_segment_ref                  # noqa: F401
