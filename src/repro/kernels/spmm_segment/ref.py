"""Oracle for the fused gather-scale-segment-sum (GNN SpMM)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_segment_ref(x: jax.Array, src: jax.Array, seg: jax.Array,
                     weights: jax.Array, num_out: int) -> jax.Array:
    """out[v] = sum_{e: seg[e]=v} weights[e] * x[src[e]].

    x (N, D) dense features; src/seg (E,) int32 (seg = destination, assumed
    sorted by ops.py before the kernel path); weights (E,).
    src >= N is padding and contributes zero.
    """
    n = x.shape[0]
    rows = jnp.take(x, jnp.minimum(src, n - 1), axis=0)
    rows = jnp.where((src < n)[:, None], rows, 0.0) * weights[:, None]
    return jax.ops.segment_sum(rows, seg, num_segments=num_out)
