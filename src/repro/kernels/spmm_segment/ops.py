"""Jitted SpMM wrapper: sorts edges by destination (kernel contract),
zero-fills untouched nodes, and exposes the degree-normalized variant used
by GCN-style layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import spmm_segment_ref
from .spmm_segment import spmm_segment_pallas


def spmm_segment(x: jax.Array, src: jax.Array, dst: jax.Array,
                 weights: jax.Array | None, num_out: int,
                 *, use_pallas: bool = False, interpret: bool = True
                 ) -> jax.Array:
    e = src.shape[0]
    if weights is None:
        weights = jnp.ones((e,), x.dtype)
    if not use_pallas:
        return spmm_segment_ref(x, src, dst, weights, num_out)
    order = jnp.argsort(dst, stable=True)
    src_s, dst_s, w_s = src[order], dst[order], weights[order]
    out = spmm_segment_pallas(x, src_s, dst_s, w_s, num_out,
                              interpret=interpret)
    # nodes with no in-edges were never visited by the kernel: zero them
    touched = jax.ops.segment_sum(jnp.ones((e,), jnp.int32), dst_s,
                                  num_segments=num_out)
    return jnp.where((touched > 0)[:, None], out, 0.0)


def gcn_norm_spmm(x: jax.Array, src: jax.Array, dst: jax.Array,
                  num_nodes: int, *, use_pallas: bool = False,
                  interpret: bool = True) -> jax.Array:
    """Symmetric-normalized aggregation: out = D^{-1/2} A D^{-1/2} x."""
    ones = jnp.ones((src.shape[0],), x.dtype)
    deg = jax.ops.segment_sum(ones, dst, num_segments=num_nodes) + \
        jax.ops.segment_sum(ones, src, num_segments=num_nodes)
    deg = jnp.maximum(deg * 0.5, 1.0)
    inv = jax.lax.rsqrt(deg)
    w = inv[src] * inv[dst]
    return spmm_segment(x, src, dst, w, num_nodes, use_pallas=use_pallas,
                        interpret=interpret)
