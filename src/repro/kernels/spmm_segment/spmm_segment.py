"""Pallas TPU kernel: fused gather x scale -> segment-sum (message passing).

This is the SpMM regime of the GNN families (GCN/SAGE/GIN/GatedGCN/PNA and
the post-softmax aggregation of GAT): for every edge, gather the source
node's feature row, scale by an edge weight, and reduce into the destination
node.  JAX-native code materializes the (E, D) message matrix in HBM;
this kernel keeps each message in VMEM only.

Edge order contract (arranged by ops.py): edges sorted by destination, so
revisits of an output block are consecutive grid steps — the TPU Pallas
accumulation pattern.  Grid is (feature_blocks, edges) with the edge axis
minor, so for a fixed feature block the edge sweep accumulates in VMEM and
each node row is written back exactly once per feature block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_ref, seg_ref, x_ref, w_ref, out_ref, *, num_nodes: int):
    i = pl.program_id(1)                      # edge index (minor axis)
    first = (i == 0) | (seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])
    valid = src_ref[i] < num_nodes
    row = x_ref[...] * w_ref[0]
    row = jnp.where(valid, row, jnp.zeros((), row.dtype))

    @pl.when(first)
    def _init():
        out_ref[...] = row

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[...] += row


@functools.partial(jax.jit, static_argnames=("num_out", "block_d",
                                             "interpret"))
def spmm_segment_pallas(x: jax.Array, src: jax.Array, seg: jax.Array,
                        weights: jax.Array, num_out: int,
                        *, block_d: int = 128, interpret: bool = True
                        ) -> jax.Array:
    n, d = x.shape
    e = src.shape[0]
    bd = min(block_d, max(d, 1))
    pad_d = (-d) % bd
    if pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_d)))
    dp = d + pad_d

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(dp // bd, e),                       # edges minor: consecutive
        in_specs=[                                # same-destination revisits
            pl.BlockSpec((1, bd),
                         lambda j, i, src_ref, seg_ref:
                         (jnp.minimum(src_ref[i], n - 1), j)),
            pl.BlockSpec((1,), lambda j, i, src_ref, seg_ref: (i,)),
        ],
        out_specs=pl.BlockSpec((1, bd),
                               lambda j, i, src_ref, seg_ref:
                               (jnp.minimum(seg_ref[i], num_out - 1), j)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, num_nodes=n),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((num_out, dp), x.dtype),
        interpret=interpret,
    )(src, seg, x, weights)
    return out[:, :d]
