"""Oracle for the bottom-up (pull) frontier step — pure XLA.

The reference mirrors the engine's own reverse-CSR pull
(:func:`repro.core.operators._dense_pull`, non-bidir branch): per
reverse-adjacency entry, test the in-neighbor's frontier membership under
the unvisited candidate mask, then segment-OR per owning vertex."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import CSRIndex


def frontier_pull_ref(rcsr: CSRIndex, join_src: jax.Array,
                      join_dst: jax.Array, frontier: jax.Array,
                      visited: jax.Array) -> jax.Array:
    nv = frontier.shape[0]
    cand = ~visited
    perm = rcsr.perm
    nbr = jnp.clip(join_src[perm], 0, nv - 1)
    vtx = jnp.clip(join_dst[perm], 0, nv - 1)
    contrib = cand[vtx] & frontier[nbr]
    nxt = jnp.zeros((nv,), bool).at[vtx].max(contrib, mode="drop")
    return nxt & cand
