"""Pallas TPU kernel: the bottom-up (pull) membership test of
direction-optimizing BFS.

One pull level asks, for every reverse-adjacency entry ``q`` (a join edge
grouped by its DESTINATION vertex), whether the entry's in-neighbor is in
the frontier bitmap while its owning vertex is still unvisited:

    contrib[q] = frontier[nbr[q]] & ~visited[vtx[q]]

The two (V,)-bitmap gathers are the whole kernel.  Like the
``expand_index`` kernel this avoids dynamic VMEM gathers (TPU-unfriendly)
with a *chunked one-hot masked-sum select*: the bitmaps live wholly in
VMEM as int32 rows, and each entry tile resolves its lookups by comparing
against a chunk-wide iota — dense VPU compares, no scatter/gather inside
the kernel.  The segment-OR per vertex (``nxt = any(contrib over the
vertex's reverse slice)``) stays outside in XLA, where a scatter-max is
native.

Output entries are int32 0/1 (Pallas-friendly); the wrapper casts back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_CHUNK = 512     # bitmap chunk per compare-select step


def _pull_contrib_kernel(nbr_ref, vtx_ref, frontier_ref, visited_ref,
                         out_ref, *, num_vertices: int):
    nbr = nbr_ref[...]            # (1, block_e) in-neighbor per entry
    vtx = vtx_ref[...]            # (1, block_e) owning (destination) vertex
    frontier = frontier_ref[...]  # (1, Vp) int32 0/1 frontier bitmap
    visited = visited_ref[...]    # (1, Vp) int32 0/1 visited bitmap

    vp = frontier.shape[1]
    nchunk = vp // _CHUNK

    def chunk_body(c, carry):
        f_sel, v_sel = carry
        c0 = c * _CHUNK
        f_c = jax.lax.dynamic_slice(frontier, (0, c0), (1, _CHUNK))
        v_c = jax.lax.dynamic_slice(visited, (0, c0), (1, _CHUNK))
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, _CHUNK), 2) + c0
        pick = lambda idx, row: jnp.sum(
            jnp.where(idx[0, :][None, :, None] == iota,
                      row[0, :][None, None, :], 0), axis=2)
        return f_sel + pick(nbr, f_c), v_sel + pick(vtx, v_c)

    zeros = jnp.zeros(nbr.shape, jnp.int32)
    f_sel, v_sel = jax.lax.fori_loop(0, nchunk, chunk_body, (zeros, zeros))
    out_ref[...] = ((f_sel > 0) & (v_sel == 0)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_vertices", "block_e",
                                             "interpret"))
def pull_contrib_pallas(nbr: jax.Array, vtx: jax.Array,
                        frontier: jax.Array, visited: jax.Array,
                        num_vertices: int, *, block_e: int = 256,
                        interpret: bool = True) -> jax.Array:
    """(E,) int32 contribution mask: entry q contributes iff
    ``frontier[nbr[q]] & ~visited[vtx[q]]``.  ``nbr``/``vtx`` must be
    pre-clipped to [0, num_vertices)."""
    e = nbr.shape[0]
    pad_v = (-num_vertices) % _CHUNK
    # pad the bitmaps with frontier=0 / visited=1: padded vertices never
    # contribute even if a (clipped) index lands on them
    f_p = jnp.pad(frontier.astype(jnp.int32), (0, pad_v))[None, :]
    v_p = jnp.pad(visited.astype(jnp.int32), (0, pad_v),
                  constant_values=1)[None, :]
    vp = num_vertices + pad_v

    pad_e = (-e) % block_e
    ep = e + pad_e
    nbr_p = jnp.pad(nbr.astype(jnp.int32), (0, pad_e))[None, :]
    vtx_p = jnp.pad(vtx.astype(jnp.int32), (0, pad_e))[None, :]

    out = pl.pallas_call(
        functools.partial(_pull_contrib_kernel, num_vertices=num_vertices),
        grid=(ep // block_e,),
        in_specs=[pl.BlockSpec((1, block_e), lambda eb: (0, eb)),
                  pl.BlockSpec((1, block_e), lambda eb: (0, eb)),
                  pl.BlockSpec((1, vp), lambda eb: (0, 0)),
                  pl.BlockSpec((1, vp), lambda eb: (0, 0))],
        out_specs=pl.BlockSpec((1, block_e), lambda eb: (0, eb)),
        out_shape=jax.ShapeDtypeStruct((1, ep), jnp.int32),
        interpret=interpret,
    )(nbr_p, vtx_p, f_p, v_p)
    return out[0, :e]
