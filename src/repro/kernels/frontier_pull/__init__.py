from .ops import frontier_pull_fused, make_pull_fn     # noqa: F401
from .frontier_pull import pull_contrib_pallas          # noqa: F401
from .ref import frontier_pull_ref                      # noqa: F401
