"""Jitted pull-step wrapper with the engine's contract.

``frontier_pull_fused(rcsr, join_src, join_dst, frontier, visited)`` is
drop-in for the ``expand_fn=`` slot of
:class:`repro.core.operators.PullStep`: the in-neighbor / owning-vertex
columns come off the reverse CSR's permutation (cheap positional
gathers), and the frontier/visited MEMBERSHIP test — the gather-heavy
heart of the bottom-up step — runs as the Pallas ``pull_contrib`` kernel.
The per-vertex segment-OR stays in XLA (scatter-max is native there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.csr import CSRIndex

from .frontier_pull import pull_contrib_pallas


def frontier_pull_fused(rcsr: CSRIndex, join_src: jax.Array,
                        join_dst: jax.Array, frontier: jax.Array,
                        visited: jax.Array, *, interpret: bool = True
                        ) -> jax.Array:
    nv = frontier.shape[0]
    perm = rcsr.perm
    if perm.shape[0] == 0:
        return jnp.zeros((nv,), bool)
    nbr = jnp.clip(join_src[perm], 0, nv - 1)
    vtx = jnp.clip(join_dst[perm], 0, nv - 1)
    contrib = pull_contrib_pallas(nbr, vtx, frontier, visited, nv,
                                  interpret=interpret).astype(bool)
    nxt = jnp.zeros((nv,), bool).at[vtx].max(contrib, mode="drop")
    return nxt & ~visited


def make_pull_fn(interpret: bool = True):
    """Engine plug-in: ``PullStep(expand_fn=make_pull_fn())``."""
    return functools.partial(frontier_pull_fused, interpret=interpret)
