"""Persistent plan store: serialize a :class:`~repro.planner.serving.
ServingSession`'s cache grains + calibration state to one JSON file and
rehydrate them in a cold process.

Everything flows through the machine-readable plan schema
(:func:`repro.planner.explain.to_json`, ``schema_version`` 2): a cached
:class:`PlannerReport` serializes as exactly the document ``explain_json``
would emit, and a :class:`PlanEntry` serializes as its cached ``plan_json``
plus the per-bucket physical choices.  Rehydration rebuilds live planner
objects WITHOUT re-planning:

* graph statistics come back from the stored stats section (seeding
  ``Dataset.stats_cache`` — no sampled traversals re-run);
* pipelines are re-COMPILED from engine names through the same
  ``PLAN_BUILDERS`` registry the planner uses (compilation is cheap and
  deterministic; costing — the expensive, statistics-dependent part — is
  restored from the stored numbers, never recomputed);
* the calibrator resumes from its serialized normal equations, so the
  refit constants survive the process boundary.

A ``ServingSession(ds, plan_store=path)`` that finds ``path`` answers its
first request for known traffic with ZERO parse / statistics / costing
passes (``session.counters``); only jit compilation (unavoidable per
process) is paid.

**Schema migration:** version-1 documents (PR 3's ``to_json``) still load —
:func:`migrate_plan_doc` fills the v2-only fields with conservative
defaults (empty profile tails, ``plain_bytes == total_bytes`` /
``kernel_bytes == 0`` — i.e. a v1 kernel candidate's statically-factored
bytes are folded into the plain term, accurate for everything the v1
writer could rank).  Documents are written atomically (temp file +
``os.replace``).
"""
from __future__ import annotations

import copy
import hashlib
import json
import os
import tempfile
from typing import Optional

import numpy as np

from repro.core.engine import Dataset, RecursiveQuery, build_plan
from repro.core.operators import EngineCaps
from repro.core.recursive import precursive_plan
from repro.obs import faultinject as _fault

from . import calibrate as _calibrate
from .ast import LogicalQuery
from .calibrate import Calibrator, kernel_expand_fn
from .cost import CostConstants, DEFAULT_CONSTANTS, OpEstimate, PlanCost
from .explain import PLAN_SCHEMA_VERSION
from .optimize import PhysicalChoice, PlannerReport, RootBucket
from .serving import PlanEntry, ServingSession, shape_key
from .stats import GraphStats

__all__ = ["graph_digest", "load_store", "logical_from_json",
           "logical_to_json", "migrate_plan_doc", "rehydrate_into",
           "rehydrate_session", "report_from_json", "save_session",
           "stats_from_json", "stats_to_json"]

STORE_KIND = "plan_store"


# ---------------------------------------------------------------------------
# leaf (de)serializers — inverses of the to_json sections
# ---------------------------------------------------------------------------

def graph_digest(ds: Dataset) -> str:
    """Digest of the actual edge list: a store written against one graph
    must refuse to warm a session over a different one."""
    h = hashlib.sha1()
    h.update(str(int(ds.num_vertices)).encode())
    h.update(np.asarray(ds.table.column("from"), np.int64).tobytes())
    h.update(np.asarray(ds.table.column("to"), np.int64).tobytes())
    return h.hexdigest()[:16]


def logical_to_json(lg: LogicalQuery) -> dict:
    return {
        "root": lg.root,
        "max_depth": lg.max_depth,
        "payload_cols": lg.payload_cols,
        "dedup": lg.dedup,
        "direction": lg.direction,
        "want_cols": list(lg.want_cols),
        "want_depth": lg.want_depth,
        "union_all": lg.union_all,
        "workload": getattr(lg, "workload", "reach"),
        "weight_col": getattr(lg, "weight_col", None),
    }


def logical_from_json(doc: dict) -> LogicalQuery:
    wc = doc.get("weight_col")
    return LogicalQuery(
        root=(None if doc["root"] is None else int(doc["root"])),
        max_depth=int(doc["max_depth"]),
        payload_cols=int(doc["payload_cols"]),
        dedup=bool(doc["dedup"]),
        direction=str(doc["direction"]),
        want_cols=tuple(str(c) for c in doc["want_cols"]),
        want_depth=bool(doc["want_depth"]),
        union_all=bool(doc["union_all"]),
        workload=str(doc.get("workload", "reach")),
        weight_col=(None if wc is None else str(wc)))


def stats_to_json(st: GraphStats) -> dict:
    return {
        "direction": st.direction,
        "num_vertices": st.num_vertices,
        "num_edges": st.num_edges,
        "density": st.density,
        "avg_degree": st.avg_degree,
        "max_degree": st.max_degree,
        "is_forest": st.is_forest,
        "sample_roots": list(st.sample_roots),
        "level_edges": list(st.level_edges),
        "max_levels": st.max_levels,
        "reach_edges": st.reach_edges,
        "degree_histogram": list(st.degree_histogram),
        "level_vertices": list(st.level_vertices),
        "max_level_edges": st.max_level_edges,
        "root_profiles": [[r, list(p)] for r, p in st.root_profiles],
        "level_walk_edges": list(st.level_walk_edges),
    }


def stats_from_json(doc: dict) -> GraphStats:
    level_edges = tuple(float(x) for x in doc["level_edges"])
    return GraphStats(
        direction=str(doc["direction"]),
        num_vertices=int(doc["num_vertices"]),
        num_edges=int(doc["num_edges"]),
        density=float(doc["density"]),
        avg_degree=float(doc["avg_degree"]),
        max_degree=int(doc["max_degree"]),
        degree_histogram=tuple(int(x)
                               for x in doc.get("degree_histogram", [])),
        is_forest=bool(doc["is_forest"]),
        sample_roots=tuple(int(r) for r in doc["sample_roots"]),
        level_edges=level_edges,
        level_vertices=tuple(float(x)
                             for x in doc.get("level_vertices", [])),
        max_level_edges=int(doc.get("max_level_edges",
                                    max(level_edges, default=0))),
        reach_edges=float(doc["reach_edges"]),
        max_levels=int(doc["max_levels"]),
        root_profiles=tuple(
            (int(r), tuple(int(x) for x in p))
            for r, p in doc.get("root_profiles", [])),
        level_walk_edges=tuple(float(x)
                               for x in doc.get("level_walk_edges", [])))


# ---------------------------------------------------------------------------
# schema migration: v1 plan documents load under the v2 reader
# ---------------------------------------------------------------------------

def migrate_plan_doc(doc: dict) -> dict:
    """Upgrade one machine-readable plan document to ``schema_version`` 6
    (a copy; the input is not mutated).  v6 documents pass through.

    v1 -> v2: fill the rehydration-only stats fields and fold the v1
    writer's statically-factored kernel bytes into ``plain_bytes``.
    v2 -> v3: candidates gain ``level_dirs: []`` (a v2 writer knew no
    direction-optimizing engines, so every stored plan is push-only) and
    the cost constants gain the default ``pull_alpha``/``pull_beta``
    thresholds (:meth:`CostConstants.from_json` defaults them).
    v3 -> v4: the document gains the top-level ``analyze`` section
    (``null`` — an older writer never reconciled predicted vs. actual).
    v4 -> v5: the logical section gains ``workload='reach'`` /
    ``weight_col=null`` and every candidate gains ``semiring='reach'`` —
    an older writer only ever planned boolean BFS.
    v5 -> v6: the document gains the top-level ``admission`` section
    (``null`` — a pre-guard writer never guarded a request) and the cost
    constants gain the default guard budgets
    (:meth:`CostConstants.from_json` defaults them)."""
    v = doc.get("schema_version")
    if v == PLAN_SCHEMA_VERSION:
        return doc
    if v not in (1, 2, 3, 4, 5):
        raise ValueError(f"unsupported plan schema_version {v!r} "
                         f"(this reader handles 1..{PLAN_SCHEMA_VERSION})")
    out = copy.deepcopy(doc)
    out["schema_version"] = PLAN_SCHEMA_VERSION
    st = out.get("stats", {})
    if v == 1:
        st.setdefault("degree_histogram", [])
        st.setdefault("level_vertices",
                      [0.0] * len(st.get("level_edges", [])))
        st.setdefault("max_level_edges",
                      int(max(st.get("level_edges", []), default=0)))
        st.setdefault("root_profiles", [])
        st.setdefault("level_walk_edges", [])
    out.setdefault("cost_constants", DEFAULT_CONSTANTS.to_json())
    lg = out.get("logical", {})
    lg.setdefault("workload", "reach")           # v<=4: boolean BFS only
    lg.setdefault("weight_col", None)
    for c in out.get("candidates", []):
        cost = c.get("cost", {})
        # a v1 writer folded any (static) kernel factor into total_bytes;
        # migrating it as plain keeps every v1 ranking reproducible
        cost.setdefault("plain_bytes", cost.get("total_bytes", 0.0))
        cost.setdefault("kernel_bytes", 0.0)
        cost.setdefault("level_dirs", [])        # v<=2: push-only plans
        c.setdefault("semiring", "reach")        # v<=4: no value plane
    out.setdefault("analyze", None)              # v<=3: never analyzed
    out.setdefault("admission", None)            # v<=5: never guarded
    return out


# ---------------------------------------------------------------------------
# rebuilding live planner objects (compile yes, cost no)
# ---------------------------------------------------------------------------

def _choice_from_json(cj: dict, logical: LogicalQuery) -> PhysicalChoice:
    """Rebuild one PhysicalChoice: RecursiveQuery from the logical axes,
    Pipeline re-COMPILED through PLAN_BUILDERS (same registry as the
    planner — bit-identical execution), PlanCost restored verbatim."""
    caps = EngineCaps(frontier=int(cj["caps"]["frontier"]),
                      result=int(cj["caps"]["result"]))
    engine = str(cj["engine"])
    use_kernel = bool(cj.get("use_kernel", False))
    q = RecursiveQuery(engine=engine, max_depth=logical.max_depth,
                       payload_cols=logical.payload_cols, caps=caps,
                       dedup=logical.dedup, direction=logical.direction,
                       workload=getattr(logical, "workload", "reach"),
                       weight_col=getattr(logical, "weight_col", None),
                       lanes=int(cj.get("lanes", 1)))
    if use_kernel:
        pipeline = precursive_plan(caps, q.max_depth, q.out_cols, q.dedup,
                                   q.direction, expand_fn=kernel_expand_fn())
    else:
        # build_plan routes weighted workloads to the semiring pipelines
        # and reach through the same PLAN_BUILDERS registry as before
        pipeline = build_plan(q)
    cost = cj["cost"]
    plan_cost = PlanCost(
        total_bytes=float(cost["total_bytes"]),
        est_us=float(cost["est_us"]),
        levels=int(cost["levels"]),
        result_rows=float(cost["result_rows"]),
        per_op=tuple(OpEstimate(str(o["label"]), float(o["rows"]),
                                float(o["bytes"])) for o in cj["ops"]),
        plain_bytes=float(cost["plain_bytes"]),
        kernel_bytes=float(cost["kernel_bytes"]),
        level_dirs=tuple(str(d) for d in cost.get("level_dirs", [])))
    return PhysicalChoice(engine=engine, query=q, logical=logical,
                          pipeline=pipeline, cost=plan_cost,
                          use_kernel=use_kernel)


def report_from_json(doc: dict) -> PlannerReport:
    """Rebuild a full PlannerReport from a (v1 or v2) plan document."""
    doc = migrate_plan_doc(doc)
    logical = logical_from_json(doc["logical"])
    stats = stats_from_json(doc["stats"])
    ranked = tuple(_choice_from_json(cj, logical)
                   for cj in doc["candidates"])
    skipped = tuple((str(s["engine"]), str(s["reason"]))
                    for s in doc.get("skipped", []))
    constants = CostConstants.from_json(
        doc.get("cost_constants", DEFAULT_CONSTANTS.to_json()))
    return PlannerReport(logical=logical, stats=stats, ranked=ranked,
                         skipped=skipped, constants=constants)


def _buckets_from_json(bdocs) -> tuple:
    return tuple(RootBucket(
        indices=tuple(int(i) for i in b["lanes"]),
        roots=tuple(int(r) for r in b["roots"]),
        caps=EngineCaps(frontier=int(b["caps"]["frontier"]),
                        result=int(b["caps"]["result"])),
        predicted_reach=float(b["predicted_reach"]),
        predicted_depth=int(b["predicted_depth"])) for b in bdocs)


# ---------------------------------------------------------------------------
# whole-session save / rehydrate
# ---------------------------------------------------------------------------

def _choice_json(c: PhysicalChoice) -> dict:
    """The candidate schema of explain.to_json, minus the rank flags (a
    bucket choice is not ranked inside an entry)."""
    return {
        "label": c.label,
        "engine": c.engine,
        "use_kernel": c.use_kernel,
        "semiring": getattr(c.pipeline, "semiring", "reach"),
        "lanes": getattr(c.query, "lanes", 1),
        "caps": {"frontier": c.query.caps.frontier,
                 "result": c.query.caps.result},
        "cost": {"est_us": c.cost.est_us,
                 "total_bytes": c.cost.total_bytes,
                 "levels": c.cost.levels,
                 "result_rows": c.cost.result_rows,
                 "plain_bytes": c.cost.plain_bytes,
                 "kernel_bytes": c.cost.kernel_bytes,
                 "level_dirs": list(c.cost.level_dirs)},
        "ops": [{"label": op.label, "rows": op.rows, "bytes": op.bytes}
                for op in c.cost.per_op],
    }


def session_to_json(session: ServingSession) -> dict:
    """The full store document for one session (plain ``json.dumps``-able)."""
    ds = session.ds
    from .explain import to_json
    stats_cache = ds.stats_cache or {}
    return {
        "schema_version": PLAN_SCHEMA_VERSION,
        "kind": STORE_KIND,
        "graph": {"num_vertices": int(ds.num_vertices),
                  "num_edges": int(ds.table.num_rows),
                  "digest": graph_digest(ds)},
        "calibration": session.calibrator.state_dict(),
        "kernel_factors_measured": _calibrate.measured_factors_state(),
        "stats": {d: stats_to_json(st) for d, st in stats_cache.items()},
        "logical": {sql: logical_to_json(lg)
                    for sql, lg in session._logical.items()},
        "shapes": [to_json(report) for report in session._choice.values()],
        "entries": [{
            "roots": list(entry.roots),
            "signature": [list(s) for s in entry.bucket_signature],
            "hits": entry.hits,
            "bucket_choices": [_choice_json(c)
                               for c in entry.bucket_choices],
            "plan_json": entry.plan_json,
        } for entry in session._plans.values()],
    }


def save_session(session: ServingSession, path: str) -> str:
    """Atomically write the session's plan store to ``path``."""
    doc = session_to_json(session)
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".plan_store.", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def load_store(path: str) -> dict:
    """Read + schema-migrate a plan-store file."""
    with open(path) as f:
        text = f.read()
    if _fault._ACTIVE and _fault.consume("plan_store_corrupt"):
        # chaos seam: serve the reader a truncated byte stream, as if the
        # writer had died mid-write without the atomic-rename protection
        text = text[:len(text) // 2]
    doc = json.loads(text)
    if doc.get("kind") != STORE_KIND:
        raise ValueError(f"{path} is not a plan store "
                         f"(kind={doc.get('kind')!r})")
    v = doc.get("schema_version")
    if v not in (1, 2, 3, 4, 5, PLAN_SCHEMA_VERSION):
        raise ValueError(f"unsupported plan-store schema_version {v!r}")
    doc = dict(doc)
    doc["schema_version"] = PLAN_SCHEMA_VERSION
    doc["shapes"] = [migrate_plan_doc(s) for s in doc.get("shapes", [])]
    for e in doc.get("entries", []):
        e["plan_json"] = migrate_plan_doc(e["plan_json"])
        for c in e.get("bucket_choices", []):
            cost = c.get("cost", {})
            cost.setdefault("plain_bytes", cost.get("total_bytes", 0.0))
            cost.setdefault("kernel_bytes", 0.0)
            cost.setdefault("level_dirs", [])
    return doc


def rehydrate_into(session: ServingSession, path: str) -> None:
    """Warm ``session`` from a plan-store file: graph statistics, logical /
    choice / bucket-choice / plan caches, the exact-request memo, and the
    calibration state.  The graph digest must match the session's dataset.

    After this, a request for stored traffic performs NO parse, NO
    statistics pass and NO costing (``session.counters`` stay zero); jit
    compilation is the only per-process cost left."""
    ds = session.ds
    doc = load_store(path)
    g = doc["graph"]
    digest = graph_digest(ds)
    if (int(g["num_vertices"]) != int(ds.num_vertices)
            or g["digest"] != digest):
        raise ValueError(
            f"plan store {path} was written for a different graph "
            f"(store: V={g['num_vertices']} digest={g['digest']}; "
            f"dataset: V={ds.num_vertices} digest={digest})")

    # graph statistics: seed the Dataset's stats cache (same slot
    # Dataset.stats() fills) so NOTHING recomputes them
    cache = ds.stats_cache
    if cache is None:
        cache = {}
        object.__setattr__(ds, "stats_cache", cache)
    for direction, st in doc.get("stats", {}).items():
        cache.setdefault(direction, stats_from_json(st))

    # resume the calibration state — unless the caller supplied a
    # configured calibrator (custom prior or already-observed traffic), in
    # which case the caller's configuration wins over the stored state
    cal = session.calibrator
    pristine = (cal.count == 0 and cal.prior == DEFAULT_CONSTANTS
                and cal.constants == cal.prior)
    if pristine:
        session.calibrator = Calibrator.from_state(doc["calibration"])
    if doc.get("kernel_factors_measured"):
        _calibrate.restore_measured_factors(doc["kernel_factors_measured"])
    if doc.get("kernel_factor_measured") is not None:
        # pre-v3 stores held ONE un-keyed factor: it was measured for the
        # writer's backend and the frontier_expand kernel.  Same policy as
        # restore_measured_factors: this process's own (current-backend)
        # measurement is fresher than the store's — only fill a missing
        # cell, never clobber one
        _calibrate.restore_measured_factors(
            {f"{_calibrate._backend()}/frontier_expand":
             float(doc["kernel_factor_measured"])})

    for sql, lg in doc.get("logical", {}).items():
        session._logical[sql] = logical_from_json(lg)
    for rep_doc in doc.get("shapes", []):
        report = report_from_json(rep_doc)
        session._choice[shape_key(report.logical)] = report

    for e in doc.get("entries", []):
        pj = e["plan_json"]
        report = report_from_json(pj)
        logical = report.logical
        buckets = _buckets_from_json(pj.get("buckets", []))
        choices = tuple(_choice_from_json(cj, logical)
                        for cj in e["bucket_choices"])
        signature = tuple(b.signature for b in buckets)
        entry = PlanEntry(
            choice=report.best, report=report,
            roots=tuple(int(r) for r in e["roots"]), buckets=buckets,
            bucket_choices=choices, bucket_signature=signature,
            plan_json=pj, hits=int(e.get("hits", 0)), served=0)
        key = (shape_key(logical), signature)
        session._plans[key] = entry
        session._requests[(shape_key(logical), entry.roots)] = key
        for b, c in zip(buckets, choices):
            session._bucket_plans.setdefault(
                (shape_key(logical), b.caps, len(b.roots)), c)


def rehydrate_session(ds: Dataset, path: str,
                      **session_kwargs) -> ServingSession:
    """Build a ServingSession warmed from a plan-store file."""
    session = ServingSession(ds, **session_kwargs)
    session.plan_store_path = path
    if not session._plans:          # plan_store kwarg may have loaded it
        rehydrate_into(session, path)
    return session
