"""Logical layer of the recursive-query planner: a ``WITH RECURSIVE``-shaped
AST and a parser for a minimal SQL dialect (§5.1 Listings 1.1–1.3).

The AST captures exactly the logical degrees of freedom the paper studies:
the seed predicate (which endpoint equals the root), the recursive join
direction, the carried columns, the depth bound, UNION vs UNION ALL, and an
optional outer depth filter.  Everything *physical* — positional vs tuple vs
row pipelines, early vs late materialization, the Exp-3 rewrite, sparse vs
dense frontiers — is deliberately absent: those are the optimizer's choices
(:mod:`repro.planner.optimize`), not the query's.

Dialect grammar (see docs/planner.md for the full write-up)::

    query  := WITH RECURSIVE cte [ '(' names ')' ] AS '(' seed
              UNION [ALL] rec ')' outer [';']
    seed   := SELECT items FROM edges [[AS] e] WHERE col '=' root
    rec    := SELECT items FROM edges [[AS] e] JOIN cte [[AS] t]
              ON joincond [WHERE cte.depth ('<'|'<=') INT]
    outer  := SELECT items FROM cte [[AS] t]
              [JOIN edges [[AS] e] ON t.id '=' e.id]
              [WHERE depth ('<'|'<=') INT]
    joincond := colref '=' colref [OR colref '=' colref]
    items  := item (',' item)* ; item := '*' | alias'.*' | colref
              | INT | colref '+' (INT | colref)
              | agg '(' colref '*' colref ')' ; agg := SUM|MIN|MAX|MUL
    root   := INT | ':' name | '?'

Because ``from`` is also a keyword, the edge columns are written quoted
(``"from"``, ``"to"``) or alias-qualified (``e.from``) — bare ``from`` in a
select list is always the keyword.  A literal ``0`` seed item and the
``t.depth + 1`` recursive item denote the depth counter; the counter column
must be named ``depth``.

Weighted accumulators (the semiring workloads, docs/workloads.md):

* ``t.depth + e.w`` in the recursive term generalizes the depth counter to
  a (min, +) distance — the query becomes weighted SSSP
  (``workload='shortest_path'``) over the edge-weight column ``w``;
* ``SUM(t.value * e.qty)`` (or MIN/MAX/MUL) declares a path-aggregation
  accumulator (``workload='aggregate_sum'`` …) over ``qty``; the
  accumulator column must be named ``value`` and is seeded with the
  literal ``1`` (the ⊗-identity) in the seed select.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

__all__ = ["RecursiveCTE", "LogicalQuery", "ParseError", "parse",
           "normalize", "paper_listing", "weighted_listing", "EDGE_COLS"]

EDGE_COLS = ("id", "from", "to", "name")

_PAYLOAD_RE = re.compile(r"column(\d+)$")


class ParseError(ValueError):
    """Raised when a query string falls outside the minimal dialect."""


@dataclasses.dataclass(frozen=True)
class RecursiveCTE:
    """The parsed logical query (one paper-listing-shaped CTE)."""

    cte_name: str
    carried_cols: Tuple[str, ...]      # CTE columns (depth counter excluded)
    carries_depth: bool                # CTE carries a depth counter column
    seed_col: str                      # 'from' | 'to' — the seed predicate
    root: Optional[int]                # literal root, or None for :param / ?
    union_all: bool                    # UNION ALL vs UNION (distinct)
    direction: str                     # 'outbound' | 'inbound' | 'both'
    max_depth: Optional[int]           # recursion bound (None = unbounded)
    outer_cols: Tuple[str, ...]        # outer select list ('*' kept literal)
    depth_filter: Optional[int]        # outer WHERE depth <= k (inclusive)
    top_level_join: bool               # Listing-1.3 shape: outer join on id
    workload: str = "reach"            # semiring workload (from accumulator)
    weight_col: Optional[str] = None   # ⊗-weight column (weighted only)


@dataclasses.dataclass(frozen=True)
class LogicalQuery:
    """The normalized query the optimizer plans: AST folded onto the
    existing :class:`~repro.core.engine.RecursiveQuery` axes, with the depth
    filter pushed down into the recursion bound."""

    root: Optional[int]
    max_depth: int                     # effective bound after pushdown
    payload_cols: int                  # the paper's N, from the output list
    dedup: bool                        # BFS semantics (False = raw UNION ALL)
    direction: str
    want_cols: Tuple[str, ...]         # value columns the caller asked for
    want_depth: bool                   # expose row depths as a 'depth' column
    union_all: bool                    # as written (pre-canonicalization)
    workload: str = "reach"            # semiring workload
    weight_col: Optional[str] = None   # ⊗-weight column (weighted only)


# ---------------------------------------------------------------------------
# tokenizer + a tiny recursive-descent parser
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(r'''
      "(?P<quoted>[^"]*)"
    | (?P<num>\d+)
    | (?P<word>[A-Za-z_][A-Za-z_0-9]*)
    | (?P<punct><=|>=|<>|[(),=<>.*+;?:])
    | (?P<ws>\s+)
    | (?P<bad>.)
''', re.VERBOSE)

_KEYWORDS = {"with", "recursive", "as", "select", "from", "where", "union",
             "all", "join", "on", "or", "and"}


class _Tok:
    __slots__ = ("kind", "text")

    def __init__(self, kind: str, text: str):
        self.kind = kind        # 'kw' | 'name' | 'num' | 'punct' | 'qname'
        self.text = text

    def __repr__(self):
        return f"{self.kind}:{self.text}"


def _tokenize(sql: str) -> list[_Tok]:
    toks = []
    for m in _TOKEN_RE.finditer(sql):
        if m.lastgroup == "ws":
            continue
        if m.lastgroup == "bad":
            raise ParseError(f"unexpected character {m.group()!r} in query")
        if m.lastgroup == "quoted":
            toks.append(_Tok("qname", m.group("quoted").lower()))
        elif m.lastgroup == "num":
            toks.append(_Tok("num", m.group()))
        elif m.lastgroup == "word":
            w = m.group().lower()
            toks.append(_Tok("kw" if w in _KEYWORDS else "name", w))
        else:
            toks.append(_Tok("punct", m.group()))
    return toks


class _Parser:
    def __init__(self, sql: str):
        self.toks = _tokenize(sql)
        self.i = 0

    # -- cursor helpers ---------------------------------------------------
    def _peek(self, k: int = 0) -> Optional[_Tok]:
        j = self.i + k
        return self.toks[j] if j < len(self.toks) else None

    def _next(self) -> _Tok:
        t = self._peek()
        if t is None:
            raise ParseError("unexpected end of query")
        self.i += 1
        return t

    def _accept(self, kind: str, text: Optional[str] = None) -> bool:
        t = self._peek()
        if t is not None and t.kind == kind and (text is None
                                                 or t.text == text):
            self.i += 1
            return True
        return False

    def _expect(self, kind: str, text: Optional[str] = None) -> _Tok:
        t = self._peek()
        if t is None or t.kind != kind or (text is not None
                                           and t.text != text):
            want = text or kind
            raise ParseError(f"expected {want!r}, got {t!r}")
        return self._next()

    def _kw(self, *words: str) -> None:
        for w in words:
            self._expect("kw", w)

    def _name(self) -> str:
        t = self._next()
        if t.kind not in ("name", "qname", "kw"):
            raise ParseError(f"expected identifier, got {t!r}")
        return t.text

    # -- grammar ----------------------------------------------------------
    def parse(self) -> RecursiveCTE:
        self._kw("with", "recursive")
        cte_name = self._name()
        named_cols: Optional[list[str]] = None
        if self._accept("punct", "("):
            named_cols = [self._ident_only()]
            while self._accept("punct", ","):
                named_cols.append(self._ident_only())
            self._expect("punct", ")")
        self._kw("as")
        self._expect("punct", "(")
        seed_items, seed_alias = self._select_from()
        self._kw("where")
        seed_col, root = self._seed_predicate(seed_alias)
        self._kw("union")
        union_all = self._accept("kw", "all")
        rec = self._recursive_term(cte_name)
        self._expect("punct", ")")
        outer_cols, top_join, depth_filter = self._outer(cte_name)
        self._accept("punct", ";")
        if self._peek() is not None:
            raise ParseError(f"trailing tokens after query: {self._peek()!r}")

        carried, carries_depth = self._carried(named_cols, seed_items)
        direction = rec["direction"]
        if seed_col not in ("from", "to"):
            raise ParseError(f"seed predicate must filter \"from\" or "
                             f"\"to\", got {seed_col!r}")
        expect_seed = {"outbound": "from", "inbound": "to"}.get(direction)
        if expect_seed is not None and seed_col != expect_seed:
            raise ParseError(
                f"seed predicate on {seed_col!r} contradicts the "
                f"{direction} recursive join (expected {expect_seed!r})")
        if rec["workload"].startswith("aggregate_") and not any(
                item[0] == "value_seed" for item in seed_items):
            raise ParseError("an aggregation accumulator needs the literal "
                             "value seed 1 in the seed select")
        return RecursiveCTE(
            cte_name=cte_name, carried_cols=tuple(carried),
            carries_depth=carries_depth, seed_col=seed_col, root=root,
            union_all=union_all, direction=direction,
            max_depth=rec["max_depth"], outer_cols=tuple(outer_cols),
            depth_filter=depth_filter, top_level_join=top_join,
            workload=rec["workload"], weight_col=rec["weight_col"])

    def _ident_only(self) -> str:
        t = self._next()
        if t.kind not in ("name", "qname") and not (t.kind == "kw"
                                                    and t.text in ("from",
                                                                   "to")):
            raise ParseError(f"expected column name, got {t!r}")
        return t.text

    def _select_from(self) -> tuple[list, Optional[str]]:
        """SELECT items FROM <table> [[AS] alias] — returns (items, alias)."""
        self._kw("select")
        items = self._select_items()
        self._kw("from")
        self._name()                       # table (always the edge table)
        alias = self._opt_alias()
        return items, alias

    def _opt_alias(self) -> Optional[str]:
        if self._accept("kw", "as"):
            return self._name()
        t = self._peek()
        if t is not None and t.kind == "name":
            return self._next().text
        return None

    _AGG_FNS = ("sum", "min", "max", "mul")

    def _select_items(self) -> list:
        """Items are ('col', name) | ('star', alias|None) | ('depth0',)
        | ('value_seed',) | ('depth+1',) | ('depth+w', col)
        | ('agg', fn, col).  Alias qualifiers are stripped."""
        items = [self._select_item()]
        while self._accept("punct", ","):
            items.append(self._select_item())
        return items

    def _select_item(self):
        if self._accept("punct", "*"):
            return ("star", None)
        t = self._peek()
        if t is not None and t.kind == "num":
            self._next()
            if t.text == "0":
                return ("depth0",)
            if t.text == "1":
                return ("value_seed",)      # ⊗-identity seed for the value
            raise ParseError("the only literal select items are the depth "
                             "seed 0 and the value seed 1")
        nxt = self._peek(1)
        if (t is not None and t.kind == "name" and t.text in self._AGG_FNS
                and nxt is not None and nxt.kind == "punct"
                and nxt.text == "("):
            return self._agg_item()
        name = self._colref()
        nxt = self._peek()
        if nxt is not None and nxt.kind == "punct" and nxt.text == "*":
            # alias '.' '*' was parsed as colref consuming '.'? handled below
            raise ParseError("unexpected '*'")
        if self._accept("punct", "+"):
            if name != "depth":
                raise ParseError("the only arithmetic select items are "
                                 "depth + 1 and depth + <weight column>")
            t = self._peek()
            if t is not None and t.kind == "num":
                one = self._next()
                if one.text != "1":
                    raise ParseError("the depth counter increments by 1; "
                                     "a weight is a column reference")
                return ("depth+1",)
            return ("depth+w", self._colref())
        return ("col", name)

    def _agg_item(self):
        """``AGG(t.value * e.w)`` — a path-aggregation accumulator."""
        fn = self._next().text
        self._expect("punct", "(")
        left = self._colref()
        if left != "value":
            raise ParseError(f"the aggregation accumulator must be named "
                             f"'value', got {left!r}")
        self._expect("punct", "*")
        weight = self._colref()
        self._expect("punct", ")")
        return ("agg", fn, weight)

    def _colref(self) -> str:
        """[alias '.'] column — returns the bare column name; ``alias.*``
        returns '*'."""
        first = self._name()
        if self._accept("punct", "."):
            if self._accept("punct", "*"):
                return "*"
            return self._ident_only()
        return first

    def _seed_predicate(self, alias: Optional[str]) -> tuple[str, Optional[int]]:
        col = self._colref()
        self._expect("punct", "=")
        t = self._next()
        if t.kind == "num":
            return col, int(t.text)
        if t.kind == "punct" and t.text == "?":
            return col, None
        if t.kind == "punct" and t.text == ":":
            self._name()
            return col, None
        raise ParseError(f"seed root must be an integer, '?' or ':name', "
                         f"got {t!r}")

    def _recursive_term(self, cte_name: str) -> dict:
        self._kw("select")
        items = self._select_items()       # carried cols re-checked via CTE
        workload, weight_col = "reach", None
        for item in items:
            if item[0] == "depth+w":
                w, c = "shortest_path", item[1]
            elif item[0] == "agg":
                w, c = "aggregate_" + item[1], item[2]
            else:
                continue
            if workload != "reach":
                raise ParseError("at most one weighted accumulator per "
                                 "recursive term")
            workload, weight_col = w, c
        self._kw("from")
        first = self._name()
        first_alias = self._opt_alias()
        self._kw("join")
        second = self._name()
        second_alias = self._opt_alias()
        self._kw("on")
        # which side is the CTE?
        names = {first: first_alias or first, second: second_alias or second}
        if cte_name not in names:
            raise ParseError(f"recursive term must join the CTE "
                             f"{cte_name!r}; joined {first!r} and {second!r}")
        cte_alias = names[cte_name]
        edge_alias = next(a for n, a in names.items() if n != cte_name)
        direction = self._join_condition(cte_alias, edge_alias)
        max_depth = None
        if self._accept("kw", "where"):
            max_depth = self._depth_bound()
        return {"direction": direction, "max_depth": max_depth,
                "workload": workload, "weight_col": weight_col}

    def _qualified(self) -> tuple[Optional[str], str]:
        first = self._name()
        if self._accept("punct", "."):
            return first, self._ident_only()
        return None, first

    def _join_condition(self, cte_alias: str, edge_alias: str) -> str:
        def one_eq() -> tuple[str, str]:
            """Returns (edge_col, cte_col) regardless of operand order."""
            q1, c1 = self._qualified()
            self._expect("punct", "=")
            q2, c2 = self._qualified()
            sides = {q1: c1, q2: c2}
            if set(sides) != {cte_alias, edge_alias}:
                raise ParseError(
                    f"join condition must relate {edge_alias!r} to "
                    f"{cte_alias!r}, got {q1!r} = {q2!r}")
            return sides[edge_alias], sides[cte_alias]

        ec, cc = one_eq()
        legs = {(ec, cc)}
        if self._accept("kw", "or"):
            legs.add(one_eq())
        if legs == {("from", "to")}:
            return "outbound"
        if legs == {("to", "from")}:
            return "inbound"
        if legs == {("from", "to"), ("to", "from")}:
            return "both"
        raise ParseError(f"unsupported join condition {sorted(legs)!r}; "
                         "expected e.from = cte.to (outbound), "
                         "e.to = cte.from (inbound), or both OR-ed")

    def _depth_bound(self) -> int:
        col = self._colref()
        if col != "depth":
            raise ParseError(f"only depth bounds are supported in the "
                             f"recursive WHERE, got {col!r}")
        op = self._expect("punct")
        if op.text not in ("<", "<="):
            raise ParseError(f"depth bound operator must be < or <=, "
                             f"got {op.text!r}")
        k = int(self._expect("num").text)
        # rows produced satisfy depth <= bound: '< k' caps depth at k
        # (seed is depth 0 and each recursion adds 1), '<= k' at k + 1.
        return k if op.text == "<" else k + 1

    def _outer(self, cte_name: str) -> tuple[list[str], bool, Optional[int]]:
        self._kw("select")
        raw = self._select_items()
        self._kw("from")
        first = self._name()
        first_alias = self._opt_alias()
        top_join = False
        if first != cte_name:
            raise ParseError(f"outer SELECT must read the CTE "
                             f"{cte_name!r}, got {first!r}")
        if self._accept("kw", "join"):
            second = self._name()
            second_alias = self._opt_alias()
            self._kw("on")
            q1, c1 = self._qualified()
            self._expect("punct", "=")
            q2, c2 = self._qualified()
            aliases = {first_alias or first, second_alias or second}
            if (c1, c2) != ("id", "id") or {q1, q2} != aliases:
                raise ParseError("the only supported top-level join is "
                                 "ON cte.id = edges.id")
            top_join = True
        depth_filter = None
        if self._accept("kw", "where"):
            col = self._colref()
            if col != "depth":
                raise ParseError(f"only depth filters are supported in the "
                                 f"outer WHERE, got {col!r}")
            op = self._expect("punct")
            if op.text not in ("<", "<="):
                raise ParseError("outer depth filter must use < or <=")
            k = int(self._expect("num").text)
            depth_filter = k if op.text == "<=" else k - 1
        cols = []
        for item in raw:
            if item[0] == "star":
                cols.append("*")
            elif item[0] == "col":
                cols.append(item[1])
            else:
                raise ParseError("outer select supports only columns "
                                 "and *")
        return cols, top_join, depth_filter

    @staticmethod
    def _carried(named_cols: Optional[list[str]],
                 seed_items: list) -> tuple[list[str], bool]:
        if named_cols is not None:
            # 'value' is the synthesized accumulator column, not a carried
            # edge column
            carried = [c for c in named_cols if c not in ("depth", "value")]
            return carried, "depth" in named_cols
        carried, depth = [], False
        for item in seed_items:
            if item[0] == "col":
                carried.append(item[1])
            elif item[0] in ("depth0", "depth+1"):
                depth = True
            elif item[0] == "value_seed":
                pass                        # accumulator column, not carried
            else:
                raise ParseError("SELECT * is not allowed inside the CTE; "
                                 "name the carried columns")
        return carried, depth


def parse(sql: str) -> RecursiveCTE:
    """Parse one minimal-dialect ``WITH RECURSIVE`` query into the AST."""
    return _Parser(sql).parse()


# ---------------------------------------------------------------------------
# normalization: AST -> LogicalQuery on the engine's RecursiveQuery axes
# ---------------------------------------------------------------------------

def _dataset_payloads(ds) -> int:
    n = 0
    for name in ds.table.names:
        m = _PAYLOAD_RE.match(name)
        if m:
            n = max(n, int(m.group(1)))
    return n


def normalize(ast: RecursiveCTE, ds, *, root=None,
              default_max_depth: Optional[int] = None) -> LogicalQuery:
    """Fold the AST onto the engine's query axes.

    * the outer depth filter is PUSHED DOWN into the recursion bound (the
      row-depth tags make the pushdown exact, so no post-filter remains);
    * ``UNION ALL`` maps to ``dedup=False`` — except on a forest, where raw
      UNION ALL walks and BFS coincide and the planner canonicalizes to the
      (cheaper, more widely supported) dedup form;
    * the paper's N follows from the columns the caller can observe, not
      from the CTE's carry list — carrying less is the optimizer's job
      (the Exp-3 rewrite), not a different logical query.
    """
    if root is None:
        root = ast.root
    available = _dataset_payloads(ds)

    def payload_n(cols) -> int:
        """The paper's N: the HIGHEST payload index referenced (the engine
        materializes the contiguous prefix column1..columnN)."""
        return max((int(m.group(1)) for c in cols
                    for m in [_PAYLOAD_RE.match(c)] if m), default=0)

    # output column set ('*' expands to the joined edge row for the
    # Listing-1.3 shape, to the carried columns otherwise; an explicit
    # select list is honored either way)
    if "*" in ast.outer_cols:
        want = (list(EDGE_COLS) + [f"column{i + 1}"
                                   for i in range(available)]
                if ast.top_level_join else list(ast.carried_cols))
        explicit = [c for c in ast.outer_cols if c != "*"]
        want += [c for c in explicit if c not in want]
    else:
        want = list(ast.outer_cols)
    want_depth = "depth" in want or (
        "*" in ast.outer_cols and not ast.top_level_join
        and ast.carries_depth)
    # 'depth' maps to row_depths; 'value' to the semiring value plane the
    # physical choice attaches — neither is a stored edge column
    want = [c for c in want if c not in ("depth", "value")]
    # N covers every referenced payload, including explicit outer extras
    payloads = payload_n(want)

    known = set(ds.table.names)
    for c in list(ast.carried_cols) + want:
        if c not in known:
            raise ParseError(f"unknown column {c!r}; the edge table has "
                             f"{sorted(known)}")
    workload = getattr(ast, "workload", "reach")
    weight_col = getattr(ast, "weight_col", None)
    if workload != "reach" and weight_col not in known:
        raise ParseError(f"unknown weight column {weight_col!r}; the edge "
                         f"table has {sorted(known)}")

    stats = ds.stats(ast.direction)
    dedup = (not ast.union_all) or stats.is_forest

    max_depth = ast.max_depth
    if max_depth is None:
        if not dedup:
            raise ParseError(
                "UNION ALL on a non-forest graph needs an explicit depth "
                "bound (WHERE depth < k) — the walk does not terminate")
        max_depth = (default_max_depth if default_max_depth is not None
                     else ds.num_vertices)
    if ast.depth_filter is not None:
        if ast.depth_filter < 0:
            raise ParseError("empty depth filter (depth < 0)")
        max_depth = min(max_depth, ast.depth_filter)

    return LogicalQuery(
        root=root, max_depth=max_depth, payload_cols=payloads, dedup=dedup,
        direction=ast.direction, want_cols=tuple(want),
        want_depth=want_depth, union_all=ast.union_all,
        workload=workload, weight_col=weight_col)


# ---------------------------------------------------------------------------
# the three paper listings, as dialect strings
# ---------------------------------------------------------------------------

def paper_listing(n: int, *, root: int = 0, depth: int = 10,
                  payload_cols: int = 0) -> str:
    """§5.1 Listings 1.1 (traversal columns), 1.2 (payloads carried through
    the recursion) and 1.3 (the Exp-3 rewrite shape: slim CTE + one
    top-level join)."""
    pays = [f"column{i + 1}" for i in range(payload_cols)]
    if n == 1:
        cols = ["id", '"from"', '"to"', "name"]
    elif n == 2:
        cols = ["id", '"from"', '"to"', "name"] + pays
    elif n == 3:
        cols = ["id", '"to"']
    else:
        raise ValueError(f"no paper listing {n}; expected 1, 2 or 3")
    names = ", ".join(c.strip('"') for c in cols)
    seed = ", ".join(cols)
    rec = ", ".join(f"e.{c}" for c in cols)
    body = (f"WITH RECURSIVE t ({names}, depth) AS (\n"
            f"  SELECT {seed}, 0 FROM edges WHERE \"from\" = {root}\n"
            f"  UNION ALL\n"
            f"  SELECT {rec}, t.depth + 1\n"
            f"  FROM edges AS e JOIN t ON e.\"from\" = t.\"to\"\n"
            f"  WHERE t.depth < {depth}\n"
            f")\n")
    if n == 3:
        return body + "SELECT e.* FROM t JOIN edges AS e ON t.id = e.id"
    return body + "SELECT * FROM t"


def weighted_listing(workload: str, *, root: int = 0, depth: int = 10,
                     weight_col: str = "w") -> str:
    """The weighted-workload query shapes (docs/workloads.md): SSSP spells
    the accumulator as a generalized depth counter (``t.depth + e.w``);
    the aggregations carry an explicit ``value`` column seeded with the
    ⊗-identity ``1`` and folded by ``AGG(t.value * e.w)``."""
    if workload == "shortest_path":
        return (f'WITH RECURSIVE t ("to", depth) AS (\n'
                f'  SELECT "to", 0 FROM edges WHERE "from" = {root}\n'
                f'  UNION\n'
                f'  SELECT e."to", t.depth + e.{weight_col}\n'
                f'  FROM edges AS e JOIN t ON e."from" = t."to"\n'
                f'  WHERE t.depth < {depth}\n'
                f')\nSELECT * FROM t')
    if workload.startswith("aggregate_"):
        fn = workload[len("aggregate_"):].upper()
        if workload not in ("aggregate_sum", "aggregate_min",
                            "aggregate_max", "aggregate_mul"):
            raise ValueError(f"no weighted listing for {workload!r}")
        return (f'WITH RECURSIVE t ("to", value, depth) AS (\n'
                f'  SELECT "to", 1, 0 FROM edges WHERE "from" = {root}\n'
                f'  UNION ALL\n'
                f'  SELECT e."to", {fn}(t.value * e.{weight_col}), '
                f't.depth + 1\n'
                f'  FROM edges AS e JOIN t ON e."from" = t."to"\n'
                f'  WHERE t.depth < {depth}\n'
                f')\nSELECT * FROM t')
    raise ValueError(f"no weighted listing for {workload!r}")
