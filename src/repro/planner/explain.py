"""EXPLAIN: render a full planning pass — the logical query, the statistics
it was priced against, and EVERY candidate engine's operator tree annotated
with per-operator estimated rows and bytes (extending ``plan_repr``, which
renders composition only).

The per-operator numbers come from the same :meth:`Operator.estimate` calls
the optimizer ranked with, so EXPLAIN is an audit of the decision, not a
separate pretty-printer.

:func:`to_json` renders the same planning pass MACHINE-READABLY (one plain
dict, ``json.dumps``-able): the serving layer caches these per query shape
so repeated traffic skips parsing/stats/costing, and external tooling can
diff plans across PRs.  ``schema_version`` gates consumers; the schema is
documented in docs/serving.md.

Schema version 2 extends v1 with everything a COLD PROCESS needs to
rehydrate a plan without re-planning (:mod:`repro.planner.plan_store`):
the full graph statistics (per-root profiles, walk profile, histogram),
the factor-independent ``plain_bytes``/``kernel_bytes`` cost split per
candidate, and the :class:`~repro.planner.cost.CostConstants` the pass was
priced with.

Schema version 3 adds the direction-optimizing switch decision: each
candidate's cost carries ``level_dirs`` (the predicted per-level
``push``/``pull`` choice of a :class:`~repro.core.operators.
DirectionSwitch` pipeline; empty for push-only engines), and the cost
constants carry the refittable ``pull_alpha``/``pull_beta`` thresholds.
v1 and v2 documents still load through
:func:`repro.planner.plan_store.migrate_plan_doc`.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.engine import Dataset
from repro.core.operators import EngineCaps

from .optimize import PhysicalChoice, PlannerReport, RootBucket, plan

__all__ = ["explain", "explain_json", "render_report", "to_json"]

PLAN_SCHEMA_VERSION = 3


def _fmt_bytes(b: float) -> str:
    if b < 1024:
        return f"{b:.0f}B"
    if b < 1024 ** 2:
        return f"{b / 1024:.1f}KB"
    return f"{b / 1024 ** 2:.1f}MB"


def _fmt_rows(r: float) -> str:
    return f"{r:.0f}"


def _candidate_block(rank: int, c: PhysicalChoice, chosen: bool) -> str:
    cost = c.cost
    head = (f"#{rank} {c.label:<24s} est {cost.est_us:8.0f}us  "
            f"{_fmt_bytes(cost.total_bytes):>9s}  "
            f"{cost.levels:3d} levels  ~{_fmt_rows(cost.result_rows)} rows")
    if chosen:
        head += "   <- CHOSEN"
    pipeline = c.pipeline
    ops = cost.per_op
    fin = ops[-1]
    lines = [head,
             f"   {fin.label:<66s} rows~{_fmt_rows(fin.rows):>7s} "
             f"bytes~{_fmt_bytes(fin.bytes):>9s}",
             f"     {pipeline.name}(maxrec={pipeline.max_depth})"]
    seed = ops[0]
    lines.append(f"       {seed.label + '            (non-recursive child)':<62s} "
                 f"rows~{_fmt_rows(seed.rows):>7s} bytes~{_fmt_bytes(seed.bytes):>9s}")
    for op in ops[1:-1]:
        lines.append(f"       {op.label:<62s} rows~{_fmt_rows(op.rows):>7s} "
                     f"bytes~{_fmt_bytes(op.bytes):>9s}")
    return "\n".join(lines)


def render_report(report: PlannerReport) -> str:
    lg = report.logical
    st = report.stats
    semantics = "UNION" if lg.dedup and not lg.union_all else (
        "UNION ALL == BFS (forest)" if lg.dedup else "UNION ALL (raw walk)")
    out_cols = list(lg.want_cols) + (["depth"] if lg.want_depth else [])
    lines = [
        "EXPLAIN recursive traversal",
        (f"logical: root={lg.root}  direction={lg.direction}  "
         f"max_depth={lg.max_depth}  payloads={lg.payload_cols}  "
         f"{semantics}"),
        f"output:  [{', '.join(out_cols)}]",
        (f"stats[{st.direction}]: V={st.num_vertices} EJ={st.num_edges} "
         f"density={st.density:.2f} avg_deg={st.avg_degree:.2f} "
         f"max_deg={st.max_degree} forest={'yes' if st.is_forest else 'no'}"),
        (f"  sampled frontier (edges/level over roots "
         f"{list(st.sample_roots)}): "
         + ", ".join(f"{s:.0f}" for s in st.level_edges[:12])
         + (", ..." if len(st.level_edges) > 12 else "")
         + f"  ({st.max_levels} levels, ~{st.reach_edges:.0f} rows "
           f"reached)"),
        "",
        "candidates (ranked by estimated cost):",
    ]
    for i, c in enumerate(report.ranked):
        lines.append("")
        lines.append(_candidate_block(i + 1, c, chosen=(i == 0)))
    if report.skipped:
        lines.append("")
        for engine, reason in report.skipped:
            lines.append(f"skipped {engine}: {reason}")
    return "\n".join(lines)


def _choice_json(c: PhysicalChoice, chosen: bool) -> dict:
    return {
        "label": c.label,
        "engine": c.engine,
        "use_kernel": c.use_kernel,
        "chosen": chosen,
        "caps": {"frontier": c.query.caps.frontier,
                 "result": c.query.caps.result},
        "cost": {"est_us": c.cost.est_us,
                 "total_bytes": c.cost.total_bytes,
                 "levels": c.cost.levels,
                 "result_rows": c.cost.result_rows,
                 # v2: factor-independent split — a rehydrating process
                 # re-prices the plan from these under ITS constants
                 "plain_bytes": c.cost.plain_bytes,
                 "kernel_bytes": c.cost.kernel_bytes,
                 # v3: the predicted per-level push/pull switch decision
                 # (empty for push-only engines)
                 "level_dirs": list(c.cost.level_dirs)},
        "ops": [{"label": op.label, "rows": op.rows, "bytes": op.bytes}
                for op in c.cost.per_op],
    }


def to_json(report: PlannerReport,
            buckets: Optional[Sequence[RootBucket]] = None) -> dict:
    """The machine-readable plan: everything ``render_report`` prints, as
    one plain ``json.dumps``-able dict (the serving layer's plan-cache
    payload).  ``buckets`` optionally embeds a reach-bucketed batch layout
    alongside the ranked candidates."""
    lg = report.logical
    st = report.stats
    doc = {
        "schema_version": PLAN_SCHEMA_VERSION,
        "logical": {
            "root": lg.root,
            "max_depth": lg.max_depth,
            "payload_cols": lg.payload_cols,
            "dedup": lg.dedup,
            "direction": lg.direction,
            "want_cols": list(lg.want_cols),
            "want_depth": lg.want_depth,
            "union_all": lg.union_all,
        },
        "stats": {
            "direction": st.direction,
            "num_vertices": st.num_vertices,
            "num_edges": st.num_edges,
            "density": st.density,
            "avg_degree": st.avg_degree,
            "max_degree": st.max_degree,
            "is_forest": st.is_forest,
            "sample_roots": list(st.sample_roots),
            "level_edges": list(st.level_edges),
            "max_levels": st.max_levels,
            "reach_edges": st.reach_edges,
            # v2: the remaining GraphStats fields, so a plan store can
            # rehydrate the statistics without touching the graph
            "degree_histogram": list(st.degree_histogram),
            "level_vertices": list(st.level_vertices),
            "max_level_edges": st.max_level_edges,
            "root_profiles": [[r, list(p)] for r, p in st.root_profiles],
            "level_walk_edges": list(st.level_walk_edges),
        },
        "cost_constants": report.constants.to_json(),
        "chosen": report.best.label,
        "candidates": [_choice_json(c, chosen=(i == 0))
                       for i, c in enumerate(report.ranked)],
        "skipped": [{"engine": e, "reason": r} for e, r in report.skipped],
    }
    if buckets is not None:
        doc["buckets"] = [{
            "lanes": list(b.indices),
            "roots": list(b.roots),
            "caps": {"frontier": b.caps.frontier, "result": b.caps.result},
            "predicted_reach": b.predicted_reach,
            "predicted_depth": b.predicted_depth,
        } for b in buckets]
    return doc


def explain_json(query, ds: Dataset, *, root: Optional[int] = None,
                 caps: Optional[EngineCaps] = None,
                 include_kernel: bool = False,
                 default_max_depth: Optional[int] = None) -> dict:
    """Plan ``query`` against ``ds`` and return the machine-readable plan."""
    report = plan(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth)
    return to_json(report)


def explain(query, ds: Dataset, *, root: Optional[int] = None,
            caps: Optional[EngineCaps] = None,
            include_kernel: bool = False,
            default_max_depth: Optional[int] = None) -> str:
    """Plan ``query`` against ``ds`` and render the full report."""
    report = plan(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth)
    return render_report(report)
