"""EXPLAIN: render a full planning pass — the logical query, the statistics
it was priced against, and EVERY candidate engine's operator tree annotated
with per-operator estimated rows and bytes (extending ``plan_repr``, which
renders composition only).

The per-operator numbers come from the same :meth:`Operator.estimate` calls
the optimizer ranked with, so EXPLAIN is an audit of the decision, not a
separate pretty-printer.
"""
from __future__ import annotations

from typing import Optional

from repro.core.engine import Dataset
from repro.core.operators import EngineCaps

from .optimize import PhysicalChoice, PlannerReport, plan

__all__ = ["explain", "render_report"]


def _fmt_bytes(b: float) -> str:
    if b < 1024:
        return f"{b:.0f}B"
    if b < 1024 ** 2:
        return f"{b / 1024:.1f}KB"
    return f"{b / 1024 ** 2:.1f}MB"


def _fmt_rows(r: float) -> str:
    return f"{r:.0f}"


def _candidate_block(rank: int, c: PhysicalChoice, chosen: bool) -> str:
    cost = c.cost
    head = (f"#{rank} {c.label:<24s} est {cost.est_us:8.0f}us  "
            f"{_fmt_bytes(cost.total_bytes):>9s}  "
            f"{cost.levels:3d} levels  ~{_fmt_rows(cost.result_rows)} rows")
    if chosen:
        head += "   <- CHOSEN"
    pipeline = c.pipeline
    ops = cost.per_op
    fin = ops[-1]
    lines = [head,
             f"   {fin.label:<66s} rows~{_fmt_rows(fin.rows):>7s} "
             f"bytes~{_fmt_bytes(fin.bytes):>9s}",
             f"     {pipeline.name}(maxrec={pipeline.max_depth})"]
    seed = ops[0]
    lines.append(f"       {seed.label + '            (non-recursive child)':<62s} "
                 f"rows~{_fmt_rows(seed.rows):>7s} bytes~{_fmt_bytes(seed.bytes):>9s}")
    for op in ops[1:-1]:
        lines.append(f"       {op.label:<62s} rows~{_fmt_rows(op.rows):>7s} "
                     f"bytes~{_fmt_bytes(op.bytes):>9s}")
    return "\n".join(lines)


def render_report(report: PlannerReport) -> str:
    lg = report.logical
    st = report.stats
    semantics = "UNION" if lg.dedup and not lg.union_all else (
        "UNION ALL == BFS (forest)" if lg.dedup else "UNION ALL (raw walk)")
    out_cols = list(lg.want_cols) + (["depth"] if lg.want_depth else [])
    lines = [
        "EXPLAIN recursive traversal",
        (f"logical: root={lg.root}  direction={lg.direction}  "
         f"max_depth={lg.max_depth}  payloads={lg.payload_cols}  "
         f"{semantics}"),
        f"output:  [{', '.join(out_cols)}]",
        (f"stats[{st.direction}]: V={st.num_vertices} EJ={st.num_edges} "
         f"density={st.density:.2f} avg_deg={st.avg_degree:.2f} "
         f"max_deg={st.max_degree} forest={'yes' if st.is_forest else 'no'}"),
        (f"  sampled frontier (edges/level over roots "
         f"{list(st.sample_roots)}): "
         + ", ".join(f"{s:.0f}" for s in st.level_edges[:12])
         + (", ..." if len(st.level_edges) > 12 else "")
         + f"  ({st.max_levels} levels, ~{st.reach_edges:.0f} rows "
           f"reached)"),
        "",
        "candidates (ranked by estimated cost):",
    ]
    for i, c in enumerate(report.ranked):
        lines.append("")
        lines.append(_candidate_block(i + 1, c, chosen=(i == 0)))
    if report.skipped:
        lines.append("")
        for engine, reason in report.skipped:
            lines.append(f"skipped {engine}: {reason}")
    return "\n".join(lines)


def explain(query, ds: Dataset, *, root: Optional[int] = None,
            caps: Optional[EngineCaps] = None,
            include_kernel: bool = False,
            default_max_depth: Optional[int] = None) -> str:
    """Plan ``query`` against ``ds`` and render the full report."""
    report = plan(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth)
    return render_report(report)
