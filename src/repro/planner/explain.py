"""EXPLAIN: render a full planning pass — the logical query, the statistics
it was priced against, and EVERY candidate engine's operator tree annotated
with per-operator estimated rows and bytes (extending ``plan_repr``, which
renders composition only).

The per-operator numbers come from the same :meth:`Operator.estimate` calls
the optimizer ranked with, so EXPLAIN is an audit of the decision, not a
separate pretty-printer.

:func:`to_json` renders the same planning pass MACHINE-READABLY (one plain
dict, ``json.dumps``-able): the serving layer caches these per query shape
so repeated traffic skips parsing/stats/costing, and external tooling can
diff plans across PRs.  ``schema_version`` gates consumers; the schema is
documented in docs/serving.md.

Schema version 2 extends v1 with everything a COLD PROCESS needs to
rehydrate a plan without re-planning (:mod:`repro.planner.plan_store`):
the full graph statistics (per-root profiles, walk profile, histogram),
the factor-independent ``plain_bytes``/``kernel_bytes`` cost split per
candidate, and the :class:`~repro.planner.cost.CostConstants` the pass was
priced with.

Schema version 3 adds the direction-optimizing switch decision: each
candidate's cost carries ``level_dirs`` (the predicted per-level
``push``/``pull`` choice of a :class:`~repro.core.operators.
DirectionSwitch` pipeline; empty for push-only engines), and the cost
constants carry the refittable ``pull_alpha``/``pull_beta`` thresholds.

Schema version 4 adds the EXPLAIN ANALYZE section: every plan document
carries a top-level ``analyze`` key (``null`` until an execution fills
it) holding per-operator predicted vs. ACTUAL rows/bytes and per-level
predicted vs. TAKEN push/pull directions.  :func:`explain_analyze`
executes the chosen (or a forced) candidate and reconciles the cost
model against the executed :class:`~repro.core.operators.BFSResult`:
the actual per-level edge counts are histogrammed from ``row_depths``
(so the actual rows ARE the result's rows, not a second estimate) and
substituted into the same :func:`~repro.planner.cost.pipeline_cost`
walk the optimizer priced with — predicted and actual columns are the
one cost model evaluated at predicted vs. measured cardinalities.
Schema version 5 records the semiring value plane: the logical section
carries ``workload`` (the semiring name, ``reach`` for boolean BFS) and
``weight_col`` (the edge-weight column of a weighted traversal), and every
candidate records the ``semiring`` its pipeline runs under — so a plan
store keyed on query shape can never serve a boolean plan to a weighted
query or vice versa.  v1..v4 documents still load through
:func:`repro.planner.plan_store.migrate_plan_doc` (they default to
``workload='reach'``).

Schema version 6 records the admission guard ladder: every plan document
carries a top-level ``admission`` key (``null`` until a guarded serving
session stamps it) holding the most recent request's per-root
:class:`~repro.planner.guards.GuardResult` decisions and the
``guard_degrade_us``/``guard_reject_us`` budgets they were made under
(the ``cost_constants`` section also gained those two fields).  v1..v5
documents migrate with ``admission: null`` — pre-guard writers never
guarded anything.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import Dataset
from repro.core.operators import BFSResult, EngineCaps

from .cost import column_bytes, pipeline_cost
from .optimize import PhysicalChoice, PlannerReport, RootBucket, plan
from .stats import _bfs_profile

__all__ = ["analyze_result", "explain", "explain_analyze", "explain_json",
           "render_analyze", "render_report", "to_json"]

PLAN_SCHEMA_VERSION = 6


def _fmt_bytes(b: float) -> str:
    if b < 1024:
        return f"{b:.0f}B"
    if b < 1024 ** 2:
        return f"{b / 1024:.1f}KB"
    return f"{b / 1024 ** 2:.1f}MB"


def _fmt_rows(r: float) -> str:
    return f"{r:.0f}"


def _candidate_block(rank: int, c: PhysicalChoice, chosen: bool) -> str:
    cost = c.cost
    head = (f"#{rank} {c.label:<24s} est {cost.est_us:8.0f}us  "
            f"{_fmt_bytes(cost.total_bytes):>9s}  "
            f"{cost.levels:3d} levels  ~{_fmt_rows(cost.result_rows)} rows")
    if chosen:
        head += "   <- CHOSEN"
    pipeline = c.pipeline
    ops = cost.per_op
    fin = ops[-1]
    lines = [head,
             f"   {fin.label:<66s} rows~{_fmt_rows(fin.rows):>7s} "
             f"bytes~{_fmt_bytes(fin.bytes):>9s}",
             f"     {pipeline.name}(maxrec={pipeline.max_depth})"]
    seed = ops[0]
    lines.append(f"       {seed.label + '            (non-recursive child)':<62s} "
                 f"rows~{_fmt_rows(seed.rows):>7s} bytes~{_fmt_bytes(seed.bytes):>9s}")
    for op in ops[1:-1]:
        lines.append(f"       {op.label:<62s} rows~{_fmt_rows(op.rows):>7s} "
                     f"bytes~{_fmt_bytes(op.bytes):>9s}")
    return "\n".join(lines)


def render_report(report: PlannerReport) -> str:
    lg = report.logical
    st = report.stats
    semantics = "UNION" if lg.dedup and not lg.union_all else (
        "UNION ALL == BFS (forest)" if lg.dedup else "UNION ALL (raw walk)")
    out_cols = list(lg.want_cols) + (["depth"] if lg.want_depth else [])
    lines = [
        "EXPLAIN recursive traversal",
        (f"logical: root={lg.root}  direction={lg.direction}  "
         f"max_depth={lg.max_depth}  payloads={lg.payload_cols}  "
         f"{semantics}"),
        f"output:  [{', '.join(out_cols)}]",
        (f"stats[{st.direction}]: V={st.num_vertices} EJ={st.num_edges} "
         f"density={st.density:.2f} avg_deg={st.avg_degree:.2f} "
         f"max_deg={st.max_degree} forest={'yes' if st.is_forest else 'no'}"),
        (f"  sampled frontier (edges/level over roots "
         f"{list(st.sample_roots)}): "
         + ", ".join(f"{s:.0f}" for s in st.level_edges[:12])
         + (", ..." if len(st.level_edges) > 12 else "")
         + f"  ({st.max_levels} levels, ~{st.reach_edges:.0f} rows "
           f"reached)"),
        "",
        "candidates (ranked by estimated cost):",
    ]
    for i, c in enumerate(report.ranked):
        lines.append("")
        lines.append(_candidate_block(i + 1, c, chosen=(i == 0)))
    if report.skipped:
        lines.append("")
        for engine, reason in report.skipped:
            lines.append(f"skipped {engine}: {reason}")
    return "\n".join(lines)


def _choice_json(c: PhysicalChoice, chosen: bool) -> dict:
    return {
        "label": c.label,
        "engine": c.engine,
        "use_kernel": c.use_kernel,
        # v5: the semiring the candidate's pipeline runs under
        "semiring": getattr(c.pipeline, "semiring", "reach"),
        "chosen": chosen,
        # the coalesced lane count a batch engine was priced for (1 for
        # the one-root-at-a-time engines)
        "lanes": getattr(c.query, "lanes", 1),
        "caps": {"frontier": c.query.caps.frontier,
                 "result": c.query.caps.result},
        "cost": {"est_us": c.cost.est_us,
                 "total_bytes": c.cost.total_bytes,
                 "levels": c.cost.levels,
                 "result_rows": c.cost.result_rows,
                 # v2: factor-independent split — a rehydrating process
                 # re-prices the plan from these under ITS constants
                 "plain_bytes": c.cost.plain_bytes,
                 "kernel_bytes": c.cost.kernel_bytes,
                 # v3: the predicted per-level push/pull switch decision
                 # (empty for push-only engines)
                 "level_dirs": list(c.cost.level_dirs)},
        "ops": [{"label": op.label, "rows": op.rows, "bytes": op.bytes}
                for op in c.cost.per_op],
    }


def to_json(report: PlannerReport,
            buckets: Optional[Sequence[RootBucket]] = None,
            analyze: Optional[dict] = None) -> dict:
    """The machine-readable plan: everything ``render_report`` prints, as
    one plain ``json.dumps``-able dict (the serving layer's plan-cache
    payload).  ``buckets`` optionally embeds a reach-bucketed batch layout
    alongside the ranked candidates; ``analyze`` optionally embeds an
    EXPLAIN ANALYZE section (v4; ``null`` until an execution fills it)."""
    lg = report.logical
    st = report.stats
    doc = {
        "schema_version": PLAN_SCHEMA_VERSION,
        "logical": {
            "root": lg.root,
            "max_depth": lg.max_depth,
            "payload_cols": lg.payload_cols,
            "dedup": lg.dedup,
            "direction": lg.direction,
            "want_cols": list(lg.want_cols),
            "want_depth": lg.want_depth,
            "union_all": lg.union_all,
            # v5: the semiring value plane axes
            "workload": getattr(lg, "workload", "reach"),
            "weight_col": getattr(lg, "weight_col", None),
        },
        "stats": {
            "direction": st.direction,
            "num_vertices": st.num_vertices,
            "num_edges": st.num_edges,
            "density": st.density,
            "avg_degree": st.avg_degree,
            "max_degree": st.max_degree,
            "is_forest": st.is_forest,
            "sample_roots": list(st.sample_roots),
            "level_edges": list(st.level_edges),
            "max_levels": st.max_levels,
            "reach_edges": st.reach_edges,
            # v2: the remaining GraphStats fields, so a plan store can
            # rehydrate the statistics without touching the graph
            "degree_histogram": list(st.degree_histogram),
            "level_vertices": list(st.level_vertices),
            "max_level_edges": st.max_level_edges,
            "root_profiles": [[r, list(p)] for r, p in st.root_profiles],
            "level_walk_edges": list(st.level_walk_edges),
        },
        "cost_constants": report.constants.to_json(),
        "chosen": report.best.label,
        "candidates": [_choice_json(c, chosen=(i == 0))
                       for i, c in enumerate(report.ranked)],
        "skipped": [{"engine": e, "reason": r} for e, r in report.skipped],
        # v4: the EXPLAIN ANALYZE section — null until an execution
        # reconciles predicted vs. actual (see explain_analyze)
        "analyze": analyze,
        # v6: admission guard decisions — null until a guarded serving
        # session stamps the most recent request's ladder outcome here
        "admission": None,
    }
    if buckets is not None:
        doc["buckets"] = [{
            "lanes": list(b.indices),
            "roots": list(b.roots),
            "caps": {"frontier": b.caps.frontier, "result": b.caps.result},
            "predicted_reach": b.predicted_reach,
            "predicted_depth": b.predicted_depth,
        } for b in buckets]
    return doc


def explain_json(query, ds: Dataset, *, root: Optional[int] = None,
                 caps: Optional[EngineCaps] = None,
                 include_kernel: bool = False,
                 default_max_depth: Optional[int] = None) -> dict:
    """Plan ``query`` against ``ds`` and return the machine-readable plan."""
    report = plan(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth)
    return to_json(report)


def explain(query, ds: Dataset, *, root: Optional[int] = None,
            caps: Optional[EngineCaps] = None,
            include_kernel: bool = False,
            default_max_depth: Optional[int] = None) -> str:
    """Plan ``query`` against ``ds`` and render the full report."""
    report = plan(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth)
    return render_report(report)


# ---------------------------------------------------------------------------
# EXPLAIN ANALYZE (schema v4): predicted vs. actual, from an executed result
# ---------------------------------------------------------------------------

_DIR_CODES = {0: "push", 1: "pull"}


def _taken_dirs(result: BFSResult) -> list:
    """Per-level TAKEN push/pull directions decoded from the executed
    ``level_dirs`` (empty for push-only engines)."""
    dirs = getattr(result, "level_dirs", None)
    if dirs is None:
        return []
    dv = np.asarray(dirs).reshape(-1)
    return [_DIR_CODES[int(c)] for c in dv if int(c) in _DIR_CODES]


def _actual_level_edges(result: BFSResult) -> list[int]:
    """Actual edges emitted per BFS level, histogrammed STRAIGHT from the
    result's ``row_depths`` — by construction the per-level actuals sum to
    ``result.count``, so "actual rows" in the ANALYZE report means exactly
    the rows this execution returned."""
    if result.row_depths is None:
        raise ValueError("result carries no row_depths; cannot ANALYZE")
    rd = np.asarray(result.row_depths)[: int(result.count)]
    rd = rd[rd >= 0]
    if rd.size == 0:
        return []
    return [int(x) for x in np.bincount(rd.astype(np.int64))]


def _actual_stats(choice: PhysicalChoice, report: PlannerReport,
                  ds: Dataset, result: BFSResult, root: int):
    """The MEASURED counterpart of the planner's sampled ``GraphStats``:
    per-level edge rows come from the executed result (``row_depths``
    histogram); per-level new-vertex counts come from one host-side BFS
    from the actual root (the in-loop cardinality a result cannot carry).
    Substituting these into the same ``pipeline_cost`` walk re-prices every
    operator at the cardinalities the execution really saw."""
    edges = _actual_level_edges(result)
    ctx = ds.context(choice.query.direction)
    src = np.asarray(ctx.join_src).astype(np.int64)
    dst = np.asarray(ctx.join_dst).astype(np.int64)
    if ctx.bidir:
        src, dst = (np.concatenate([src, dst]), np.concatenate([dst, src]))
    _, verts = _bfs_profile(src, dst, int(root), int(ds.num_vertices),
                            max(len(edges), 1))
    verts = verts[: len(edges)] + [0] * max(len(edges) - len(verts), 0)
    return dataclasses.replace(
        report.stats,
        sample_roots=(int(root),),
        level_edges=tuple(float(x) for x in edges),
        level_vertices=tuple(float(x) for x in verts),
        max_level_edges=int(max(edges, default=0)),
        reach_edges=float(sum(edges)),
        max_levels=len(edges),
        root_profiles=((int(root), tuple(int(x) for x in edges)),),
        level_walk_edges=tuple(float(x) for x in edges))


def analyze_result(choice: PhysicalChoice, report: PlannerReport,
                   ds: Dataset, result: BFSResult, *, root: int,
                   elapsed_us: Optional[float] = None) -> dict:
    """Reconcile one executed :class:`BFSResult` against the plan that
    produced it: the ``analyze`` section of a schema-v4 plan document.

    Predicted numbers are the candidate's :class:`~repro.planner.cost.
    PlanCost` (what the optimizer ranked); actual numbers re-run the SAME
    cost walk over statistics measured from this execution, so per-operator
    "actual rows" are derived from the result's own ``row_depths``/
    ``count`` — when the sampled profile was exact (e.g. the root was a
    sample root of a single-profile graph), predicted == actual to the
    row."""
    actual_stats = _actual_stats(choice, report, ds, result, root)
    col_bytes = column_bytes(ds.table)
    row_bytes = ds.rows.width * 4
    actual = pipeline_cost(choice.pipeline, actual_stats,
                           row_bytes=row_bytes, col_bytes=col_bytes,
                           constants=report.constants)
    pred = choice.cost
    edges_act = list(actual_stats.level_edges)
    taken = _taken_dirs(result)
    n_levels = max(pred.levels, actual.levels, len(taken))
    levels = []
    for lvl in range(n_levels):
        levels.append({
            "level": lvl,
            "dir_predicted": (pred.level_dirs[lvl]
                              if lvl < len(pred.level_dirs) else None),
            "dir_taken": taken[lvl] if lvl < len(taken) else None,
            "edges_predicted": report.stats.edges_at(lvl),
            "edges_actual": (int(edges_act[lvl])
                             if lvl < len(edges_act) else 0),
        })
    return {
        "engine": choice.label,
        "root": int(root),
        "elapsed_us": (None if elapsed_us is None else float(elapsed_us)),
        "result_count": int(result.count),
        "overflow": bool(np.any(np.asarray(result.overflow))),
        "predicted": {"rows": pred.result_rows, "bytes": pred.total_bytes,
                      "levels": pred.levels, "est_us": pred.est_us,
                      "level_dirs": list(pred.level_dirs)},
        "actual": {"rows": actual.result_rows, "bytes": actual.total_bytes,
                   "levels": actual.levels,
                   "est_us": actual.est_us,     # the model at actual cards
                   "level_dirs": taken},
        "ops": [{"label": p.label,
                 "rows_predicted": p.rows, "bytes_predicted": p.bytes,
                 "rows_actual": a.rows, "bytes_actual": a.bytes}
                for p, a in zip(pred.per_op, actual.per_op)],
        "levels": levels,
    }


def _find_candidate(report: PlannerReport, engine: str) -> PhysicalChoice:
    for c in report.ranked:
        if c.label == engine or c.engine == engine:
            return c
    for eng, reason in report.skipped:
        if eng == engine:
            raise ValueError(f"engine {engine!r} was skipped for this "
                             f"query: {reason}")
    known = sorted({c.label for c in report.ranked})
    raise ValueError(f"unknown engine {engine!r}; ranked: {known}")


def explain_analyze(query, ds: Dataset, *, root: Optional[int] = None,
                    engine: Optional[str] = None,
                    caps: Optional[EngineCaps] = None,
                    include_kernel: bool = False,
                    default_max_depth: Optional[int] = None,
                    check_overflow: bool = True) -> dict:
    """EXPLAIN ANALYZE: plan ``query``, EXECUTE the chosen candidate (or
    the forced ``engine``) on the query's root, and return the schema-v4
    plan document with its ``analyze`` section filled — per-operator
    predicted vs. actual rows/bytes, predicted vs. actual levels, and the
    per-level predicted vs. taken push/pull directions of a
    direction-optimizing pipeline.  ``render_analyze`` formats it."""
    report = plan(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth)
    choice = report.best if engine is None else _find_candidate(report,
                                                                engine)
    run_root = root if root is not None else report.logical.root
    if run_root is None:
        raise ValueError("explain_analyze executes the plan: the query "
                         "needs a literal root (or pass root=...)")
    t0 = time.perf_counter()
    result = choice.run(ds, int(run_root), check_overflow=check_overflow)
    np.asarray(result.count)     # synchronize: the timing needs completion
    elapsed_us = (time.perf_counter() - t0) * 1e6
    analysis = analyze_result(choice, report, ds, result,
                              root=int(run_root), elapsed_us=elapsed_us)
    return to_json(report, analyze=analysis)


def render_analyze(doc: dict) -> str:
    """Human-readable EXPLAIN ANALYZE from a schema-v4 plan document with
    a filled ``analyze`` section."""
    a = doc.get("analyze")
    if a is None:
        raise ValueError("plan document has no analyze section "
                         "(run explain_analyze first)")
    p, ac = a["predicted"], a["actual"]
    lines = [
        f"EXPLAIN ANALYZE  engine={a['engine']}  root={a['root']}",
        (f"total: predicted {_fmt_rows(p['rows'])} rows / "
         f"{_fmt_bytes(p['bytes'])} / {p['levels']} levels "
         f"(est {p['est_us']:.0f}us)  ->  actual "
         f"{_fmt_rows(ac['rows'])} rows / {_fmt_bytes(ac['bytes'])} / "
         f"{ac['levels']} levels"
         + (f" (measured {a['elapsed_us']:.0f}us)"
            if a.get("elapsed_us") is not None else "")),
    ]
    for op in a["ops"]:
        lines.append(
            f"  {op['label']:<58s} rows {_fmt_rows(op['rows_predicted']):>7s}"
            f" -> {_fmt_rows(op['rows_actual']):>7s}   bytes "
            f"{_fmt_bytes(op['bytes_predicted']):>9s} -> "
            f"{_fmt_bytes(op['bytes_actual']):>9s}")
    if any(lv["dir_predicted"] or lv["dir_taken"] for lv in a["levels"]):
        lines.append("  per-level direction (predicted -> taken):")
        for lv in a["levels"]:
            lines.append(
                f"    level {lv['level']:<3d} "
                f"{lv['dir_predicted'] or '-':<5s} -> "
                f"{lv['dir_taken'] or '-':<5s}  edges "
                f"{_fmt_rows(lv['edges_predicted']):>7s} -> "
                f"{lv['edges_actual']}")
    return "\n".join(lines)
