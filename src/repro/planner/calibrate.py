"""Self-calibrating cost model: close the loop from MEASURED serving
latencies back into the planner's :class:`~repro.planner.cost.CostConstants`.

The cost model prices a plan as ``base + level_us * levels +
(plain_bytes + kernel_factor * kernel_bytes) / bytes_per_us``
(:func:`repro.planner.cost.estimate_us`).  The four constants were
hand-calibrated for one CPU profile; on any other backend (the ROADMAP's
TPU targets) the ranking can silently invert.  This module makes them
measured:

* the shared bucket-dispatch executor (:func:`repro.core.engine.
  dispatch_buckets`) times every served bucket once, consistently — the
  serving session feeds each ``(plan signature, levels, plain_bytes,
  kernel_bytes, measured_us)`` observation to a :class:`Calibrator`;
* the calibrator accumulates the least-squares NORMAL EQUATIONS online
  (O(16) state, no sample buffer needed to refit) for the model above,
  which is linear in ``w = [base_us, level_us, 1/bytes_per_us,
  kernel_factor/bytes_per_us]``;
* :meth:`Calibrator.refit` solves the ridge-anchored system (the prior
  constants regularize degenerate directions — e.g. no kernel traffic yet)
  and returns a new :class:`CostConstants`, which the serving session feeds
  into every subsequent :func:`repro.planner.optimize.plan` call;
* :func:`measured_kernel_factor` replaces the old static 0.7x/200x kernel
  guess with a real timed micro-benchmark of the Pallas ``frontier_expand``
  kernel against the XLA expansion, run once per process and cached.

Calibration state serializes (:meth:`Calibrator.state_dict`) into the
persistent plan store, so a warm process resumes with the previous
process's fitted constants.
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple, Optional, Tuple

import numpy as np

from .cost import CostConstants, DEFAULT_CONSTANTS
from .stats import GraphStats

__all__ = ["Calibrator", "Observation", "kernel_expand_fn",
           "kernel_pull_fn", "measured_factors_state",
           "measured_kernel_factor", "plan_signature",
           "restore_measured_factors", "resolve_constants",
           "set_measured_kernel_factor", "stats_digest"]


# ---------------------------------------------------------------------------
# plan signatures: what an observation is keyed by
# ---------------------------------------------------------------------------

def stats_digest(stats: GraphStats) -> str:
    """A short stable digest of the graph statistics a plan was priced
    against — observations from different graphs (or a regenerated graph)
    must not be conflated under one signature."""
    h = hashlib.sha1()
    h.update(repr((stats.direction, stats.num_vertices, stats.num_edges,
                   stats.max_degree, stats.is_forest,
                   tuple(round(x, 3) for x in stats.level_edges),
                   tuple(round(x, 3) for x in stats.level_walk_edges),
                   )).encode())
    return h.hexdigest()[:12]


def plan_signature(label: str, direction: str, caps, digest: str,
                   lanes: int = 1, shape: Tuple = (),
                   mix: Tuple = (), workload: str = "reach") -> Tuple:
    """The calibration key of one served plan: engine label (kernel
    included), direction, the bucket's caps, the graph-stats digest, the
    dispatched lane count, the query-shape axes (max_depth, payloads,
    dedup, ...), the semiring ``workload``, and — for
    direction-optimizing plans — the predicted per-level push/pull
    ``mix``.  Lanes and shape matter: a 1-lane and an 8-lane dispatch of
    the same pipeline do different amounts of work, and two query shapes
    clamped to the same caps must not pool their latencies under one
    signature.  (For the bit-parallel ``multiquery`` engine the lane count
    is doubly load-bearing: one signature covers one coalesced word width,
    and its byte predictors arrive UNSCALED — the plan already prices the
    whole batch — where vmap-batched engines are scaled by the lane
    count.)  The mix matters for the same reason: a push-heavy and a
    pull-heavy execution of the SAME diropt pipeline move very different
    bytes, and pooling them would corrupt the per-signature means the
    refit validator trusts.  So does the workload: a weighted traversal
    of the same engine moves the value plane's extra bytes and can run
    extra correction levels, so it must not pool with boolean reach.
    Shape and mix are canonicalized to strings so signatures stay flat
    primitives and round-trip JSON (the plan store) exactly."""
    return (label, direction, int(caps.frontier), int(caps.result), digest,
            int(lanes), repr(tuple(shape)), repr(tuple(mix)), str(workload))


class Observation(NamedTuple):
    """One measured bucket dispatch, paired with the cost model's inputs."""

    signature: Tuple
    levels: int
    plain_bytes: float
    kernel_bytes: float
    measured_us: float


# ---------------------------------------------------------------------------
# the measured kernel factor
# ---------------------------------------------------------------------------

# kernel plug-ins, one per (kernel name, backend): a JAX backend change
# mid-process (tests do this) must not serve a stale interpret-mode choice
_KERNEL_FNS: dict = {}


def _backend() -> str:
    import jax
    return jax.default_backend()


def kernel_expand_fn():
    """The Pallas ``frontier_expand`` plug-in for ``CSRIndexJoin``, created
    once per backend so every planned pipeline shares one jit cache entry.
    Interpret mode is used off-TPU (numerically identical, not
    perf-representative)."""
    key = ("frontier_expand", _backend())
    if key not in _KERNEL_FNS:
        from repro.kernels.frontier_expand.ops import make_expand_fn
        _KERNEL_FNS[key] = make_expand_fn(interpret=key[1] != "tpu")
    return _KERNEL_FNS[key]


def kernel_pull_fn():
    """The Pallas ``frontier_pull`` plug-in for ``PullStep`` (the
    bottom-up membership-test kernel), created once per backend."""
    key = ("frontier_pull", _backend())
    if key not in _KERNEL_FNS:
        from repro.kernels.frontier_pull.ops import make_pull_fn
        _KERNEL_FNS[key] = make_pull_fn(interpret=key[1] != "tpu")
    return _KERNEL_FNS[key]


# measured kernel factors, keyed on (backend, kernel name): a backend
# change mid-process must not serve a stale factor, and every kernel
# (frontier_expand, frontier_pull) gets its own measurement
_MEASURED_KERNEL_FACTORS: dict = {}

_MEASURE_V = 256          # micro-benchmark graph size
_MEASURE_E = 1024
_MEASURE_CAP = 512
_MEASURE_REPEAT = 5

KERNEL_NAMES = ("frontier_expand", "frontier_pull", "spmm_segment")


def set_measured_kernel_factor(value: Optional[float], *,
                               kernel: str = "frontier_expand",
                               backend: Optional[str] = None) -> None:
    """Inject (or, with ``None``, clear) the cached factor for one
    (backend, kernel) cell — used by tests and by plan-store rehydration
    to skip the micro-benchmark.  ``backend`` defaults to the CURRENT JAX
    backend (the cell a subsequent same-backend lookup will hit)."""
    key = (backend if backend is not None else _backend(), kernel)
    if value is None:
        _MEASURED_KERNEL_FACTORS.pop(key, None)
    else:
        _MEASURED_KERNEL_FACTORS[key] = float(value)


def measured_factors_state() -> dict:
    """JSON-serializable snapshot of every measured (backend, kernel)
    factor (persisted in the plan store)."""
    return {f"{b}/{k}": v for (b, k), v in _MEASURED_KERNEL_FACTORS.items()}


def restore_measured_factors(state: dict) -> None:
    """Seed the per-(backend, kernel) cache from a plan-store snapshot
    (existing cells win — this process's own measurements are fresher)."""
    for key, v in (state or {}).items():
        b, _, k = key.partition("/")
        _MEASURED_KERNEL_FACTORS.setdefault((b, k), float(v))


def _median_us(fn, *args) -> float:
    import time

    import jax

    jax.block_until_ready(fn(*args))                 # compile
    ts = []
    for _ in range(_MEASURE_REPEAT):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _measure_expand_factor() -> float:
    import jax
    import jax.numpy as jnp

    from repro.core.csr import build_csr, expand_frontier

    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, _MEASURE_V, _MEASURE_E), jnp.int32)
    csr = build_csr(src, _MEASURE_V)
    targets = jnp.asarray(rng.integers(0, _MEASURE_V, _MEASURE_CAP),
                          jnp.int32)
    valid = jnp.ones((_MEASURE_CAP,), bool)
    kern_fn = kernel_expand_fn()

    plain = jax.jit(lambda t, v: expand_frontier(csr, t, v, _MEASURE_CAP))
    kern = jax.jit(lambda t, v: kern_fn(csr, t, v, _MEASURE_CAP))
    t_plain = max(_median_us(plain, targets, valid), 1e-3)
    t_kern = max(_median_us(kern, targets, valid), 1e-3)
    return float(np.clip(t_kern / t_plain, 1e-3, 1e6))


def _measure_pull_factor() -> float:
    import jax
    import jax.numpy as jnp

    from repro.core.csr import build_csr
    from repro.core.engine import Dataset
    from repro.core.operators import _dense_pull
    from repro.core.table import ColumnTable

    rng = np.random.default_rng(0)
    src = rng.integers(0, _MEASURE_V, _MEASURE_E).astype(np.int32)
    dst = rng.integers(0, _MEASURE_V, _MEASURE_E).astype(np.int32)
    table = ColumnTable.from_numpy({
        "id": np.arange(_MEASURE_E, dtype=np.int32), "from": src, "to": dst,
        "name": np.zeros((_MEASURE_E, 4), np.float32)})
    ds = Dataset.prepare(table, _MEASURE_V)
    ds.ensure_reverse()                     # the pull kernel walks it
    ctx = ds.context("outbound")
    frontier = jnp.asarray(rng.random(_MEASURE_V) < 0.25)
    visited = jnp.asarray(rng.random(_MEASURE_V) < 0.5) | frontier
    kern_fn = kernel_pull_fn()

    plain = jax.jit(lambda f, vis: _dense_pull(ctx, f, vis))
    kern = jax.jit(lambda f, vis: _dense_pull(ctx, f, vis, kern_fn))
    t_plain = max(_median_us(plain, frontier, visited), 1e-3)
    t_kern = max(_median_us(kern, frontier, visited), 1e-3)
    return float(np.clip(t_kern / t_plain, 1e-3, 1e6))


def _measure_spmm_factor() -> float:
    """Time the Pallas ``spmm_segment`` dense ⊕-combine against the plain
    XLA (sum, ×) scatter it replaces inside ``WeightedDenseStep``."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, _MEASURE_V, _MEASURE_E), jnp.int32)
    dst = jnp.asarray(rng.integers(0, _MEASURE_V, _MEASURE_E), jnp.int32)
    w = jnp.asarray(rng.random(_MEASURE_E), jnp.float32)
    fval = jnp.asarray(rng.random(_MEASURE_V), jnp.float32)
    interpret = _backend() != "tpu"

    from repro.kernels.spmm_segment import spmm_segment

    def plain(v):
        return jnp.zeros((_MEASURE_V,), jnp.float32).at[dst].add(
            v[src] * w, mode="drop")

    def kern(v):
        return spmm_segment(v[:, None], src, dst, w, _MEASURE_V,
                            use_pallas=True, interpret=interpret)[:, 0]

    t_plain = max(_median_us(jax.jit(plain), fval), 1e-3)
    t_kern = max(_median_us(jax.jit(kern), fval), 1e-3)
    return float(np.clip(t_kern / t_plain, 1e-3, 1e6))


def measured_kernel_factor(*, kernel: str = "frontier_expand",
                           refresh: bool = False) -> float:
    """MEASURE the relative cost of a Pallas kernel vs its XLA counterpart
    on the CURRENT backend: one tiny synthetic graph, both paths jitted,
    median of a few timed calls.  Cached per (backend, kernel) — the first
    pricing on a backend pays it once, and a backend change mid-process
    gets a fresh measurement instead of a stale cached one.

    ``frontier_expand`` times the VMEM-tiled expansion vs the XLA
    two-phase expansion; ``frontier_pull`` times the bottom-up
    membership-test kernel vs the XLA reverse-CSR pull; ``spmm_segment``
    times the Pallas dense ⊕-combine vs the plain (sum, ×) scatter the
    weighted dense step otherwise runs.  This replaces the
    old static 0.7x-on-TPU / 200x-elsewhere constant: on a real TPU the
    measurement reflects the fused kernel, on CPU it reflects interpret
    mode (large, correctly steering the planner away off-TPU)."""
    if kernel not in KERNEL_NAMES:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"known: {KERNEL_NAMES}")
    key = (_backend(), kernel)
    if key in _MEASURED_KERNEL_FACTORS and not refresh:
        return _MEASURED_KERNEL_FACTORS[key]
    factor = {"frontier_expand": _measure_expand_factor,
              "frontier_pull": _measure_pull_factor,
              "spmm_segment": _measure_spmm_factor}[kernel]()
    _MEASURED_KERNEL_FACTORS[key] = factor
    return factor


def resolve_constants(constants: Optional[CostConstants], *,
                      need_kernel: bool) -> CostConstants:
    """The constants a planning pass will actually price with: the given
    (or default) constants, with an unresolved ``kernel_factor`` replaced
    by the measured one IFF a kernel candidate is being priced (so plain
    planning never pays the micro-benchmark)."""
    consts = constants if constants is not None else DEFAULT_CONSTANTS
    if need_kernel and consts.kernel_factor is None:
        consts = consts._replace(kernel_factor=measured_kernel_factor())
    return consts


# ---------------------------------------------------------------------------
# the online least-squares calibrator
# ---------------------------------------------------------------------------

_N_PARAMS = 4      # w = [base_us, level_us, 1/bpu, kernel_factor/bpu]


def _kendall_tau(pred, meas) -> float:
    """Kendall rank correlation between predicted and measured times
    (pairs tied on either side contribute nothing)."""
    n = len(pred)
    concordant = discordant = 0
    for i in range(n):
        for j in range(i + 1, n):
            s = (pred[i] - pred[j]) * (meas[i] - meas[j])
            if s > 0:
                concordant += 1
            elif s < 0:
                discordant += 1
    total = n * (n - 1) // 2
    return (concordant - discordant) / total if total else 0.0


class Calibrator:
    """Online refit of :class:`CostConstants` from measured plan latencies.

    Observations accumulate as normal equations (``X^T X`` / ``X^T y``), so
    memory is O(1) in traffic volume; per-signature running means and a
    bounded tail of raw observations are kept for validation, introspection
    and store persistence.

    :meth:`refit` solves the ridge-anchored system — with few observations
    the result stays near the prior, with many the data dominates — and
    then VALIDATES the candidate against the per-signature aggregates
    before adopting it: the new constants must (a) fit the measured
    latencies better than the incumbent (lower RMSE) and (b) actually rank
    the observed plans — positive Kendall tau between predicted and
    measured times.  Measured serving latency includes effects the cost
    model does not carry (dispatch overhead, scheduler noise); when those
    dominate, the honest least-squares direction is garbage and adopting
    it could invert the planner's ranking currency.  Validation makes the
    loop fail SAFE: garbage windows keep the incumbent constants, clean
    windows (the model explains the hardware) move them."""

    def __init__(self, prior: CostConstants = DEFAULT_CONSTANTS, *,
                 min_observations: int = 8, min_signatures: int = 3,
                 ridge: float = 1.0, max_log: int = 256,
                 max_signatures: int = 512):
        self.prior = prior
        self.constants = prior
        self.min_observations = int(min_observations)
        self.min_signatures = int(min_signatures)
        self.ridge = float(ridge)
        self.max_log = int(max_log)
        self.max_signatures = int(max_signatures)
        self._xtx = np.zeros((_N_PARAMS, _N_PARAMS))
        self._xty = np.zeros(_N_PARAMS)
        # signature -> [n, us_sum, levels, plain_bytes, kernel_bytes]
        self._sig_stats: dict = {}
        self.count = 0
        self.kernel_count = 0
        self.refits = 0
        self.rejected_refits = 0
        self.discarded = 0
        self.log: list[Observation] = []

    # -- recording --------------------------------------------------------
    def observe(self, signature: Tuple, *, levels: int, plain_bytes: float,
                kernel_bytes: float, measured_us: float) -> None:
        """Record one measured dispatch.  ``plain_bytes``/``kernel_bytes``
        are the plan's factor-independent byte split
        (:attr:`~repro.planner.cost.PlanCost.plain_bytes`).

        Non-finite or negative measurements are DISCARDED (counted in
        ``discarded``): a single NaN entering the normal equations would
        poison every later refit, and a clock can glitch — the calibrator
        must never let one bad sample corrupt its state."""
        m = float(measured_us)
        if not np.isfinite(m) or m < 0.0:
            self.discarded += 1
            return
        x = np.array([1.0, float(levels), float(plain_bytes),
                      float(kernel_bytes)])
        self._xtx += np.outer(x, x)
        self._xty += x * float(measured_us)
        self.count += 1
        if kernel_bytes > 0.0:
            self.kernel_count += 1
        sig = tuple(signature)
        slot = self._sig_stats.get(sig)
        if slot is not None:
            slot[0] += 1
            slot[1] += float(measured_us)
        elif len(self._sig_stats) < self.max_signatures:
            self._sig_stats[sig] = [1, float(measured_us), int(levels),
                                    float(plain_bytes), float(kernel_bytes)]
        self.log.append(Observation(sig, int(levels),
                                    float(plain_bytes), float(kernel_bytes),
                                    float(measured_us)))
        if len(self.log) > self.max_log:
            del self.log[: len(self.log) - self.max_log]

    # -- refitting --------------------------------------------------------
    def _prior_w(self) -> np.ndarray:
        kf = self.prior.kernel_factor
        a = 1.0 / self.prior.bytes_per_us
        return np.array([self.prior.base_us, self.prior.level_us, a,
                         (kf if kf is not None else 1.0) * a])

    def _predict(self, constants: CostConstants, levels, plain,
                 kernel) -> float:
        kf = constants.kernel_factor or 0.0
        return (constants.base_us + constants.level_us * levels
                + (plain + kf * kernel) / constants.bytes_per_us)

    def _validates(self, candidate: CostConstants) -> bool:
        """The adoption test, on per-signature mean latencies: the
        candidate must fit better than the incumbent AND rank the observed
        plans (tau > 0)."""
        sigs = [(s[2], s[3], s[4], s[1] / s[0])
                for s in self._sig_stats.values()]
        if len(sigs) < self.min_signatures:
            return False
        meas = [m for _, _, _, m in sigs]

        def preds(c):
            return [self._predict(c, lv, p, k) for lv, p, k, _ in sigs]

        def rmse(c):
            return float(np.sqrt(np.mean(
                (np.asarray(preds(c)) - np.asarray(meas)) ** 2)))

        return (rmse(candidate) < rmse(self.constants)
                and _kendall_tau(preds(candidate), meas) > 0.0)

    def refit(self) -> CostConstants:
        """Solve + validate; below ``min_observations`` (or when the
        candidate fails validation) the incumbent constants are returned
        unchanged.  The fitted ``kernel_factor`` only replaces the
        incumbent's once kernel traffic has actually been observed."""
        if self.count < self.min_observations:
            return self.constants
        w0 = self._prior_w()
        # ridge anchor, scaled per-parameter so the tiny byte slopes are
        # anchored as strongly (relatively) as the large overhead terms
        lam = np.diag(self.ridge / np.maximum(w0, 1e-12) ** 2)
        w = np.linalg.solve(self._xtx + lam, self._xty + lam @ w0)

        base = float(np.clip(w[0], 0.0, 1e9))
        level = float(np.clip(w[1], 0.0, 1e9))
        a = float(w[2])
        if a <= 0.0:                      # degenerate window: keep bandwidth
            bpu = self.constants.bytes_per_us
            a = 1.0 / bpu
        else:
            bpu = float(np.clip(1.0 / a, self.prior.bytes_per_us / 1e4,
                                self.prior.bytes_per_us * 1e4))
        if self.kernel_count > 0:
            kf = float(np.clip(w[3] / max(a, 1e-18), 1e-3, 1e6))
        else:
            kf = self.constants.kernel_factor
        # _replace keeps the axes the linear model does not fit — notably
        # the pull_alpha/pull_beta switch thresholds — instead of
        # silently resetting them to the defaults on every adopted refit
        candidate = self.constants._replace(
            bytes_per_us=bpu, level_us=level, base_us=base,
            kernel_factor=kf)
        if not self._validates(candidate):
            self.rejected_refits += 1
            return self.constants
        self.constants = candidate
        self.refits += 1
        return self.constants

    # -- persistence ------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable calibration state (goes into the plan store)."""
        return {
            "prior": self.prior.to_json(),
            "constants": self.constants.to_json(),
            "min_observations": self.min_observations,
            "min_signatures": self.min_signatures,
            "ridge": self.ridge,
            "max_log": self.max_log,
            "max_signatures": self.max_signatures,
            "xtx": self._xtx.tolist(),
            "xty": self._xty.tolist(),
            "sig_stats": [{"signature": list(sig), "n": s[0],
                           "us_sum": s[1], "levels": s[2],
                           "plain_bytes": s[3], "kernel_bytes": s[4]}
                          for sig, s in self._sig_stats.items()],
            "count": self.count,
            "kernel_count": self.kernel_count,
            "refits": self.refits,
            "rejected_refits": self.rejected_refits,
            "log": [{"signature": list(o.signature), "levels": o.levels,
                     "plain_bytes": o.plain_bytes,
                     "kernel_bytes": o.kernel_bytes,
                     "measured_us": o.measured_us} for o in self.log],
        }

    @classmethod
    def from_state(cls, state: dict) -> "Calibrator":
        cal = cls(prior=CostConstants.from_json(state["prior"]),
                  min_observations=int(state["min_observations"]),
                  min_signatures=int(state.get("min_signatures", 3)),
                  ridge=float(state["ridge"]),
                  max_log=int(state.get("max_log", 256)),
                  max_signatures=int(state.get("max_signatures", 512)))
        cal.constants = CostConstants.from_json(state["constants"])
        cal._xtx = np.asarray(state["xtx"], dtype=float)
        cal._xty = np.asarray(state["xty"], dtype=float)
        cal._sig_stats = {
            tuple(s["signature"]): [int(s["n"]), float(s["us_sum"]),
                                    int(s["levels"]),
                                    float(s["plain_bytes"]),
                                    float(s["kernel_bytes"])]
            for s in state.get("sig_stats", [])}
        cal.count = int(state["count"])
        cal.kernel_count = int(state["kernel_count"])
        cal.refits = int(state.get("refits", 0))
        cal.rejected_refits = int(state.get("rejected_refits", 0))
        cal.log = [Observation(tuple(o["signature"]), int(o["levels"]),
                               float(o["plain_bytes"]),
                               float(o["kernel_bytes"]),
                               float(o["measured_us"]))
                   for o in state.get("log", [])]
        return cal
