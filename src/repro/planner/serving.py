"""The traversal serving layer: a plan cache over the reach-bucketed batch
execution path, with a calibration feedback loop and an optional persistent
plan store.

A serving process answers the same handful of query SHAPES over and over
with different root batches (many users, one graph).  Re-running the full
planning pass — parse, statistics, per-candidate costing — on every request
wastes the latency budget on work whose inputs did not change, so this
module memoizes it at three grains:

* **logical cache** — normalized SQL text → :class:`LogicalQuery` (parsing
  and normalization amortized);
* **choice cache** — query shape (root stripped) → the planner's ranked
  pick (statistics + costing amortized);
* **plan cache** — (query shape, direction, bucket signature) →
  :class:`PlanEntry` holding the machine-readable JSON plan
  (:func:`repro.planner.explain.to_json`) for that exact serving
  configuration.  The bucket signature is the tuple of per-bucket
  ``(lanes, frontier cap, result cap)`` — precisely what jit specializes
  on, so a plan-cache hit implies the compiled dispatches are warm too.

Execution is reach-bucketed with a PER-BUCKET physical choice: the root
vector is partitioned by root-conditional predicted reach
(:func:`repro.planner.optimize.bucket_roots`), then every bucket is
re-costed WITH ITS OWN CAPS and gets its own engine — the capacity-aware
cost model means a leaf bucket's tiny blocks favor the positional engine
even when the hub bucket (or the whole-batch plan) favors the dense
bitmap.  Each bucket runs as one jitted batched dispatch through THE shared
bucket executor (:func:`repro.core.engine.dispatch_buckets` — launch,
overflow-retry and scatter live there, once); a bucket that overflows its
predicted caps is retried once with the global caps.

Two feedback mechanisms close the loop:

* **calibration** — the executor times every warm bucket dispatch once,
  consistently; the session feeds ``(plan signature, levels, byte split,
  measured us)`` to its :class:`~repro.planner.calibrate.Calibrator`, which
  periodically refits the :class:`~repro.planner.cost.CostConstants` used
  by every subsequent planning pass (``calibrate_every``);
* **the plan store** — ``session.save_plan_store(path)`` serializes every
  cache grain plus the calibration state through the schema-version-2 plan
  JSON (:mod:`repro.planner.plan_store`); ``ServingSession(ds,
  plan_store=path)`` rehydrates them, so a warm process answers its first
  request with ZERO parse/stats/cost calls (see ``session.counters``).

**Request coalescing** (``enqueue``/``flush``): single-root requests that
arrive together are grouped by (graph, query shape, direction) and each
group is answered by ONE batched dispatch — inside the bucketed path every
multi-lane bucket is planned with its lane count, which admits the
bit-parallel ``multiquery`` engine (up to 32 roots as bits of one packed
uint32 frontier word, one MS-BFS sweep per level for all of them).  The
per-root results scatter back to the callers' :class:`PendingResult`
tickets in enqueue order.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.engine import (SKIPPED, Dataset, DispatchReport, RetryPolicy,
                               WORD_LANES, dispatch_buckets, run_query_batch,
                               run_query_multi)
from repro.core.operators import BFSResult, EngineCaps, execute_batch
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.obs import faultinject as _fault
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry

from .ast import LogicalQuery, normalize, parse
from .calibrate import Calibrator, plan_signature, stats_digest
from .explain import analyze_result, to_json
from .guards import (AdmissionError, GuardResult, InvalidRequestError,
                     admit_roots)
from .optimize import (PhysicalChoice, PlannerReport, RootBucket,
                       bucket_roots, plan)
from .stats import compute_stats, root_estimates

__all__ = ["PendingResult", "PlanEntry", "RequestReport", "ServingSession",
           "shape_key"]


ShapeKey = Tuple
PlanKey = Tuple


def shape_key(logical: LogicalQuery) -> ShapeKey:
    """The normalized query shape: every logical axis EXCEPT the root —
    requests that differ only in their root batch share one planning pass."""
    return (logical.max_depth, logical.payload_cols, logical.dedup,
            logical.direction, logical.want_cols, logical.want_depth,
            logical.union_all, getattr(logical, "workload", "reach"),
            getattr(logical, "weight_col", None))


class PendingResult:
    """The ticket for ONE enqueued root: :meth:`ServingSession.enqueue`
    returns it immediately, :meth:`ServingSession.flush` fills it.  Reading
    :meth:`result` before the flush raises — the whole point of enqueueing
    is that nothing executes until the batch is coalesced."""

    __slots__ = ("_value", "_done")

    def __init__(self):
        self._done = False
        self._value = None

    @property
    def done(self) -> bool:
        return self._done

    def result(self) -> BFSResult:
        if not self._done:
            raise RuntimeError("request not yet dispatched: call "
                               "ServingSession.flush() first")
        return self._value

    def _fill(self, value: BFSResult) -> None:
        self._value = value
        self._done = True


@dataclasses.dataclass
class PlanEntry:
    """One plan-cache entry: the shape-level chosen plan, the bucket layout
    it serves, the PER-BUCKET physical choices (each bucket re-costed with
    its own caps), and the machine-readable JSON plan."""

    choice: PhysicalChoice                       # shape-level pick
    report: PlannerReport
    roots: Tuple[int, ...]                       # request-order root vector
    buckets: Tuple[RootBucket, ...]
    bucket_choices: Tuple[PhysicalChoice, ...]   # one per bucket
    bucket_signature: Tuple[Tuple[int, int, int], ...]
    plan_json: dict
    hits: int = 0
    served: int = 0          # executions IN THIS PROCESS (gates calibration:
    #   a rehydrated entry is plan-warm but its dispatches still compile
    #   on first serve, and compile time must not enter the fit)
    last_latency_us: float = 0.0


@dataclasses.dataclass
class RequestReport:
    """What the front door did to ONE request beyond returning rows —
    the explicit classification of every degraded answer (readable as
    ``session.last_report`` right after ``submit``).  A lane is either
    served in full, or appears in exactly one of these lists."""

    admission: Optional[List[GuardResult]] = None   # per-root decisions
    degraded_roots: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)       # (root, clamped depth): prefix answers
    skipped_roots: List[int] = dataclasses.field(default_factory=list)
    #   roots whose bucket the deadline budget never launched (empty answer)
    denied_roots: List[int] = dataclasses.field(default_factory=list)
    #   roots whose overflow retry / degraded re-dispatch the RetryPolicy
    #   refused (truncated or empty answer)
    skipped_buckets: int = 0
    straggler_buckets: int = 0
    retries: int = 0
    evictions: int = 0

    @property
    def truncated(self) -> bool:
        """True iff ANY lane's answer is not the full traversal."""
        return bool(self.degraded_roots or self.skipped_roots
                    or self.denied_roots)


class ServingSession:
    """One graph, many requests: plan once per query shape, serve every
    batch through the reach-bucketed path.

    >>> session = ServingSession(ds)
    >>> results = session.submit(sql, roots=[3, 17, 4096])

    ``results`` is one dressed :class:`BFSResult` per root, in request
    order.  Each is ROW-SET identical to ``plan_and_run(sql, ds, root)``
    on that root (same rows, counts and depths); row ORDER may differ,
    because every bucket is re-costed with its own caps and may pick a
    different engine than the single-root plan, and engines order result
    rows differently.  ``session.stats`` reports request/hit counters and
    the last request's latency; ``session.counters`` reports how many
    parse / statistics / costing passes the session has actually paid
    (a plan-store-rehydrated session replaying known traffic pays none).
    """

    def __init__(self, ds: Dataset, *, max_buckets: int = 4,
                 caps: Optional[EngineCaps] = None,
                 include_kernel: bool = False,
                 calibrator: Optional[Calibrator] = None,
                 calibrate_every: int = 32,
                 plan_store: Optional[str] = None,
                 tracer: Optional[_trace.Tracer] = None,
                 guards: bool = True,
                 retry_policy: Optional[RetryPolicy] = None):
        self.ds = ds
        self.max_buckets = max_buckets
        self.caps = caps
        self.include_kernel = include_kernel
        self.calibrator = calibrator if calibrator is not None \
            else Calibrator()
        self.calibrate_every = int(calibrate_every)
        self.plan_store_path = plan_store
        self.tracer = tracer     # installed process-wide for each submit()
        # the admission guard ladder (planner/guards.py): every submitted
        # root's pre-dispatch reach estimate is priced against the
        # CostConstants budgets; guards=False serves everything as planned
        # (the admission_overhead_ratio perf gate compares the two)
        self.guards = bool(guards)
        # ONE bounded retry budget for the whole session: overflow retries,
        # lane evictions and guard-degraded re-dispatches all spend from it
        self.retry_policy = retry_policy if retry_policy is not None \
            else RetryPolicy()
        # per-bucket wall-time EMA: fed by every measured dispatch, read by
        # the executor's deadline budgeting to decide skip-vs-launch
        self._straggler = StragglerMonitor()
        self.last_report: Optional[RequestReport] = None
        self._logical: Dict[str, LogicalQuery] = {}
        self._choice: Dict[ShapeKey, PlannerReport] = {}
        self._bucket_plans: Dict[Tuple, PhysicalChoice] = {}
        self._plans: Dict[PlanKey, PlanEntry] = {}
        self._requests: Dict[Tuple, PlanKey] = {}   # (shape, roots) -> key
        self.requests = 0
        self.plan_hits = 0
        self.plan_misses = 0
        self.last_latency_us = 0.0
        # how much planning work this session has actually paid — a
        # rehydrated session replaying known traffic keeps all three at 0
        self.counters = {"parse_calls": 0, "stats_calls": 0,
                         "cost_calls": 0}
        self._last_refit_count = 0
        self._metrics = MetricsRegistry()
        self._m_requests = self._metrics.counter(
            "repro_requests_total", "serving requests submitted")
        self._m_roots = self._metrics.counter(
            "repro_roots_served_total", "roots answered across requests")
        self._m_latency = self._metrics.histogram(
            "repro_request_latency_us",
            "end-to-end submit() latency (microseconds)")
        self._m_bucket = self._metrics.histogram(
            "repro_bucket_dispatch_us",
            "per-bucket dispatch latency (microseconds)")
        self._m_hits = self._metrics.counter(
            "repro_plan_cache_hits_total", "plan-cache hits")
        self._m_misses = self._metrics.counter(
            "repro_plan_cache_misses_total", "plan-cache misses")
        self._m_retries = self._metrics.counter(
            "repro_overflow_retries_total",
            "bucket dispatches re-run at fallback caps after overflow")
        self._m_lane_evictions = self._metrics.counter(
            "repro_overflow_lane_evictions_total",
            "lanes evicted to solo fallback-caps re-dispatches (the rest "
            "of their bucket kept its right-sized caps)")
        self._m_coalesced = self._metrics.counter(
            "repro_coalesced_dispatches_total",
            "flush() request groups answered by one coalesced dispatch")
        self._m_coalesced_roots = self._metrics.counter(
            "repro_coalesced_roots_total",
            "enqueued roots answered through coalesced dispatches")
        self._m_admit_traverse = self._metrics.counter(
            "repro_admission_traverse_total",
            "roots admitted to run as planned by the guard ladder")
        self._m_admit_degrade = self._metrics.counter(
            "repro_admission_degrade_total",
            "roots depth-clamped to a bounded prefix by the guard ladder")
        self._m_admit_reject = self._metrics.counter(
            "repro_admission_reject_total",
            "roots rejected at the front door (AdmissionError)")
        self._m_deadline_skipped = self._metrics.counter(
            "repro_deadline_skipped_buckets_total",
            "buckets skipped by a deadline budget or exceeding their "
            "straggler deadline")
        self._m_retry_denied = self._metrics.counter(
            "repro_retry_denied_total",
            "re-dispatches refused by the exhausted RetryPolicy budget "
            "(the answer degraded instead of retrying)")
        self._pending: Dict[ShapeKey, list] = {}
        self._warned_overflow = False
        self._warned_deadline = False
        if plan_store is not None and os.path.exists(plan_store):
            # front-door hardening: a truncated, corrupted, future-schema
            # or wrong-graph store must not take serving down — warn, drop
            # whatever the partial rehydrate touched, and cold-start (the
            # next save_plan_store() rewrites the file atomically).  Direct
            # rehydrate_session()/migrate_plan_doc() calls still raise.
            from .plan_store import rehydrate_into
            try:
                rehydrate_into(self, plan_store)
            except Exception as e:
                self._logical.clear()
                self._choice.clear()
                self._bucket_plans.clear()
                self._plans.clear()
                self._requests.clear()
                if calibrator is None:
                    self.calibrator = Calibrator()
                warnings.warn(
                    f"plan store {plan_store!r} could not be rehydrated "
                    f"({type(e).__name__}: {e}); cold-starting the "
                    "session — the next save_plan_store() rewrites it "
                    "atomically", RuntimeWarning, stacklevel=2)

    # -- the three cache grains -------------------------------------------
    def _normalize_sql(self, sql: str) -> str:
        return " ".join(sql.split())

    def _logical_for(self, sql: str) -> LogicalQuery:
        key = self._normalize_sql(sql)
        if key not in self._logical:
            before = compute_stats.calls
            self.counters["parse_calls"] += 1
            self._logical[key] = normalize(parse(sql), self.ds)
            self.counters["stats_calls"] += compute_stats.calls - before
        return self._logical[key]

    def _report_for(self, logical: LogicalQuery) -> PlannerReport:
        key = shape_key(logical)
        if key not in self._choice:
            self.counters["cost_calls"] += 1
            self._choice[key] = plan(logical, self.ds, caps=self.caps,
                                     include_kernel=self.include_kernel,
                                     constants=self.calibrator.constants)
        return self._choice[key]

    def _bucket_choice(self, logical: LogicalQuery,
                       bucket: RootBucket) -> PhysicalChoice:
        """Re-cost the candidate engines WITH THE BUCKET'S CAPS AND LANE
        COUNT and pick per bucket: the capacity-aware cost model makes
        small blocks favor positional pipelines even when the whole-batch
        plan favors a dense O(E) engine — this is where a leaf bucket stops
        paying bitmap scans.  The padded lane count goes to the planner as
        ``lanes``, which admits the bit-parallel ``multiquery`` engine
        (ranked per-root amortized) for multi-lane buckets.  Memoized per
        (shape, caps, lanes) — the lane count changes both the candidate
        set and the amortized ranking."""
        key = (shape_key(logical), bucket.caps, len(bucket.roots))
        if key not in self._bucket_plans:
            self.counters["cost_calls"] += 1
            self._bucket_plans[key] = plan(
                logical, self.ds, caps=bucket.caps,
                include_kernel=self.include_kernel,
                constants=self.calibrator.constants,
                lanes=len(bucket.roots)).best
        return self._bucket_plans[key]

    def _plan_doc(self, report: PlannerReport, buckets, choices) -> dict:
        doc = to_json(report, buckets=buckets)
        for b, c in zip(doc["buckets"], choices):
            b["engine"] = c.label
        return doc

    _REQUEST_MEMO_MAX = 4096      # bound the exact-request fast path

    def _entry_for(self, logical: LogicalQuery, roots) -> PlanEntry:
        before = compute_stats.calls
        try:
            return self._entry_for_inner(logical, roots)
        finally:
            self.counters["stats_calls"] += compute_stats.calls - before

    def _entry_for_inner(self, logical: LogicalQuery, roots) -> PlanEntry:
        roots = tuple(int(r) for r in np.asarray(roots).reshape(-1))
        # exact-repeat fast path: a byte-identical request skips the
        # bucket derivation entirely (bucketing is deterministic per
        # (shape, roots) on one dataset)
        memo_key = (shape_key(logical), roots)
        key = self._requests.get(memo_key)
        if key is not None:
            entry = self._plans.get(key)
            if entry is not None and entry.roots == roots:
                entry.hits += 1
                self.plan_hits += 1
                return entry
        report = self._report_for(logical)
        choice = report.best
        buckets = bucket_roots(
            self.ds, roots, direction=choice.query.direction,
            max_depth=choice.query.max_depth, dedup=choice.query.dedup,
            caps=choice.query.caps, max_buckets=self.max_buckets)
        signature = tuple(b.signature for b in buckets)
        key = (shape_key(logical), signature)
        entry = self._plans.get(key)
        if entry is None:
            choices = tuple(self._bucket_choice(logical, b)
                            for b in buckets)
            entry = PlanEntry(
                choice=choice, report=report, roots=roots, buckets=buckets,
                bucket_choices=choices, bucket_signature=signature,
                plan_json=self._plan_doc(report, buckets, choices))
            self._plans[key] = entry
            self.plan_misses += 1
        else:
            # same shape + signature: reuse the cached layout only for the
            # SAME request-order roots; otherwise rebind to the fresh
            # bucket layout (signature equality guarantees the compiled
            # dispatches still match, but the lane->root mapping does not)
            if roots != entry.roots:
                entry = dataclasses.replace(
                    entry, roots=roots, buckets=buckets,
                    plan_json=self._plan_doc(report, buckets,
                                             entry.bucket_choices),
                    hits=entry.hits)
                self._plans[key] = entry
            entry.hits += 1
            self.plan_hits += 1
        if len(self._requests) >= self._REQUEST_MEMO_MAX:
            self._requests.clear()
        self._requests[memo_key] = key
        return entry

    # -- the serving entry point ------------------------------------------
    def _observer(self, entry: PlanEntry, calibrate: bool):
        """The executor's per-bucket timing tap.  ALWAYS feeds the metrics
        registry (dispatch-latency histogram, overflow-retry counter, the
        once-per-session retry warning); feeds the CALIBRATOR only when
        ``calibrate`` (warm dispatches) and the bucket was not retried —
        a retried dispatch ran at caps the bucket plan was not priced for,
        and a cold dispatch's timing includes jit compilation.  The plan's
        byte estimates price ONE lane; the measured dispatch vmaps over
        the bucket's padded lanes, so the predictors are scaled by the
        lane count (and the lane count joins the signature — a 1-lane and
        an 8-lane dispatch are different jit programs doing different
        work)."""
        digest = stats_digest(entry.report.stats)
        shape = shape_key(entry.report.logical)
        workload = getattr(entry.report.logical, "workload", "reach")

        def _observe(t):
            self._m_bucket.observe(t.elapsed_us)
            if t.evicted_lanes:
                self._m_lane_evictions.inc(t.evicted_lanes)
            if t.retried:
                self._m_retries.inc()
                if not self._warned_overflow:
                    self._warned_overflow = True
                    pc = t.predicted_caps
                    warnings.warn(
                        f"serving bucket {t.index} overflowed its "
                        f"predicted caps"
                        + (f" (frontier={pc.frontier}, result={pc.result})"
                           if pc is not None else "")
                        + " and was re-dispatched at the global caps — a "
                        "transparent retry that doubles that bucket's "
                        "dispatch cost (warned once per session; "
                        "repro_overflow_retries_total counts every one)",
                        RuntimeWarning, stacklevel=2)
                return
            if not calibrate:
                return
            c = entry.bucket_choices[t.index]
            lanes = max(t.padded_lanes, 1)
            # the bit-parallel engine's plan already prices the WHOLE
            # coalesced batch (its emit term carries the lane factor), so
            # its predictors are fed unscaled; a vmap-batched engine's
            # plan prices ONE lane and is scaled by the dispatched count
            scale = 1 if c.engine == "multiquery" else lanes
            measured = t.elapsed_us
            if _fault._ACTIVE:
                # chaos seam: a poisoned measurement stands in for a host
                # clock glitch / preempted timer — the calibrator's own
                # guards (finite-check + validated refit) must absorb it
                v = _fault.consume("calibrator_poison")
                if v is not None and v is not True:
                    measured = float(v)
            self.calibrator.observe(
                plan_signature(c.label, c.query.direction, t.caps, digest,
                               lanes=lanes, shape=shape,
                               mix=c.cost.level_dirs, workload=workload),
                levels=c.cost.levels,
                plain_bytes=scale * c.cost.plain_bytes,
                kernel_bytes=scale * c.cost.kernel_bytes,
                measured_us=measured)

        return _observe

    def _lane_limits(self, q, bucket: RootBucket):
        """Per-lane depth caps for one coalesced multiquery bucket: a lane
        whose root has an EXACT (sampled) reach profile is frozen at its
        known convergence depth instead of riding along for the full
        ``max_depth`` sweeps.  Degree-conditioned estimates can undershoot
        and a short cap silently truncates the lane's rows, so unsampled
        roots keep the uncapped depth.  Returns None when no lane can be
        capped (the dispatch is then identical to the uncapped one)."""
        ests = root_estimates(self.ds, q.direction, bucket.roots,
                              q.max_depth)
        caps = np.asarray(
            [min(e.depth, q.max_depth) if e.exact else q.max_depth
             for e in ests], np.int32)
        return caps if bool(np.any(caps < q.max_depth)) else None

    def _execute(self, entry: PlanEntry, check_overflow: bool,
                 observe: bool = False,
                 deadline_us: Optional[float] = None
                 ) -> Tuple[list, DispatchReport]:
        """One batched dispatch per bucket, each with ITS chosen engine and
        caps, through THE shared bucket executor
        (:func:`repro.core.engine.dispatch_buckets`).  Only the dispatch
        callback (each bucket's own engine/pipeline) and the dressing hook
        are serving-specific; launch ordering, the retry-policy overflow
        handling, deadline skipping, the host transfer/scatter and the
        per-bucket timing live in the executor, shared with every other
        bucketed path.  Returns ``(per-lane results, DispatchReport)`` —
        deadline-skipped lanes hold the :data:`~repro.core.engine.SKIPPED`
        sentinel; retry-denied buckets are dressed WITHOUT the overflow
        check (their truncated rows stand, classified on the report)."""
        global_caps = entry.choice.query.caps
        choices = entry.bucket_choices
        rep = DispatchReport()

        def _dispatch(i, b, caps):
            c = choices[i]
            if c.use_kernel:
                ctx = self.ds.context(c.query.direction)
                return execute_batch(c._kernel_pipeline(caps), ctx,
                                     np.asarray(b.roots, np.int32),
                                     self.ds.num_vertices)
            if c.engine == "multiquery":
                # one bit-parallel dispatch for the whole bucket: its lanes
                # pack into one frontier word, each lane depth-capped by
                # its root's (exact-only) predicted convergence depth
                q = dataclasses.replace(c.query, caps=caps,
                                        lanes=len(b.roots))
                return run_query_multi(q, self.ds,
                                       np.asarray(b.roots, np.int32),
                                       self._lane_limits(c.query, b))
            q = (c.query if caps == c.query.caps
                 else dataclasses.replace(c.query, caps=caps))
            return run_query_batch(q, self.ds, list(b.roots))

        def _finish(i, b, r):
            # the executor fills the report for bucket i before finish(i):
            # a retry-denied bucket's rows are truncated BY DESIGN — dress
            # them without the overflow check (degraded, not an error)
            co = check_overflow and i not in rep.denied_buckets
            return choices[i].dress(r, check_overflow=co,
                                    caps=choices[i].query.caps)

        out = dispatch_buckets(
            entry.buckets, _dispatch, fallback_caps=global_caps,
            finish=_finish, observer=self._observer(entry, observe),
            to_host=True, retry=self.retry_policy,
            deadline_us=deadline_us, straggler=self._straggler, report=rep)
        return out, rep

    # -- the failure-hardened front door ------------------------------------
    def _validate_request(self, logical: LogicalQuery, roots,
                          op: str = "submit") -> list[int]:
        """Typed front-door validation, BEFORE tracing or JIT: bad roots
        and non-positive depths raise :class:`InvalidRequestError` here
        instead of surfacing as opaque shape errors deep in a dispatch."""
        if logical.max_depth <= 0:
            raise InvalidRequestError(
                f"{op}: max_depth must be >= 1 (got {logical.max_depth})")
        arr = np.asarray(roots).reshape(-1)
        if arr.size == 0:
            return []
        if arr.dtype.kind not in "iu":
            raise InvalidRequestError(
                f"{op}: roots must be integers (got dtype {arr.dtype})")
        v = self.ds.num_vertices
        bad = arr[(arr < 0) | (arr >= v)]
        if bad.size:
            raise InvalidRequestError(
                f"{op}: root(s) {bad[:8].tolist()} out of range for a "
                f"graph with {v} vertices (valid: 0..{v - 1})")
        return [int(r) for r in arr]

    def _admit_request(self, logical: LogicalQuery, roots: Sequence[int]
                       ) -> Optional[List[GuardResult]]:
        """Run every root through the guard ladder; count + trace each
        decision; raise :class:`AdmissionError` on the first reject (after
        every decision is counted — the metrics see the whole batch)."""
        if not self.guards or not roots:
            return None
        decisions = admit_roots(self.ds, logical.direction, roots,
                                logical.max_depth,
                                self.calibrator.constants)
        reject = None
        for g in decisions:
            if g.decision == "traverse":
                self._m_admit_traverse.inc()
            elif g.decision == "degrade":
                self._m_admit_degrade.inc()
            else:
                self._m_admit_reject.inc()
                reject = reject if reject is not None else g
            if g.decision != "traverse":
                _trace.trace_event("admission", root=g.root,
                                   decision=g.decision,
                                   est_us=g.est_us,
                                   threshold_us=g.threshold_us,
                                   clamp_depth=g.clamp_depth)
        if reject is not None:
            raise AdmissionError(reject)
        return decisions

    @staticmethod
    def _admission_groups(logical: LogicalQuery,
                          decisions: Optional[List[GuardResult]],
                          n_roots: int):
        """Partition the request's lanes by admission outcome: one group
        for the as-planned roots, plus one per distinct degrade clamp
        depth (each with its OWN depth-clamped logical — a degraded answer
        is the same traversal cut at a shallower bound, so its rows are a
        prefix of the full answer)."""
        if decisions is None or all(g.decision == "traverse"
                                    for g in decisions):
            return [(logical, list(range(n_roots)), None)]
        groups = []
        full = [i for i, g in enumerate(decisions)
                if g.decision == "traverse"]
        if full:
            groups.append((logical, full, None))
        by_clamp: Dict[int, list] = {}
        for i, g in enumerate(decisions):
            if g.decision == "degrade":
                by_clamp.setdefault(int(g.clamp_depth), []).append(i)
        for clamp in sorted(by_clamp):
            groups.append((dataclasses.replace(logical, max_depth=clamp),
                           by_clamp[clamp], clamp))
        return groups

    @staticmethod
    def _degraded_result(template=None) -> BFSResult:
        """A classified EMPTY answer for a lane the budget refused to
        serve: zero rows, zero depth, no overflow.  Shaped like a sibling
        lane's dressed result when one exists (same columns and dtypes),
        otherwise a minimal zero-row result."""
        if template is not None:
            def cut(a):
                a = np.asarray(a)
                return a[:0] if a.ndim else np.zeros((), a.dtype)
            return jax.tree_util.tree_map(cut, template)
        z = np.zeros((), np.int32)
        return BFSResult(values={}, positions=np.zeros(0, np.int32),
                         count=z, depth=z,
                         overflow=np.zeros((), bool),
                         row_depths=np.zeros(0, np.int32))

    def _note_dispatch_report(self, rep: DispatchReport,
                              report: RequestReport, roots: Sequence[int],
                              lanes: Sequence[int]) -> None:
        """Fold one group dispatch's :class:`DispatchReport` into the
        request-level report + metrics, with the once-per-session warning
        that makes deadline degradation observable (satellite of the
        silent-block hazard: a skipped or straggling bucket must never be
        inferable only from the latency histogram)."""
        report.retries += rep.retries
        report.evictions += rep.evictions
        report.skipped_buckets += len(rep.skipped_buckets)
        report.straggler_buckets += len(rep.straggler_buckets)
        for idx in rep.denied_lanes:
            report.denied_roots.append(int(roots[lanes[idx]]))
        if rep.denied_lanes:
            self._m_retry_denied.inc(len(rep.denied_lanes))
        n_skip = len(rep.skipped_buckets)
        if n_skip:
            self._m_deadline_skipped.inc(n_skip)
        if (n_skip or rep.straggler_buckets) and not self._warned_deadline:
            # the silent-block fix: a deadline that drops work or a bucket
            # that straggles past its predicted wall time must be LOUD the
            # first time, not just a counter nobody reads
            self._warned_deadline = True
            what = []
            if n_skip:
                what.append(f"{n_skip} bucket(s) skipped by the deadline "
                            "budget (the affected answers are explicitly "
                            "truncated)")
            if rep.straggler_buckets:
                what.append(f"{len(rep.straggler_buckets)} bucket(s) "
                            "straggled past their predicted wall time")
            warnings.warn(
                "; ".join(what) + " — see session.last_report "
                "(repro_deadline_skipped_buckets_total counts every "
                "skip; warned once per session)",
                RuntimeWarning, stacklevel=3)

    def submit(self, sql: str, roots: Sequence[int],
               *, check_overflow: bool = True,
               deadline_us: Optional[float] = None) -> list[BFSResult]:
        """Answer one batched traversal request: per-root results in
        request order (one bucketed dispatch per reach class, each bucket
        running ITS OWN chosen engine with right-sized caps).

        The front door validates first (typed errors before tracing/JIT),
        then runs every root through the admission guard ladder: rejected
        roots raise :class:`AdmissionError`; degraded roots are served a
        depth-clamped PREFIX of their traversal (classified on
        ``session.last_report``).  ``deadline_us`` bounds the request's
        dispatch wall time: buckets that no longer fit the remaining
        budget are skipped and their lanes answered with explicit empty
        results — ``last_report.truncated`` says so, nothing blocks
        silently.

        Warm requests (plan-cache hits: the dispatches are compiled) are
        timed per bucket and fed to the calibrator; every
        ``calibrate_every`` observations the cost constants are refit, and
        subsequent planning passes price with the refit values.  With a
        session ``tracer`` (or a process-global one) the request is traced:
        ``request`` > ``parse``/``plan``/``compile`` spans here,
        ``stats``/``dispatch``/``transfer`` spans and per-level events
        downstream."""
        logical = self._logical_for(sql)
        roots = self._validate_request(logical, roots)
        prev_tracer = (_trace.set_tracer(self.tracer)
                       if self.tracer is not None else None)
        try:
            return self._submit_traced(sql, logical, roots, check_overflow,
                                       deadline_us)
        finally:
            if self.tracer is not None:
                _trace.set_tracer(prev_tracer)

    def _submit_traced(self, sql: str, logical: LogicalQuery,
                       roots: list[int], check_overflow: bool,
                       deadline_us: Optional[float]) -> list[BFSResult]:
        self.requests += 1
        self._m_requests.inc()
        hits0, misses0 = self.plan_hits, self.plan_misses
        report = RequestReport()
        self.last_report = report
        out: list = [None] * len(roots)
        last_entry = None
        with _trace.trace_span("request", requests=self.requests) as rattrs:
            with _trace.trace_span("parse"):
                logical = self._logical_for(sql)
            decisions = self._admit_request(logical, roots)
            report.admission = decisions
            groups = self._admission_groups(logical, decisions, len(roots))
            t0 = time.perf_counter()
            warm_all = True
            progress = False        # at least one group actually dispatched
            for glogical, lanes, clamp in groups:
                sub_roots = [roots[i] for i in lanes]
                with _trace.trace_span("plan"):
                    entry = self._entry_for(glogical, sub_roots)
                last_entry = entry
                if decisions is not None:
                    entry.plan_json["admission"] = {
                        "decisions": [g.to_json() for g in decisions],
                        "degrade_us":
                            self.calibrator.constants.guard_degrade_us,
                        "reject_us":
                            self.calibrator.constants.guard_reject_us}
                remaining = None
                if deadline_us is not None:
                    spent = (time.perf_counter() - t0) * 1e6
                    remaining = max(deadline_us - spent, 0.0)
                    if remaining <= 0.0 and progress:
                        # the budget died before this group launched
                        # anything: answer its lanes with classified
                        # empties (the FIRST group always runs — a
                        # request makes progress, the budget only stops
                        # further work)
                        for i in lanes:
                            out[i] = self._degraded_result()
                            report.skipped_roots.append(roots[i])
                        report.skipped_buckets += len(entry.buckets)
                        self._m_deadline_skipped.inc(len(entry.buckets))
                        continue
                if clamp is not None:
                    # a guard-degraded re-dispatch spends the SAME bounded
                    # retry budget as overflow retries; an exhausted budget
                    # degrades further, to the empty classified answer
                    if not self.retry_policy.spend():
                        self._m_retry_denied.inc(len(lanes))
                        for i in lanes:
                            out[i] = self._degraded_result()
                            report.denied_roots.append(roots[i])
                        continue
                    report.degraded_roots.extend(
                        (roots[i], clamp) for i in lanes)
                progress = True
                warm = entry.served > 0  # dispatches compiled here
                warm_all = warm_all and warm
                if warm:
                    sub_out, rep = self._execute(
                        entry, check_overflow, observe=True,
                        deadline_us=remaining)
                else:
                    # first serve of this entry in this process: the span
                    # makes jit compilation visible (it dominates cold
                    # latency)
                    with _trace.trace_span("compile",
                                           engine=entry.choice.label):
                        sub_out, rep = self._execute(
                            entry, check_overflow, observe=False,
                            deadline_us=remaining)
                self._note_dispatch_report(rep, report, roots, lanes)
                template = next((r for r in sub_out
                                 if r is not SKIPPED), None)
                for pos, i in enumerate(lanes):
                    r = sub_out[pos]
                    if r is SKIPPED:
                        report.skipped_roots.append(roots[i])
                        r = self._degraded_result(template)
                    out[i] = r
                entry.served += 1
            rattrs["warm"] = warm_all
            self.last_latency_us = (time.perf_counter() - t0) * 1e6
            rattrs["latency_us"] = self.last_latency_us
            if report.truncated:
                rattrs["truncated"] = True
        self._m_latency.observe(self.last_latency_us)
        self._m_roots.inc(len(out))
        self._m_hits.inc(self.plan_hits - hits0)
        self._m_misses.inc(self.plan_misses - misses0)
        if last_entry is not None:
            last_entry.last_latency_us = self.last_latency_us
        if (self.calibrate_every > 0
                and self.calibrator.count - self._last_refit_count
                >= self.calibrate_every):
            self.calibrator.refit()
            self._last_refit_count = self.calibrator.count
        return out

    # -- request coalescing -------------------------------------------------
    def enqueue(self, sql: str, root: int) -> PendingResult:
        """Queue ONE single-root request for coalesced dispatch and return
        its ticket immediately (nothing executes).  Requests on the same
        (graph, query shape, direction) — the session is one graph; the
        shape key carries the direction — are grouped, and the next
        :meth:`flush` answers each group with ONE batched dispatch instead
        of one dispatch per request; the per-root results scatter back to
        the tickets in enqueue order.  Because the grouped batch flows
        through the reach-bucketed path with per-bucket lane counts, its
        multi-lane buckets plan (and almost always pick) the bit-parallel
        ``multiquery`` engine: up to :data:`~repro.core.engine.WORD_LANES`
        queued roots ride the bits of one frontier word.

        The front door applies here too: invalid roots raise
        :class:`InvalidRequestError` NOW (not at flush), a batch already
        holding :data:`~repro.core.engine.WORD_LANES` pending roots for
        this shape refuses the next one (a coalesced word has 32 lanes —
        callers flush and re-enqueue), and a root the guard ladder would
        REJECT raises :class:`AdmissionError` immediately (degrade
        decisions are applied at flush, by ``submit``)."""
        logical = self._logical_for(sql)
        [root] = self._validate_request(logical, [root], op="enqueue")
        key = shape_key(logical)
        if len(self._pending.get(key, ())) >= WORD_LANES:
            raise InvalidRequestError(
                f"enqueue: this query shape already has {WORD_LANES} "
                "pending roots (one coalesced word) — call flush() "
                "before enqueueing more")
        if self.guards:
            decision = admit_roots(self.ds, logical.direction, [root],
                                   logical.max_depth,
                                   self.calibrator.constants)[0]
            if decision.decision == "reject":
                self._m_admit_reject.inc()
                _trace.trace_event("admission", root=decision.root,
                                   decision="reject",
                                   est_us=decision.est_us,
                                   threshold_us=decision.threshold_us)
                raise AdmissionError(decision)
        ticket = PendingResult()
        self._pending.setdefault(key, []).append(
            (sql, int(root), ticket))
        return ticket

    def flush(self, *, check_overflow: bool = True) -> int:
        """Dispatch every pending shape group as one coalesced batched
        request and fill the tickets; returns the number of dispatches
        (groups).  A group's requests may come from textually different SQL
        (only the shape matters — any member's text plans identically), and
        duplicate roots are fine: each ticket gets its own lane's result."""
        pending, self._pending = self._pending, {}
        dispatches = 0
        for _, items in sorted(pending.items(), key=lambda kv: repr(kv[0])):
            sql = items[0][0]
            roots = [r for _, r, _ in items]
            out = self.submit(sql, roots, check_overflow=check_overflow)
            for (_, _, ticket), r in zip(items, out):
                ticket._fill(r)
            dispatches += 1
            self._m_coalesced.inc()
            self._m_coalesced_roots.inc(len(items))
        return dispatches

    def plan_for(self, sql: str, roots: Sequence[int]) -> PlanEntry:
        """The cached plan entry this session would serve ``roots`` with
        (plans/caches on first use; does not execute)."""
        return self._entry_for(self._logical_for(sql), roots)

    def plan_json(self, sql: str, roots: Sequence[int]) -> dict:
        """The machine-readable plan this session would serve ``roots``
        with (cached; does not execute)."""
        return self.plan_for(sql, roots).plan_json

    # -- the feedback loops -----------------------------------------------
    def recalibrate(self) -> None:
        """Force a refit and RE-RANK: the choice / bucket-choice / plan
        caches are dropped so the next request prices every candidate with
        the refit constants (the logical cache and the request memo keep
        their parse work; compiled dispatches stay warm in jit's cache)."""
        self.calibrator.refit()
        self._last_refit_count = self.calibrator.count
        self._choice.clear()
        self._bucket_plans.clear()
        self._plans.clear()
        self._requests.clear()

    def save_plan_store(self, path: Optional[str] = None) -> str:
        """Persist every cache grain + calibration state to ``path`` (or
        the ``plan_store`` path the session was constructed with)."""
        from .plan_store import save_session
        path = path if path is not None else self.plan_store_path
        if path is None:
            raise ValueError("no plan-store path: pass one here or to "
                             "ServingSession(plan_store=...)")
        return save_session(self, path)

    @property
    def stats(self) -> dict:
        """One-shot session counters — every historical key plus the
        histogram-backed latency quantiles and cache hit-rate ratios
        (``last_latency_us`` stays, as an alias for the newest request's
        latency; ``latency_us_p50/p95/p99`` summarize the whole session)."""
        lat = self._m_latency.snapshot()
        lookups = self.plan_hits + self.plan_misses
        return {
            "requests": self.requests,
            "plan_hits": self.plan_hits,
            "plan_misses": self.plan_misses,
            "plan_hit_rate": (self.plan_hits / lookups) if lookups else 0.0,
            "cached_shapes": len(self._choice),
            "cached_plans": len(self._plans),
            "last_latency_us": self.last_latency_us,
            "latency_us_p50": lat["p50"],
            "latency_us_p95": lat["p95"],
            "latency_us_p99": lat["p99"],
            "overflow_retries": int(self._m_retries.value),
            "overflow_lane_evictions": int(self._m_lane_evictions.value),
            "admission_traverse": int(self._m_admit_traverse.value),
            "admission_degrade": int(self._m_admit_degrade.value),
            "admission_reject": int(self._m_admit_reject.value),
            "deadline_skipped_buckets": int(
                self._m_deadline_skipped.value),
            "retry_denied": int(self._m_retry_denied.value),
            "retry_budget_spent": self.retry_policy.spent,
            "coalesced_dispatches": int(self._m_coalesced.value),
            "coalesced_roots": int(self._m_coalesced_roots.value),
            "pending_requests": sum(len(v)
                                    for v in self._pending.values()),
            "parse_calls": self.counters["parse_calls"],
            "stats_calls": self.counters["stats_calls"],
            "cost_calls": self.counters["cost_calls"],
            "calibration_observations": self.calibrator.count,
            "calibration_refits": self.calibrator.refits,
            "calibration_refits_rejected": self.calibrator.rejected_refits,
        }

    # -- observability ------------------------------------------------------
    def metrics(self) -> dict:
        """Snapshot of the serving metrics registry: counters, gauges and
        latency-histogram summaries (p50/p95/p99), keyed by metric name.
        Calibrator refit outcomes are mirrored in as gauges so one snapshot
        covers the whole feedback loop."""
        self._sync_gauges()
        return self._metrics.to_dict()

    def metrics_text(self) -> str:
        """The registry rendered in Prometheus text exposition format
        (``# HELP``/``# TYPE`` + samples; histograms as cumulative
        ``_bucket{le=...}`` series) — scrape-ready for ``launch/serve.py
        --metrics``."""
        self._sync_gauges()
        return self._metrics.render_text()

    def _sync_gauges(self) -> None:
        g = self._metrics.gauge
        g("repro_plan_cache_entries",
          "Distinct cached bucket plans").set(len(self._plans))
        g("repro_calibration_observations_total",
          "Calibrator observations accepted").set(self.calibrator.count)
        g("repro_calibration_refits_total",
          "Calibrator refits accepted").set(self.calibrator.refits)
        g("repro_calibration_refits_rejected_total",
          "Calibrator refits rejected by the holdout check").set(
              self.calibrator.rejected_refits)

    def explain_analyze(self, sql: str, roots: Sequence[int]) -> dict:
        """EXPLAIN ANALYZE through the serving path: submit the batch, then
        reconcile each root's ACTUAL rows / levels / push-pull directions
        against ITS bucket's plan (each bucket ran its own engine at its
        own caps).  Returns the schema-4 plan document with ``analyze`` set
        to the per-root reconciliations, grouped by bucket."""
        from .explain import analyze_result
        results = self.submit(sql, roots)
        entry = self._entry_for(self._logical_for(sql), roots)
        by_bucket = []
        for i, b in enumerate(entry.buckets):
            c = entry.bucket_choices[i]
            real = b.roots[:len(b.indices)]
            per_root = [
                analyze_result(c, entry.report, self.ds, results[idx],
                               root=int(r))
                for r, idx in zip(real, b.indices)]
            by_bucket.append({"bucket": i, "engine": c.label,
                              "caps": [c.query.caps.frontier,
                                       c.query.caps.result],
                              "roots": [int(r) for r in real],
                              "analyze": per_root})
        doc = dict(entry.plan_json)
        doc["analyze"] = {"mode": "serving", "buckets": by_bucket}
        return doc
