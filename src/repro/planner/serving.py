"""The traversal serving layer: a plan cache over the reach-bucketed batch
execution path.

A serving process answers the same handful of query SHAPES over and over
with different root batches (many users, one graph).  Re-running the full
planning pass — parse, statistics, per-candidate costing — on every request
wastes the latency budget on work whose inputs did not change, so this
module memoizes it at three grains:

* **logical cache** — normalized SQL text → :class:`LogicalQuery` (parsing
  and normalization amortized);
* **choice cache** — query shape (root stripped) → the planner's ranked
  pick (statistics + costing amortized);
* **plan cache** — (query shape, direction, bucket signature) →
  :class:`PlanEntry` holding the machine-readable JSON plan
  (:func:`repro.planner.explain.to_json`) for that exact serving
  configuration.  The bucket signature is the tuple of per-bucket
  ``(lanes, frontier cap, result cap)`` — precisely what jit specializes
  on, so a plan-cache hit implies the compiled dispatches are warm too.

Execution is reach-bucketed with a PER-BUCKET physical choice: the root
vector is partitioned by root-conditional predicted reach
(:func:`repro.planner.optimize.bucket_roots`), then every bucket is
re-costed WITH ITS OWN CAPS and gets its own engine — the capacity-aware
cost model means a leaf bucket's tiny blocks favor the positional engine
even when the hub bucket (or the whole-batch plan) favors the dense
bitmap.  Each bucket runs as one jitted batched dispatch; a bucket that
overflows its predicted caps is retried once with the global caps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import Dataset, run_query_batch
from repro.core.operators import BFSResult, EngineCaps

from .ast import LogicalQuery, normalize, parse
from .explain import to_json
from .optimize import (PhysicalChoice, PlannerReport, RootBucket,
                       bucket_roots, plan)

__all__ = ["PlanEntry", "ServingSession", "shape_key"]


ShapeKey = Tuple
PlanKey = Tuple


def shape_key(logical: LogicalQuery) -> ShapeKey:
    """The normalized query shape: every logical axis EXCEPT the root —
    requests that differ only in their root batch share one planning pass."""
    return (logical.max_depth, logical.payload_cols, logical.dedup,
            logical.direction, logical.want_cols, logical.want_depth,
            logical.union_all)


@dataclasses.dataclass
class PlanEntry:
    """One plan-cache entry: the shape-level chosen plan, the bucket layout
    it serves, the PER-BUCKET physical choices (each bucket re-costed with
    its own caps), and the machine-readable JSON plan."""

    choice: PhysicalChoice                       # shape-level pick
    report: PlannerReport
    roots: Tuple[int, ...]                       # request-order root vector
    buckets: Tuple[RootBucket, ...]
    bucket_choices: Tuple[PhysicalChoice, ...]   # one per bucket
    bucket_signature: Tuple[Tuple[int, int, int], ...]
    plan_json: dict
    hits: int = 0
    last_latency_us: float = 0.0


class ServingSession:
    """One graph, many requests: plan once per query shape, serve every
    batch through the reach-bucketed path.

    >>> session = ServingSession(ds)
    >>> results = session.submit(sql, roots=[3, 17, 4096])

    ``results`` is one dressed :class:`BFSResult` per root, in request
    order.  Each is ROW-SET identical to ``plan_and_run(sql, ds, root)``
    on that root (same rows, counts and depths); row ORDER may differ,
    because every bucket is re-costed with its own caps and may pick a
    different engine than the single-root plan, and engines order result
    rows differently.  ``session.stats`` reports request/hit counters and
    the last request's latency."""

    def __init__(self, ds: Dataset, *, max_buckets: int = 4,
                 caps: Optional[EngineCaps] = None,
                 include_kernel: bool = False):
        self.ds = ds
        self.max_buckets = max_buckets
        self.caps = caps
        self.include_kernel = include_kernel
        self._logical: Dict[str, LogicalQuery] = {}
        self._choice: Dict[ShapeKey, PlannerReport] = {}
        self._bucket_plans: Dict[Tuple, PhysicalChoice] = {}
        self._plans: Dict[PlanKey, PlanEntry] = {}
        self._requests: Dict[Tuple, PlanKey] = {}   # (shape, roots) -> key
        self.requests = 0
        self.plan_hits = 0
        self.plan_misses = 0
        self.last_latency_us = 0.0

    # -- the three cache grains -------------------------------------------
    def _normalize_sql(self, sql: str) -> str:
        return " ".join(sql.split())

    def _logical_for(self, sql: str) -> LogicalQuery:
        key = self._normalize_sql(sql)
        if key not in self._logical:
            self._logical[key] = normalize(parse(sql), self.ds)
        return self._logical[key]

    def _report_for(self, logical: LogicalQuery) -> PlannerReport:
        key = shape_key(logical)
        if key not in self._choice:
            self._choice[key] = plan(logical, self.ds, caps=self.caps,
                                     include_kernel=self.include_kernel)
        return self._choice[key]

    def _bucket_choice(self, logical: LogicalQuery,
                       bucket: RootBucket) -> PhysicalChoice:
        """Re-cost the candidate engines WITH THE BUCKET'S CAPS and pick
        per bucket: the capacity-aware cost model makes small blocks favor
        positional pipelines even when the whole-batch plan favors a dense
        O(E) engine — this is where a leaf bucket stops paying bitmap
        scans.  Memoized per (shape, caps)."""
        key = (shape_key(logical), bucket.caps)
        if key not in self._bucket_plans:
            self._bucket_plans[key] = plan(
                logical, self.ds, caps=bucket.caps,
                include_kernel=self.include_kernel).best
        return self._bucket_plans[key]

    def _plan_doc(self, report: PlannerReport, buckets, choices) -> dict:
        doc = to_json(report, buckets=buckets)
        for b, c in zip(doc["buckets"], choices):
            b["engine"] = c.label
        return doc

    _REQUEST_MEMO_MAX = 4096      # bound the exact-request fast path

    def _entry_for(self, logical: LogicalQuery, roots) -> PlanEntry:
        report = self._report_for(logical)
        choice = report.best
        roots = tuple(int(r) for r in np.asarray(roots).reshape(-1))
        # exact-repeat fast path: a byte-identical request skips the
        # bucket derivation entirely (bucketing is deterministic per
        # (shape, roots) on one dataset)
        memo_key = (shape_key(logical), roots)
        key = self._requests.get(memo_key)
        if key is not None:
            entry = self._plans.get(key)
            if entry is not None and entry.roots == roots:
                entry.hits += 1
                self.plan_hits += 1
                return entry
        buckets = bucket_roots(
            self.ds, roots, direction=choice.query.direction,
            max_depth=choice.query.max_depth, dedup=choice.query.dedup,
            caps=choice.query.caps, max_buckets=self.max_buckets)
        signature = tuple(b.signature for b in buckets)
        key = (shape_key(logical), signature)
        entry = self._plans.get(key)
        if entry is None:
            choices = tuple(self._bucket_choice(logical, b)
                            for b in buckets)
            entry = PlanEntry(
                choice=choice, report=report, roots=roots, buckets=buckets,
                bucket_choices=choices, bucket_signature=signature,
                plan_json=self._plan_doc(report, buckets, choices))
            self._plans[key] = entry
            self.plan_misses += 1
        else:
            # same shape + signature: reuse the cached layout only for the
            # SAME request-order roots; otherwise rebind to the fresh
            # bucket layout (signature equality guarantees the compiled
            # dispatches still match, but the lane->root mapping does not)
            if roots != entry.roots:
                entry = dataclasses.replace(
                    entry, roots=roots, buckets=buckets,
                    plan_json=self._plan_doc(report, buckets,
                                             entry.bucket_choices),
                    hits=entry.hits)
                self._plans[key] = entry
            entry.hits += 1
            self.plan_hits += 1
        if len(self._requests) >= self._REQUEST_MEMO_MAX:
            self._requests.clear()
        self._requests[memo_key] = key
        return entry

    # -- the serving entry point ------------------------------------------
    def _execute(self, entry: PlanEntry,
                 check_overflow: bool) -> list[BFSResult]:
        """One batched dispatch per bucket, each with ITS chosen engine and
        caps; overflowed buckets retry once with the shape-level (global)
        caps on the same engine.

        ALL buckets are launched before the first result is touched (the
        dispatches are async; a Python-side overflow check must not
        serialize them), and lanes are sliced as free host views off one
        per-bucket transfer rather than as per-lane device ops."""
        import jax

        global_caps = entry.choice.query.caps
        nroots = sum(len(b.indices) for b in entry.buckets)
        out: list = [None] * nroots
        launched = []
        for b, c in zip(entry.buckets, entry.bucket_choices):
            if c.use_kernel:
                sub = dataclasses.replace(b, indices=tuple(
                    range(len(b.roots))))
                lanes = c.run_bucketed(self.ds, list(b.roots),
                                       buckets=(sub,),
                                       check_overflow=check_overflow,
                                       fallback_caps=global_caps)
                for lane, idx in enumerate(b.indices):
                    out[idx] = lanes[lane]
                continue
            launched.append((b, c,
                             run_query_batch(c.query, self.ds,
                                             list(b.roots))))
        for b, c, r in launched:
            if (c.query.caps != global_caps
                    and bool(np.any(np.asarray(r.overflow)))):
                retry = dataclasses.replace(c.query, caps=global_caps)
                r = run_query_batch(retry, self.ds, list(b.roots))
            dressed = c.dress(r, check_overflow=check_overflow,
                              caps=c.query.caps)
            host = jax.tree_util.tree_map(np.asarray, dressed)
            for lane, idx in enumerate(b.indices):
                out[idx] = jax.tree_util.tree_map(
                    lambda a, lane=lane: a[lane], host)
        return out

    def submit(self, sql: str, roots: Sequence[int],
               *, check_overflow: bool = True) -> list[BFSResult]:
        """Answer one batched traversal request: per-root results in
        request order (one bucketed dispatch per reach class, each bucket
        running ITS OWN chosen engine with right-sized caps)."""
        self.requests += 1
        logical = self._logical_for(sql)
        entry = self._entry_for(logical, roots)
        t0 = time.perf_counter()
        out = self._execute(entry, check_overflow)
        self.last_latency_us = (time.perf_counter() - t0) * 1e6
        entry.last_latency_us = self.last_latency_us
        return out

    def plan_for(self, sql: str, roots: Sequence[int]) -> PlanEntry:
        """The cached plan entry this session would serve ``roots`` with
        (plans/caches on first use; does not execute)."""
        return self._entry_for(self._logical_for(sql), roots)

    def plan_json(self, sql: str, roots: Sequence[int]) -> dict:
        """The machine-readable plan this session would serve ``roots``
        with (cached; does not execute)."""
        return self.plan_for(sql, roots).plan_json

    @property
    def stats(self) -> dict:
        return {
            "requests": self.requests,
            "plan_hits": self.plan_hits,
            "plan_misses": self.plan_misses,
            "cached_shapes": len(self._choice),
            "cached_plans": len(self._plans),
            "last_latency_us": self.last_latency_us,
        }
