"""Recursive-query planner: logical ``WITH RECURSIVE`` frontend, graph
statistics, and cost-based engine selection over the operator algebra.

The layers (one module each):

* :mod:`repro.planner.ast`      — the logical query: a tiny AST + a parser
  for a minimal SQL dialect (§5.1 Listings 1.1–1.3 all parse);
* :mod:`repro.planner.stats`    — per-``Dataset`` degree histograms and
  sampled frontier-growth profiles (cached on the Dataset);
* :mod:`repro.planner.cost`     — prices a candidate pipeline by walking its
  ACTUAL operator composition and summing per-operator estimates;
* :mod:`repro.planner.optimize` — enumerates every legal engine (plus the
  Pallas-kernel expansion), ranks, and executes the winner;
* :mod:`repro.planner.explain`  — EXPLAIN with per-operator estimated rows
  and bytes for every candidate, the machine-readable plan
  (:func:`to_json`, ``schema_version`` 4), and EXPLAIN ANALYZE
  (:func:`explain_analyze`: execute, then reconcile predicted vs. actual
  per-operator rows/bytes and per-level push/pull directions);
* :mod:`repro.planner.serving`  — the plan-cached, reach-bucketed serving
  session (one graph, many root batches);
* :mod:`repro.planner.guards`   — the admission guard ladder pricing every
  root's predicted cost before dispatch (traverse / degrade / reject; see
  docs/robustness.md);
* :mod:`repro.planner.calibrate` — the feedback loop: measured per-bucket
  serving latencies refit the :class:`CostConstants` (and the kernel
  factor is MEASURED, not guessed);
* :mod:`repro.planner.plan_store` — persist the plan + calibration caches
  across processes (schema-version-2 JSON, v1 still loads).

Entry points: :func:`plan_and_run` (also re-exported as
``repro.core.engine.plan_and_run``), :func:`choose`, :func:`explain`,
:class:`ServingSession`.
"""
from .ast import (LogicalQuery, ParseError, RecursiveCTE,      # noqa: F401
                  normalize, paper_listing, parse)
from .calibrate import (Calibrator, Observation,               # noqa: F401
                        measured_kernel_factor, plan_signature,
                        stats_digest)
from .cost import (CostConstants, DEFAULT_CONSTANTS,           # noqa: F401
                   OpEstimate, PlanCost, estimate_us, pipeline_cost)
from .explain import (analyze_result, explain,                 # noqa: F401
                      explain_analyze, explain_json,
                      render_analyze, render_report, to_json)
from .optimize import (KERNEL_LABEL, PhysicalChoice,           # noqa: F401
                       PlannerReport, RootBucket, bucket_roots,
                       choose, default_caps, kernel_expand_fn, plan,
                       plan_and_run)
from .guards import (AdmissionError, GuardResult,              # noqa: F401
                     InvalidRequestError, admit_roots, guard_cost_us)
from .serving import (PlanEntry, RequestReport,                # noqa: F401
                      ServingSession, shape_key)
from .plan_store import (graph_digest, load_store,             # noqa: F401
                         migrate_plan_doc, rehydrate_session,
                         save_session)
from .stats import (GraphStats, RootEstimate, compute_stats,   # noqa: F401
                    root_estimates)
