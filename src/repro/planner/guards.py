"""Admission guard ladder: classify every root BEFORE any dispatch.

The positional pipelines commit to caps and a plan before the traversal's
true reach is known — which is exactly what makes a naive serving front
door fragile: one adversarial root on a hub can blow past every cap while
well-behaved requests queue behind it.  The guard ladder closes that hole
with the planner's OWN estimates: each root's pre-dispatch reach prediction
(:func:`repro.planner.stats.root_estimates` — exact for sampled roots,
degree-conditioned otherwise) is priced through the cost model's
:func:`~repro.planner.cost.estimate_us` under the session's CURRENT
constants, and the predicted wall time is compared against two budgets
owned by :class:`~repro.planner.cost.CostConstants`:

* ``predicted <= guard_degrade_us``  -> **traverse**: run as planned.
* ``predicted <= guard_reject_us``   -> **degrade**: depth-clamp the root
  to the deepest prefix whose predicted cost fits the degrade budget (a
  degraded answer is a depth-TRUNCATION of the full traversal — a prefix,
  never a different row set).
* otherwise                          -> **reject**: a typed
  :class:`AdmissionError` carrying the estimate that triggered it.

Because the price is computed under the calibrator-refit constants, a
machine measured slower admits fewer rows under the same budgets — the
ladder re-thresholds itself from measured dispatches without anyone
editing a row count.  Decisions are a pure function of
(estimate, constants, max_depth): deterministic for a fixed
(graph digest, constants) pair, and monotone — tightening either budget
can only move a root DOWN the ladder (traverse -> degrade -> reject),
never up.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

from .cost import CostConstants, estimate_us
from .stats import RootEstimate, root_estimates

__all__ = ["AdmissionError", "InvalidRequestError", "GuardResult",
           "guard_cost_us", "decide", "admit_roots", "GUARD_ROW_BYTES"]

# per-row byte proxy for the guard price: one 4-byte edge position plus the
# 4-byte depth column a positional result row materializes.  A coarse but
# DETERMINISTIC width — the guard ranks roots against a wall-time budget,
# not against each other, so the bandwidth constant absorbs the slack.
GUARD_ROW_BYTES = 8.0


class InvalidRequestError(ValueError):
    """A malformed front-door request (bad root, non-positive depth,
    oversized enqueue batch) — raised at ``submit``/``enqueue`` time,
    before tracing or JIT, instead of surfacing as an opaque shape error
    deep inside a dispatch."""


class GuardResult(NamedTuple):
    """One root's admission decision (see module docstring)."""

    decision: str               # 'traverse' | 'degrade' | 'reject'
    root: int
    estimate: RootEstimate      # the pre-dispatch prediction that decided
    est_us: float               # predicted full-depth wall time
    threshold_us: float         # the budget the decision was made against
    clamp_depth: Optional[int] = None   # degrade: admitted depth bound

    def to_json(self) -> dict:
        e = self.estimate
        return {"decision": self.decision, "root": int(self.root),
                "est_us": float(self.est_us),
                "threshold_us": float(self.threshold_us),
                "clamp_depth": self.clamp_depth,
                "estimate": {"reach_rows": float(e.reach_rows),
                             "max_level_rows": float(e.max_level_rows),
                             "depth": int(e.depth), "exact": bool(e.exact)}}


class AdmissionError(RuntimeError):
    """A root's predicted cost exceeded ``guard_reject_us`` — refused at
    the front door, before any dispatch.  Carries the triggering
    :class:`GuardResult` (and through it the :class:`RootEstimate`)."""

    def __init__(self, result: GuardResult):
        self.result = result
        e = result.estimate
        super().__init__(
            f"root {result.root} rejected by admission guard: predicted "
            f"{result.est_us:.0f}us (reach~{e.reach_rows:.0f} rows, "
            f"depth {e.depth}) exceeds guard_reject_us="
            f"{result.threshold_us:.0f}")


def guard_cost_us(est: RootEstimate, constants: CostConstants, *,
                  depth: Optional[int] = None,
                  row_bytes: float = GUARD_ROW_BYTES) -> float:
    """Price one root's predicted traversal at an (optionally clamped)
    depth.  Rows are scaled linearly with the admitted depth fraction — a
    monotone proxy that keeps the clamp search deterministic."""
    levels = max(int(est.depth), 1)
    d = levels if depth is None else max(min(int(depth), levels), 0)
    rows = est.reach_rows * (d / levels)
    return estimate_us(constants, plain_bytes=rows * row_bytes,
                       kernel_bytes=0.0, levels=d)


def decide(est: RootEstimate, constants: CostConstants, *, max_depth: int,
           row_bytes: float = GUARD_ROW_BYTES) -> GuardResult:
    """Run ONE root's estimate through the ladder.  Pure and monotone:
    lowering either budget can only escalate the decision."""
    degrade_us = float(constants.guard_degrade_us)
    reject_us = max(float(constants.guard_reject_us), degrade_us)
    full_us = guard_cost_us(est, constants, depth=min(est.depth, max_depth)
                            if est.depth else None, row_bytes=row_bytes)
    if full_us > reject_us:
        return GuardResult("reject", est.root, est, full_us, reject_us)
    if full_us <= degrade_us:
        return GuardResult("traverse", est.root, est, full_us, degrade_us)
    # degrade: the deepest prefix whose predicted cost fits the budget
    # (cost is monotone in depth, so scan down; floor at depth 1 — the
    # degraded answer stays a bounded prefix, never an empty refusal)
    clamp = 1
    for d in range(min(est.depth, max_depth), 0, -1):
        if guard_cost_us(est, constants, depth=d,
                         row_bytes=row_bytes) <= degrade_us:
            clamp = d
            break
    return GuardResult("degrade", est.root, est, full_us, degrade_us,
                       clamp_depth=clamp)


def admit_roots(ds, direction: str, roots: Sequence[int], max_depth: int,
                constants: CostConstants, *,
                row_bytes: float = GUARD_ROW_BYTES) -> list[GuardResult]:
    """Ladder a whole batch of roots (one O(1) degree lookup + a few float
    ops per root — cheap enough to run on EVERY request; the
    ``admission_overhead_ratio`` perf gate holds it to that)."""
    ests = root_estimates(ds, direction, roots, max_depth)
    return [decide(e, constants, max_depth=max_depth, row_bytes=row_bytes)
            for e in ests]
