"""Cost-based engine selection: enumerate every physical realization of a
:class:`~repro.planner.ast.LogicalQuery`, price each against the dataset's
statistics, and return a ranked list of :class:`PhysicalChoice`.

The candidate space is the axis the paper measures, plus the beyond-paper
engines this repo grew:

* positional vs tuple vs row recursion (``precursive`` / ``trecursive`` /
  ``rowstore[_index]``) — early vs late materialization;
* the Exp-3 rewrite on and off (``*_rewrite`` engines: slim carry + one
  top-level join);
* sparse CSR expansion vs the dense ``DenseBitmapStep`` vs ``HybridStep``
  (``bitmap`` / ``hybrid``);
* the Pallas ``frontier_expand`` kernel plugged into ``CSRIndexJoin`` as an
  alternative physical expansion (``precursive+kernel``, opt-in).

Every candidate compiles through the same :data:`~repro.core.engine.
PLAN_BUILDERS` registry the forced-engine path uses, so the planner's pick
is bit-identical to ``run_query`` with the chosen engine name.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.engine import (ENGINE_NAMES, Dataset, PLAN_BUILDERS,
                               RecursiveQuery, WEIGHTED_ENGINE_NAMES,
                               WORD_LANES, build_plan, query_context,
                               run_query, run_query_batch,
                               run_query_buckets, run_query_multi)
from repro.core.operators import (BFSResult, EngineCaps, Pipeline, execute,
                                  execute_batch)
from repro.core.recursive import precursive_plan

from .ast import LogicalQuery, RecursiveCTE, normalize, parse
from .calibrate import kernel_expand_fn, resolve_constants
from .cost import (CostConstants, DEFAULT_CONSTANTS, PlanCost, column_bytes,
                   pipeline_cost)
from .stats import GraphStats, root_estimates

__all__ = ["PhysicalChoice", "PlannerReport", "RootBucket", "plan",
           "choose", "plan_and_run", "bucket_roots", "default_caps",
           "kernel_expand_fn", "KERNEL_LABEL"]

KERNEL_LABEL = "precursive+kernel"

# The kernel candidate's relative cost is NOT a constant here: it is
# CostConstants.kernel_factor — measured (repro.planner.calibrate.
# measured_kernel_factor) when unresolved, then refit online from served
# traffic.  The old static 0.7x-on-TPU / 200x-elsewhere guess is gone.


@dataclasses.dataclass(frozen=True)
class PhysicalChoice:
    """One ranked physical plan: an engine name (plus the optional kernel
    expansion), the concrete RecursiveQuery it compiles from, the Pipeline
    it was costed with (the same object run()/EXPLAIN use), and its cost
    estimate."""

    engine: str
    query: RecursiveQuery
    logical: LogicalQuery
    pipeline: Pipeline
    cost: PlanCost
    use_kernel: bool = False

    @property
    def label(self) -> str:
        return KERNEL_LABEL if self.use_kernel else self.engine

    def dress(self, r: BFSResult, *, check_overflow: bool,
              caps: EngineCaps) -> BFSResult:
        """Post-execution dressing shared by every execution path: overflow
        check, projection to the requested columns, the ``depth`` column."""
        if check_overflow and bool(np.any(np.asarray(r.overflow))):
            raise RuntimeError(
                f"capacity overflow executing {self.label} with "
                f"caps={caps}: the result is truncated — pass "
                "larger caps to plan()/plan_and_run(), or "
                "check_overflow=False to accept the partial result")
        values = {k: v for k, v in r.values.items()
                  if k in self.logical.want_cols}
        missing = set(self.logical.want_cols) - set(values)
        if missing:
            raise KeyError(f"engine {self.label!r} did not materialize "
                           f"requested column(s) {sorted(missing)} "
                           f"(produced {sorted(r.values)})")
        if self.logical.want_depth:
            values["depth"] = r.row_depths
        if (getattr(self.logical, "workload", "reach") != "reach"
                and r.vertex_values is not None):
            values["value"] = self._row_values(r)
        return r._replace(values=values)

    def _row_values(self, r: BFSResult):
        """The per-row ``value`` output column: each emitted row reports its
        TARGET vertex's converged accumulator (gathered from the value
        plane after the fixed point, the weighted analogue of late
        materialization).  The fused bidirectional view has no single
        target column, so ``both`` exposes the value plane only through
        ``vertex_values``."""
        import jax.numpy as jnp

        tgt_col = {"outbound": "to", "inbound": "from"}.get(
            self.logical.direction)
        if tgt_col is None or tgt_col not in r.values:
            return None
        nv = r.vertex_values.shape[-1]
        tgt = jnp.clip(r.values[tgt_col].astype(jnp.int32), 0, nv - 1)
        if r.vertex_values.ndim == 2:          # vmap-batched lanes
            return jnp.take_along_axis(r.vertex_values, tgt, axis=1)
        return r.vertex_values[tgt]

    def _resolve_roots(self, roots):
        """Default to the query's literal root and coerce to int32 — the
        SAME coercion on every path (kernel or not, scalar or batch), so a
        Python list / int64 vector cannot diverge between paths."""
        import jax.numpy as jnp

        roots = self.logical.root if roots is None else roots
        if roots is None:
            raise ValueError("no root: the query has no literal seed and "
                             "none was passed to run()")
        return jnp.asarray(roots, jnp.int32)

    def run(self, ds: Dataset, roots: Union[int, Sequence[int], None] = None,
            *, check_overflow: bool = True) -> BFSResult:
        """Execute the chosen plan (single root or a vmap batch) and dress
        the result per the logical query: attach the ``depth`` output column
        and project the requested value columns.

        A capacity overflow (stats-derived block sizes can undershoot for
        unsampled roots or raw UNION ALL walks) raises rather than silently
        truncating; pass bigger ``caps`` to plan(), or
        ``check_overflow=False`` to accept the flagged partial result."""
        roots = self._resolve_roots(roots)
        batched = np.ndim(roots) > 0
        if self.use_kernel:
            ctx = ds.context(self.query.direction)
            r = (execute_batch(self.pipeline, ctx, roots, ds.num_vertices)
                 if batched
                 else execute(self.pipeline, ctx, roots, ds.num_vertices))
        elif self.engine == "multiquery":
            # the bit-parallel engine always dispatches a lane vector; a
            # scalar root rides in lane 0 of a one-lane word
            import jax.numpy as jnp

            from repro.core.engine import result_lane

            lane_roots = roots if batched else jnp.reshape(roots, (1,))
            r = run_query_multi(self.query, ds, lane_roots)
            if not batched:
                r = result_lane(r, 0)
        else:
            r = (run_query_batch(self.query, ds, roots) if batched
                 else run_query(self.query, ds, roots))
        return self.dress(r, check_overflow=check_overflow,
                          caps=self.query.caps)

    def _kernel_pipeline(self, caps: EngineCaps) -> Pipeline:
        """The kernel-expansion pipeline at the given caps (the planned
        pipeline when the caps match, a rebuild otherwise)."""
        if caps == self.query.caps:
            return self.pipeline
        return precursive_plan(caps, self.query.max_depth,
                               self.query.out_cols, self.query.dedup,
                               self.query.direction,
                               expand_fn=kernel_expand_fn())

    def run_bucketed(self, ds: Dataset, roots: Sequence[int], *,
                     max_buckets: int = 4, check_overflow: bool = True,
                     buckets: Optional[Tuple["RootBucket", ...]] = None,
                     fallback_caps: Optional[EngineCaps] = None
                     ) -> list[BFSResult]:
        """The reach-bucketed serving path: partition ``roots`` by predicted
        reach (:func:`bucket_roots`), run one jitted batched dispatch per
        bucket with that bucket's caps, and return PER-ROOT dressed results
        in the original order (each bit-identical to ``run()`` on that
        root).  A precomputed bucket layout can be passed in (the serving
        layer caches it with the plan).

        A bucket that overflows its caps is retried once with
        ``fallback_caps`` (default: this plan's own caps)."""
        roots = self._resolve_roots(roots)
        if np.ndim(roots) == 0:
            raise ValueError("run_bucketed needs a VECTOR of roots; "
                             "use run() for a single root")
        if buckets is None:
            buckets = bucket_roots(
                ds, np.asarray(roots), direction=self.query.direction,
                max_depth=self.query.max_depth, dedup=self.query.dedup,
                caps=self.query.caps, max_buckets=max_buckets)
        if fallback_caps is None:
            fallback_caps = self.query.caps
        if self.use_kernel:
            # launch/retry/scatter live in the ONE shared bucket executor;
            # only the dispatch callback (kernel-expansion pipeline at the
            # bucket's caps) is this plan's own
            from repro.core.engine import dispatch_buckets

            ctx = ds.context(self.query.direction)

            def _dispatch(i, b, caps):
                return execute_batch(self._kernel_pipeline(caps), ctx,
                                     np.asarray(b.roots), ds.num_vertices)

            results = dispatch_buckets(buckets, _dispatch,
                                       fallback_caps=fallback_caps)
        elif self.engine == "multiquery":
            # one bit-parallel word sweep per bucket: the bucket's lanes
            # pack into one frontier word, dispatched at the bucket's caps
            from repro.core.engine import dispatch_buckets

            def _dispatch(i, b, caps):
                qb = dataclasses.replace(self.query, caps=caps,
                                         lanes=len(b.roots))
                return run_query_multi(qb, ds, np.asarray(b.roots,
                                                          np.int32))

            results = dispatch_buckets(buckets, _dispatch,
                                       fallback_caps=fallback_caps)
        else:
            q = dataclasses.replace(self.query, caps=fallback_caps)
            results = run_query_buckets(q, ds, buckets)
        return [self.dress(r, check_overflow=check_overflow,
                           caps=self.query.caps) for r in results]


@dataclasses.dataclass(frozen=True)
class PlannerReport:
    """Everything one planning pass produced (EXPLAIN renders this)."""

    logical: LogicalQuery
    stats: GraphStats
    ranked: Tuple[PhysicalChoice, ...]          # best first
    skipped: Tuple[Tuple[str, str], ...]        # (engine, reason)
    constants: CostConstants = DEFAULT_CONSTANTS   # priced with THESE

    @property
    def best(self) -> PhysicalChoice:
        return self.ranked[0]


# a raw UNION ALL walk's path count can explode combinatorially; cap the
# result buffer a planner will allocate (overflow still raises, with the
# real required size in the message, if the walk truly exceeds this)
_MAX_WALK_RESULT = 1 << 22


def default_caps(stats: GraphStats, logical: LogicalQuery) -> EngineCaps:
    """Volcano block sizing from statistics.

    Dedup (BFS) semantics bound the result exactly: every join-space edge is
    emitted at most once, so ``EJ + 8`` covers any root.  Raw UNION ALL
    walks count PATHS, not edges — on a cyclic or reconverging graph a
    depth-bounded walk can legally emit far more than E rows — so both
    blocks are sized from the sampled WALK profile
    (:meth:`GraphStats.total_walk_rows`), with margin, and are deliberately
    NOT clamped to a multiple of E."""
    ej = stats.num_edges
    if logical.dedup:
        frontier = int(min(ej + 8, max(1024, 4 * stats.max_level_edges)))
        result = ej + 8
    else:
        md = logical.max_depth
        frontier = int(max(1024, 4 * stats.max_level_edges,
                           2 * stats.max_walk_level_rows(md)))
        frontier = min(frontier, _MAX_WALK_RESULT)
        result = int(min(max(4 * stats.total_walk_rows(md), 4096),
                         _MAX_WALK_RESULT))
    return EngineCaps(frontier=frontier, result=result)


@dataclasses.dataclass(frozen=True)
class RootBucket:
    """One reach bucket of a batched root vector: the lanes it owns in the
    original vector, the roots themselves, and the (quantized, clamped)
    per-bucket caps one batched dispatch will run with.

    ``roots`` is PADDED to a power-of-two lane count by repeating the last
    root (jit specializes on the lane count, so padding keeps the dispatch
    signature stable as batch compositions vary); only the first
    ``len(indices)`` lanes are real, and executors drop the padding."""

    indices: Tuple[int, ...]        # lanes in the original roots vector
    roots: Tuple[int, ...]          # len(roots) >= len(indices) (padding)
    caps: EngineCaps
    predicted_reach: float          # max predicted reach over the bucket
    predicted_depth: int            # max predicted depth over the bucket

    @property
    def signature(self) -> Tuple[int, int, int]:
        """(padded lane count, frontier cap, result cap) — what the serving
        layer keys dispatch reuse on (jit specializes on exactly these)."""
        return (len(self.roots), self.caps.frontier, self.caps.result)


def _pow2_ceil(n: int) -> int:
    return 1 << max(int(math.ceil(math.log2(max(n, 1)))), 0)


# margin over the predicted reach when sizing bucket buffers: estimates for
# unsampled roots are degree-conditioned, not measured, and undershooting
# costs a whole retry dispatch
_BUCKET_MARGIN = 4
# a root joins the current bucket while its reach is within this factor of
# the bucket's smallest; beyond it a new bucket opens (geometric split)
_BUCKET_SPREAD = 8.0


def bucket_roots(ds: Dataset, roots, *, direction: str, max_depth: int,
                 dedup: bool = True, caps: EngineCaps,
                 max_buckets: int = 4) -> Tuple[RootBucket, ...]:
    """Partition a root vector into <= ``max_buckets`` reach buckets.

    Roots are sorted by root-conditional predicted reach
    (:func:`repro.planner.stats.root_estimates` — exact for sampled roots,
    degree-conditioned otherwise) and split geometrically: a new bucket
    opens when a root's reach exceeds ``_BUCKET_SPREAD`` times the smallest
    reach in the current bucket.  Each bucket gets its own ``EngineCaps``
    sized to its worst member with margin, quantized to powers of two (so
    repeated serving traffic reuses a handful of jit cache entries) and
    NEVER exceeding the global ``caps`` — a leaf-rooted lane stops paying a
    hub root's padding.

    Raw UNION ALL (``dedup=False``) reach is path-count-shaped and not
    root-conditioned by the sampled profiles, so those queries keep one
    bucket with the global caps (safe, same as the lockstep path)."""
    roots = np.asarray(roots, dtype=np.int64).reshape(-1)
    lanes = list(range(roots.shape[0]))
    if roots.shape[0] == 0:
        return ()
    if not dedup or roots.shape[0] == 1 or max_buckets <= 1:
        return (RootBucket(indices=tuple(lanes),
                           roots=tuple(int(r) for r in roots), caps=caps,
                           predicted_reach=-1.0,      # unpredicted fallback
                           predicted_depth=max_depth),)

    ests = root_estimates(ds, direction, roots, max_depth)
    order = sorted(lanes, key=lambda i: (ests[i].reach_rows, i))

    groups: list[list[int]] = []
    for i in order:
        if groups:
            lo = ests[groups[-1][0]].reach_rows
            if (ests[i].reach_rows <= max(lo, 1.0) * _BUCKET_SPREAD
                    or len(groups) >= max_buckets):
                groups[-1].append(i)
                continue
        groups.append([i])

    out = []
    for g in groups:
        reach = max(ests[i].reach_rows for i in g)
        level = max(ests[i].max_level_rows for i in g)
        depth = max(ests[i].depth for i in g)
        exact = all(ests[i].exact for i in g)
        margin = 2 if exact else _BUCKET_MARGIN
        frontier = min(_pow2_ceil(int(margin * level) + 8), caps.frontier)
        result = min(_pow2_ceil(int(margin * reach) + 8), caps.result)
        # pad the lane count to a power of two (repeat the last root) so
        # varying batch compositions reuse one compiled dispatch shape
        g_roots = [int(roots[i]) for i in g]
        g_roots += [g_roots[-1]] * (_pow2_ceil(len(g_roots)) - len(g_roots))
        out.append(RootBucket(
            indices=tuple(g), roots=tuple(g_roots),
            caps=EngineCaps(frontier=frontier, result=result),
            predicted_reach=float(reach), predicted_depth=int(depth)))
    return tuple(out)


def _illegal_reason(engine: str, logical: LogicalQuery) -> Optional[str]:
    if getattr(logical, "workload", "reach") != "reach":
        if engine not in WEIGHTED_ENGINE_NAMES:
            return ("no value plane: weighted workloads run on the "
                    f"semiring engines {WEIGHTED_ENGINE_NAMES}")
        if engine == "bitmap" and logical.direction == "both":
            return ("the dense weighted step is single-direction; the "
                    "fused bidirectional view expands positionally")
        # the boolean-dedup legality axes below do not apply: weighted
        # pipelines have no VisitedDedup (the ⊕-combine subsumes it)
        return None
    if logical.direction != "outbound" and engine.startswith("rowstore"):
        return ("outbound-only: the row-store emulation models the "
                "PostgreSQL baseline")
    if not logical.dedup and engine in ("bitmap", "hybrid", "diropt",
                                        "diropt_hybrid"):
        return ("needs BFS dedup: raw UNION ALL on a non-forest graph "
                "differs from the dense visited-bitmap semantics")
    return None


def _stamp_switch_thresholds(pipeline: Pipeline,
                             constants: CostConstants) -> Pipeline:
    """Stamp the cost constants' refittable switch thresholds
    (``pull_alpha``/``pull_beta``) onto every DirectionSwitch of a diropt
    pipeline — the planner prices AND executes the thresholds it owns.
    (Thresholds steer performance only; the row set is branch-invariant,
    so ``run_query`` with the default-threshold registry build stays
    row-identical.)"""
    from repro.core.operators import DirectionSwitch

    changed = False
    ops = []
    for op in pipeline.ops:
        if isinstance(op, DirectionSwitch) and (
                op.alpha != constants.pull_alpha
                or op.beta != constants.pull_beta):
            op = dataclasses.replace(op, alpha=constants.pull_alpha,
                                     beta=constants.pull_beta)
            changed = True
        ops.append(op)
    if not changed:
        return pipeline
    return dataclasses.replace(pipeline, ops=tuple(ops))


def _multiquery_reason(logical: LogicalQuery, lanes: int) -> Optional[str]:
    """Why the bit-parallel multiquery engine is not a candidate (None when
    it is).  It is a BATCH engine: without a coalesced lane count there is
    nothing to amortize the word sweep over."""
    if lanes <= 1:
        return ("bit-parallel MS-BFS amortizes one word sweep over a "
                "coalesced batch; single-root planning has no lanes "
                "(pass lanes=N)")
    if lanes > WORD_LANES:
        return (f"packs at most {WORD_LANES} lanes per frontier word; "
                "split the batch across dispatches")
    if getattr(logical, "workload", "reach") != "reach":
        return ("no value plane: the packed word carries one reach bit "
                "per lane")
    if not logical.dedup:
        return ("needs BFS dedup: raw UNION ALL on a non-forest graph "
                "differs from the dense visited-bitmap semantics")
    return None


def _rank_key(c: PhysicalChoice):
    """Ranking is per ROOT: a batch engine's whole-dispatch estimate is
    amortized over its coalesced lanes before comparing against the
    one-root-at-a-time engines."""
    lanes = max(getattr(c.query, "lanes", 1), 1)
    return (c.cost.est_us / lanes, c.label)


def plan(query: Union[str, RecursiveCTE, LogicalQuery], ds: Dataset, *,
         root: Optional[int] = None, caps: Optional[EngineCaps] = None,
         include_kernel: bool = False,
         default_max_depth: Optional[int] = None,
         constants: Optional[CostConstants] = None,
         lanes: int = 1) -> PlannerReport:
    """One full planning pass: parse/normalize as needed, price every legal
    candidate, rank.

    ``constants`` are the cost-model time constants to price with — the
    hand-calibrated prior by default, a :class:`~repro.planner.calibrate.
    Calibrator`'s refit values when the serving feedback loop supplies
    them.  An unresolved ``kernel_factor`` is measured on first use.

    ``lanes`` is the coalesced batch size this plan will serve (the
    serving layer passes its bucket's lane count).  With ``lanes > 1`` the
    bit-parallel ``multiquery`` engine joins the candidate set, priced per
    coalesced batch; ranking compares PER-ROOT amortized cost, so one
    word-sweep dispatch answering N roots competes fairly with N scalar
    dispatches."""
    if isinstance(query, str):
        query = parse(query)
    if isinstance(query, RecursiveCTE):
        logical = normalize(query, ds, root=root,
                            default_max_depth=default_max_depth)
    else:
        logical = query
        if root is not None:
            logical = dataclasses.replace(logical, root=root)
    stats = ds.stats(logical.direction)
    if caps is None:
        caps = default_caps(stats, logical)

    workload = getattr(logical, "workload", "reach")
    weight_col = getattr(logical, "weight_col", None)
    candidates, skipped = [], []
    if include_kernel and logical.direction == "both":
        skipped.append((KERNEL_LABEL,
                        "the Pallas expand kernel walks one direction CSR; "
                        "the fused bidirectional view expands through "
                        "expand_frontier_both"))
        include_kernel = False
    if include_kernel and workload != "reach":
        skipped.append((KERNEL_LABEL,
                        "the expand kernel is boolean-only; the weighted "
                        "dense combine has its own spmm_segment routing"))
        include_kernel = False
    consts = resolve_constants(constants, need_kernel=include_kernel)

    col_bytes = column_bytes(ds.table)
    row_bytes = ds.rows.width * 4
    for engine in ENGINE_NAMES:
        reason = _illegal_reason(engine, logical)
        if reason is not None:
            skipped.append((engine, reason))
            continue
        q = RecursiveQuery(engine=engine, max_depth=logical.max_depth,
                           payload_cols=logical.payload_cols, caps=caps,
                           dedup=logical.dedup,
                           direction=logical.direction,
                           workload=workload, weight_col=weight_col)
        pipeline = _stamp_switch_thresholds(build_plan(q), consts)
        cost = pipeline_cost(pipeline, stats, row_bytes=row_bytes,
                             col_bytes=col_bytes, constants=consts)
        candidates.append(PhysicalChoice(engine=engine, query=q,
                                         logical=logical, pipeline=pipeline,
                                         cost=cost))
    mq_reason = _multiquery_reason(logical, lanes)
    if mq_reason is not None:
        # single-root planning (lanes <= 1) never asked for the batch
        # engine — recording "no lanes" on every plain plan() would be
        # noise in EXPLAIN and the golden plan documents; a skip entry
        # only means a REQUESTED coalesced batch was inadmissible
        if lanes > 1:
            skipped.append(("multiquery", mq_reason))
    else:
        q = RecursiveQuery(engine="multiquery", max_depth=logical.max_depth,
                           payload_cols=logical.payload_cols, caps=caps,
                           dedup=logical.dedup, direction=logical.direction,
                           workload=workload, weight_col=weight_col,
                           lanes=int(lanes))
        pipeline = build_plan(q)
        cost = pipeline_cost(pipeline, stats, row_bytes=row_bytes,
                             col_bytes=col_bytes, constants=consts)
        candidates.append(PhysicalChoice(engine="multiquery", query=q,
                                         logical=logical, pipeline=pipeline,
                                         cost=cost))
    if include_kernel and _illegal_reason("precursive", logical) is None:
        q = RecursiveQuery(engine="precursive", max_depth=logical.max_depth,
                           payload_cols=logical.payload_cols, caps=caps,
                           dedup=logical.dedup, direction=logical.direction)
        pipeline = precursive_plan(caps, logical.max_depth, q.out_cols,
                                   logical.dedup, logical.direction,
                                   expand_fn=kernel_expand_fn())
        cost = pipeline_cost(pipeline, stats, row_bytes=row_bytes,
                             col_bytes=col_bytes, constants=consts)
        candidates.append(PhysicalChoice(engine="precursive", query=q,
                                         logical=logical, pipeline=pipeline,
                                         cost=cost, use_kernel=True))
    if not candidates:
        raise ValueError("no legal physical plan for this query "
                         f"(skipped: {skipped!r})")
    candidates.sort(key=_rank_key)
    return PlannerReport(logical=logical, stats=stats,
                         ranked=tuple(candidates), skipped=tuple(skipped),
                         constants=consts)


def choose(query, ds: Dataset, **kwargs) -> PhysicalChoice:
    """The planner's pick: best-ranked physical plan for the query."""
    return plan(query, ds, **kwargs).best


def plan_and_run(query, ds: Dataset,
                 roots: Union[int, Sequence[int], None] = None, *,
                 caps: Optional[EngineCaps] = None,
                 include_kernel: bool = False,
                 default_max_depth: Optional[int] = None,
                 constants: Optional[CostConstants] = None) -> BFSResult:
    """Parse -> normalize -> cost -> pick -> execute, no engine name needed.

    ``roots`` may be one root (scalar) or a sequence (served as ONE
    vmap-batched dispatch).  Omit it to use the literal root in the query
    text."""
    root = None
    if roots is not None and np.ndim(roots) == 0:
        root = int(roots)
    best = choose(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth, constants=constants)
    return best.run(ds, roots)
