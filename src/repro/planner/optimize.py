"""Cost-based engine selection: enumerate every physical realization of a
:class:`~repro.planner.ast.LogicalQuery`, price each against the dataset's
statistics, and return a ranked list of :class:`PhysicalChoice`.

The candidate space is the axis the paper measures, plus the beyond-paper
engines this repo grew:

* positional vs tuple vs row recursion (``precursive`` / ``trecursive`` /
  ``rowstore[_index]``) — early vs late materialization;
* the Exp-3 rewrite on and off (``*_rewrite`` engines: slim carry + one
  top-level join);
* sparse CSR expansion vs the dense ``DenseBitmapStep`` vs ``HybridStep``
  (``bitmap`` / ``hybrid``);
* the Pallas ``frontier_expand`` kernel plugged into ``CSRIndexJoin`` as an
  alternative physical expansion (``precursive+kernel``, opt-in).

Every candidate compiles through the same :data:`~repro.core.engine.
PLAN_BUILDERS` registry the forced-engine path uses, so the planner's pick
is bit-identical to ``run_query`` with the chosen engine name.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.engine import (ENGINE_NAMES, Dataset, PLAN_BUILDERS,
                               RecursiveQuery, run_query, run_query_batch)
from repro.core.operators import (BFSResult, EngineCaps, Pipeline, execute,
                                  execute_batch)
from repro.core.recursive import precursive_plan

from .ast import LogicalQuery, RecursiveCTE, normalize, parse
from .cost import PlanCost, column_bytes, pipeline_cost
from .stats import GraphStats

__all__ = ["PhysicalChoice", "PlannerReport", "plan", "choose",
           "plan_and_run", "default_caps", "kernel_expand_fn",
           "KERNEL_LABEL"]

KERNEL_LABEL = "precursive+kernel"

_KERNEL_FN = None


def kernel_expand_fn():
    """The Pallas ``frontier_expand`` plug-in for ``CSRIndexJoin``, created
    once so every planned pipeline shares one jit cache entry.  Interpret
    mode is used off-TPU (numerically identical, not perf-representative)."""
    global _KERNEL_FN
    if _KERNEL_FN is None:
        import jax

        from repro.kernels.frontier_expand.ops import make_expand_fn
        _KERNEL_FN = make_expand_fn(
            interpret=jax.default_backend() != "tpu")
    return _KERNEL_FN


def _kernel_factor() -> float:
    """Relative cost of the kernel expansion vs the XLA formulation: cheap
    on TPU (fused VMEM-tiled phases), heavily penalized elsewhere where it
    runs in interpret mode (~200x measured on the CI profile)."""
    import jax
    return 0.7 if jax.default_backend() == "tpu" else 200.0


@dataclasses.dataclass(frozen=True)
class PhysicalChoice:
    """One ranked physical plan: an engine name (plus the optional kernel
    expansion), the concrete RecursiveQuery it compiles from, the Pipeline
    it was costed with (the same object run()/EXPLAIN use), and its cost
    estimate."""

    engine: str
    query: RecursiveQuery
    logical: LogicalQuery
    pipeline: Pipeline
    cost: PlanCost
    use_kernel: bool = False

    @property
    def label(self) -> str:
        return KERNEL_LABEL if self.use_kernel else self.engine

    def run(self, ds: Dataset, roots: Union[int, Sequence[int], None] = None,
            *, check_overflow: bool = True) -> BFSResult:
        """Execute the chosen plan (single root or a vmap batch) and dress
        the result per the logical query: attach the ``depth`` output column
        and project the requested value columns.

        A capacity overflow (stats-derived block sizes can undershoot for
        unsampled roots or raw UNION ALL walks) raises rather than silently
        truncating; pass bigger ``caps`` to plan(), or
        ``check_overflow=False`` to accept the flagged partial result."""
        roots = self.logical.root if roots is None else roots
        if roots is None:
            raise ValueError("no root: the query has no literal seed and "
                             "none was passed to run()")
        batched = np.ndim(roots) > 0
        if self.use_kernel:
            ctx = ds.context(self.query.direction)
            r = (execute_batch(self.pipeline, ctx, roots, ds.num_vertices)
                 if batched
                 else execute(self.pipeline, ctx, roots, ds.num_vertices))
        else:
            r = (run_query_batch(self.query, ds, roots) if batched
                 else run_query(self.query, ds, roots))
        if check_overflow and bool(np.any(np.asarray(r.overflow))):
            raise RuntimeError(
                f"capacity overflow executing {self.label} with "
                f"caps={self.query.caps}: the result is truncated — pass "
                "larger caps to plan()/plan_and_run(), or "
                "check_overflow=False to accept the partial result")
        values = {k: v for k, v in r.values.items()
                  if k in self.logical.want_cols}
        missing = set(self.logical.want_cols) - set(values)
        if missing:
            raise KeyError(f"engine {self.label!r} did not materialize "
                           f"requested column(s) {sorted(missing)} "
                           f"(produced {sorted(r.values)})")
        if self.logical.want_depth:
            values["depth"] = r.row_depths
        return r._replace(values=values)


@dataclasses.dataclass(frozen=True)
class PlannerReport:
    """Everything one planning pass produced (EXPLAIN renders this)."""

    logical: LogicalQuery
    stats: GraphStats
    ranked: Tuple[PhysicalChoice, ...]          # best first
    skipped: Tuple[Tuple[str, str], ...]        # (engine, reason)

    @property
    def best(self) -> PhysicalChoice:
        return self.ranked[0]


def default_caps(stats: GraphStats, logical: LogicalQuery) -> EngineCaps:
    """Volcano block sizing from statistics: the frontier block covers the
    widest sampled level with headroom; the result block covers the exact
    worst case under dedup (every join-space edge once) or a margin over
    the sampled expectation for raw UNION ALL walks."""
    ej = stats.num_edges
    frontier = int(min(ej + 8, max(1024, 4 * stats.max_level_edges)))
    if logical.dedup:
        result = ej + 8
    else:
        est = stats.total_edges(logical.max_depth)
        result = int(min(max(4 * est, 4096), max(4 * ej, 4096)))
    return EngineCaps(frontier=frontier, result=result)


def _illegal_reason(engine: str, logical: LogicalQuery) -> Optional[str]:
    if logical.direction != "outbound" and engine.startswith("rowstore"):
        return ("outbound-only: the row-store emulation models the "
                "PostgreSQL baseline")
    if not logical.dedup and engine in ("bitmap", "hybrid"):
        return ("needs BFS dedup: raw UNION ALL on a non-forest graph "
                "differs from the dense visited-bitmap semantics")
    return None


def plan(query: Union[str, RecursiveCTE, LogicalQuery], ds: Dataset, *,
         root: Optional[int] = None, caps: Optional[EngineCaps] = None,
         include_kernel: bool = False,
         default_max_depth: Optional[int] = None) -> PlannerReport:
    """One full planning pass: parse/normalize as needed, price every legal
    candidate, rank."""
    if isinstance(query, str):
        query = parse(query)
    if isinstance(query, RecursiveCTE):
        logical = normalize(query, ds, root=root,
                            default_max_depth=default_max_depth)
    else:
        logical = query
        if root is not None:
            logical = dataclasses.replace(logical, root=root)
    stats = ds.stats(logical.direction)
    if caps is None:
        caps = default_caps(stats, logical)

    col_bytes = column_bytes(ds.table)
    row_bytes = ds.rows.width * 4

    candidates, skipped = [], []
    for engine in ENGINE_NAMES:
        reason = _illegal_reason(engine, logical)
        if reason is not None:
            skipped.append((engine, reason))
            continue
        q = RecursiveQuery(engine=engine, max_depth=logical.max_depth,
                           payload_cols=logical.payload_cols, caps=caps,
                           dedup=logical.dedup,
                           direction=logical.direction)
        pipeline = PLAN_BUILDERS[engine](q)
        cost = pipeline_cost(pipeline, stats, row_bytes=row_bytes,
                             col_bytes=col_bytes)
        candidates.append(PhysicalChoice(engine=engine, query=q,
                                         logical=logical, pipeline=pipeline,
                                         cost=cost))
    if include_kernel and _illegal_reason("precursive", logical) is None:
        q = RecursiveQuery(engine="precursive", max_depth=logical.max_depth,
                           payload_cols=logical.payload_cols, caps=caps,
                           dedup=logical.dedup, direction=logical.direction)
        pipeline = precursive_plan(caps, logical.max_depth, q.out_cols,
                                   logical.dedup, logical.direction,
                                   expand_fn=kernel_expand_fn())
        cost = pipeline_cost(pipeline, stats, row_bytes=row_bytes,
                             col_bytes=col_bytes,
                             kernel_factor=_kernel_factor())
        candidates.append(PhysicalChoice(engine="precursive", query=q,
                                         logical=logical, pipeline=pipeline,
                                         cost=cost, use_kernel=True))
    if not candidates:
        raise ValueError("no legal physical plan for this query "
                         f"(skipped: {skipped!r})")
    candidates.sort(key=lambda c: (c.cost.est_us, c.label))
    return PlannerReport(logical=logical, stats=stats,
                         ranked=tuple(candidates), skipped=tuple(skipped))


def choose(query, ds: Dataset, **kwargs) -> PhysicalChoice:
    """The planner's pick: best-ranked physical plan for the query."""
    return plan(query, ds, **kwargs).best


def plan_and_run(query, ds: Dataset,
                 roots: Union[int, Sequence[int], None] = None, *,
                 caps: Optional[EngineCaps] = None,
                 include_kernel: bool = False,
                 default_max_depth: Optional[int] = None) -> BFSResult:
    """Parse -> normalize -> cost -> pick -> execute, no engine name needed.

    ``roots`` may be one root (scalar) or a sequence (served as ONE
    vmap-batched dispatch).  Omit it to use the literal root in the query
    text."""
    root = None
    if roots is not None and np.ndim(roots) == 0:
        root = int(roots)
    best = choose(query, ds, root=root, caps=caps,
                  include_kernel=include_kernel,
                  default_max_depth=default_max_depth)
    return best.run(ds, roots)
