"""Cost model: price a candidate :class:`~repro.core.operators.Pipeline`
against sampled graph statistics.

The model walks the ACTUAL operator composition — the same objects the
fixed-point driver executes — and asks each operator for its per-level
estimate (:meth:`~repro.core.operators.Operator.estimate`).  Per level the
planner supplies three measured cardinalities from the frontier-growth
samples (frontier rows in, dedup survivors, edge rows out) plus the
dataset's real column widths; the operator answers with rows and bytes.
Costs therefore track the paper's analysis directly: tuple pipelines pay
(3+N) gathers per level, row pipelines pay full heap widths, positional
pipelines pay one column per level and one late gather, dense pipelines pay
O(E) per level regardless of frontier size.  One port-specific twist: under
the static-shape padding convention every block operator touches its whole
fixed-capacity buffer, so per-level byte estimates scale with the Volcano
block CAPACITY, not the live row count (measured: this is what makes the
dense bitmap engine win small graphs with generous blocks, while positional
wins once ``E`` dwarfs the block size — the planner reproduces both).

Bytes are converted to an estimated wall time through a small set of
:class:`CostConstants` — an effective memory bandwidth, a fixed per-level
driver overhead, a per-query base, and the relative cost of the plugged
Pallas expansion kernel — so that a 2-level query on a dense O(E) pipeline
is not mistaken for free.  The constants only break ties; the ranking
currency is bytes.  :data:`DEFAULT_CONSTANTS` is the hand-calibrated CPU
prior; :mod:`repro.planner.calibrate` REFITS all four constants online from
measured per-bucket serving latencies, and the refit values flow back into
:func:`pipeline_cost` through the ``constants`` argument (this is why
:class:`PlanCost` keeps the factor-independent ``plain_bytes`` /
``kernel_bytes`` split: re-pricing a plan under new constants is arithmetic,
not a re-walk of the operator tree).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

from repro.core.operators import CostEnv, Pipeline

from .stats import GraphStats

__all__ = ["CostConstants", "DEFAULT_CONSTANTS", "OpEstimate", "PlanCost",
           "pipeline_cost", "estimate_us", "column_bytes"]

# effective bandwidth (bytes/us) + fixed per-level and per-query overheads.
# Deliberately round numbers: they convert bytes into a human-readable
# microsecond scale and arbitrate between "more levels" and "more bytes";
# the byte counts themselves carry the ranking.  These are the PRIOR values
# (one CPU profile); the calibrator refits them from measured latencies.
BYTES_PER_US = 10_000.0
LEVEL_US = 25.0
BASE_US = 50.0


# direction-switch thresholds (Beamer's alpha/beta restated for the cost
# model's work terms): pull iff alpha * m_f > m_u and beta * n_f >= V
PULL_ALPHA = 1.0
PULL_BETA = 64.0


# admission guard-ladder thresholds (microsecond budgets): a root whose
# PRE-DISPATCH cost estimate (reach rows priced through estimate_us under
# the session's CURRENT constants) exceeds guard_degrade_us is depth-clamped
# to a bounded prefix; exceeding guard_reject_us raises a typed
# AdmissionError before any dispatch.  The budgets are wall-time, so a
# calibrator refit of bytes_per_us/level_us/base_us automatically
# re-thresholds admission in ROWS — a machine measured slower admits less.
GUARD_DEGRADE_US = 1e6    # one second of predicted traversal -> degrade
GUARD_REJECT_US = 1e7     # ten seconds predicted -> reject outright


class CostConstants(NamedTuple):
    """The cost model's time constants, refittable as one unit.

    ``kernel_factor`` is the relative byte cost of the Pallas
    ``frontier_expand`` kernel vs the XLA expansion.  ``None`` means "not
    yet measured": the planner resolves it lazily through
    :func:`repro.planner.calibrate.measured_kernel_factor` (a real timed
    micro-benchmark, replacing the static 0.7x/200x guess) the first time a
    kernel candidate is priced.

    ``pull_alpha``/``pull_beta`` own the direction-optimizing switch
    thresholds (:class:`repro.core.operators.DirectionSwitch`): the planner
    stamps them onto every diropt pipeline it prices, so a calibrator
    refit that updates the constants re-thresholds the executed switch —
    the decision is priced and measured, not hard-coded.

    ``guard_degrade_us``/``guard_reject_us`` own the admission guard
    ladder (:mod:`repro.planner.guards`): fixed microsecond budgets that a
    root's pre-dispatch cost estimate is compared against.  Because the
    estimate is priced through :func:`estimate_us` under the SAME constants
    the calibrator refits, a refit re-thresholds admission in rows without
    touching the budgets themselves (the refit preserves them via
    ``_replace``, like the pull thresholds)."""

    bytes_per_us: float = BYTES_PER_US
    level_us: float = LEVEL_US
    base_us: float = BASE_US
    kernel_factor: Optional[float] = None
    pull_alpha: float = PULL_ALPHA
    pull_beta: float = PULL_BETA
    guard_degrade_us: float = GUARD_DEGRADE_US
    guard_reject_us: float = GUARD_REJECT_US

    def to_json(self) -> dict:
        return {"bytes_per_us": self.bytes_per_us, "level_us": self.level_us,
                "base_us": self.base_us, "kernel_factor": self.kernel_factor,
                "pull_alpha": self.pull_alpha, "pull_beta": self.pull_beta,
                "guard_degrade_us": self.guard_degrade_us,
                "guard_reject_us": self.guard_reject_us}

    @classmethod
    def from_json(cls, doc: dict) -> "CostConstants":
        return cls(bytes_per_us=float(doc["bytes_per_us"]),
                   level_us=float(doc["level_us"]),
                   base_us=float(doc["base_us"]),
                   kernel_factor=(None if doc.get("kernel_factor") is None
                                  else float(doc["kernel_factor"])),
                   pull_alpha=float(doc.get("pull_alpha", PULL_ALPHA)),
                   pull_beta=float(doc.get("pull_beta", PULL_BETA)),
                   guard_degrade_us=float(doc.get("guard_degrade_us",
                                                  GUARD_DEGRADE_US)),
                   guard_reject_us=float(doc.get("guard_reject_us",
                                                 GUARD_REJECT_US)))


DEFAULT_CONSTANTS = CostConstants()


def estimate_us(constants: CostConstants, *, plain_bytes: float,
                kernel_bytes: float, levels: int) -> float:
    """The cost model's time formula over the factor-independent byte split:
    ``base + level_us * levels + (plain + kf * kernel) / bandwidth``.
    This is the single place bytes become microseconds — the optimizer, the
    calibrator's least-squares design matrix, and EXPLAIN all agree on it."""
    kf = constants.kernel_factor
    if kernel_bytes > 0.0 and kf is None:
        raise ValueError(
            "pricing a kernel-expansion pipeline needs a concrete "
            "kernel_factor; resolve it first (see "
            "repro.planner.calibrate.measured_kernel_factor)")
    total = plain_bytes + (kf or 0.0) * kernel_bytes
    return (constants.base_us + constants.level_us * levels
            + total / constants.bytes_per_us)


class OpEstimate(NamedTuple):
    """One operator's totals across all executed levels."""

    label: str
    rows: float
    bytes: float


class PlanCost(NamedTuple):
    total_bytes: float
    est_us: float
    levels: int
    result_rows: float
    per_op: Tuple[OpEstimate, ...]     # seed, *loop ops, finisher
    # factor-independent byte split: total_bytes == plain_bytes +
    # kernel_factor * kernel_bytes.  The calibrator's design matrix and the
    # plan store re-price plans from these without re-walking the pipeline.
    plain_bytes: float = 0.0
    kernel_bytes: float = 0.0
    # a DirectionSwitch pipeline's PREDICTED per-level decision
    # ('push'/'pull'), one entry per priced level: the calibration
    # signature carries it so push-heavy and pull-heavy executions never
    # pool under one regression, and the plan store persists it
    level_dirs: Tuple[str, ...] = ()


def column_bytes(table) -> dict:
    """Per-row byte width of every column of a ColumnTable (+ the synthetic
    planner columns)."""
    widths = {name: table.width_bytes([name]) for name in table.names}
    widths["__next__"] = 4
    widths["depth"] = 4
    return widths


def _level_envs(pipeline: Pipeline, stats: GraphStats, *, row_bytes: int,
                col_bytes: dict, kernel_factor: float) -> list[CostEnv]:
    """One CostEnv per executed level, mirroring the driver's loop:

    * edge-seeded pipelines append the seed block (level 0) before the loop,
      then iteration ``i`` turns the level-``i`` frontier into level ``i+1``
      and runs while ``depth < max_depth`` and the frontier is non-empty;
    * the dense pipeline seeds a vertex bitmap and emits level ``i`` INSIDE
      iteration ``i`` (``inclusive`` loop bound).
    """
    md = pipeline.max_depth
    s = stats.level_edges
    n = stats.level_vertices

    def mk(f, u, m, seen):
        return CostEnv(frontier_rows=f, unique_rows=u, emitted_rows=m,
                       num_vertices=stats.num_vertices,
                       num_edges=stats.num_edges,
                       frontier_cap=pipeline.caps.frontier,
                       result_cap=pipeline.caps.result,
                       row_bytes=row_bytes, col_bytes=col_bytes,
                       kernel_factor=kernel_factor, visited_rows=seen)

    envs = []
    # vertices discovered before iteration i: the root + every earlier
    # level's new vertices (the pull-side work term)
    if pipeline.seed.kind == "dense":
        # frontier entering iteration i is the level-i vertex set
        limit = md + (1 if pipeline.inclusive else 0)
        seen = 1.0
        for i in range(limit):
            f = 1.0 if i == 0 else stats.vertices_at(i - 1)
            if f <= 0:
                break
            envs.append(mk(f, stats.vertices_at(i), stats.edges_at(i),
                           seen))
            seen += stats.vertices_at(i)
    else:
        seen = 1.0
        for i in range(md):
            f = stats.edges_at(i)
            if f <= 0:
                break
            envs.append(mk(f, stats.vertices_at(i), stats.edges_at(i + 1),
                           seen))
            seen += stats.vertices_at(i)
    return envs


def pipeline_cost(pipeline: Pipeline, stats: GraphStats, *, row_bytes: int,
                  col_bytes: dict,
                  constants: Optional[CostConstants] = None) -> PlanCost:
    """Estimate rows and bytes for every operator of ``pipeline`` and the
    total cost of running it to its fixed point.

    The per-operator byte estimates are linear in ``CostEnv.kernel_factor``
    (only a plugged expansion kernel scales with it), so two walks — one at
    factor 0, one at factor 1 — recover the factor-independent split
    ``plain_bytes + kernel_factor * kernel_bytes`` that the calibrator
    refits against and the plan store re-prices from."""
    consts = constants if constants is not None else DEFAULT_CONSTANTS
    envs = _level_envs(pipeline, stats, row_bytes=row_bytes,
                       col_bytes=col_bytes, kernel_factor=1.0)
    result_rows = stats.total_edges(pipeline.max_depth)
    all_ops = (pipeline.seed, *pipeline.ops, pipeline.finisher)
    # only a plugged expansion kernel (or the dense ⊕-combine routed
    # through spmm_segment) makes byte estimates factor-sensitive;
    # everything else is priced in one walk
    has_kernel = any(getattr(op, "expand_fn", None) is not None
                     or getattr(op, "use_kernel", False)
                     for op in all_ops)

    def total_env(rows):
        return CostEnv(frontier_rows=rows, unique_rows=rows,
                       emitted_rows=rows, num_vertices=stats.num_vertices,
                       num_edges=stats.num_edges,
                       frontier_cap=pipeline.caps.frontier,
                       result_cap=pipeline.caps.result,
                       row_bytes=row_bytes, col_bytes=col_bytes,
                       kernel_factor=1.0, visited_rows=0.0)

    # (plain bytes at factor 0, unit kernel bytes = bytes@1 - bytes@0)
    def split(op, env) -> tuple[float, float, float]:
        at1 = op.estimate(env)
        if not has_kernel:
            return at1.rows, at1.bytes, 0.0
        at0 = op.estimate(env._replace(kernel_factor=0.0))
        return at1.rows, at0.bytes, at1.bytes - at0.bytes

    # the seed runs once, with the level-0 cardinalities
    seed_env = envs[0] if envs else total_env(stats.edges_at(0))
    rows, plain, kern = split(pipeline.seed, seed_env)
    per_op = [[pipeline.seed.describe(), rows, plain, kern]]

    for op in pipeline.ops:
        per_op.append([op.describe(), 0.0, 0.0, 0.0])
    for env in envs:
        for slot, op in zip(per_op[1:], pipeline.ops):
            rows, plain, kern = split(op, env)
            slot[1] += rows
            slot[2] += plain
            slot[3] += kern

    rows, plain, kern = split(pipeline.finisher, total_env(result_rows))
    per_op.append([pipeline.finisher.describe(), rows, plain, kern])

    plain_bytes = sum(slot[2] for slot in per_op)
    kernel_bytes = sum(slot[3] for slot in per_op)
    # a DirectionSwitch pipeline's predicted per-level decisions (the same
    # predicate the runtime lax.cond evaluates, on the sampled profile)
    switch = next((op for op in pipeline.ops
                   if hasattr(op, "predict")), None)
    level_dirs = (tuple(switch.predict(env) for env in envs)
                  if switch is not None else ())
    # estimate_us is THE pricing formula (and the unresolved-kernel guard)
    est_us = estimate_us(consts, plain_bytes=plain_bytes,
                         kernel_bytes=kernel_bytes, levels=len(envs))
    kf = consts.kernel_factor or 0.0
    return PlanCost(
        total_bytes=plain_bytes + kf * kernel_bytes, est_us=est_us,
        levels=len(envs), result_rows=result_rows,
        per_op=tuple(OpEstimate(lbl, r, p + kf * k)
                     for lbl, r, p, k in per_op),
        plain_bytes=plain_bytes, kernel_bytes=kernel_bytes,
        level_dirs=level_dirs)
