"""Cost model: price a candidate :class:`~repro.core.operators.Pipeline`
against sampled graph statistics.

The model walks the ACTUAL operator composition — the same objects the
fixed-point driver executes — and asks each operator for its per-level
estimate (:meth:`~repro.core.operators.Operator.estimate`).  Per level the
planner supplies three measured cardinalities from the frontier-growth
samples (frontier rows in, dedup survivors, edge rows out) plus the
dataset's real column widths; the operator answers with rows and bytes.
Costs therefore track the paper's analysis directly: tuple pipelines pay
(3+N) gathers per level, row pipelines pay full heap widths, positional
pipelines pay one column per level and one late gather, dense pipelines pay
O(E) per level regardless of frontier size.  One port-specific twist: under
the static-shape padding convention every block operator touches its whole
fixed-capacity buffer, so per-level byte estimates scale with the Volcano
block CAPACITY, not the live row count (measured: this is what makes the
dense bitmap engine win small graphs with generous blocks, while positional
wins once ``E`` dwarfs the block size — the planner reproduces both).

Bytes are converted to an estimated wall time with two constants — an
effective memory bandwidth and a fixed per-level driver overhead — so that a
2-level query on a dense O(E) pipeline is not mistaken for free.  The
constants only break ties; the ranking currency is bytes.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

from repro.core.operators import CostEnv, Pipeline

from .stats import GraphStats

__all__ = ["OpEstimate", "PlanCost", "pipeline_cost", "column_bytes"]

# effective bandwidth (bytes/us) + fixed per-level and per-query overheads.
# Deliberately round numbers: they convert bytes into a human-readable
# microsecond scale and arbitrate between "more levels" and "more bytes";
# the byte counts themselves carry the ranking.
BYTES_PER_US = 10_000.0
LEVEL_US = 25.0
BASE_US = 50.0


class OpEstimate(NamedTuple):
    """One operator's totals across all executed levels."""

    label: str
    rows: float
    bytes: float


class PlanCost(NamedTuple):
    total_bytes: float
    est_us: float
    levels: int
    result_rows: float
    per_op: Tuple[OpEstimate, ...]     # seed, *loop ops, finisher


def column_bytes(table) -> dict:
    """Per-row byte width of every column of a ColumnTable (+ the synthetic
    planner columns)."""
    widths = {name: table.width_bytes([name]) for name in table.names}
    widths["__next__"] = 4
    widths["depth"] = 4
    return widths


def _level_envs(pipeline: Pipeline, stats: GraphStats, *, row_bytes: int,
                col_bytes: dict, kernel_factor: float) -> list[CostEnv]:
    """One CostEnv per executed level, mirroring the driver's loop:

    * edge-seeded pipelines append the seed block (level 0) before the loop,
      then iteration ``i`` turns the level-``i`` frontier into level ``i+1``
      and runs while ``depth < max_depth`` and the frontier is non-empty;
    * the dense pipeline seeds a vertex bitmap and emits level ``i`` INSIDE
      iteration ``i`` (``inclusive`` loop bound).
    """
    md = pipeline.max_depth
    s = stats.level_edges
    n = stats.level_vertices

    def mk(f, u, m):
        return CostEnv(frontier_rows=f, unique_rows=u, emitted_rows=m,
                       num_vertices=stats.num_vertices,
                       num_edges=stats.num_edges,
                       frontier_cap=pipeline.caps.frontier,
                       result_cap=pipeline.caps.result,
                       row_bytes=row_bytes, col_bytes=col_bytes,
                       kernel_factor=kernel_factor)

    envs = []
    if pipeline.seed.kind == "dense":
        # frontier entering iteration i is the level-i vertex set
        limit = md + (1 if pipeline.inclusive else 0)
        for i in range(limit):
            f = 1.0 if i == 0 else stats.vertices_at(i - 1)
            if f <= 0:
                break
            envs.append(mk(f, stats.vertices_at(i), stats.edges_at(i)))
    else:
        for i in range(md):
            f = stats.edges_at(i)
            if f <= 0:
                break
            envs.append(mk(f, stats.vertices_at(i), stats.edges_at(i + 1)))
    return envs


def pipeline_cost(pipeline: Pipeline, stats: GraphStats, *, row_bytes: int,
                  col_bytes: dict, kernel_factor: float = 1.0) -> PlanCost:
    """Estimate rows and bytes for every operator of ``pipeline`` and the
    total cost of running it to its fixed point."""
    envs = _level_envs(pipeline, stats, row_bytes=row_bytes,
                       col_bytes=col_bytes, kernel_factor=kernel_factor)
    result_rows = stats.total_edges(pipeline.max_depth)

    def total_env(rows):
        return CostEnv(frontier_rows=rows, unique_rows=rows,
                       emitted_rows=rows, num_vertices=stats.num_vertices,
                       num_edges=stats.num_edges,
                       frontier_cap=pipeline.caps.frontier,
                       result_cap=pipeline.caps.result,
                       row_bytes=row_bytes, col_bytes=col_bytes,
                       kernel_factor=kernel_factor)

    # the seed runs once, with the level-0 cardinalities
    seed_env = envs[0] if envs else total_env(stats.edges_at(0))
    seed_cost = pipeline.seed.estimate(seed_env)
    per_op = [[pipeline.seed.describe(), seed_cost.rows, seed_cost.bytes]]

    for op in pipeline.ops:
        per_op.append([op.describe(), 0.0, 0.0])
    for env in envs:
        for slot, op in zip(per_op[1:], pipeline.ops):
            c = op.estimate(env)
            slot[1] += c.rows
            slot[2] += c.bytes

    fin = pipeline.finisher.estimate(total_env(result_rows))
    per_op.append([pipeline.finisher.describe(), fin.rows, fin.bytes])

    total_bytes = sum(slot[2] for slot in per_op)
    est_us = BASE_US + LEVEL_US * len(envs) + total_bytes / BYTES_PER_US
    return PlanCost(
        total_bytes=total_bytes, est_us=est_us, levels=len(envs),
        result_rows=result_rows,
        per_op=tuple(OpEstimate(lbl, r, b) for lbl, r, b in per_op))
