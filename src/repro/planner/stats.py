"""Graph statistics for the planner: degree histograms, frontier-growth
samples, and density/shape estimates, computed once per (Dataset, direction)
and cached on the Dataset (:meth:`repro.core.engine.Dataset.stats`).

Everything here runs in numpy on the host — statistics are a build-time
artifact, like the CSR index, not a per-query cost.  The frontier profile is
measured, not modeled: a handful of deterministic sample roots are traversed
level by level, recording how many edges each level emits and how many new
vertices it discovers.  Those two per-level series are exactly the
cardinalities every operator's :meth:`~repro.core.operators.Operator.estimate`
needs.

Two refinements feed the batched serving path:

* **root-conditional estimates** (:meth:`GraphStats.estimate_root`,
  :func:`root_estimates`): the per-sample-root profiles are kept, so a query
  root that WAS sampled gets its exact measured reach/depth; any other root
  gets the mean profile rescaled by its own out-degree (level 0 is exact —
  it is the degree — and later levels are degree-conditioned).  These are
  what the planner buckets a batch of roots by.
* **walk profiles** (``level_walk_edges``): raw UNION ALL semantics count
  *paths*, not vertices, so a cyclic or reconverging graph can legally emit
  far more than E rows within a depth bound.  The walk profile propagates
  per-vertex path counts level by level (one ``bincount`` per level) and is
  what sizes non-dedup result buffers.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence, Tuple

import numpy as np

__all__ = ["GraphStats", "RootEstimate", "compute_stats", "root_estimates"]

_MAX_SAMPLE_ROOTS = 6
_MAX_SAMPLE_LEVELS = 64
_MAX_WALK_LEVELS = 40
_WALK_COUNT_CEIL = 1e15
_HIST_BUCKETS = 16


class RootEstimate(NamedTuple):
    """Predicted traversal shape for ONE root (depth-bounded).

    ``exact`` is True when the root was one of the sampled profile roots —
    then the numbers are measured, not modeled."""

    root: int
    reach_rows: float       # edge rows a depth-bounded BFS emits
    max_level_rows: float   # widest single level
    depth: int              # levels until the frontier dies (<= max_depth)
    exact: bool


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Per-direction statistics of one prepared :class:`Dataset`."""

    direction: str
    num_vertices: int
    num_edges: int                     # join-space edge count (2E for 'both')
    density: float                     # E / V
    avg_degree: float                  # mean out-degree of source vertices
    max_degree: int
    degree_histogram: Tuple[int, ...]  # log2-bucketed out-degrees (deg >= 1)
    is_forest: bool                    # unique-path graph: UNION ALL == BFS
    sample_roots: Tuple[int, ...]
    level_edges: Tuple[float, ...]     # mean edges emitted at level l
    level_vertices: Tuple[float, ...]  # mean new vertices found at level l
    max_level_edges: int               # widest level over all samples
    reach_edges: float                 # mean edges reached per sample root
    max_levels: int                    # longest sampled traversal
    root_profiles: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()
    #   (root, edges-per-level) for EACH sample root — the exact branch of
    #   the root-conditional estimator
    level_walk_edges: Tuple[float, ...] = ()
    #   worst sampled UNION-ALL walk rows (path counts) emitted at level l

    def edges_at(self, level: int) -> float:
        if 0 <= level < len(self.level_edges):
            return self.level_edges[level]
        return 0.0

    def vertices_at(self, level: int) -> float:
        if 0 <= level < len(self.level_vertices):
            return self.level_vertices[level]
        return 0.0

    def total_edges(self, max_depth: int) -> float:
        """Expected result cardinality of a depth-bounded BFS."""
        return float(sum(self.level_edges[: max_depth + 1]))

    @property
    def _walk_sample_truncated(self) -> bool:
        """True iff the walk sample was CUT (level horizon or count
        ceiling) rather than terminated by the frontier dying — only a cut
        sample justifies extrapolating past its end."""
        w = self.level_walk_edges
        return bool(w) and (len(w) >= _MAX_WALK_LEVELS
                            or w[-1] >= _WALK_COUNT_CEIL)

    def _walk_levels(self, max_depth: int) -> list[float]:
        """Per-level walk rows up to ``max_depth``, geometrically
        extrapolated past the sampled horizon ONLY when the sample was
        truncated (walks on cyclic graphs never die, so their sample is
        cut, not terminated; a terminated walk contributes nothing past
        its last level)."""
        w = list(self.level_walk_edges[: max_depth + 1])
        n = max_depth + 1 - len(w)
        if (n > 0 and self._walk_sample_truncated
                and len(self.level_walk_edges) >= 2 and w and w[-1] > 0):
            tail = self.level_walk_edges[-2:]
            ratio = tail[1] / tail[0] if tail[0] > 0 else 1.0
            if ratio > 1.0:      # still growing when the sample was cut
                cur = w[-1]
                for _ in range(n):
                    cur = min(cur * ratio, _WALK_COUNT_CEIL)
                    w.append(cur)
        return w

    def total_walk_rows(self, max_depth: int) -> float:
        """Expected result cardinality of a depth-bounded raw UNION ALL
        walk (path-count semantics — can far exceed ``num_edges``)."""
        return float(min(sum(self._walk_levels(max_depth)),
                         _WALK_COUNT_CEIL))

    def max_walk_level_rows(self, max_depth: int) -> float:
        """Widest single walk level within the depth bound."""
        return float(max(self._walk_levels(max_depth), default=0.0))

    def estimate_root(self, root: int, out_degree: int, max_depth: int
                      ) -> RootEstimate:
        """Root-conditional reach/depth prediction (BFS semantics).

        Exact when ``root`` was a sample root; otherwise the mean profile is
        rescaled by ``out_degree`` (level 0 IS the degree; deeper levels are
        degree-conditioned and clamped to the graph totals)."""
        for r, prof in self.root_profiles:
            if r == root:
                lv = [float(x) for x in prof[: max_depth + 1]]
                return RootEstimate(
                    root=root,
                    reach_rows=float(sum(lv)),
                    max_level_rows=float(max(lv, default=0.0)),
                    depth=len(lv), exact=True)
        if out_degree <= 0:
            return RootEstimate(root=root, reach_rows=0.0,
                                max_level_rows=0.0, depth=0, exact=True)
        base = self.level_edges[0] if self.level_edges else 0.0
        scale = out_degree / base if base > 0 else 1.0
        lv = [float(out_degree)]
        for l in range(1, max_depth + 1):
            x = self.edges_at(l) * scale
            if x <= 0.0:
                break
            lv.append(min(x, float(self.num_edges)))
        return RootEstimate(
            root=root,
            reach_rows=float(min(sum(lv), self.num_edges)),
            max_level_rows=float(min(max(lv), self.num_edges)),
            depth=len(lv), exact=False)


def _chains_terminate(heads: np.ndarray, tails: np.ndarray,
                      num_vertices: int) -> bool:
    """Given a functional map (each head has at most one tail), True iff
    every chain escapes to the sentinel — i.e. no cycle.  Pointer doubling:
    tree vertices saturate at the sentinel, ring vertices chase forever."""
    v = num_vertices
    step = np.full(v + 1, v, dtype=np.int64)
    step[heads] = tails
    step[v] = v
    hops = 1
    while hops < v:
        step = step[step]
        hops *= 2
    return bool((step[:v] == v).all())


def _is_forest(src: np.ndarray, dst: np.ndarray, num_vertices: int) -> bool:
    """True iff every vertex is reachable by AT MOST ONE path from any
    single root — the regime where raw UNION ALL walks and BFS dedup
    coincide.  That holds when the graph is acyclic and either never
    reconverges (in-degree <= 1: a forest) or never branches (out-degree
    <= 1: e.g. a reversed forest, whose frontier is always one vertex)."""
    if dst.size == 0:
        return True
    indeg = np.bincount(dst, minlength=num_vertices)
    if indeg.max() <= 1:
        return _chains_terminate(dst, src, num_vertices)
    outdeg = np.bincount(src, minlength=num_vertices)
    if outdeg.max() <= 1:
        return _chains_terminate(src, dst, num_vertices)
    return False


def _bfs_profile(src: np.ndarray, dst: np.ndarray, root: int,
                 num_vertices: int, max_levels: int
                 ) -> tuple[list[int], list[int]]:
    """One sampled traversal: (edges emitted, new vertices) per level."""
    visited = np.zeros(num_vertices, bool)
    frontier = np.zeros(num_vertices, bool)
    visited[root] = frontier[root] = True
    edges, verts = [], []
    for _ in range(max_levels):
        hit = frontier[src]
        s = int(hit.sum())
        if s == 0:
            break
        new = np.zeros(num_vertices, bool)
        new[dst[hit]] = True
        new &= ~visited
        visited |= new
        edges.append(s)
        verts.append(int(new.sum()))
        frontier = new
    return edges, verts


def _walk_profile(src: np.ndarray, dst: np.ndarray, root: int,
                  num_vertices: int, max_levels: int) -> list[float]:
    """Raw UNION ALL walk rows per level: propagate per-vertex PATH counts
    (floats, capped — walks on cyclic graphs grow without bound)."""
    c = np.zeros(num_vertices)
    c[root] = 1.0
    rows = []
    for _ in range(max_levels):
        w = c[src]                       # walk count carried by each edge
        lvl = float(w.sum())
        if lvl <= 0.0:
            break
        rows.append(min(lvl, _WALK_COUNT_CEIL))
        if lvl >= _WALK_COUNT_CEIL:
            break
        c = np.bincount(dst, weights=w, minlength=num_vertices)
    return rows


def _pick_roots(src: np.ndarray, num_vertices: int) -> np.ndarray:
    """Deterministic sample roots: source vertices spread across the id
    range (always includes the smallest source vertex — the benchmark and
    example root)."""
    outdeg = np.bincount(src, minlength=num_vertices)
    cand = np.flatnonzero(outdeg > 0)
    if cand.size == 0:
        return np.zeros(1, dtype=np.int64)
    take = min(_MAX_SAMPLE_ROOTS, cand.size)
    idx = np.linspace(0, cand.size - 1, num=take).astype(np.int64)
    return cand[np.unique(idx)]


def compute_stats(ds, direction: str = "outbound") -> GraphStats:
    """Compute (host-side) the planner statistics for one direction view.
    Called through :meth:`Dataset.stats`, which caches the result.

    ``compute_stats.calls`` counts executions process-wide — the serving
    session's ``stats_calls`` counter (and the plan-store tests asserting a
    rehydrated session pays ZERO statistics passes) read it."""
    compute_stats.calls += 1
    ctx = ds.context(direction)
    src = np.asarray(ctx.join_src).astype(np.int64)
    dst = np.asarray(ctx.join_dst).astype(np.int64)
    if ctx.bidir:
        # the fused 'both' view keeps E-sized columns on device; the
        # HOST-side statistics pass materializes the virtual 2E join space
        # transiently (same numbers the old doubled view produced)
        src, dst = (np.concatenate([src, dst]), np.concatenate([dst, src]))
    v = int(ds.num_vertices)
    e = int(src.shape[0])

    outdeg = np.bincount(src, minlength=v)
    nonzero = outdeg[outdeg > 0]
    hist = np.zeros(_HIST_BUCKETS, dtype=np.int64)
    if nonzero.size:
        buckets = np.minimum(np.log2(nonzero).astype(np.int64),
                             _HIST_BUCKETS - 1)
        np.add.at(hist, buckets, 1)

    roots = _pick_roots(src, v)
    profiles = [_bfs_profile(src, dst, int(r), v, _MAX_SAMPLE_LEVELS)
                for r in roots]
    depth = max((len(p[0]) for p in profiles), default=0)
    level_edges = np.zeros(depth)
    level_verts = np.zeros(depth)
    for edges, verts in profiles:
        level_edges[:len(edges)] += edges
        level_verts[:len(verts)] += verts
    level_edges /= max(len(profiles), 1)
    level_verts /= max(len(profiles), 1)
    max_level = max((max(p[0]) for p in profiles if p[0]), default=0)

    # capacity is sized from walks, so take the WORST sampled root per level
    walks = [_walk_profile(src, dst, int(r), v, _MAX_WALK_LEVELS)
             for r in roots]
    wdepth = max((len(w) for w in walks), default=0)
    walk_edges = np.zeros(wdepth)
    for w in walks:
        walk_edges[:len(w)] = np.maximum(walk_edges[:len(w)], w)

    return GraphStats(
        direction=direction,
        num_vertices=v,
        num_edges=e,
        density=e / max(v, 1),
        avg_degree=float(nonzero.mean()) if nonzero.size else 0.0,
        max_degree=int(outdeg.max()) if v else 0,
        degree_histogram=tuple(int(x) for x in hist),
        is_forest=_is_forest(src, dst, v),
        sample_roots=tuple(int(r) for r in roots),
        level_edges=tuple(float(x) for x in level_edges),
        level_vertices=tuple(float(x) for x in level_verts),
        max_level_edges=int(max_level),
        reach_edges=float(sum(sum(p[0]) for p in profiles)
                          / max(len(profiles), 1)),
        max_levels=depth,
        root_profiles=tuple(
            (int(r), tuple(int(x) for x in p[0]))
            for r, p in zip(roots, profiles)),
        level_walk_edges=tuple(float(x) for x in walk_edges),
    )


compute_stats.calls = 0


def root_estimates(ds, direction: str, roots: Sequence[int], max_depth: int
                   ) -> list[RootEstimate]:
    """Root-conditional estimates for a whole batch of roots: exact for
    sampled roots, degree-conditioned otherwise.  Out-degrees come straight
    from the direction view's CSR ``indptr`` (O(1) per root, host-side)."""
    stats = ds.stats(direction)
    ctx = ds.context(direction)
    indptr = np.asarray(ctx.both_indptr if ctx.bidir else ctx.csr.indptr)
    v = stats.num_vertices
    out = []
    for r in np.asarray(roots, dtype=np.int64).reshape(-1):
        r = int(r)
        deg = int(indptr[r + 1] - indptr[r]) if 0 <= r < v else 0
        out.append(stats.estimate_root(r, deg, max_depth))
    return out
