"""Graph statistics for the planner: degree histograms, frontier-growth
samples, and density/shape estimates, computed once per (Dataset, direction)
and cached on the Dataset (:meth:`repro.core.engine.Dataset.stats`).

Everything here runs in numpy on the host — statistics are a build-time
artifact, like the CSR index, not a per-query cost.  The frontier profile is
measured, not modeled: a handful of deterministic sample roots are traversed
level by level, recording how many edges each level emits and how many new
vertices it discovers.  Those two per-level series are exactly the
cardinalities every operator's :meth:`~repro.core.operators.Operator.estimate`
needs.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["GraphStats", "compute_stats"]

_MAX_SAMPLE_ROOTS = 6
_MAX_SAMPLE_LEVELS = 64
_HIST_BUCKETS = 16


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Per-direction statistics of one prepared :class:`Dataset`."""

    direction: str
    num_vertices: int
    num_edges: int                     # join-space edge count (2E for 'both')
    density: float                     # E / V
    avg_degree: float                  # mean out-degree of source vertices
    max_degree: int
    degree_histogram: Tuple[int, ...]  # log2-bucketed out-degrees (deg >= 1)
    is_forest: bool                    # unique-path graph: UNION ALL == BFS
    sample_roots: Tuple[int, ...]
    level_edges: Tuple[float, ...]     # mean edges emitted at level l
    level_vertices: Tuple[float, ...]  # mean new vertices found at level l
    max_level_edges: int               # widest level over all samples
    reach_edges: float                 # mean edges reached per sample root
    max_levels: int                    # longest sampled traversal

    def edges_at(self, level: int) -> float:
        if 0 <= level < len(self.level_edges):
            return self.level_edges[level]
        return 0.0

    def vertices_at(self, level: int) -> float:
        if 0 <= level < len(self.level_vertices):
            return self.level_vertices[level]
        return 0.0

    def total_edges(self, max_depth: int) -> float:
        """Expected result cardinality of a depth-bounded BFS."""
        return float(sum(self.level_edges[: max_depth + 1]))


def _chains_terminate(heads: np.ndarray, tails: np.ndarray,
                      num_vertices: int) -> bool:
    """Given a functional map (each head has at most one tail), True iff
    every chain escapes to the sentinel — i.e. no cycle.  Pointer doubling:
    tree vertices saturate at the sentinel, ring vertices chase forever."""
    v = num_vertices
    step = np.full(v + 1, v, dtype=np.int64)
    step[heads] = tails
    step[v] = v
    hops = 1
    while hops < v:
        step = step[step]
        hops *= 2
    return bool((step[:v] == v).all())


def _is_forest(src: np.ndarray, dst: np.ndarray, num_vertices: int) -> bool:
    """True iff every vertex is reachable by AT MOST ONE path from any
    single root — the regime where raw UNION ALL walks and BFS dedup
    coincide.  That holds when the graph is acyclic and either never
    reconverges (in-degree <= 1: a forest) or never branches (out-degree
    <= 1: e.g. a reversed forest, whose frontier is always one vertex)."""
    if dst.size == 0:
        return True
    indeg = np.bincount(dst, minlength=num_vertices)
    if indeg.max() <= 1:
        return _chains_terminate(dst, src, num_vertices)
    outdeg = np.bincount(src, minlength=num_vertices)
    if outdeg.max() <= 1:
        return _chains_terminate(src, dst, num_vertices)
    return False


def _bfs_profile(src: np.ndarray, dst: np.ndarray, root: int,
                 num_vertices: int, max_levels: int
                 ) -> tuple[list[int], list[int]]:
    """One sampled traversal: (edges emitted, new vertices) per level."""
    visited = np.zeros(num_vertices, bool)
    frontier = np.zeros(num_vertices, bool)
    visited[root] = frontier[root] = True
    edges, verts = [], []
    for _ in range(max_levels):
        hit = frontier[src]
        s = int(hit.sum())
        if s == 0:
            break
        new = np.zeros(num_vertices, bool)
        new[dst[hit]] = True
        new &= ~visited
        visited |= new
        edges.append(s)
        verts.append(int(new.sum()))
        frontier = new
    return edges, verts


def _pick_roots(src: np.ndarray, num_vertices: int) -> np.ndarray:
    """Deterministic sample roots: source vertices spread across the id
    range (always includes the smallest source vertex — the benchmark and
    example root)."""
    outdeg = np.bincount(src, minlength=num_vertices)
    cand = np.flatnonzero(outdeg > 0)
    if cand.size == 0:
        return np.zeros(1, dtype=np.int64)
    take = min(_MAX_SAMPLE_ROOTS, cand.size)
    idx = np.linspace(0, cand.size - 1, num=take).astype(np.int64)
    return cand[np.unique(idx)]


def compute_stats(ds, direction: str = "outbound") -> GraphStats:
    """Compute (host-side) the planner statistics for one direction view.
    Called through :meth:`Dataset.stats`, which caches the result."""
    ctx = ds.context(direction)
    src = np.asarray(ctx.join_src).astype(np.int64)
    dst = np.asarray(ctx.join_dst).astype(np.int64)
    v = int(ds.num_vertices)
    e = int(src.shape[0])

    outdeg = np.bincount(src, minlength=v)
    nonzero = outdeg[outdeg > 0]
    hist = np.zeros(_HIST_BUCKETS, dtype=np.int64)
    if nonzero.size:
        buckets = np.minimum(np.log2(nonzero).astype(np.int64),
                             _HIST_BUCKETS - 1)
        np.add.at(hist, buckets, 1)

    roots = _pick_roots(src, v)
    profiles = [_bfs_profile(src, dst, int(r), v, _MAX_SAMPLE_LEVELS)
                for r in roots]
    depth = max((len(p[0]) for p in profiles), default=0)
    level_edges = np.zeros(depth)
    level_verts = np.zeros(depth)
    for edges, verts in profiles:
        level_edges[:len(edges)] += edges
        level_verts[:len(verts)] += verts
    level_edges /= max(len(profiles), 1)
    level_verts /= max(len(profiles), 1)
    max_level = max((max(p[0]) for p in profiles if p[0]), default=0)

    return GraphStats(
        direction=direction,
        num_vertices=v,
        num_edges=e,
        density=e / max(v, 1),
        avg_degree=float(nonzero.mean()) if nonzero.size else 0.0,
        max_degree=int(outdeg.max()) if v else 0,
        degree_histogram=tuple(int(x) for x in hist),
        is_forest=_is_forest(src, dst, v),
        sample_roots=tuple(int(r) for r in roots),
        level_edges=tuple(float(x) for x in level_edges),
        level_vertices=tuple(float(x) for x in level_verts),
        max_level_edges=int(max_level),
        reach_edges=float(sum(sum(p[0]) for p in profiles)
                          / max(len(profiles), 1)),
        max_levels=depth,
    )
