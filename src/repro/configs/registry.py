"""Architecture & shape registry — the source of truth for the dry-run grid.

10 assigned architectures x their own 4-shape sets = 40 cells, plus the
paper's own workload (``posdb-bfs``).  ``cells()`` enumerates every cell
with its skip-status; ``launch/steps.py`` turns a cell into (step_fn,
ShapeDtypeStruct inputs, shardings) for lowering.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Iterator

FAMILIES = ("lm", "gnn", "recsys", "bfs")

ARCHS: dict[str, tuple[str, str]] = {
    # arch id                  family    config module
    "deepseek-v2-lite-16b":   ("lm", "repro.configs.deepseek_v2_lite_16b"),
    "phi3.5-moe-42b":         ("lm", "repro.configs.phi35_moe_42b"),
    "qwen2-0.5b":             ("lm", "repro.configs.qwen2_0_5b"),
    "stablelm-1.6b":          ("lm", "repro.configs.stablelm_1_6b"),
    "stablelm-12b":           ("lm", "repro.configs.stablelm_12b"),
    "gatedgcn":               ("gnn", "repro.configs.gatedgcn"),
    "graphsage-reddit":       ("gnn", "repro.configs.graphsage_reddit"),
    "egnn":                   ("gnn", "repro.configs.egnn"),
    "gat-cora":               ("gnn", "repro.configs.gat_cora"),
    "deepfm":                 ("recsys", "repro.configs.deepfm"),
    "posdb-bfs":              ("bfs", "repro.configs.posdb_bfs"),
}

ASSIGNED = tuple(a for a in ARCHS if a != "posdb-bfs")

LM_SHAPES: dict[str, dict[str, Any]] = {
    "train_4k":    dict(kind="train",   seq=4096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524288, batch=1),
}

GNN_SHAPES: dict[str, dict[str, Any]] = {
    "full_graph_sm": dict(kind="full_graph", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg":  dict(kind="minibatch", n_nodes=232965,
                          n_edges=114615892, batch_nodes=1024,
                          fanout=(15, 10), d_feat=602, n_classes=41),
    "ogb_products":  dict(kind="full_graph", n_nodes=2449029,
                          n_edges=61859140, d_feat=100, n_classes=47),
    "molecule":      dict(kind="molecule", n_nodes=30, n_edges=64,
                          batch=128, d_feat=16, n_classes=2),
}

RECSYS_SHAPES: dict[str, dict[str, Any]] = {
    "train_batch":    dict(kind="train", batch=65536),
    "serve_p99":      dict(kind="serve", batch=512),
    "serve_bulk":     dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000),
}

BFS_SHAPES: dict[str, dict[str, Any]] = {
    "traverse_1m": dict(kind="bfs"),
}

# reduced dims for per-cell smoke tests (same code path, CPU-sized)
SMOKE_LM_SHAPES = {
    "train_4k":    dict(kind="train",   seq=32,  batch=2),
    "prefill_32k": dict(kind="prefill", seq=32,  batch=2),
    "decode_32k":  dict(kind="decode",  seq=32,  batch=2),
    "long_500k":   dict(kind="decode",  seq=64,  batch=1),
}
SMOKE_GNN_SHAPES = {
    "full_graph_sm": dict(kind="full_graph", n_nodes=120, n_edges=480,
                          d_feat=24, n_classes=5),
    "minibatch_lg":  dict(kind="minibatch", n_nodes=500, n_edges=4000,
                          batch_nodes=16, fanout=(4, 3), d_feat=24,
                          n_classes=5),
    "ogb_products":  dict(kind="full_graph", n_nodes=300, n_edges=1500,
                          d_feat=24, n_classes=5),
    "molecule":      dict(kind="molecule", n_nodes=12, n_edges=30, batch=8,
                          d_feat=8, n_classes=2),
}
SMOKE_RECSYS_SHAPES = {
    "train_batch":    dict(kind="train", batch=64),
    "serve_p99":      dict(kind="serve", batch=16),
    "serve_bulk":     dict(kind="serve", batch=128),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=512),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    family: str
    dims: dict
    skip: str | None = None           # reason if the cell is skipped


def get_config(arch: str, smoke: bool = False):
    family, mod_name = ARCHS[arch]
    mod = importlib.import_module(mod_name)
    return (mod.SMOKE if smoke else mod.CONFIG), family


def shapes_for(family: str, smoke: bool = False) -> dict[str, dict]:
    if family == "lm":
        return SMOKE_LM_SHAPES if smoke else LM_SHAPES
    if family == "gnn":
        return SMOKE_GNN_SHAPES if smoke else GNN_SHAPES
    if family == "recsys":
        return SMOKE_RECSYS_SHAPES if smoke else RECSYS_SHAPES
    return BFS_SHAPES


def cells(include_bfs: bool = False, smoke: bool = False) -> Iterator[Cell]:
    for arch, (family, _) in ARCHS.items():
        if family == "bfs" and not include_bfs:
            continue
        cfg, _ = get_config(arch, smoke)
        for shape_id, dims in shapes_for(family, smoke).items():
            skip = None
            if family == "lm" and shape_id == "long_500k" and not smoke:
                if getattr(cfg, "attn_window", None) is None:
                    skip = ("pure full-attention arch: 512k-KV decode cell "
                            "reserved for sub-quadratic attention "
                            "(DESIGN.md §4); run with --attn-window for the "
                            "documented extra")
            yield Cell(arch=arch, shape=shape_id, family=family,
                       dims=dict(dims), skip=skip)
