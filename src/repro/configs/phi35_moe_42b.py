"""Phi-3.5-MoE 42B / 6.6B active.  [hf:microsoft/Phi-3.5-MoE-instruct]

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, 16 experts top-2.
"""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_expert=6400),
)

SMOKE = LMConfig(
    name="phi3.5-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
    attn_chunk=16, loss_chunk=8,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=0, d_expert=96),
)
