"""GraphSAGE-Reddit (mean aggregator, 25-10 fanout).  [arXiv:1706.02216]

n_layers=2 d_hidden=128; minibatch training samples 25 then 10 neighbors
(the `minibatch_lg` shape overrides fanout to 15-10 per the assignment).
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(name="graphsage-reddit", kind="graphsage", n_layers=2,
                   d_hidden=128, aggregator="mean", sample_sizes=(25, 10))

SMOKE = GNNConfig(name="graphsage-smoke", kind="graphsage", n_layers=2,
                  d_hidden=16, aggregator="mean", sample_sizes=(4, 3))
