"""The paper's own workload as an arch: distributed positional BFS.

1M-vertex tree, 8 payload columns, depth-16 traversal — the production-mesh
deployment of the PRecursive engine (edges row-sharded, frontier exchanged
by all_gather, values never cross a link).
"""
from repro.configs.base import BFSConfig

CONFIG = BFSConfig(name="posdb-bfs", engine="precursive",
                   num_vertices=1 << 20, payload_cols=8, max_depth=16,
                   frontier_cap=1 << 15, result_cap=1 << 20)

SMOKE = BFSConfig(name="posdb-bfs-smoke", engine="precursive",
                  num_vertices=4096, payload_cols=2, max_depth=8,
                  frontier_cap=1024, result_cap=4096)
