"""Config dataclasses for every architecture family the framework serves.

Every assigned architecture gets a module in this package defining
``CONFIG`` (the exact published shape) and ``SMOKE`` (a reduced same-family
config for CPU tests).  ``repro.configs.registry`` maps ``--arch`` ids to
them.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_expert: int = 0            # per-expert FFN width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Decoder-only LM (dense or MoE, GQA or MLA attention)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None            # default d_model // n_heads
    qkv_bias: bool = False               # qwen2-style
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    norm_eps: float = 1e-6
    attn_chunk: int = 1024               # online-softmax KV chunk
    loss_chunk: int = 512                # chunked unembed+xent
    attn_window: int | None = None       # sliding window (500k extra only)
    dtype: str = "bfloat16"
    unroll: bool = False                 # unroll scans (dry-run: exact
                                         # cost_analysis; XLA counts while
                                         # bodies once otherwise)
    # --- perf levers (EXPERIMENTS.md §Perf; defaults = paper-faithful
    # baseline) -----------------------------------------------------------
    attn_q_block: int | None = None      # q-blocked triangular prefill
    remat: bool = True                   # activation checkpointing
    moe_shard_axis: str | None = None    # explicit expert-parallel
                                         # sharding constraints
    moe_data_axes: str | None = None     # comma list, e.g. "data" or
                                         # "pod,data": token-row sharding
                                         # for the staged EP dispatch
    prefill_via_cache: bool = False      # legacy prefill path (HC1
                                         # baseline): attend against the
                                         # padded cache instead of the
                                         # streaming fresh-context path

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.mla is not None:
            m = self.mla
            attn = (d * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                    + d * m.kv_lora_rank + d * m.rope_head_dim
                    + m.kv_lora_rank * self.n_heads *
                    (m.nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        if self.moe is not None:
            e = self.moe
            ff = e.num_experts * 3 * d * e.d_expert + d * e.num_experts
            ff += 3 * d * (e.num_shared * e.d_expert)
        else:
            ff = 3 * d * self.d_ff
        return self.n_layers * (attn + ff) + 2 * d * self.vocab

    def active_param_count(self) -> int:
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        e = self.moe
        per_layer_dense = self.param_count() // 1  # not used; recompute below
        del per_layer_dense
        if self.mla is not None:
            m = self.mla
            attn = (d * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                    + d * m.kv_lora_rank + d * m.rope_head_dim
                    + m.kv_lora_rank * self.n_heads *
                    (m.nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            hd = self.head_dim
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        ff_active = (e.top_k + e.num_shared) * 3 * d * e.d_expert \
            + d * e.num_experts
        return self.n_layers * (attn + ff_active) + 2 * d * self.vocab


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: Literal["gatedgcn", "graphsage", "egnn", "gat"]
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    d_feat: int = 128
    num_classes: int = 16
    sample_sizes: Sequence[int] = ()     # graphsage fanouts
    aggregator: str = "mean"
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 10
    mlp_dims: Sequence[int] = (400, 400, 400)
    vocab_scale: float = 1.0             # scales the Criteo vocabularies
    dtype: str = "float32"
    table_dtype: str = "float32"         # bf16 = §Perf HC3 iter-3 lever


@dataclasses.dataclass(frozen=True)
class BFSConfig:
    """The paper's own workload as an arch (engine + dataset shape)."""

    name: str
    engine: str = "precursive"
    num_vertices: int = 1 << 20
    payload_cols: int = 8
    max_depth: int = 16
    frontier_cap: int = 1 << 16
    result_cap: int = 1 << 20


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (arch x input-shape) dry-run cell."""

    shape_id: str
    kind: Literal["train", "prefill", "decode", "serve", "full_graph",
                  "minibatch", "retrieval"]
    dims: Mapping[str, int]
