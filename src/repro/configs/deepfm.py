"""DeepFM on Criteo-scale vocabularies.  [arXiv:1703.04247]

n_sparse=39 fields (13 bucketized numeric + 26 categorical), embed_dim=10,
MLP 400-400-400, FM interaction.  The shared embedding table has ~33.8M
rows (published Criteo-1TB per-field cardinalities).
"""
from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(name="deepfm", n_dense=13, n_sparse=26, embed_dim=10,
                      mlp_dims=(400, 400, 400), vocab_scale=1.0)

SMOKE = RecsysConfig(name="deepfm-smoke", n_dense=13, n_sparse=26,
                     embed_dim=8, mlp_dims=(32, 32), vocab_scale=1e-4)
