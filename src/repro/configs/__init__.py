"""Arch configs + registry (``--arch <id>`` resolution)."""
from .base import (LMConfig, MoEConfig, MLAConfig, GNNConfig,   # noqa: F401
                   RecsysConfig, BFSConfig)
from .registry import ARCHS, ASSIGNED, Cell, cells, get_config, shapes_for  # noqa: F401
