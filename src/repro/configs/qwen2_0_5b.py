"""Qwen2-0.5B (dense, GQA, QKV bias).  [arXiv:2407.10671; hf]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen2-0.5b",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151936, qkv_bias=True, rope_theta=1e6,
)

SMOKE = LMConfig(
    name="qwen2-smoke",
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=128, vocab=128,
    qkv_bias=True, attn_chunk=16, loss_chunk=8,
)
