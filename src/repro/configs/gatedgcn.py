"""GatedGCN (benchmark config of Dwivedi et al.).  [arXiv:2003.00982]

n_layers=16 d_hidden=70, gated aggregator with edge features.
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(name="gatedgcn", kind="gatedgcn", n_layers=16,
                   d_hidden=70, aggregator="gated")

SMOKE = GNNConfig(name="gatedgcn-smoke", kind="gatedgcn", n_layers=3,
                  d_hidden=16, aggregator="gated")
