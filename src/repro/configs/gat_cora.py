"""GAT on Cora (2 layers, 8 hidden x 8 heads).  [arXiv:1710.10903]"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(name="gat-cora", kind="gat", n_layers=2, d_hidden=8,
                   n_heads=8, aggregator="attn")

SMOKE = GNNConfig(name="gat-smoke", kind="gat", n_layers=2, d_hidden=8,
                  n_heads=2, aggregator="attn")
