"""DeepSeek-V2-Lite 16B (MoE, MLA attention).  [arXiv:2405.04434; hf]

Assignment line: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512, 2 shared + routed top-6.  (The assignment
note "160 routed" matches full V2; Lite publishes 64 routed experts — we
follow the published Lite config, which also matches the "64e" in the
assignment line.)
"""
from repro.configs.base import LMConfig, MLAConfig, MoEConfig

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,                      # dense FFN of layer group (lite)
    vocab=102400,
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
)

SMOKE = LMConfig(
    name="deepseek-v2-lite-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=128,
    attn_chunk=16, loss_chunk=8,
    mla=MLAConfig(kv_lora_rank=24, rope_head_dim=8, nope_head_dim=16,
                  v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=2, d_expert=24),
)
