"""StableLM-2-1.6B (dense, full MHA: kv=32).  [hf:stabilityai/stablelm-2-1_6b]

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="stablelm-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352,
)

SMOKE = LMConfig(
    name="stablelm-1.6b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=128,
    attn_chunk=16, loss_chunk=8,
)
