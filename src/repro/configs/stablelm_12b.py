"""StableLM-2-12B (dense, GQA kv=8).  [hf:stabilityai/stablelm-2-12b]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.  head_dim =
5120/32 = 160.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="stablelm-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352,
)

SMOKE = LMConfig(
    name="stablelm-12b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
    attn_chunk=16, loss_chunk=8,
)
