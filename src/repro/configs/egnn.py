"""EGNN (E(n)-equivariant GNN).  [arXiv:2102.09844]

n_layers=4 d_hidden=64.
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(name="egnn", kind="egnn", n_layers=4, d_hidden=64,
                   aggregator="sum")

SMOKE = GNNConfig(name="egnn-smoke", kind="egnn", n_layers=2, d_hidden=16,
                  aggregator="sum")
