"""Learning-rate schedules (pure functions of the step scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        c = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * c)
    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(lr, max(1, total_steps - warmup), final_frac)
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.float32(lr) * s / max(1, warmup)
        return jnp.where(s < warmup, warm, cos(s - warmup))
    return fn
