from .adamw import AdamW, sgd_momentum     # noqa: F401
from .schedule import (constant, cosine_decay, linear_warmup_cosine)  # noqa: F401
from .clip import global_norm, clip_by_global_norm                    # noqa: F401
