"""AdamW with decoupled weight decay, fp32 moments, global-norm clipping.

Optimizer states follow the param pytree, so pjit shards them identically to
the params (ZeRO-1-style: each device holds the moments of its own param
shards for free under GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .clip import clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable          # step -> learning rate
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, params, grads, state):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self.lr(step)
        c1 = 1 - self.b1 ** step.astype(jnp.float32)
        c2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu2 = self.b1 * mu + (1 - self.b1) * g32
            nu2 = self.b2 * nu + (1 - self.b2) * g32 * g32
            mhat = mu2 / c1
            vhat = nu2 / c2
            p32 = p.astype(jnp.float32)
            step_v = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p32
            return (p32 - lr * step_v).astype(p.dtype), mu2, nu2

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_mu = jax.tree_util.tree_leaves(state["mu"])
        flat_nu = jax.tree_util.tree_leaves(state["nu"])
        out = [upd(p, g, m, n)
               for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
        return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm


@dataclasses.dataclass(frozen=True)
class sgd_momentum:
    lr: Callable
    momentum: float = 0.9
    max_grad_norm: float = 1.0

    def init(self, params):
        return {"vel": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state["step"] + 1
        lr = self.lr(step)

        def upd(p, g, v):
            v2 = self.momentum * v + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * v2).astype(p.dtype), v2

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        out = [upd(p, g, v) for p, g, v in zip(
            flat_p, jax.tree_util.tree_leaves(grads),
            jax.tree_util.tree_leaves(state["vel"]))]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_v = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        return new_p, {"vel": new_v, "step": step}, gnorm
