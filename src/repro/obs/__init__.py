"""Observability: structured tracing and serving metrics.

This package is a LEAF dependency — it imports nothing from
:mod:`repro.core` or :mod:`repro.planner`, so both can thread tracer and
metrics hooks through their hot paths without an import cycle.  The three
surfaces:

* :mod:`repro.obs.trace` — a lightweight span/event :class:`Tracer` with
  JSON-lines and Chrome-trace (Perfetto-loadable) exporters, plus the
  module-global ``current_tracer()`` seam the engine and serving layers
  consult (one attribute read + ``None`` check when tracing is off);
* :mod:`repro.obs.metrics` — counters, gauges and bounded-memory latency
  histograms (p50/p95/p99) behind a :class:`MetricsRegistry` with a
  Prometheus-style text rendering;
* :mod:`repro.obs.faultinject` — the chaos suite's named fault-injection
  points (same disabled-path budget as the tracer: one attribute read);
* ``EXPLAIN ANALYZE`` lives in :mod:`repro.planner.explain`
  (``explain_analyze``): it needs the planner's cost model, which sits
  ABOVE this package in the import graph.

See docs/observability.md for the trace schema and the metrics catalog,
and docs/robustness.md for the fault seam and chaos suite.
"""
from . import faultinject
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import (TRACE_SCHEMA_VERSION, Tracer, current_tracer,
                    read_jsonl, set_tracer, trace_event, trace_span)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TRACE_SCHEMA_VERSION", "Tracer", "current_tracer", "faultinject",
    "read_jsonl", "set_tracer", "trace_event", "trace_span",
]
