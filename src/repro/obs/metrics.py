"""Serving metrics: counters, gauges and bounded-memory latency histograms.

The registry is deliberately tiny — a serving session needs hit rates,
retry counts and latency quantiles, not a metrics vendor.  Three
constraints shape it:

* **bounded memory** — a histogram holds a FIXED set of log-spaced buckets
  (plus count/sum/min/max), so a session serving forever never grows its
  metrics footprint; quantiles are interpolated within the winning bucket
  (log-spaced buckets bound the relative error by the bucket ratio);
* **no dependencies** — plain Python, importable from anywhere in the
  stack without cycles (this module must stay a leaf);
* **Prometheus-style text** — :meth:`MetricsRegistry.render_text` emits
  the standard exposition format (``# TYPE`` headers, cumulative
  ``_bucket{le=...}`` series), so the ``--metrics`` flag in
  ``launch/serve.py`` produces something a real scraper would accept.

Single-threaded by design, matching the serving session (one request at a
time per session); there are no locks.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """A monotonically increasing count."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n


class Gauge:
    """A value that can go up and down."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """A fixed-footprint log-bucketed histogram with interpolated quantiles.

    Bucket upper bounds are geometric: ``per_decade`` buckets per factor of
    10 between ``lo`` and ``hi`` (values outside clamp into the end
    buckets), so p50/p95/p99 carry a bounded RELATIVE error of one bucket
    ratio (~33% per bucket at the default 8/decade — tight enough to rank
    latency regressions) while total storage stays a few hundred floats
    regardless of how many observations arrive."""

    __slots__ = ("name", "help", "bounds", "counts", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, help: str = "", *, lo: float = 1.0,
                 hi: float = 1e9, per_decade: int = 8):
        if not (lo > 0 and hi > lo):
            raise ValueError("need 0 < lo < hi")
        self.name = name
        self.help = help
        n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
        ratio = (hi / lo) ** (1.0 / max(n - 1, 1))
        self.bounds = [lo * ratio ** i for i in range(n)]   # upper edges
        self.counts = [0] * (n + 1)                          # + overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        idx = len(self.bounds)                   # overflow bucket
        for i, b in enumerate(self.bounds):      # few hundred bounds max
            if v <= b:
                idx = i
                break
        self.counts[idx] += 1

    def quantile(self, q: float) -> float:
        """The q-quantile (0..1), interpolated inside the winning bucket.
        NaN with no observations; exact at the observed min/max ends."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                lo = self.bounds[i - 1] if i > 0 else min(self.min,
                                                          self.bounds[0])
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(max(hi, lo), self.max)
                frac = (target - seen) / c
                return lo + (hi - lo) * frac
            seen += c
        return self.max

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.sum,
                "min": (None if self.count == 0 else self.min),
                "max": (None if self.count == 0 else self.max),
                "p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Get-or-create registry over the three instrument kinds, with a dict
    snapshot (``to_dict``) and a Prometheus-style rendering
    (``render_text``).  Names are conventional Prometheus identifiers
    (``snake_case``, ``_total`` suffix on counters, unit suffixes like
    ``_us``)."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = kind(name, **kwargs)
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "", **kwargs) -> Histogram:
        return self._get(name, Histogram, help=help, **kwargs)

    def __iter__(self) -> Iterable:
        return iter(self._metrics.values())

    def get(self, name: str) -> Optional[object]:
        return self._metrics.get(name)

    def to_dict(self) -> dict:
        out = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[name] = m.snapshot()
            else:
                out[name] = m.value
        return out

    @staticmethod
    def _fmt(v: float) -> str:
        if v == math.inf:
            return "+Inf"
        if float(v).is_integer():
            return str(int(v))
        return repr(float(v))

    def render_text(self) -> str:
        """Prometheus exposition format: ``# HELP``/``# TYPE`` headers,
        cumulative ``_bucket{le=...}`` series for histograms."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {self._fmt(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {self._fmt(m.value)}")
            else:
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for b, c in zip(m.bounds, m.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{self._fmt(b)}"}} '
                                 f"{cum}")
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{name}_sum {self._fmt(m.sum)}")
                lines.append(f"{name}_count {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")
