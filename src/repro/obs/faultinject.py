"""Fault-injection seam for the chaos suite (``tests/test_chaos.py``).

A tiny registry of NAMED injection points that the engine and serving
layers consult at the exact places real faults would land.  Like the
tracer seam, the disabled path is one module-attribute read plus a
truthiness check on an empty dict — nothing is paid when no fault is
armed, and this module stays a LEAF (no :mod:`repro.core` /
:mod:`repro.planner` imports), preserving the obs package's import
contract.

Armed faults are CONSUMED: ``inject(point, value, times=n)`` fires on the
next ``n`` consults and then disarms itself (``times=None`` keeps firing
until :func:`clear`).  The injected *value* is point-specific:

* ``"bucket_overflow"``   — truthy: the executor treats the bucket's
  dispatch as overflowed, forcing the retry/eviction path.
* ``"straggler_sleep"``   — float seconds: the executor sleeps that long
  inside one bucket's timed interval, manufacturing a straggler.
* ``"plan_store_corrupt"``— truthy: ``load_store`` truncates the bytes it
  just read before parsing, simulating a torn write.
* ``"calibrator_poison"`` — float (may be NaN/inf): replaces one measured
  per-bucket latency before it reaches ``Calibrator.observe``.

Garbage ROOTS need no seam — they are plain invalid input, rejected by the
front door's typed validation (:class:`repro.planner.guards.InvalidRequestError`).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["FAULT_POINTS", "inject", "clear", "consume", "armed",
           "injected"]

FAULT_POINTS = ("bucket_overflow", "straggler_sleep", "plan_store_corrupt",
                "calibrator_poison")

# point -> [value, remaining_fires or None]; consumers guard on the dict's
# truthiness first, so the common (nothing armed) case costs one attribute
# read — same budget as the disabled tracer
_ACTIVE: Dict[str, List[Any]] = {}


def inject(point: str, value: Any = True, *,
           times: Optional[int] = 1) -> None:
    """Arm ``point`` to fire ``times`` consults (``None`` = until cleared)."""
    if point not in FAULT_POINTS:
        raise ValueError(f"unknown fault point {point!r}; known: "
                         f"{FAULT_POINTS}")
    _ACTIVE[point] = [value, times]


def clear(point: Optional[str] = None) -> None:
    """Disarm one point, or every point when ``point`` is None."""
    if point is None:
        _ACTIVE.clear()
    else:
        _ACTIVE.pop(point, None)


def armed() -> bool:
    """True iff ANY fault is armed (the cheap outer guard consumers use)."""
    return bool(_ACTIVE)


def consume(point: str) -> Any:
    """The armed value for ``point`` (None if unarmed), decrementing its
    remaining fire count — a fault armed with ``times=1`` fires exactly
    once."""
    slot = _ACTIVE.get(point)
    if slot is None:
        return None
    value, remaining = slot
    if remaining is not None:
        remaining -= 1
        if remaining <= 0:
            del _ACTIVE[point]
        else:
            slot[1] = remaining
    return value


@contextlib.contextmanager
def injected(point: str, value: Any = True, *,
             times: Optional[int] = None) -> Iterator[None]:
    """Scope an armed fault to a ``with`` block (always disarmed on exit —
    chaos tests cannot leak faults into later tests)."""
    inject(point, value, times=times)
    try:
        yield
    finally:
        clear(point)
