"""Structured tracing: spans, events, JSONL and Chrome-trace exporters.

A :class:`Tracer` records two record kinds into one in-memory list:

* **spans** — named intervals (``parse``/``stats``/``cost``/``compile``/
  ``dispatch``/``transfer``/...) with microsecond start/duration relative
  to the tracer's epoch, a unique ``id`` and the enclosing span's
  ``parent`` id (spans are recorded on EXIT, so children precede their
  parent in the record stream but nest inside it in time);
* **events** — named instants (per-traversal-level progress, overflow
  retries) attributed to the enclosing span.

Per-level traversal events are derived HOST-SIDE from an executed
:class:`~repro.core.operators.BFSResult` (:func:`emit_level_events`): the
fixed-point driver is one jitted ``lax.while_loop``, so per-iteration
host callbacks are off the table — instead ``row_depths`` (BFS level per
result row) is histogrammed into per-level edge counts and ``level_dirs``
decodes each level's taken push/pull direction.  This keeps the traced
numbers exactly the executed result's numbers, and keeps the hot loop
untouched.

The module-global ``current_tracer()`` seam is how the engine and serving
layers find the active tracer: installing one (``set_tracer``) turns
tracing on everywhere downstream; the disabled path is a module attribute
read plus a ``None`` check (measured at parity with no tracing at all —
the perf gate's ``disabled_tracer_ratio`` cell holds it there).

Schema (JSON-lines, one record per line; see docs/observability.md):

.. code-block:: text

    {"type": "header", "schema_version": 1, "clock": "...", "meta": {...}}
    {"type": "span",  "id": 3, "parent": 1, "name": "dispatch",
     "ts_us": 12.5, "dur_us": 480.2, "attrs": {...}}
    {"type": "event", "name": "level", "parent": 3, "ts_us": 200.1,
     "attrs": {"level": 2, "dir": "pull", "edges": 4096, ...}}

The Chrome-trace export (:meth:`Tracer.chrome_trace`) maps spans onto
complete (``"ph": "X"``) events and events onto thread-scoped instants —
load the written file directly in Perfetto / ``chrome://tracing``.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Iterator, Optional

__all__ = ["TRACE_SCHEMA_VERSION", "Tracer", "current_tracer", "set_tracer",
           "trace_span", "trace_event", "emit_level_events", "read_jsonl"]

TRACE_SCHEMA_VERSION = 1

_CLOCK = "perf_counter, microseconds since tracer epoch"


class Tracer:
    """Span/event recorder.  ``enabled=False`` makes every call a cheap
    no-op (kept for symmetry with a config flag; an uninstalled tracer is
    cheaper still).  ``level_events=False`` suppresses the per-level
    traversal events (which require a device->host read of ``row_depths``)
    while keeping the spans."""

    def __init__(self, *, enabled: bool = True, level_events: bool = True,
                 meta: Optional[dict] = None):
        self.enabled = enabled
        self.level_events = level_events
        self.meta = dict(meta or {})
        self.records: list[dict] = []
        self._stack: list[int] = []
        self._next_id = 0
        self._epoch = time.perf_counter()

    # -- recording ---------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Record a named interval.  Yields the (mutable) attrs dict so the
        body can attach results discovered mid-span."""
        if not self.enabled:
            yield attrs
            return
        sid = self._next_id
        self._next_id += 1
        parent = self._stack[-1] if self._stack else None
        self._stack.append(sid)
        t0 = self._now_us()
        try:
            yield attrs
        finally:
            self._stack.pop()
            self.records.append({
                "type": "span", "id": sid, "parent": parent, "name": name,
                "ts_us": t0, "dur_us": self._now_us() - t0, "attrs": attrs})

    def event(self, name: str, **attrs) -> None:
        """Record a named instant inside the current span (if any)."""
        if not self.enabled:
            return
        self.records.append({
            "type": "event", "name": name,
            "parent": self._stack[-1] if self._stack else None,
            "ts_us": self._now_us(), "attrs": attrs})

    # -- exporters ---------------------------------------------------------
    def _header(self) -> dict:
        return {"type": "header", "schema_version": TRACE_SCHEMA_VERSION,
                "clock": _CLOCK, "meta": self.meta}

    def iter_records(self) -> Iterator[dict]:
        yield self._header()
        yield from self.records

    def write_jsonl(self, path: str) -> str:
        """One JSON record per line, header first."""
        with open(path, "w") as f:
            for rec in self.iter_records():
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return path

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON (Perfetto-loadable): spans as
        complete ``"X"`` slices, events as thread-scoped instants."""
        evs = []
        for rec in self.records:
            if rec["type"] == "span":
                evs.append({"name": rec["name"], "ph": "X",
                            "ts": rec["ts_us"], "dur": rec["dur_us"],
                            "pid": 0, "tid": 0, "args": rec["attrs"]})
            else:
                evs.append({"name": rec["name"], "ph": "i", "s": "t",
                            "ts": rec["ts_us"], "pid": 0, "tid": 0,
                            "args": rec["attrs"]})
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"schema_version": TRACE_SCHEMA_VERSION,
                              **self.meta}}

    def write_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


def read_jsonl(path: str) -> list[dict]:
    """Read a JSONL trace back (header first) — the roundtrip inverse of
    :meth:`Tracer.write_jsonl`.  Raises ``ValueError`` on a missing or
    version-incompatible header."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    if not records or records[0].get("type") != "header":
        raise ValueError(f"{path}: not a trace (no header record)")
    v = records[0].get("schema_version")
    if v != TRACE_SCHEMA_VERSION:
        raise ValueError(f"{path}: unsupported trace schema_version {v!r} "
                         f"(this reader handles {TRACE_SCHEMA_VERSION})")
    return records


# ---------------------------------------------------------------------------
# the module-global seam (what the engine / serving layers consult)
# ---------------------------------------------------------------------------

_CURRENT: Optional[Tracer] = None
_NOOP = contextlib.nullcontext({})     # reentrant: one shared instance


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install ``tracer`` as the process-global tracer; returns the
    previous one (restore it when done)."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer
    return prev


def current_tracer() -> Optional[Tracer]:
    t = _CURRENT
    return t if (t is not None and t.enabled) else None


def trace_span(name: str, **attrs):
    """Span on the current tracer, or a shared no-op context manager —
    this is the only cost a hot path pays when tracing is off."""
    t = _CURRENT
    if t is None or not t.enabled:
        return _NOOP
    return t.span(name, **attrs)


def trace_event(name: str, **attrs) -> None:
    t = _CURRENT
    if t is not None and t.enabled:
        t.event(name, **attrs)


# ---------------------------------------------------------------------------
# per-level traversal events, derived from an executed BFSResult
# ---------------------------------------------------------------------------

def _dir_name(code: int) -> Optional[str]:
    return {0: "push", 1: "pull"}.get(int(code))


def emit_level_events(tracer: Tracer, result, *, bytes_per_row: float = 0.0,
                      **attrs) -> None:
    """Emit one ``level`` event per executed BFS level of ``result`` (a
    single-root or batched ``BFSResult``), derived host-side:

    * ``edges`` — result rows whose ``row_depths`` equal the level (the
      edges emitted while that level's frontier expanded), summed over
      lanes for a batched result;
    * ``frontier`` — the rows that ENTERED the level (the previous level's
      emitted edges; 1 root row at level 0);
    * ``dir`` — the taken push/pull direction decoded from ``level_dirs``
      (``None`` for push-only engines; ``"mixed"`` when a batched
      dispatch's lanes disagree);
    * ``bytes_est`` — ``edges * bytes_per_row`` when a per-row byte width
      is supplied (e.g. the plan's ``total_bytes / result_rows``).

    Forcing ``row_depths`` to host synchronizes the dispatch — level
    events are an enabled-tracing cost only."""
    if tracer is None or not tracer.enabled or not tracer.level_events:
        return
    if getattr(result, "row_depths", None) is None:
        return
    import numpy as np

    rd = np.asarray(result.row_depths)
    count = np.asarray(result.count).reshape(-1)
    depth = int(np.max(np.asarray(result.depth)))
    if rd.ndim == 1:
        rd = rd[None, :]
    # per-lane valid-row masks -> pooled per-level edge counts
    lanes = np.arange(rd.shape[1])[None, :] < count[:, None]
    valid = rd[lanes]
    valid = valid[valid >= 0]
    edges = np.bincount(valid.astype(np.int64), minlength=depth or 1)

    dirs = getattr(result, "level_dirs", None)
    taken = None
    if dirs is not None:
        dv = np.asarray(dirs)
        if dv.size:
            taken = dv if dv.ndim == 2 else dv[None, :]
    n_lanes = int(count.shape[0])
    for lvl in range(depth):
        d = None
        if taken is not None and lvl < taken.shape[1]:
            codes = {int(c) for c in taken[:, lvl] if int(c) >= 0}
            if len(codes) == 1:
                d = _dir_name(codes.pop())
            elif codes:
                d = "mixed"
        n = int(edges[lvl]) if lvl < edges.shape[0] else 0
        frontier = n_lanes if lvl == 0 else (
            int(edges[lvl - 1]) if lvl - 1 < edges.shape[0] else 0)
        ev = {"level": lvl, "dir": d, "edges": n, "frontier": frontier}
        if bytes_per_row:
            ev["bytes_est"] = n * float(bytes_per_row)
        tracer.event("level", **ev, **attrs)
