"""Exact HLO cost extraction via affine trip-count probing.

XLA's ``HloCostAnalysis`` tallies every ``while`` body exactly once, so a
rolled ``lax.scan`` undercounts FLOPs/bytes/collective-bytes by its trip
count.  Fully unrolling the production configs makes 512-device compiles
take minutes per cell; instead we exploit that module cost is **affine** in
the static trip counts:

    T(L, C, K) = a + L·c + (L·C)·d + K·e

with L = layer-scan length, C = attention KV-chunk count, K = loss-chunk
count (c = per-layer cost at one KV chunk, d = per-extra-chunk overhead,
e = per-loss-chunk cost, a = everything outside the scans).  Four tiny
UNROLLED probes (L,C,K) ∈ {(1,1,1), (2,1,1), (1,2,1), (1,1,2)} on the real
production mesh identify (a, c, d, e); the target cell's exact cost follows
by extrapolation.  Validated against a fully-unrolled compile in
tests/test_roofline.py.

Only the LM family needs this (GNN/recsys/MoE cells contain no scans — their
cost_analysis is already exact; the data-dependent BFS while is reported
per-level by design).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax

from repro.configs.registry import get_config, shapes_for
from repro.launch import roofline as rl
from repro.launch.steps import build_lm_cell


def _measure(cfg, dims, mesh) -> Dict[str, float]:
    plan = build_lm_cell(cfg, dims, mesh, concrete=False)
    jf = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                 donate_argnums=plan.donate_argnums)
    with mesh:
        lowered = jf.lower(*plan.args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    coll = rl.parse_collectives(text)
    return {"flops": float(cost.get("flops", 0.0)),
            "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": float(coll.total_bytes)}


def lm_exact_costs(arch: str, shape_id: str, mesh,
                   attn_window: int | None = None,
                   overrides: dict | None = None) -> Dict[str, float]:
    """Returns exact per-device {flops, hbm_bytes, collective_bytes} for the
    production cell, plus the probe bookkeeping."""
    cfg, _ = get_config(arch)
    if attn_window is not None:
        cfg = dataclasses.replace(cfg, attn_window=attn_window)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    dims = shapes_for("lm")[shape_id]
    seq = dims["seq"]
    kind = dims["kind"]
    has_loss = kind == "train"

    l_target = cfg.n_layers
    c_target = max(1, -(-seq // cfg.attn_chunk))
    k_target = max(1, seq // min(cfg.loss_chunk, seq)) if has_loss else 1

    def probe(l, c, k):
        pc = dataclasses.replace(
            cfg, n_layers=l, unroll=True,
            attn_chunk=max(1, seq // c),
            loss_chunk=max(1, seq // k))
        return _measure(pc, dims, mesh)

    # base the affine fit at L=2/4, C=1/2, K=1/2: L=1 scans get
    # special-cased by XLA (CSE/fusion differ), skewing the slope
    t211 = probe(2, 1, 1)
    t411 = probe(4, 1, 1)
    t221 = probe(2, 2, 1)
    t212 = probe(2, 1, 2) if has_loss else None

    out = {}
    for key in ("flops", "hbm_bytes", "collective_bytes"):
        d = (t221[key] - t211[key]) / 2.0            # per (layer x chunk)
        e = (t212[key] - t211[key]) if has_loss else 0.0
        c = (t411[key] - t211[key]) / 2.0 - d        # per layer at C=1
        a = t211[key] - 2 * c - 2 * d - e
        val = a + l_target * c + l_target * c_target * d + k_target * e
        out[key] = max(val, 0.0)
        out[f"probe_{key}"] = {"a": a, "per_layer": c, "per_chunk": d,
                               "per_loss_chunk": e}
    out["probe_counts"] = {"L": l_target, "C": c_target, "K": k_target}
    return out
