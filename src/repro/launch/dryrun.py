import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices build the production meshes, every
cell's step function is ``jax.jit(...).lower(*ShapeDtypeStructs).compile()``d
against them, and the compiled artifact yields the roofline terms
(launch/roofline.py) recorded in EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results.json
  python -m repro.launch.dryrun --arch posdb-bfs            # paper's engine
"""
import argparse        # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import numpy as np     # noqa: E402

from repro.configs.registry import ARCHS, cells, get_config, shapes_for  # noqa: E402
from repro.launch import roofline as rl                                  # noqa: E402
from repro.launch.mesh import make_production_mesh                       # noqa: E402
from repro.launch.steps import build_cell                                # noqa: E402


def run_cell(arch: str, shape_id: str, multi_pod: bool,
             attn_window=None, verbose: bool = True,
             probe: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    t0 = time.time()
    if arch == "posdb-bfs":
        lowered, compiled, extra = _lower_bfs(mesh)
    else:
        plan = build_cell(arch, shape_id, mesh, attn_window=attn_window)
        jf = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     donate_argnums=plan.donate_argnums)
        with mesh:
            lowered = jf.lower(*plan.args)
            compiled = lowered.compile()
        extra = {"description": plan.description}
    t1 = time.time()

    # LM cells contain scans whose bodies HloCostAnalysis counts once;
    # recover exact per-device costs by affine trip-count probing.
    exact = None
    if probe and ARCHS.get(arch, ("", ""))[0] == "lm":
        from repro.launch.probe import lm_exact_costs
        exact = lm_exact_costs(arch, shape_id, mesh,
                               attn_window=attn_window)

    model_flops = None
    cfg, family = get_config(arch)
    if family == "lm":
        dims = shapes_for("lm")[shape_id]
        if dims["kind"] == "train":
            model_flops = rl.lm_model_flops(cfg, dims["batch"], dims["seq"],
                                            train=True)
        elif dims["kind"] == "decode":
            model_flops = rl.lm_model_flops(cfg, dims["batch"], 1,
                                            train=False)
        else:
            model_flops = rl.lm_model_flops(cfg, dims["batch"], dims["seq"],
                                            train=False)
    result = rl.analyze(lowered, compiled, chips, model_flops=model_flops)
    if exact is not None:
        result["rolled_raw"] = {k: result[k] for k in
                                ("flops", "hbm_bytes", "collective_bytes")}
        # probe numbers come from cost_analysis -> per-device; globalize
        gflops = exact["flops"] * chips
        gbytes = exact["hbm_bytes"] * chips
        gcoll = exact["collective_bytes"] * chips
        rf = rl.Roofline(flops=gflops, hbm_bytes=gbytes,
                         collective_bytes=gcoll, chips=chips)
        result.update({"flops": gflops, "hbm_bytes": gbytes,
                       "collective_bytes": gcoll,
                       "probe": {k: exact[k] for k in exact
                                 if k.startswith("probe")},
                       **rf.row()})
        if model_flops:
            result["useful_flops_ratio"] = model_flops / max(gflops, 1.0)
    result.update(extra)
    result["arch"] = arch
    result["shape"] = shape_id
    result["mesh"] = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    result["compile_s"] = round(t1 - t0, 2)
    if verbose:
        mem = result.get("memory_analysis")
        print(f"[{arch} x {shape_id} x {result['mesh']}] "
              f"compile={result['compile_s']}s "
              f"flops={result['flops']:.3e} bytes={result['hbm_bytes']:.3e} "
              f"coll={result['collective_bytes']:.3e} "
              f"dominant={result['dominant']} "
              f"frac={result['roofline_frac']:.3f}")
        print(f"  memory_analysis: {mem}")
        print(f"  collectives: {result['collectives']}")
    return result


def _lower_bfs(mesh):
    """Lower the paper's distributed positional BFS on the mesh."""
    import jax.numpy as jnp
    from repro.configs.posdb_bfs import CONFIG as bcfg
    from repro.core.distributed_bfs import make_distributed_pbfs
    from repro.core.recursive import EngineCaps

    axes = tuple(a for a in mesh.axis_names if a != "model")
    nshards = int(np.prod([mesh.shape[a] for a in axes]))
    e = bcfg.num_vertices - 1
    e_pad = -(-e // nshards) * nshards
    caps = EngineCaps(frontier=bcfg.frontier_cap,
                      result=bcfg.result_cap // nshards)
    fn = make_distributed_pbfs(mesh, axes, bcfg.num_vertices, caps=caps,
                               max_depth=bcfg.max_depth,
                               num_payload_cols=bcfg.payload_cols)
    sds = jax.ShapeDtypeStruct
    args = (sds((e_pad,), jnp.int32), sds((e_pad,), jnp.int32),
            sds((e_pad, bcfg.payload_cols), jnp.float32),
            sds((), jnp.int32))
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled, {
        "description": f"distributed PRecursive BFS V={bcfg.num_vertices} "
                       f"depth={bcfg.max_depth} shards={nshards}"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--family", default=None,
                    help="comma list filter: lm,gnn,recsys,bfs")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the affine trip-count cost probes (LM)")
    ap.add_argument("--attn-window", type=int, default=None,
                    help="enable sliding-window attention (long_500k extra)")
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args(argv)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    fams = set(args.family.split(",")) if args.family else None

    todo = []
    if args.all:
        for c in cells(include_bfs=True):
            if fams and c.family not in fams:
                continue
            todo.append((c.arch, c.shape, c.skip))
    else:
        if not args.arch:
            ap.error("--arch or --all required")
        fam = ARCHS[args.arch][0]
        shape_ids = ([args.shape] if args.shape
                     else list(shapes_for(fam)))
        for s in shape_ids:
            skip = None
            for c in cells(include_bfs=True):
                if c.arch == args.arch and c.shape == s:
                    skip = c.skip
            if args.attn_window is not None:
                skip = None
            todo.append((args.arch, s, skip))

    results, failures = [], []
    for arch, shape_id, skip in todo:
        if skip:
            print(f"[{arch} x {shape_id}] SKIP: {skip}")
            results.append({"arch": arch, "shape": shape_id,
                            "skipped": skip})
            continue
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape_id, mp,
                                        attn_window=args.attn_window,
                                        probe=not args.no_probe))
            except Exception:
                traceback.print_exc()
                failures.append((arch, shape_id, mp))
            if args.out:                       # incremental flush
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"wrote {args.out} ({len(results)} entries)")
    if failures:
        print("FAILURES:", failures)
        return 1
    print(f"dry-run OK: {len(results)} cells")
    return 0


if __name__ == "__main__":
    sys.exit(main())
