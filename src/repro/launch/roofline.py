"""Roofline analysis from compiled dry-run artifacts.

Terms per (arch x shape x mesh), TPU v5e constants:

    compute    = HLO_FLOPs  / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes  / (chips * 819e9  B/s HBM)
    collective = coll_bytes / (chips * 50e9   B/s per ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
there, so ``as_text()`` is parsed: sum of operand sizes of every all-gather
/ all-reduce / reduce-scatter / all-to-all / collective-permute (async
``-start`` forms counted once, ``-done`` skipped).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)(-start)?\s*\(([^)]*)\)")
_DONE_RE = re.compile(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)-done\b")


def _bytes_of_type(text: str) -> int:
    """Sum byte sizes of every shape literal in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of collective ops from (stable)HLO text."""
    defs: Dict[str, int] = {}
    by_kind: Dict[str, int] = {}
    cnt: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, rhs = m.groups()
            # record result size (type text precedes the op name)
            defs[name] = _bytes_of_type(rhs.split("(")[0])
        if _DONE_RE.search(line):
            continue
        cm = _COLL_RE.search(line)
        if not cm:
            continue
        kind, _start, operands = cm.groups()
        total = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            # operands may carry inline types: "bf16[8,128] %x.1"
            inline = _bytes_of_type(op)
            if inline:
                total += inline
                continue
            total += defs.get(op, 0)
        by_kind[kind] = by_kind.get(kind, 0) + total
        cnt[kind] = cnt.get(kind, 0) + 1
    return CollectiveStats(by_kind, cnt)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self) -> float:
        """Useful-compute fraction if perfectly overlapped: compute term
        over the max term (1.0 = compute-bound at peak)."""
        return self.compute_s / max(self.bound_s, 1e-30)

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "roofline_frac": self.fraction_of_roofline(),
        }


def analyze(lowered, compiled, chips: int, *, model_flops: float | None = None
            ) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):               # older API returns [dict]
        cost = cost[0]
    # cost_analysis of an SPMD-partitioned module is PER-DEVICE (verified in
    # tests/test_roofline.py); the roofline terms want global HLO totals.
    flops = float(cost.get("flops", 0.0)) * chips
    hbm = float(cost.get("bytes accessed", 0.0)) * chips
    try:
        text = compiled.as_text()            # post-SPMD partitioning
    except Exception:
        text = lowered.as_text()
    coll = parse_collectives(text)
    rf = Roofline(flops=flops, hbm_bytes=hbm,
                  collective_bytes=float(coll.total_bytes) * chips,
                  chips=chips)
    out = {"flops": flops, "hbm_bytes": hbm,
           "collective_bytes": float(coll.total_bytes) * chips,
           "collectives": dict(coll.count_by_kind),
           "collective_bytes_by_kind": {k: v * chips for k, v in
                                        coll.bytes_by_kind.items()},
           **rf.row()}
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / max(flops, 1.0)
    try:
        mem = compiled.memory_analysis()
        out["memory_analysis"] = {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:                   # CPU backend may not support
        out["memory_analysis"] = f"unavailable: {e}"
    return out


def lm_model_flops(cfg, batch: int, seq: int, *, train: bool) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)."""
    n = cfg.active_param_count()
    mult = 6.0 if train else 2.0
    return mult * n * batch * seq
