import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Measures one cell's exact roofline terms under config overrides, on the
single-pod production mesh.  LM cells are measured with FULLY UNROLLED
scans (exact cost_analysis; slower compiles are acceptable for the three
hillclimbed cells); recsys/GNN cells have no scans so direct measurement is
already exact.

  python -m repro.launch.hillclimb --cell qwen2-prefill \
      --set attn_q_block=4096 --set attn_chunk=8192
  python -m repro.launch.hillclimb --cell deepseek-train --set moe_shard_axis=model
  python -m repro.launch.hillclimb --cell deepfm-train --lazy-optimizer
"""
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs.registry import get_config, shapes_for            # noqa: E402
from repro.launch import roofline as rl                              # noqa: E402
from repro.launch.mesh import make_production_mesh                   # noqa: E402
from repro.launch.steps import build_lm_cell, build_recsys_cell      # noqa: E402

CELLS = {
    "qwen2-prefill": ("qwen2-0.5b", "prefill_32k"),
    "deepseek-train": ("deepseek-v2-lite-16b", "train_4k"),
    "deepfm-train": ("deepfm", "train_batch"),
}


def _coerce(v: str):
    if v in ("None", "none"):
        return None
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def measure(arch, shape, overrides, lazy_optimizer=False, label="variant",
            use_probe=False):
    mesh = make_production_mesh(multi_pod=False)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    cfg, family = get_config(arch)
    dims = shapes_for(family)[shape]

    if family == "lm" and use_probe:
        from repro.launch.probe import lm_exact_costs
        t0 = time.time()
        exact = lm_exact_costs(arch, shape, mesh, overrides=overrides)
        rf = rl.Roofline(flops=exact["flops"] * chips,
                         hbm_bytes=exact["hbm_bytes"] * chips,
                         collective_bytes=exact["collective_bytes"] * chips,
                         chips=chips)
        out = {"flops": rf.flops, "hbm_bytes": rf.hbm_bytes,
               "collective_bytes": rf.collective_bytes, **rf.row(),
               "collectives": "(probe)", "label": label,
               "overrides": {k: str(v) for k, v in overrides.items()},
               "compile_s": round(time.time() - t0, 1), "method": "probe"}
        print(f"[{label}] probes={out['compile_s']}s  "
              f"compute={out['compute_s']:.4g}s "
              f"memory={out['memory_s']:.4g}s "
              f"collective={out['collective_s']:.4g}s  "
              f"dominant={out['dominant']} "
              f"frac={out['roofline_frac']:.4f}")
        return out

    if family == "lm":
        cfg = dataclasses.replace(cfg, unroll=True, **overrides)
        plan = build_lm_cell(cfg, dims, mesh, concrete=False)
    elif family == "recsys":
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        plan = build_recsys_cell(cfg, dims, mesh, concrete=False)
        if lazy_optimizer:
            from repro.launch.steps import make_optimizer
            from repro.models.recsys import make_deepfm_train_step_lazy
            plan.fn = make_deepfm_train_step_lazy(
                cfg, make_optimizer(),
                mesh=mesh if lazy_optimizer == "shardmap" else None)
    else:
        raise SystemExit(f"hillclimb supports lm/recsys cells, got {family}")

    jf = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                 donate_argnums=plan.donate_argnums)
    t0 = time.time()
    with mesh:
        lowered = jf.lower(*plan.args)
        compiled = lowered.compile()
    out = rl.analyze(lowered, compiled, chips)
    out["label"] = label
    out["overrides"] = {k: str(v) for k, v in overrides.items()}
    out["lazy_optimizer"] = lazy_optimizer
    out["compile_s"] = round(time.time() - t0, 1)
    print(f"[{label}] compile={out['compile_s']}s  "
          f"compute={out['compute_s']:.4g}s memory={out['memory_s']:.4g}s "
          f"collective={out['collective_s']:.4g}s  "
          f"dominant={out['dominant']} frac={out['roofline_frac']:.4f}")
    print(f"  flops={out['flops']:.4g} bytes={out['hbm_bytes']:.4g} "
          f"coll_bytes={out['collective_bytes']:.4g}")
    print(f"  collectives={out['collectives']}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), required=True)
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE")
    ap.add_argument("--lazy-optimizer", nargs="?", const="plain",
                    default=False, choices=["plain", "shardmap"])
    ap.add_argument("--probe", action="store_true",
                    help="affine-probe measurement (valid when the scan "
                         "structure is unchanged by the overrides)")
    ap.add_argument("--label", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = _coerce(v)
    arch, shape = CELLS[args.cell]
    label = args.label or (",".join(args.set) or
                           ("lazy-opt" if args.lazy_optimizer else
                            "baseline"))
    res = measure(arch, shape, overrides, args.lazy_optimizer, label,
                  use_probe=args.probe)
    res.update({"arch": arch, "shape": shape})
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        existing.append(res)
        with open(args.out, "w") as f:
            json.dump(existing, f, indent=1, default=str)


if __name__ == "__main__":
    main()
