"""Training driver: data-parallel step loop with the full fault-tolerance
story — atomic checkpoints, exact-resume data streams, straggler monitoring,
and elastic restart onto a different mesh.

On real hardware this runs under pjit on the production mesh; on CPU it
drives the same code with smoke-sized configs (see examples/train_lm.py).

    python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import ARCHS, get_config
from repro.data.tokens import lm_batch
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.launch.steps import make_optimizer
from repro.models import transformer as tfm


@dataclasses.dataclass
class TrainRun:
    """Holds the jitted step + state; restartable."""

    cfg: object
    params: dict
    opt_state: dict
    step_fn: object
    step: int = 0

    def run(self, *, steps: int, batch: int, seq: int, seed: int,
            ckpt: CheckpointManager | None, ckpt_every: int = 50,
            log_every: int = 10, monitor: StragglerMonitor | None = None):
        metrics_hist = []
        for s in range(self.step, steps):
            t0 = time.time()
            data = lm_batch(seed, s, batch, seq, self.cfg.vocab)
            data = {k: jnp.asarray(v) for k, v in data.items()}
            self.params, self.opt_state, m = self.step_fn(
                self.params, self.opt_state, data)
            m = {k: float(v) for k, v in m.items()}
            dt = time.time() - t0
            if monitor is not None and monitor.record(dt):
                # straggling step: on a cluster the launcher re-dispatches
                # the microbatch to a hot spare; single-process we log it.
                print(f"  [straggler] step {s} took {dt:.2f}s "
                      f"(deadline {monitor.deadline:.2f}s)")
            self.step = s + 1
            metrics_hist.append(m)
            if s % log_every == 0:
                print(f"step {s:5d} loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.2f} {dt*1e3:.0f}ms")
            if ckpt is not None and (s + 1) % ckpt_every == 0:
                ckpt.save(s + 1, {"params": self.params,
                                  "opt_state": self.opt_state})
        if ckpt is not None:
            ckpt.save(self.step, {"params": self.params,
                                  "opt_state": self.opt_state})
            ckpt.wait()
        return metrics_hist


def build_run(arch: str, *, smoke: bool, resume_dir: str | None = None,
              shardings=None) -> TrainRun:
    cfg, family = get_config(arch, smoke=smoke)
    if family != "lm":
        raise SystemExit(f"train.py drives LM archs; use examples/ for "
                         f"{family}")
    opt = make_optimizer()
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(tfm.make_train_step(cfg, opt), donate_argnums=(0, 1))
    run = TrainRun(cfg, params, opt_state, step_fn)
    if resume_dir:
        mgr = CheckpointManager(resume_dir)
        like = {"params": params, "opt_state": opt_state}
        step, restored = mgr.restore_latest(like, shardings)
        if restored is not None:
            run.params = restored["params"]
            run.opt_state = restored["opt_state"]
            run.step = step
            print(f"resumed from step {step}")
    return run


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a for a, (f, _) in ARCHS.items()
                                       if f == "lm"], required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    ckpt = CheckpointManager(args.ckpt_dir, async_save=True) \
        if args.ckpt_dir else None
    run = build_run(args.arch, smoke=args.smoke,
                    resume_dir=args.ckpt_dir if args.resume else None)
    hist = run.run(steps=args.steps, batch=args.batch, seq=args.seq,
                   seed=args.seed, ckpt=ckpt, ckpt_every=args.ckpt_every,
                   monitor=StragglerMonitor())
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
