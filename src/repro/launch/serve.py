"""Serving driver: batched prefill + decode with a position-addressed cache.

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --batch 4 \
        --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.models import transformer as tfm


def serve_batch(cfg, params, prompts: jax.Array, gen: int,
                greedy: bool = True):
    """prompts (B, S) -> generated tokens (B, gen). Returns (tokens, stats)."""
    b, s = prompts.shape
    max_len = s + gen
    t0 = time.time()
    prefill = jax.jit(lambda p, t: tfm.prefill(p, t, cfg, max_len=max_len))
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, t, c: tfm.decode_step(p, t, c, cfg))
    out = []
    t1 = time.time()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(gen):
        out.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t1
    stats = {"prefill_s": t_prefill, "decode_s": t_decode,
             "tok_per_s": b * gen / max(t_decode, 1e-9)}
    return jnp.stack(out, axis=1), stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a for a, (f, _) in ARCHS.items()
                                       if f == "lm"], required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg, _ = get_config(args.arch, smoke=args.smoke)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab,
                                 jnp.int32)
    toks, stats = serve_batch(cfg, params, prompts, args.gen)
    print(f"generated {toks.shape}  prefill={stats['prefill_s']*1e3:.1f}ms "
          f"decode={stats['decode_s']*1e3:.1f}ms "
          f"({stats['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
