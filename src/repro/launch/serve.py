"""Serving drivers.

LM mode — batched prefill + decode with a position-addressed cache:

    python -m repro.launch.serve --arch qwen2-0.5b --smoke --batch 4 \
        --prompt-len 32 --gen 16

Traversal mode — the plan-cached, reach-bucketed graph-query serving path
(:class:`repro.planner.serving.ServingSession`): build a graph, then answer
batches of per-user traversal roots, one bucketed dispatch per reach class,
with the plan cache amortizing parse/stats/costing across requests:

    python -m repro.launch.serve --traversal --vertices 20000 --height 10 \
        --batch 8 --requests 32 --depth 4

With ``--plan-store PATH`` the session persists its plan + calibration
caches: the first run writes PATH, every later run rehydrates from it and
answers its first request with zero parse/stats/costing work (the
"(rehydrated)" line reports the session counters to prove it).

Observability flags (traversal mode): ``--metrics`` prints the session's
Prometheus text exposition on exit (latency histograms, cache hit
counters, overflow retries, calibrator refits); ``--trace PATH`` traces
every request (spans + per-level traversal events) to JSON lines at PATH;
``--trace-chrome PATH`` writes the same trace as a Chrome/Perfetto-loadable
JSON file.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.models import transformer as tfm


def serve_batch(cfg, params, prompts: jax.Array, gen: int,
                greedy: bool = True):
    """prompts (B, S) -> generated tokens (B, gen). Returns (tokens, stats)."""
    b, s = prompts.shape
    max_len = s + gen
    t0 = time.time()
    prefill = jax.jit(lambda p, t: tfm.prefill(p, t, cfg, max_len=max_len))
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, t, c: tfm.decode_step(p, t, c, cfg))
    out = []
    t1 = time.time()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for i in range(gen):
        out.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t1
    stats = {"prefill_s": t_prefill, "decode_s": t_decode,
             "tok_per_s": b * gen / max(t_decode, 1e-9)}
    return jnp.stack(out, axis=1), stats


def serve_traversals(args) -> dict:
    """The graph-traversal serving loop: one ServingSession, ``--requests``
    batches of mixed hub/leaf roots, steady-state latency from the plan
    cache + bucketed dispatch.  Returns the session's counters."""
    import os

    from repro.core.engine import Dataset
    from repro.data.treegen import TreeSpec, make_edge_table
    from repro.planner import ServingSession, paper_listing

    spec = TreeSpec(num_vertices=args.vertices, height=args.height,
                    payload_cols=0, seed=0)
    ds = Dataset.prepare(make_edge_table(spec), spec.num_vertices)
    sql = paper_listing(1, root=0, depth=args.depth)
    tracer = None
    if args.trace or args.trace_chrome:
        from repro.obs import Tracer
        tracer = Tracer(meta={"mode": "traversal-serve",
                              "vertices": args.vertices,
                              "batch": args.batch,
                              "requests": args.requests})
    rehydrated = (args.plan_store is not None
                  and os.path.exists(args.plan_store))
    session = ServingSession(ds, plan_store=args.plan_store, tracer=tracer,
                             guards=not args.no_guards)
    if rehydrated:
        print(f"(rehydrated) plan store {args.plan_store}: "
              f"{len(session._plans)} plan(s), "
              f"{session.calibrator.count} calibration observation(s)")

    rng = np.random.RandomState(0)
    t_first = t_steady = 0.0
    for i in range(args.requests):
        # every batch mixes the hub root 0 with random (mostly leaf) roots
        roots = [0] + rng.randint(0, args.vertices,
                                  size=args.batch - 1).tolist()
        t0 = time.perf_counter()
        results = session.submit(sql, roots,
                                 deadline_us=args.deadline_us)
        jax.block_until_ready([r.count for r in results])
        dt = time.perf_counter() - t0
        if i == 0:
            t_first = dt
        else:
            t_steady += dt
    stats = session.stats
    steady_us = t_steady / max(args.requests - 1, 1) * 1e6
    print(f"traversal serving: {args.requests} requests x "
          f"batch {args.batch}  first={t_first * 1e3:.1f}ms (plans+compile) "
          f"steady={steady_us / 1e3:.2f}ms/req "
          f"({steady_us / args.batch:.0f}us/root)")
    print(f"plan cache: {stats['plan_hits']} hits / "
          f"{stats['plan_misses']} misses over "
          f"{stats['cached_plans']} plan(s), "
          f"{stats['cached_shapes']} query shape(s)")
    print(f"planning paid: {stats['parse_calls']} parse / "
          f"{stats['stats_calls']} stats / {stats['cost_calls']} costing "
          f"pass(es); calibration: {stats['calibration_observations']} "
          f"observation(s), {stats['calibration_refits']} refit(s)")
    print(f"latency: p50={stats['latency_us_p50'] / 1e3:.2f}ms "
          f"p95={stats['latency_us_p95'] / 1e3:.2f}ms "
          f"p99={stats['latency_us_p99'] / 1e3:.2f}ms  "
          f"hit rate {stats['plan_hit_rate']:.2f}, "
          f"{stats['overflow_retries']} overflow retr(ies)")
    print(f"front door: admission {stats['admission_traverse']} traverse / "
          f"{stats['admission_degrade']} degrade / "
          f"{stats['admission_reject']} reject; "
          f"{stats['deadline_skipped_buckets']} deadline-skipped "
          f"bucket(s), {stats['retry_denied']} retry-denied lane(s)")
    if args.plan_store is not None:
        session.save_plan_store()
        print(f"plan store saved to {args.plan_store}")
    if tracer is not None:
        if args.trace:
            tracer.write_jsonl(args.trace)
            print(f"trace written to {args.trace} "
                  f"({len(tracer.records)} record(s))")
        if args.trace_chrome:
            tracer.write_chrome_trace(args.trace_chrome)
            print(f"chrome trace written to {args.trace_chrome}")
    if args.metrics:
        print("-- metrics --")
        print(session.metrics_text(), end="")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--traversal", action="store_true",
                    help="serve graph-traversal queries (plan-cached, "
                         "reach-bucketed) instead of an LM")
    ap.add_argument("--arch", choices=[a for a, (f, _) in ARCHS.items()
                                       if f == "lm"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--vertices", type=int, default=20_000)
    ap.add_argument("--height", type=int, default=10)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--plan-store", default=None, metavar="PATH",
                    help="persist plans + calibration: rehydrate from PATH "
                         "when it exists, save to it on exit")
    ap.add_argument("--metrics", action="store_true",
                    help="print the serving metrics registry in Prometheus "
                         "text format on exit")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="trace every request (spans + per-level events) "
                         "to JSON lines at PATH")
    ap.add_argument("--trace-chrome", default=None, metavar="PATH",
                    help="write the trace as a Chrome/Perfetto-loadable "
                         "JSON file at PATH")
    ap.add_argument("--deadline-us", type=float, default=None,
                    metavar="US",
                    help="per-request deadline budget in microseconds: "
                         "buckets predicted to blow the budget are "
                         "skipped and the answer is explicitly truncated "
                         "(session.last_report names the skipped roots)")
    ap.add_argument("--no-guards", action="store_true",
                    help="disable the admission guard ladder (default: "
                         "every root is priced against the guard budgets "
                         "before dispatch; see docs/robustness.md)")
    args = ap.parse_args(argv)

    if args.traversal:
        serve_traversals(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --traversal is given")

    cfg, _ = get_config(args.arch, smoke=args.smoke)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab,
                                 jnp.int32)
    toks, stats = serve_batch(cfg, params, prompts, args.gen)
    print(f"generated {toks.shape}  prefill={stats['prefill_s']*1e3:.1f}ms "
          f"decode={stats['decode_s']*1e3:.1f}ms "
          f"({stats['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
