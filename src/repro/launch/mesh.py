"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state.  Single pod = 256 chips as (data=16, model=16);
multi-pod = 2 pods x 256 = 512 chips with the leading "pod" axis composing
with "data" for data parallelism (gradient reductions cross the inter-pod
links last).
"""
from __future__ import annotations

import jax


def _build_mesh(shape: tuple, axes: tuple):
    if hasattr(jax.sharding, "AxisType"):        # jax >= 0.5
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):                # 0.4.35 .. 0.4.x
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils      # older fallback
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _build_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-scale)."""
    return _build_mesh(tuple(shape), tuple(axes))
