"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state.  Single pod = 256 chips as (data=16, model=16);
multi-pod = 2 pods x 256 = 512 chips with the leading "pod" axis composing
with "data" for data parallelism (gradient reductions cross the inter-pod
links last).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-scale)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
