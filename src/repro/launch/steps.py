"""Cell builder: (arch x shape x mesh) -> (step_fn, inputs, shardings).

``build_cell(..., concrete=False)`` produces ShapeDtypeStruct stand-ins for
every input (weak-type-correct, shardable, no device allocation) — what the
multi-pod dry-run lowers.  ``concrete=True`` instantiates real (smoke-sized)
tensors for the per-arch CPU smoke tests, running the *same* code path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import BFSConfig, GNNConfig, LMConfig, RecsysConfig
from repro.configs.registry import get_config, shapes_for
from repro.distributed.sharding import (DP_AXES, gnn_batch_specs,
                                        lm_batch_specs, lm_cache_specs,
                                        lm_param_specs, recsys_batch_specs,
                                        recsys_param_specs, valid_spec)
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.optim import AdamW, linear_warmup_cosine

F32, I32, U32 = jnp.float32, jnp.int32, jnp.uint32


@dataclasses.dataclass
class CellPlan:
    fn: Callable                 # jittable step
    args: tuple                  # pytrees of SDS (dry-run) or arrays (smoke)
    in_shardings: Any            # matching pytree of NamedSharding (or None)
    donate_argnums: tuple[int, ...] = ()
    static_argnums: tuple[int, ...] = ()
    description: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _concretize(tree, seed=0):
    """Turn a ShapeDtypeStruct pytree into deterministic real arrays."""
    rng = np.random.default_rng(seed)

    def one(x):
        if not isinstance(x, jax.ShapeDtypeStruct):
            return x
        if jnp.issubdtype(x.dtype, jnp.integer):
            return jnp.asarray(
                rng.integers(0, 2, x.shape).astype(np.int32), dtype=x.dtype)
        if x.dtype == jnp.bool_:
            return jnp.ones(x.shape, jnp.bool_)
        return jnp.asarray(
            (rng.standard_normal(x.shape) * 0.05).astype(np.float32),
            dtype=x.dtype)

    return jax.tree_util.tree_map(one, tree)


def _shard_tree(mesh, spec_tree):
    if mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def _shard_valid(mesh, spec_tree, sds_tree):
    """NamedShardings with non-dividing axes dropped per actual shapes
    (lets the same cells lower on tiny test meshes)."""
    if mesh is None:
        return None
    return jax.tree_util.tree_map(
        lambda s, x: NamedSharding(mesh, valid_spec(mesh, x.shape, s)),
        spec_tree, sds_tree, is_leaf=lambda s: isinstance(s, P))


def _opt_specs(param_specs):
    return {"mu": param_specs, "nu": param_specs, "step": P()}


def make_optimizer():
    return AdamW(lr=linear_warmup_cosine(3e-4, 200, 10_000))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_param_sds(cfg: LMConfig):
    return jax.eval_shape(lambda k: tfm.init_lm(k, cfg),
                          jax.random.PRNGKey(0))


def build_lm_cell(cfg: LMConfig, dims: dict, mesh, *, concrete: bool
                  ) -> CellPlan:
    kind, seq, batch = dims["kind"], dims["seq"], dims["batch"]
    opt = make_optimizer()
    params = _lm_param_sds(cfg)
    pspecs = lm_param_specs(mesh, params) if mesh else None

    if concrete:
        params_v = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    else:
        params_v = params

    if kind == "train":
        step = tfm.make_train_step(cfg, opt)
        opt_state = jax.eval_shape(opt.init, params)
        batch_sds = {"tokens": _sds((batch, seq), I32),
                     "labels": _sds((batch, seq), I32)}
        in_sh = None
        if mesh:
            in_sh = (_shard_tree(mesh, pspecs),
                     _shard_tree(mesh, _opt_specs(pspecs)),
                     _shard_valid(mesh, lm_batch_specs(mesh), batch_sds))
        args = (params_v,
                opt.init(params_v) if concrete else opt_state,
                _concretize(batch_sds) if concrete else batch_sds)
        return CellPlan(step, args, in_sh, donate_argnums=(0, 1),
                        description=f"train_step {batch}x{seq}")

    if kind == "prefill":
        def step(params, tokens):
            return tfm.prefill(params, tokens, cfg)

        batch_sds = _sds((batch, seq), I32)
        in_sh = None
        if mesh:
            cache_sds = jax.eval_shape(
                lambda: tfm.init_cache(cfg, batch, seq))
            in_sh = (_shard_tree(mesh, pspecs),
                     NamedSharding(mesh, valid_spec(
                         mesh, batch_sds.shape, P(DP_AXES(mesh), None))))
        args = (params_v,
                _concretize(batch_sds) if concrete else batch_sds)
        return CellPlan(step, args, in_sh,
                        description=f"prefill {batch}x{seq}")

    if kind == "decode":
        def step(params, tokens, cache):
            return tfm.decode_step(params, tokens, cache, cfg)

        cache_sds = jax.eval_shape(lambda: tfm.init_cache(cfg, batch, seq))
        # cache arrives filled to seq-1; one new token is decoded
        cache_sds = tfm.KVCache(cache_sds.a, cache_sds.b, _sds((), I32))
        tok_sds = _sds((batch,), I32)
        in_sh = None
        if mesh:
            cspec = lm_cache_specs(mesh, cache_sds)
            in_sh = (_shard_tree(mesh, pspecs),
                     NamedSharding(mesh, valid_spec(
                         mesh, tok_sds.shape, P(DP_AXES(mesh)))),
                     _shard_valid(mesh, cspec, cache_sds))
        if concrete:
            cache_v = tfm.init_cache(cfg, batch, seq)
            cache_v = tfm.KVCache(cache_v.a, cache_v.b,
                                  jnp.asarray(seq - 1, I32))
            args = (params_v, _concretize(tok_sds), cache_v)
        else:
            args = (params_v, tok_sds, cache_sds)
        return CellPlan(step, args, in_sh, donate_argnums=(2,),
                        description=f"serve_step(decode) {batch}xKV{seq}")

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_loss_graph(cfg: GNNConfig, n_classes: int, pooled: bool):
    def loss_fn(params, batch):
        logits = gnn_mod.gnn_forward(params, cfg, batch)
        if pooled:                       # molecule: graph-level head
            seg = batch["graph_of_node"]
            ngraph = batch["labels"].shape[0]
            pool = jax.ops.segment_sum(logits, seg, num_segments=ngraph)
            cnt = jax.ops.segment_sum(jnp.ones((logits.shape[0],), F32),
                                      seg, num_segments=ngraph)
            pooled_logits = pool / jnp.maximum(cnt, 1.0)[:, None]
            return gnn_mod.node_xent(pooled_logits, batch["labels"])
        return gnn_mod.node_xent(logits, batch["labels"],
                                 batch.get("mask"))
    return loss_fn


def _pad32(n: int) -> int:
    """Round graph dims up to a multiple of 32 so the (pod,data) axes divide
    (dry-run SDS only; concrete smoke graphs keep exact published sizes)."""
    return -(-n // 32) * 32


def build_gnn_cell(arch: str, cfg: GNNConfig, dims: dict, mesh,
                   *, concrete: bool) -> CellPlan:
    kind = dims["kind"]
    if mesh is not None and not concrete:
        dims = dict(dims)
        for k in ("n_nodes", "n_edges"):
            if k in dims:
                dims[k] = _pad32(dims[k])
    opt = make_optimizer()
    d_feat, n_classes = dims["d_feat"], dims["n_classes"]
    init = lambda k: gnn_mod.init_gnn(k, cfg, d_feat, n_classes)
    params = jax.eval_shape(init, jax.random.PRNGKey(0))
    params_v = init(jax.random.PRNGKey(0)) if concrete else params
    # GNN params are small -> replicated; graph data is what shards
    pspecs = jax.tree_util.tree_map(lambda l: P(*([None] * l.ndim)), params)

    if kind in ("full_graph", "molecule"):
        if kind == "molecule":
            v = dims["batch"] * dims["n_nodes"]
            e = dims["batch"] * dims["n_edges"]
            nlab = dims["batch"]
        else:
            v, e, nlab = dims["n_nodes"], dims["n_edges"], dims["n_nodes"]
        batch_sds = {"src": _sds((e,), I32), "dst": _sds((e,), I32),
                     "feats": _sds((v, d_feat), F32),
                     "labels": _sds((nlab,), I32)}
        if kind == "full_graph":
            batch_sds["mask"] = _sds((v,), F32)
        else:
            batch_sds["graph_of_node"] = _sds((v,), I32)
        if cfg.kind == "egnn":
            batch_sds["coords"] = _sds((v, 3), F32)

        loss_fn = _gnn_loss_graph(cfg, n_classes, pooled=kind == "molecule")

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, gnorm = opt.update(params, grads, opt_state)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm}

        opt_state = jax.eval_shape(opt.init, params)
        in_sh = None
        if mesh:
            in_sh = (_shard_tree(mesh, pspecs),
                     _shard_tree(mesh, _opt_specs(pspecs)),
                     _shard_valid(mesh, gnn_batch_specs(mesh, batch_sds),
                                  batch_sds))
        if concrete:
            gb = _concrete_graph(dims, cfg, kind, d_feat, n_classes)
            args = (params_v, opt.init(params_v), gb)
        else:
            args = (params_v, opt_state, batch_sds)
        return CellPlan(step, args, in_sh, donate_argnums=(0, 1),
                        description=f"{kind} train_step V={v} E={e}")

    if kind == "minibatch":
        return _build_minibatch_cell(arch, cfg, dims, mesh, opt, params,
                                     params_v, pspecs, d_feat, n_classes,
                                     concrete)
    raise ValueError(kind)


def _concrete_graph(dims, cfg, kind, d_feat, n_classes):
    from repro.data.graphgen import make_graph, make_molecule_batch
    if kind == "molecule":
        g = make_molecule_batch(dims["batch"], dims["n_nodes"],
                                dims["n_edges"], d_feat, seed=3)
        rng = np.random.default_rng(5)
        b = {"src": jnp.asarray(g.src), "dst": jnp.asarray(g.dst),
             "feats": jnp.asarray(g.feats),
             "labels": jnp.asarray(g.labels),
             "graph_of_node": jnp.repeat(
                 jnp.arange(dims["batch"], dtype=I32), dims["n_nodes"])}
    else:
        g = make_graph(dims["n_nodes"], dims["n_edges"], d_feat,
                       num_classes=n_classes, seed=3)
        b = {"src": jnp.asarray(g.src), "dst": jnp.asarray(g.dst),
             "feats": jnp.asarray(g.feats), "labels": jnp.asarray(g.labels),
             "mask": jnp.ones((g.num_vertices,), F32)}
    if cfg.kind == "egnn":
        rng = np.random.default_rng(7)
        b["coords"] = jnp.asarray(
            rng.standard_normal((b["feats"].shape[0], 3)).astype(np.float32))
    return b


def _build_minibatch_cell(arch, cfg, dims, mesh, opt, params, params_v,
                          pspecs, d_feat, n_classes, concrete):
    """Fused sampler + train step over the full Reddit-scale graph:
    the sampler is the paper's positional BFS (see data/sampler.py)."""
    from repro.core.csr import CSRIndex
    from repro.data.sampler import gather_block_features, sample_block

    v, e = dims["n_nodes"], dims["n_edges"]
    bsz, fanout = dims["batch_nodes"], tuple(dims["fanout"])

    graph_sds = {"indptr": _sds((v + 1,), I32), "perm": _sds((e,), I32),
                 "dst": _sds((e,), I32), "feats": _sds((v, d_feat), F32),
                 "labels": _sds((v,), I32)}
    if cfg.kind == "egnn":
        graph_sds["coords"] = _sds((v, 3), F32)
    seeds_sds = _sds((bsz,), I32)

    is_sage = cfg.kind == "graphsage"
    sage_cfg = dataclasses.replace(cfg, sample_sizes=fanout) if is_sage \
        else cfg

    def loss_fn(params, graph, seeds, seed_scalar):
        csr = CSRIndex(graph["indptr"], graph["perm"])
        key = jax.random.PRNGKey(seed_scalar)
        layers = sample_block(key, csr, graph["dst"], seeds, fanout)
        labels = jnp.take(graph["labels"], seeds, axis=0)
        if is_sage:
            block = {"layer_feats": gather_block_features(graph["feats"],
                                                          layers),
                     "labels": labels}
            logits = gnn_mod.sage_block_forward(params, sage_cfg, block)
            return gnn_mod.node_xent(logits, labels)
        # generic arch: run on the sampled subgraph (positions -> one gather)
        nodes = jnp.concatenate(layers)
        offs = np.cumsum([0] + [int(l.shape[0])
                                for l in layers]).tolist()
        srcs, dsts = [], []
        for li, f in enumerate(fanout):
            n_par = offs[li + 1] - offs[li]
            srcs.append(offs[li + 1]
                        + jnp.arange(n_par * f, dtype=I32))
            dsts.append(offs[li] + jnp.repeat(
                jnp.arange(n_par, dtype=I32), f))
        sub = {"src": jnp.concatenate(srcs), "dst": jnp.concatenate(dsts),
               "feats": jnp.take(graph["feats"], nodes, axis=0),
               "labels": labels}
        if cfg.kind == "egnn":
            sub["coords"] = jnp.take(graph["coords"], nodes, axis=0)
        logits = gnn_mod.gnn_forward(params, cfg, sub)
        return gnn_mod.node_xent(logits[:bsz], labels)

    def step(params, opt_state, graph, seeds, seed_scalar):
        loss, grads = jax.value_and_grad(loss_fn)(params, graph, seeds,
                                                  seed_scalar)
        params, opt_state, gnorm = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    opt_state = jax.eval_shape(opt.init, params)
    in_sh = None
    if mesh:
        in_sh = (_shard_tree(mesh, pspecs),
                 _shard_tree(mesh, _opt_specs(pspecs)),
                 _shard_valid(mesh, gnn_batch_specs(mesh, graph_sds),
                              graph_sds),
                 NamedSharding(mesh, P()),
                 NamedSharding(mesh, P()))
    if concrete:
        from repro.core.csr import build_csr
        from repro.data.graphgen import make_graph
        g = make_graph(v, e, d_feat, num_classes=n_classes, seed=4)
        csr = build_csr(jnp.asarray(g.src), v)
        graph_v = {"indptr": csr.indptr, "perm": csr.perm,
                   "dst": jnp.asarray(g.dst),
                   "feats": jnp.asarray(g.feats),
                   "labels": jnp.asarray(g.labels)}
        if cfg.kind == "egnn":
            rng = np.random.default_rng(9)
            graph_v["coords"] = jnp.asarray(
                rng.standard_normal((v, 3)).astype(np.float32))
        args = (params_v, opt.init(params_v), graph_v,
                jnp.arange(bsz, dtype=I32), jnp.asarray(0, I32))
    else:
        args = (params_v, opt_state, graph_sds, seeds_sds, _sds((), I32))
    return CellPlan(step, args, in_sh, donate_argnums=(0, 1),
                    description=f"sampled train_step B={bsz} "
                                f"fanout={fanout} over V={v} E={e}")


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def build_recsys_cell(cfg: RecsysConfig, dims: dict, mesh,
                      *, concrete: bool) -> CellPlan:
    kind = dims["kind"]
    opt = make_optimizer()
    init = lambda k: recsys_mod.init_deepfm(k, cfg)
    params = jax.eval_shape(init, jax.random.PRNGKey(0))
    params_v = init(jax.random.PRNGKey(0)) if concrete else params
    pspecs = recsys_param_specs(mesh, params) if mesh else None
    nf = cfg.n_dense + cfg.n_sparse
    offsets = jnp.asarray(recsys_mod.field_offsets(cfg))

    def batch_sds(b):
        return {"dense": _sds((b, cfg.n_dense), F32),
                "sparse": _sds((b, cfg.n_sparse), I32),
                "label": _sds((b,), F32),
                "offsets": _sds((nf,), I32)}

    def concrete_batch(b):
        from repro.data.recsys_stream import recsys_batch, vocab_sizes
        d = recsys_batch(0, 0, b, vocabs=vocab_sizes(cfg.vocab_scale))
        out = {k: jnp.asarray(v) for k, v in d.items()}
        out["offsets"] = offsets
        return out

    if kind == "train":
        b = dims["batch"]
        step = recsys_mod.make_deepfm_train_step(cfg, opt)
        opt_state = jax.eval_shape(opt.init, params)
        in_sh = None
        if mesh:
            in_sh = (_shard_tree(mesh, pspecs),
                     _shard_tree(mesh, _opt_specs(pspecs)),
                     _shard_valid(mesh, recsys_batch_specs(mesh),
                                  batch_sds(b)))
        args = (params_v,
                opt.init(params_v) if concrete else opt_state,
                concrete_batch(b) if concrete else batch_sds(b))
        return CellPlan(step, args, in_sh, donate_argnums=(0, 1),
                        description=f"train_step B={b}")

    if kind == "serve":
        b = dims["batch"]

        def step(params, batch):
            return recsys_mod.serve_scores(params, cfg, batch["dense"],
                                           batch["sparse"],
                                           batch["offsets"])

        in_sh = None
        if mesh:
            in_sh = (_shard_tree(mesh, pspecs),
                     _shard_valid(mesh, recsys_batch_specs(mesh),
                                  batch_sds(b)))
        bd = concrete_batch(b) if concrete else batch_sds(b)
        return CellPlan(step, (params_v, bd), in_sh,
                        description=f"serve_scores B={b}")

    if kind == "retrieval":
        nc = dims["n_candidates"]

        def step(params, batch, cand_ids):
            return recsys_mod.retrieval_scores(
                params, cfg, batch["dense"], batch["sparse"],
                batch["offsets"], cand_ids)

        cand_sds = _sds((nc,), I32)
        in_sh = None
        if mesh:
            # single-query context: replicate the (1, ...) batch, shard the
            # 1M candidate ids over DP
            rep = {k: P(*([None] * len(v.shape)))
                   for k, v in batch_sds(1).items()}
            in_sh = (_shard_tree(mesh, pspecs),
                     _shard_tree(mesh, rep),
                     NamedSharding(mesh, valid_spec(
                         mesh, (nc,), P(DP_AXES(mesh)))))
        bd = concrete_batch(1) if concrete else batch_sds(1)
        cand = jnp.arange(nc, dtype=I32) % 1000 if concrete else cand_sds
        return CellPlan(step, (params_v, bd, cand), in_sh,
                        description=f"retrieval_scores C={nc}")
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_id: str, mesh=None, *, smoke: bool = False,
               concrete: bool = False, attn_window: int | None = None
               ) -> CellPlan:
    cfg, family = get_config(arch, smoke=smoke)
    dims = shapes_for(family, smoke=smoke)[shape_id]
    if family == "lm":
        if attn_window is not None:
            cfg = dataclasses.replace(cfg, attn_window=attn_window)
        if not concrete:
            # dry-run: unroll scans so cost_analysis counts every layer /
            # KV chunk (XLA tallies while bodies exactly once otherwise)
            cfg = dataclasses.replace(cfg, unroll=True)
        return build_lm_cell(cfg, dims, mesh, concrete=concrete)
    if family == "gnn":
        return build_gnn_cell(arch, cfg, dims, mesh, concrete=concrete)
    if family == "recsys":
        return build_recsys_cell(cfg, dims, mesh, concrete=concrete)
    raise ValueError(family)
