"""Transformer building blocks: RMSNorm, RoPE, chunked (online-softmax)
attention for GQA and MLA, SwiGLU, and the positional MoE dispatch.

Everything is a pure function over a param pytree; layer stacks are scanned
(params carry a leading layer axis) so the HLO stays compact at 27-40
layers and 512 devices.

The MoE dispatch is deliberately built on the paper's positional discipline
(:func:`repro.core.positions.sort_positions_by_key`): token *positions* are
sorted by expert id, activations are gathered once into per-expert
contiguous blocks, and scattered back once — values move exactly twice.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, MLAConfig, MoEConfig
from repro.core.positions import sort_positions_by_key

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# norms / rope / basic ops
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """(..., ) int positions -> (..., dim//2) angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, d) with d even; positions: (..., S)."""
    d = x.shape[-1]
    ang = rope_angles(positions, d, theta)                 # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array
           ) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int, q_start, kv_len,
                      window: int | None = None,
                      unroll: bool = False) -> jax.Array:
    """Online-softmax attention scanned over KV chunks.

    q: (B, Hkv, G, Sq, dk) — query heads grouped over their KV head
    k: (B, Hkv, Skv, dk);  v: (B, Hkv, Skv, dv)
    q_start: scalar — absolute position of q[...,0,:] (decode offset)
    kv_len: scalar — number of valid KV positions (cache may be padded)

    Peak memory is O(Sq * chunk) per head instead of O(Sq * Skv); the TPU
    production path would swap in a fused flash kernel, but the roofline
    terms (FLOPs/bytes) of this formulation already match it.
    """
    b, hkv, g, sq, dk = q.shape
    skv = k.shape[2]
    dv = v.shape[-1]
    scale = dk ** -0.5
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (skv + pad) // chunk
    kc = k.reshape(b, hkv, n_chunks, chunk, dk).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)

    q_pos = q_start + jnp.arange(sq)                       # (Sq,)
    neg = jnp.float32(-1e30)

    def step(carry, xs):
        m, l, acc, c0 = carry
        k_i, v_i = xs
        s = jnp.einsum("bhgqd,bhcd->bhgqc", q.astype(jnp.float32),
                       k_i.astype(jnp.float32)) * scale
        k_pos = c0 + jnp.arange(chunk)                     # (C,)
        valid = (k_pos < kv_len)[None, :]
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(valid[None, None, None], s, neg)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, v_i.astype(jnp.float32))
        l = l * corr + p.sum(axis=-1)
        return (m_new, l, acc, c0 + chunk), None

    m0 = jnp.full((b, hkv, g, sq), neg, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)),
                                     (kc, vc), unroll=unroll)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def blocked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             q_block: int, chunk: int,
                             window: int | None = None,
                             unroll: bool = False) -> jax.Array:
    """Flash-structured self-attention: queries processed in blocks, each
    scanning only its causal KV *prefix* (triangular skip).

    vs. plain ``chunked_attention`` over the full sequence this (a) halves
    score FLOPs/bytes (no fully-masked chunks), and (b) shrinks the
    online-softmax carry from (Sq, dv) to (q_block, dv) per inner step —
    the carry round-trips were the dominant HBM term of the 32k prefill
    (EXPERIMENTS.md §Perf).  The terminal version of this structure is the
    fused Pallas flash kernel where the carry never leaves VMEM.
    """
    b, hkv, g, sq, dk = q.shape
    nqb = -(-sq // q_block)
    outs = []
    for i in range(nqb):
        q0, q1 = i * q_block, min((i + 1) * q_block, sq)
        kv_end = q1                                # causal prefix only
        qi = q[:, :, :, q0:q1]
        ki = k[:, :, :kv_end]
        vi = v[:, :, :kv_end]
        outs.append(chunked_attention(
            qi, ki, vi, causal=True, chunk=min(chunk, kv_end),
            q_start=q0, kv_len=kv_end, window=window, unroll=unroll))
    return jnp.concatenate(outs, axis=3)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: LMConfig):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, h * hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, hkv * hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, hkv * hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (h * hd, d), jnp.float32) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def gqa_project_qkv(p: Params, x: jax.Array, cfg: LMConfig, positions):
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(p: Params, x: jax.Array, cfg: LMConfig, *, positions,
                  cache=None):
    """Self-attention.  ``cache=None`` -> train/prefill over x itself;
    ``cache=(k_cache, v_cache, cur_len)`` -> decode: the new block's K/V are
    inserted at ``cur_len`` and attention runs over the whole cache."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hkv
    q, k, v = gqa_project_qkv(p, x, cfg, positions)
    if cache is not None:
        kc, vc, cur = cache                          # (B, Smax, Hkv, hd)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, cur, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, cur, 0, 0))
        k_full, v_full, kv_n, q_start = kc, vc, cur + s, cur
        new_cache = (kc, vc)
    else:
        k_full, v_full, kv_n, q_start = k, v, s, 0
        new_cache = (k, v)
    qg = q.reshape(b, s, hkv, g, hd).transpose(0, 2, 3, 1, 4)
    kt = k_full.transpose(0, 2, 1, 3)
    vt = v_full.transpose(0, 2, 1, 3)
    if cache is None and cfg.attn_q_block is not None:
        out = blocked_causal_attention(qg, kt, vt,
                                       q_block=cfg.attn_q_block,
                                       chunk=cfg.attn_chunk,
                                       window=cfg.attn_window,
                                       unroll=cfg.unroll)
    else:
        out = chunked_attention(qg, kt, vt, causal=True,
                                chunk=cfg.attn_chunk, q_start=q_start,
                                kv_len=kv_n, window=cfg.attn_window,
                                unroll=cfg.unroll)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h * hd)
    return out @ p["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2 family)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: LMConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h * (m.nope_head_dim +
                                                m.rope_head_dim)),
                                jnp.float32) * s,
        "w_dkv": jax.random.normal(ks[1], (d, m.kv_lora_rank),
                                   jnp.float32) * s,
        "w_kr": jax.random.normal(ks[2], (d, m.rope_head_dim),
                                  jnp.float32) * s,
        "w_uk": jax.random.normal(ks[3], (m.kv_lora_rank,
                                          h * m.nope_head_dim),
                                  jnp.float32) * (m.kv_lora_rank ** -0.5),
        "w_uv": jax.random.normal(ks[4], (m.kv_lora_rank, h * m.v_head_dim),
                                  jnp.float32) * (m.kv_lora_rank ** -0.5),
        "wo": jax.random.normal(ks[5], (h * m.v_head_dim, d),
                                jnp.float32) * s,
    }


def mla_compress(p: Params, x: jax.Array, cfg: LMConfig, positions):
    """x -> (c_kv, k_rope): the ONLY tensors the MLA decode cache stores."""
    dt = x.dtype
    m = cfg.mla
    c = x @ p["w_dkv"].astype(dt)                        # (B,S,kvr)
    kr = (x @ p["w_kr"].astype(dt))[:, :, None, :]       # (B,S,1,dr)
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0]
    return c, kr


def mla_attention(p: Params, x: jax.Array, cfg: LMConfig, *, positions,
                  cache=None):
    """MLA with the *absorbed* decode path: when ``cache=(c_cache, kr_cache,
    cur_len)`` is present the new block's latents are inserted at
    ``cur_len`` and scores/values are computed directly in the latent
    (kv_lora) space — q is folded through W_uk and the attention output
    through W_uv, so the cache stays (kv_lora + rope_dim) per position (the
    paper-faithful MLA memory saving) and no per-step decompression of the
    history happens."""
    b, s, d = x.shape
    m: MLAConfig = cfg.mla
    h = cfg.n_heads
    dn, dr, dv, r = m.nope_head_dim, m.rope_head_dim, m.v_head_dim, \
        m.kv_lora_rank
    dt = x.dtype

    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)

    c_new, kr_new = mla_compress(p, x, cfg, positions)

    if cache is None:
        # prefill/train: decompress and run standard MHA
        c, kr = c_new, kr_new
        kn = (c @ p["w_uk"].astype(dt)).reshape(b, s, h, dn)
        v = (c @ p["w_uv"].astype(dt)).reshape(b, s, h, dv)
        kfull = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :],
                                                      (b, s, h, dr))], -1)
        qfull = jnp.concatenate([qn, qr], -1)
        qg = qfull.reshape(b, s, h, 1, dn + dr).transpose(0, 2, 3, 1, 4)
        if cfg.attn_q_block is not None:
            out = blocked_causal_attention(
                qg, kfull.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                q_block=cfg.attn_q_block, chunk=cfg.attn_chunk,
                window=cfg.attn_window, unroll=cfg.unroll)
        else:
            out = chunked_attention(qg, kfull.transpose(0, 2, 1, 3),
                                    v.transpose(0, 2, 1, 3), causal=True,
                                    chunk=cfg.attn_chunk, q_start=0,
                                    kv_len=s, window=cfg.attn_window,
                                    unroll=cfg.unroll)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h * dv)
        new_cache = (c_new, kr_new)
    else:
        # absorbed decode: scores in latent space against the c/kr cache
        cc, krc, cur = cache
        cc = jax.lax.dynamic_update_slice(cc, c_new.astype(cc.dtype),
                                          (0, cur, 0))
        krc = jax.lax.dynamic_update_slice(krc, kr_new.astype(krc.dtype),
                                           (0, cur, 0))
        kv_len = cur + s
        smax = cc.shape[1]
        w_uk = p["w_uk"].astype(dt).reshape(r, h, dn)
        q_lat = jnp.einsum("bshn,rhn->bshr", qn, w_uk)    # fold W_uk into q
        scale = (dn + dr) ** -0.5
        s_lat = jnp.einsum("bshr,btr->bhst", q_lat, cc)
        s_rot = jnp.einsum("bshd,btd->bhst", qr, krc)
        scores = (s_lat + s_rot).astype(jnp.float32) * scale
        t_pos = jnp.arange(smax)
        q_pos = cur + jnp.arange(s)
        mask = (t_pos[None, :] < kv_len) & \
            (q_pos[:, None] >= t_pos[None, :])
        if cfg.attn_window is not None:
            mask = mask & (q_pos[:, None] - t_pos[None, :] < cfg.attn_window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        pattn = jax.nn.softmax(scores, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhst,btr->bshr", pattn, cc)     # latent output
        w_uv = p["w_uv"].astype(dt).reshape(r, h, dv)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv).reshape(b, s, h * dv)
        new_cache = (cc, krc)

    return out @ p["wo"].astype(dt), new_cache


# ---------------------------------------------------------------------------
# dense + MoE FFN
# ---------------------------------------------------------------------------

def init_dense_ffn(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": jax.random.normal(k1, (d, f), jnp.float32) * d ** -0.5,
            "w3": jax.random.normal(k2, (d, f), jnp.float32) * d ** -0.5,
            "w2": jax.random.normal(k3, (f, d), jnp.float32) * f ** -0.5}


def dense_ffn(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    return swiglu(x, p["w1"].astype(dt), p["w3"].astype(dt),
                  p["w2"].astype(dt))


def init_moe(key, cfg: LMConfig):
    e: MoEConfig = cfg.moe
    d, f = cfg.d_model, e.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(ks[0], (d, e.num_experts),
                                    jnp.float32) * d ** -0.5,
        "w1": jax.random.normal(ks[1], (e.num_experts, d, f),
                                jnp.float32) * d ** -0.5,
        "w3": jax.random.normal(ks[2], (e.num_experts, d, f),
                                jnp.float32) * d ** -0.5,
        "w2": jax.random.normal(ks[3], (e.num_experts, f, d),
                                jnp.float32) * f ** -0.5,
    }
    if e.num_shared:
        p["shared"] = init_dense_ffn(ks[4], d, e.num_shared * f)
    return p


def moe_ffn(p: Params, x: jax.Array, cfg: LMConfig
            ) -> tuple[jax.Array, jax.Array]:
    """Positional top-k MoE.  Returns (output, aux_loss).

    Dispatch = the paper's positional discipline: positions sorted by expert
    (``sort_positions_by_key``), ONE gather into (E, C, d) contiguous expert
    blocks, batched expert GEMMs, ONE weighted scatter back.
    """
    e: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = e.top_k
    n_e = e.num_experts
    cap = int(e.capacity_factor * t * k / n_e + 1)
    cap = max(8, -(-cap // 8) * 8)                   # round up, MXU-friendly
    dt = x.dtype

    xt = x.reshape(t, d)
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)             # (T, k)
    gates = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(t * k)
    order, counts = sort_positions_by_key(flat_e, n_e)     # paper primitive
    starts = jnp.cumsum(counts) - counts
    sorted_e = flat_e[order]
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, n_e * cap)
    token_of = (order // k).astype(jnp.int32)

    dispatch = jnp.full((n_e * cap,), t, jnp.int32).at[slot].set(
        jnp.where(keep, token_of, t), mode="drop")
    gate_sorted = gates.reshape(t * k)[order].astype(dt)

    if cfg.moe_shard_axis is None:
        # paper-faithful baseline path (EXPERIMENTS.md §Perf HC2 baseline):
        # slot-gather combine; GSPMD resolves the cross-shard gathers with
        # zero-fill + all-reduce of (T*k, d) f32 partials.
        xg = jnp.take(xt, jnp.minimum(dispatch, t - 1), axis=0)
        xg = jnp.where((dispatch < t)[:, None], xg, 0).reshape(n_e, cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg,
                                   p["w1"].astype(dt))) * \
            jnp.einsum("ecd,edf->ecf", xg, p["w3"].astype(dt))
        y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt)).reshape(
            n_e * cap, d)
        y_rows = jnp.take(y, jnp.minimum(slot, n_e * cap - 1), axis=0)
        out = jnp.zeros((t, d), dt).at[jnp.where(keep, token_of, t)].add(
            y_rows * jnp.where(keep, gate_sorted, 0)[:, None], mode="drop")
    else:
        # staged expert-parallel dispatch (beyond-paper §Perf HC2):
        #  1. gather stays in token-row sharding (all-gather of bf16
        #     activations, not f32 zero-fill all-reduce);
        #  2. one explicit reshard token-rows -> expert-major (all-to-all);
        #  3. combine scatters expert outputs DIRECTLY to tokens (no
        #     (T*k, d) slot-gather intermediate at all).
        from jax.sharding import PartitionSpec as _P
        ax = cfg.moe_shard_axis
        dpx = tuple(cfg.moe_data_axes.split(",")) if cfg.moe_data_axes \
            else None
        p_rows = _P(dpx, None) if dpx else _P(None, None)
        wsc = jax.lax.with_sharding_constraint

        xg_flat = jnp.take(xt, jnp.minimum(dispatch, t - 1), axis=0)
        xg_flat = jnp.where((dispatch < t)[:, None], xg_flat, 0)
        xg_flat = wsc(xg_flat, p_rows)                  # token-row sharded
        xg = wsc(xg_flat.reshape(n_e, cap, d), _P(ax, None, None))
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg,
                                   p["w1"].astype(dt))) * \
            jnp.einsum("ecd,edf->ecf", xg, p["w3"].astype(dt))
        y = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt))
        y = wsc(y, _P(ax, None, None))                  # expert-major
        yflat = wsc(y.reshape(n_e * cap, d), p_rows)    # all-to-all back
        gate_by_slot = jnp.zeros((n_e * cap,), dt).at[slot].set(
            jnp.where(keep, gate_sorted, 0), mode="drop")
        out = jnp.zeros((t, d), dt).at[dispatch].add(
            yflat * gate_by_slot[:, None], mode="drop")
        out = wsc(out, p_rows)

    if e.num_shared:
        out = out + dense_ffn(p["shared"], xt)

    # GShard/Switch load-balance auxiliary
    frac = counts.astype(jnp.float32) / jnp.maximum(t * k, 1)
    pmean = probs.mean(axis=0)
    aux = n_e * jnp.sum(frac * pmean) * e.router_aux_weight
    return out.reshape(b, s, d), aux
