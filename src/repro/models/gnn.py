"""GNN zoo: GatedGCN, GraphSAGE, EGNN, GAT — on positional message passing.

Message passing here IS the paper's positional discipline: an edge list is a
join index (positions into the node table), aggregation is a positional join
(``spmm_segment`` / ``segment_sum``), and node features are materialized by
gathers only where touched.  JAX has no sparse message-passing primitive —
this module (plus the ``spmm_segment``/``embedding_bag`` kernels) is the
framework's own, per the assignment.

All four architectures share one interface:
  ``init_gnn(key, cfg, d_feat, num_classes)`` / ``gnn_forward(params, cfg,
  graph)`` where ``graph`` = dict(src, dst, feats[, coords, efeat, mask]).
Sampled minibatches (GraphSAGE fan-out blocks) use ``sage_block_forward``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.kernels.spmm_segment import spmm_segment

Params = Dict[str, Any]


def _dense(key, din, dout):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (din, dout), jnp.float32)
            * (2.0 / din) ** 0.5,
            "b": jnp.zeros((dout,), jnp.float32)}


def _apply_dense(p, x):
    return x @ p["w"] + p["b"]


def _mlp(key, dims):
    keys = jax.random.split(key, len(dims) - 1)
    return [_dense(k, a, b) for k, a, b in zip(keys, dims[:-1], dims[1:])]


def _apply_mlp(ps, x, act=jax.nn.silu, final_act=False):
    for i, p in enumerate(ps):
        x = _apply_dense(p, x)
        if i < len(ps) - 1 or final_act:
            x = act(x)
    return x


def segment_softmax(scores: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    smax = jax.ops.segment_max(scores, seg, num_segments=num)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    e = jnp.exp(scores - smax[seg])
    den = jax.ops.segment_sum(e, seg, num_segments=num)
    return e / jnp.maximum(den[seg], 1e-12)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

def init_gatedgcn_layer(key, d):
    ks = jax.random.split(key, 5)
    return {"A": _dense(ks[0], d, d), "B": _dense(ks[1], d, d),
            "C": _dense(ks[2], d, d), "U": _dense(ks[3], d, d),
            "V": _dense(ks[4], d, d),
            "ln_h": jnp.ones((d,)), "ln_e": jnp.ones((d,))}


def gatedgcn_layer(p, h, e, src, dst, n, *, use_pallas=False):
    """Bresson & Laurent gated graph conv with edge features + residuals."""
    eh = _apply_dense(p["A"], h)[src] + _apply_dense(p["B"], h)[dst] \
        + _apply_dense(p["C"], e)
    eta = jax.nn.sigmoid(eh)                                  # (E, d)
    vh = _apply_dense(p["V"], h)
    num = jax.ops.segment_sum(eta * vh[src], dst, num_segments=n)
    den = jax.ops.segment_sum(eta, dst, num_segments=n)
    agg = num / (den + 1e-6)
    h2 = _apply_dense(p["U"], h) + agg
    h2 = h + jax.nn.relu(h2 * p["ln_h"] /
                         (jnp.linalg.norm(h2, axis=-1, keepdims=True) /
                          jnp.sqrt(h2.shape[-1]) + 1e-6))
    e2 = e + jax.nn.relu(eh * p["ln_e"] /
                         (jnp.linalg.norm(eh, axis=-1, keepdims=True) /
                          jnp.sqrt(eh.shape[-1]) + 1e-6))
    return h2, e2


def init_sage_layer(key, din, dout):
    k1, k2 = jax.random.split(key)
    return {"self": _dense(k1, din, dout), "nbr": _dense(k2, din, dout)}


def sage_layer(p, h, src, dst, n, *, use_pallas=False):
    deg = jax.ops.segment_sum(jnp.ones_like(src, dtype=h.dtype), dst,
                              num_segments=n)
    mean = spmm_segment(h, src, dst, None, n, use_pallas=use_pallas) / \
        jnp.maximum(deg, 1.0)[:, None]
    return jax.nn.relu(_apply_dense(p["self"], h) + _apply_dense(p["nbr"],
                                                                 mean))


def init_egnn_layer(key, d):
    ks = jax.random.split(key, 3)
    return {"phi_e": _mlp(ks[0], (2 * d + 1, d, d)),
            "phi_x": _mlp(ks[1], (d, d, 1)),
            "phi_h": _mlp(ks[2], (2 * d, d, d))}


def egnn_layer(p, h, x, src, dst, n):
    """E(n)-equivariant layer (Satorras et al.): scalar messages from
    invariant distances; coordinate updates along edge vectors."""
    dx = x[src] - x[dst]
    d2 = jnp.sum(dx * dx, axis=-1, keepdims=True)
    m = _apply_mlp(p["phi_e"], jnp.concatenate([h[src], h[dst], d2], -1),
                   final_act=True)
    coef = jnp.tanh(_apply_mlp(p["phi_x"], m))               # bounded update
    deg = jax.ops.segment_sum(jnp.ones((src.shape[0],), x.dtype), dst,
                              num_segments=n)
    xup = jax.ops.segment_sum(dx * coef, dst, num_segments=n) / \
        jnp.maximum(deg, 1.0)[:, None]
    magg = jax.ops.segment_sum(m, dst, num_segments=n)
    h2 = h + _apply_mlp(p["phi_h"], jnp.concatenate([h, magg], -1))
    return h2, x + xup


def init_gat_layer(key, din, dout, heads):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": jax.random.normal(k1, (din, heads, dout), jnp.float32)
            * (2.0 / din) ** 0.5,
            "a_src": jax.random.normal(k2, (heads, dout), jnp.float32) * 0.1,
            "a_dst": jax.random.normal(k3, (heads, dout), jnp.float32) * 0.1}


def gat_layer(p, h, src, dst, n, *, concat=True):
    """SDDMM edge scores -> segment softmax -> weighted aggregation."""
    z = jnp.einsum("nd,dhk->nhk", h, p["w"])                  # (N, H, K)
    s_src = jnp.einsum("nhk,hk->nh", z, p["a_src"])
    s_dst = jnp.einsum("nhk,hk->nh", z, p["a_dst"])
    scores = jax.nn.leaky_relu(s_src[src] + s_dst[dst], 0.2)  # (E, H)
    heads = scores.shape[1]
    alphas = []
    for hh in range(heads):                                   # static unroll
        alphas.append(segment_softmax(scores[:, hh], dst, n))
    alpha = jnp.stack(alphas, axis=1)                          # (E, H)
    msg = z[src] * alpha[..., None]
    agg = jax.ops.segment_sum(msg, dst, num_segments=n)        # (N, H, K)
    if concat:
        return jax.nn.elu(agg.reshape(n, -1))
    return agg.mean(axis=1)


# ---------------------------------------------------------------------------
# full models
# ---------------------------------------------------------------------------

def init_gnn(key, cfg: GNNConfig, d_feat: int, num_classes: int) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 3)
    d = cfg.d_hidden
    p: Params = {"embed_in": _dense(ks[-1], d_feat, d),
                 "head": _dense(ks[-2], d, num_classes)}
    if cfg.kind == "gatedgcn":
        p["edge_in"] = _dense(ks[-3], 1, d)
        p["layers"] = [init_gatedgcn_layer(ks[i], d)
                       for i in range(cfg.n_layers)]
    elif cfg.kind == "graphsage":
        p["layers"] = [init_sage_layer(ks[i], d, d)
                       for i in range(cfg.n_layers)]
    elif cfg.kind == "egnn":
        p["layers"] = [init_egnn_layer(ks[i], d)
                       for i in range(cfg.n_layers)]
    elif cfg.kind == "gat":
        heads = cfg.n_heads
        p["layers"] = [init_gat_layer(ks[i], d if i == 0 else d * heads,
                                      d, heads)
                       for i in range(cfg.n_layers - 1)]
        p["layers"].append(init_gat_layer(ks[cfg.n_layers - 1],
                                          d * heads if cfg.n_layers > 1
                                          else d, d, heads))
        p["head"] = _dense(ks[-2], d * heads, num_classes)
    else:
        raise ValueError(cfg.kind)
    return p


def gnn_forward(params: Params, cfg: GNNConfig, graph: Dict[str, jax.Array],
                *, use_pallas: bool = False) -> jax.Array:
    """graph: src, dst (E,) int32; feats (N, F); [coords (N, 3)].
    Returns per-node logits (N, num_classes)."""
    src, dst = graph["src"], graph["dst"]
    n = graph["feats"].shape[0]
    h = _apply_dense(params["embed_in"], graph["feats"])
    if cfg.kind == "gatedgcn":
        e = _apply_dense(params["edge_in"],
                         jnp.ones((src.shape[0], 1), h.dtype))
        for lp in params["layers"]:
            h, e = gatedgcn_layer(lp, h, e, src, dst, n,
                                  use_pallas=use_pallas)
    elif cfg.kind == "graphsage":
        for lp in params["layers"]:
            h = sage_layer(lp, h, src, dst, n, use_pallas=use_pallas)
    elif cfg.kind == "egnn":
        x = graph["coords"]
        for lp in params["layers"]:
            h, x = egnn_layer(lp, h, x, src, dst, n)
    elif cfg.kind == "gat":
        for i, lp in enumerate(params["layers"]):
            h = gat_layer(lp, h, src, dst, n,
                          concat=True)
    return _apply_dense(params["head"], h)


# ---------------------------------------------------------------------------
# sampled-block forward (GraphSAGE minibatch; the paper's PRecursive applied
# to neighbor sampling)
# ---------------------------------------------------------------------------

def sage_block_forward(params: Params, cfg: GNNConfig,
                       block: Dict[str, jax.Array]) -> jax.Array:
    """block: layer_feats = [h_L ... h_0] outermost-first node features
    (gathered late by the sampler), fanouts static.  Layer l aggregates the
    fan-out children of each layer-(l-1) node by mean."""
    feats = block["layer_feats"]          # list; feats[i]: (N_i, F)
    fanouts = cfg.sample_sizes
    hs = [_apply_dense(params["embed_in"], f) for f in feats]
    # hs[0] = deepest (largest) layer ... hs[-1] = seeds
    for li, lp in enumerate(params["layers"]):
        nxt = []
        for depth in range(len(hs) - 1):
            child = hs[depth]             # (N * f, d)
            parent = hs[depth + 1]        # (N, d)
            n_par = parent.shape[0]
            f = child.shape[0] // n_par
            seg = jnp.repeat(jnp.arange(n_par, dtype=jnp.int32), f)
            mean = jax.ops.segment_sum(child, seg, num_segments=n_par) / f
            nxt.append(jax.nn.relu(_apply_dense(lp["self"], parent)
                                   + _apply_dense(lp["nbr"], mean)))
        hs = nxt
    return _apply_dense(params["head"], hs[-1])


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def node_xent(logits: jax.Array, labels: jax.Array,
              mask: jax.Array | None = None) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    per = lse - gold
    if mask is not None:
        return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return per.mean()


def make_gnn_train_step(cfg: GNNConfig, optimizer, *, block: bool = False):
    def loss_fn(params, batch):
        if block:
            logits = sage_block_forward(params, cfg, batch)
            return node_xent(logits, batch["labels"]), logits
        logits = gnn_forward(params, cfg, batch)
        return node_xent(logits, batch["labels"],
                         batch.get("mask")), logits

    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, gnorm = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step
