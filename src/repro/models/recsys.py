"""DeepFM over one huge row-sharded embedding table.

All 39 fields (13 bucketized numeric + 26 categorical) share a single
concatenated table with static per-field offsets: ids+offsets are positions
into that table — the framework's purest instance of the paper's positional
late-materialization (rows are gathered only where hit; under a sharded
mesh only positions cross the network).  The lookup runs through the
``embedding_bag``/``late_gather`` kernels.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.data.recsys_stream import vocab_sizes

Params = Dict[str, Any]

N_BUCKETS_DENSE = 1000


def field_vocabs(cfg: RecsysConfig) -> list[int]:
    return [N_BUCKETS_DENSE] * cfg.n_dense + vocab_sizes(cfg.vocab_scale)


def field_offsets(cfg: RecsysConfig) -> np.ndarray:
    v = field_vocabs(cfg)
    return np.concatenate([[0], np.cumsum(v)[:-1]]).astype(np.int32)


def total_rows(cfg: RecsysConfig) -> int:
    """Table rows padded to a mesh-friendly multiple (512 covers every axis
    size we deploy) so row-wise model-parallel sharding always divides."""
    raw = int(sum(field_vocabs(cfg)))
    return -(-raw // 512) * 512


def init_deepfm(key, cfg: RecsysConfig) -> Params:
    rows = total_rows(cfg)
    nf = cfg.n_dense + cfg.n_sparse
    ks = jax.random.split(key, 3 + len(cfg.mlp_dims) + 1)
    mlp_dims = (nf * cfg.embed_dim, *cfg.mlp_dims, 1)
    mlp = []
    for i, (a, b) in enumerate(zip(mlp_dims[:-1], mlp_dims[1:])):
        k1, k2 = jax.random.split(ks[3 + i])
        mlp.append({"w": jax.random.normal(k1, (a, b), jnp.float32)
                    * (2.0 / a) ** 0.5,
                    "b": jnp.zeros((b,), jnp.float32)})
    tdt = jnp.dtype(cfg.table_dtype)
    return {
        "table": (jax.random.normal(ks[0], (rows, cfg.embed_dim),
                                    jnp.float32) * 0.01).astype(tdt),
        "first_order": (jax.random.normal(ks[1], (rows,), jnp.float32)
                        * 0.01).astype(tdt),
        "bias": jnp.zeros((), jnp.float32),
        "mlp": mlp,
    }


def featurize(cfg: RecsysConfig, dense: jax.Array, sparse: jax.Array,
              offsets: jax.Array) -> jax.Array:
    """-> (B, 39) positions into the shared table (the positional step)."""
    buckets = jnp.clip(((jax.nn.sigmoid(dense) * N_BUCKETS_DENSE)
                        .astype(jnp.int32)), 0, N_BUCKETS_DENSE - 1)
    ids = jnp.concatenate([buckets, sparse], axis=1)
    return ids + offsets[None, :]


def deepfm_forward(params: Params, cfg: RecsysConfig, dense: jax.Array,
                   sparse: jax.Array, offsets: jax.Array,
                   *, use_pallas: bool = False) -> jax.Array:
    """-> (B,) logits."""
    b = dense.shape[0]
    pos = featurize(cfg, dense, sparse, offsets)              # (B, 39)
    if use_pallas:
        from repro.kernels.embedding_bag import fixed_hot_lookup
        emb = fixed_hot_lookup(params["table"], pos, use_pallas=True)
    else:
        emb = jnp.take(params["table"], pos, axis=0)          # (B, 39, D)
    emb = emb.astype(jnp.float32)
    fo = jnp.take(params["first_order"], pos, axis=0).astype(
        jnp.float32).sum(axis=1)                                    # (B,)
    # FM second order: ½[(Σv)² − Σv²] summed over embed dim
    s = emb.sum(axis=1)
    fm2 = 0.5 * (jnp.sum(s * s, -1) - jnp.sum(emb * emb, (-1, -2)))
    h = emb.reshape(b, -1)
    for i, lp in enumerate(params["mlp"]):
        h = h @ lp["w"] + lp["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
    return params["bias"] + fo + fm2 + h[:, 0]


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_deepfm_train_step(cfg: RecsysConfig, optimizer,
                           *, use_pallas: bool = False):
    def loss_fn(params, batch):
        logits = deepfm_forward(params, cfg, batch["dense"], batch["sparse"],
                                batch["offsets"], use_pallas=use_pallas)
        return bce_loss(logits, batch["label"])

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, gnorm = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


def _dedup_positions(pos_flat: jax.Array, grads_flat: jax.Array,
                     num_rows: int):
    """Aggregate duplicate row positions (paper discipline: sort positions,
    segment-sum values).  Returns (unique_pos (N,), agg_grads (N, ...)) with
    sentinel ``num_rows`` padding past the unique count."""
    n = pos_flat.shape[0]
    order = jnp.argsort(pos_flat, stable=True)
    ps = pos_flat[order]
    gs = grads_flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ps[1:] != ps[:-1]])
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1           # (n,)
    agg = jax.ops.segment_sum(gs, seg, num_segments=n)
    upos = jnp.full((n,), num_rows, jnp.int32).at[seg].set(ps, mode="drop")
    return upos, agg


def make_deepfm_train_step_lazy(cfg: RecsysConfig, opt, mesh=None,
                                model_axis: str = "model"):
    """Beyond-paper §Perf optimization: POSITIONAL optimizer updates.

    The dense AdamW step streams the full 33.8M-row table + both moments
    every step even though a 65k batch touches <0.7% of rows.  Here the
    table and first_order params (and their moments) receive row-sparse
    updates at exactly the touched positions — the paper's
    late-materialization discipline applied to the optimizer.  Weight decay
    is lazy (applied only to touched rows), the standard trade-off of
    sparse optimizers.  ``opt`` supplies the AdamW hyperparameters; dense
    (small) params still take the ordinary dense update.

    With ``mesh`` given (iteration 3 of §Perf HC3), the row update runs
    inside ``shard_map`` over the table's row-sharding axis: the small
    (position, aggregated-grad) lists are replicated once and every shard
    updates ONLY its own row range locally — positions cross the mesh,
    table/moment values never do (the paper's distributed discipline,
    applied to the optimizer), and GSPMD's zero-fill all-reduce fallback
    for cross-shard scatters disappears.
    """

    def loss_from_rows(small, emb_rows, fo_rows, batch):
        b = batch["dense"].shape[0]
        fo = fo_rows.sum(axis=1)
        s = emb_rows.sum(axis=1)
        fm2 = 0.5 * (jnp.sum(s * s, -1) - jnp.sum(emb_rows * emb_rows,
                                                  (-1, -2)))
        h = emb_rows.reshape(b, -1)
        for i, lp in enumerate(small["mlp"]):
            h = h @ lp["w"] + lp["b"]
            if i < len(small["mlp"]) - 1:
                h = jax.nn.relu(h)
        logits = small["bias"] + fo + fm2 + h[:, 0]
        return bce_loss(logits, batch["label"])

    def step(params, opt_state, batch):
        rows_n = params["table"].shape[0]
        pos = featurize(cfg, batch["dense"], batch["sparse"],
                        batch["offsets"])                    # (B, F)
        emb_rows = jnp.take(params["table"], pos, axis=0).astype(
            jnp.float32)                                     # ONE gather
        fo_rows = jnp.take(params["first_order"], pos, axis=0).astype(
            jnp.float32)
        small = {"mlp": params["mlp"], "bias": params["bias"]}

        loss, (g_small, g_emb, g_fo) = jax.value_and_grad(
            loss_from_rows, argnums=(0, 1, 2))(small, emb_rows, fo_rows,
                                               batch)

        stp = opt_state["step"] + 1
        lr = opt.lr(stp)
        c1 = 1 - opt.b1 ** stp.astype(jnp.float32)
        c2 = 1 - opt.b2 ** stp.astype(jnp.float32)

        def adam_slice(p_rows, g_rows, mu_rows, nu_rows):
            pdt = p_rows.dtype
            p32 = p_rows.astype(jnp.float32)
            g32 = g_rows.astype(jnp.float32)
            mu2 = opt.b1 * mu_rows + (1 - opt.b1) * g32
            nu2 = opt.b2 * nu_rows + (1 - opt.b2) * g32 * g32
            upd = (mu2 / c1) / (jnp.sqrt(nu2 / c2) + opt.eps) \
                + opt.weight_decay * p32
            return (p32 - lr * upd).astype(pdt), mu2, nu2

        def lazy_update(name, grads_flat, width):
            pf = pos.reshape(-1)
            gf = grads_flat.reshape((-1,) + ((width,) if width else ()))
            upos, agg = _dedup_positions(pf, gf, rows_n)
            if mesh is None:
                safe = jnp.minimum(upos, rows_n - 1)
                p_rows = jnp.take(params[name], safe, axis=0)
                mu_rows = jnp.take(opt_state["mu"][name], safe, axis=0)
                nu_rows = jnp.take(opt_state["nu"][name], safe, axis=0)
                p2, mu2, nu2 = adam_slice(p_rows, agg, mu_rows, nu_rows)
                new_p = params[name].at[upos].set(p2, mode="drop")
                new_mu = opt_state["mu"][name].at[upos].set(mu2,
                                                            mode="drop")
                new_nu = opt_state["nu"][name].at[upos].set(nu2,
                                                            mode="drop")
                return new_p, new_mu, new_nu
            # owner-local shard_map update: values never cross shards
            from jax.sharding import PartitionSpec as _P
            nsh = mesh.shape[model_axis]
            rows_loc = rows_n // nsh
            upos_r = jax.lax.with_sharding_constraint(upos, _P(None))
            agg_r = jax.lax.with_sharding_constraint(
                agg, _P(*([None] * agg.ndim)))

            def upd_shard(p_loc, mu_loc, nu_loc, up, ag):
                base = jax.lax.axis_index(model_axis) * rows_loc
                lpos = jnp.where((up >= base) & (up < base + rows_loc),
                                 up - base, rows_loc)       # drop-sentinel
                safe = jnp.minimum(lpos, rows_loc - 1)
                pr = jnp.take(p_loc, safe, axis=0)
                mr = jnp.take(mu_loc, safe, axis=0)
                nr = jnp.take(nu_loc, safe, axis=0)
                p2, mu2, nu2 = adam_slice(pr, ag, mr, nr)
                return (p_loc.at[lpos].set(p2, mode="drop"),
                        mu_loc.at[lpos].set(mu2, mode="drop"),
                        nu_loc.at[lpos].set(nu2, mode="drop"))

            row_sp = _P(model_axis, *([None] * (params[name].ndim - 1)))
            rep_i = _P(None)
            rep_g = _P(*([None] * agg.ndim))
            fn = jax.shard_map(
                upd_shard, mesh=mesh,
                in_specs=(row_sp, row_sp, row_sp, rep_i, rep_g),
                out_specs=(row_sp, row_sp, row_sp), check_vma=False)
            return fn(params[name], opt_state["mu"][name],
                      opt_state["nu"][name], upos_r, agg_r)

        new_table, mu_t, nu_t = lazy_update("table", g_emb, cfg.embed_dim)
        new_fo, mu_f, nu_f = lazy_update("first_order", g_fo, 0)

        # dense update for the small params
        def dense_upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu2 = opt.b1 * mu + (1 - opt.b1) * g32
            nu2 = opt.b2 * nu + (1 - opt.b2) * g32 * g32
            upd = (mu2 / c1) / (jnp.sqrt(nu2 / c2) + opt.eps) \
                + opt.weight_decay * p
            return p - lr * upd, mu2, nu2

        flat_p, tdef = jax.tree_util.tree_flatten(small)
        outs = [dense_upd(p, g, mu, nu) for p, g, mu, nu in zip(
            flat_p, jax.tree_util.tree_leaves(g_small),
            jax.tree_util.tree_leaves({"mlp": opt_state["mu"]["mlp"],
                                       "bias": opt_state["mu"]["bias"]}),
            jax.tree_util.tree_leaves({"mlp": opt_state["nu"]["mlp"],
                                       "bias": opt_state["nu"]["bias"]}))]
        new_small = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        mu_small = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        nu_small = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])

        new_params = {"table": new_table, "first_order": new_fo,
                      "mlp": new_small["mlp"], "bias": new_small["bias"]}
        new_state = {
            "mu": {"table": mu_t, "first_order": mu_f,
                   "mlp": mu_small["mlp"], "bias": mu_small["bias"]},
            "nu": {"table": nu_t, "first_order": nu_f,
                   "mlp": nu_small["mlp"], "bias": nu_small["bias"]},
            "step": stp,
        }
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in
                             jax.tree_util.tree_leaves((g_small, g_emb,
                                                        g_fo))))
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return step


def serve_scores(params: Params, cfg: RecsysConfig, dense, sparse, offsets,
                 *, use_pallas: bool = False) -> jax.Array:
    return jax.nn.sigmoid(deepfm_forward(params, cfg, dense, sparse, offsets,
                                         use_pallas=use_pallas))


def retrieval_scores(params: Params, cfg: RecsysConfig, dense, sparse,
                     offsets, cand_ids: jax.Array) -> jax.Array:
    """Score ONE query context against ``n_candidates`` items: the user
    context folds to a single FM vector, candidates are scored with one
    batched dot against their (late-materialized) embedding rows."""
    pos = featurize(cfg, dense, sparse, offsets)              # (1, 39)
    u = jnp.take(params["table"], pos[0], axis=0).sum(axis=0)   # (D,)
    cand = jnp.take(params["table"], cand_ids, axis=0)        # (C, D)
    cand_fo = jnp.take(params["first_order"], cand_ids, axis=0)
    return cand @ u + cand_fo                                  # (C,)
