"""Decoder-only LM: init / train / prefill / decode, scanned over layers.

All five assigned LM architectures (dense GQA: qwen2-0.5b, stablelm-1.6b/12b;
MoE: phi3.5-moe; MLA+MoE: deepseek-v2-lite) instantiate this one module with
different ``LMConfig``s.  Layer params carry a leading ``n_layers`` axis and
the stack is a ``jax.lax.scan`` (with rematerialization for training), so the
lowered HLO stays compact for the 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig

from .layers import (dense_ffn, gqa_attention, init_dense_ffn, init_gqa,
                     init_mla, init_moe, mla_attention, moe_ffn, rmsnorm)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: LMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    attn = init_mla(k1, cfg) if cfg.mla is not None else init_gqa(k1, cfg)
    ffn = init_moe(k2, cfg) if cfg.moe is not None else \
        init_dense_ffn(k2, cfg.d_model, cfg.d_ff)
    return {"attn": attn, "ffn": ffn,
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32)}


def init_lm(key, cfg: LMConfig) -> Params:
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "layers": layers,
        "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
        "unembed": jax.random.normal(ku, (cfg.d_model, cfg.vocab),
                                     jnp.float32) * cfg.d_model ** -0.5,
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_fwd(lp: Params, x: jax.Array, cfg: LMConfig, positions,
               cache=None):
    attn_fn = mla_attention if cfg.mla is not None else gqa_attention
    a, new_cache = attn_fn(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                           cfg, positions=positions, cache=cache)
    h = x + a
    z = rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = moe_ffn(lp["ffn"], z, cfg)
    else:
        f, aux = dense_ffn(lp["ffn"], z), jnp.zeros((), jnp.float32)
    return h + f, aux, new_cache


def forward(params: Params, tokens: jax.Array, cfg: LMConfig,
            *, remat: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> final hidden states (B, S, D) + total aux loss."""
    remat = cfg.remat if remat is None else remat
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = jnp.arange(s)

    def body(carry, lp):
        x, aux = carry
        y, a, _ = _layer_fwd(lp, x, cfg, positions)
        return (y, aux + a), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.dots_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"], unroll=cfg.unroll)
    return rmsnorm(x, params["final_ln"], cfg.norm_eps), aux


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: LMConfig
            ) -> tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked cross-entropy: the (B, S, V) logits tensor never fully
    materializes — the unembed+softmax runs per sequence chunk."""
    h, aux = forward(params, batch["tokens"], cfg)
    b, s, d = h.shape
    ck = min(cfg.loss_chunk, s)
    n = s // ck
    hc = h.reshape(b, n, ck, d).transpose(1, 0, 2, 3)
    lc = batch["labels"].reshape(b, n, ck).transpose(1, 0, 2)
    w = params["unembed"]

    def step(tot, xs):
        hx, lx = xs
        logits = (hx @ w.astype(hx.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc),
                          unroll=cfg.unroll)
    xent = tot / (b * s)
    return xent + aux, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Position-addressed cache.  GQA: a=(L,B,Smax,Hkv,hd) keys, b=values.
    MLA: a=(L,B,Smax,kv_lora) latents, b=(L,B,Smax,rope_dim) rope keys."""

    a: jax.Array
    b: jax.Array
    length: jax.Array       # () int32


def init_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=None) -> KVCache:
    dt = dtype or jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        a = jnp.zeros((cfg.n_layers, batch, max_len, cfg.mla.kv_lora_rank),
                      dt)
        c = jnp.zeros((cfg.n_layers, batch, max_len, cfg.mla.rope_head_dim),
                      dt)
    else:
        a = jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                       cfg.head_dim), dt)
        c = jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                       cfg.head_dim), dt)
    return KVCache(a, c, jnp.zeros((), jnp.int32))


def _block_fwd(params: Params, tokens: jax.Array, cfg: LMConfig,
               cache: KVCache) -> tuple[jax.Array, KVCache]:
    """Run a token block through all layers against the cache (covers both
    prefill, block size S, and decode, block size 1).

    Prefill (S > 1, empty cache) runs the STREAMING attention path (chunked
    online-softmax / q-blocked triangular — same as training) and then
    inserts the fresh K/V (or MLA latents) into the cache; the legacy
    attend-against-the-padded-cache path (kept under
    ``cfg.prefill_via_cache`` as the §Perf HC1 baseline) materializes
    O(S·S_max) scores and round-trips the online-softmax carry."""
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    cur = cache.length
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = cur + jnp.arange(s)
    streaming_prefill = s > 1 and not cfg.prefill_via_cache

    def body(x, xs):
        lp, ca, cb = xs
        if streaming_prefill:               # fresh-context attention
            y, _, (fa, fb) = _layer_fwd(lp, x, cfg, positions)
            na = jax.lax.dynamic_update_slice(
                ca, fa.astype(ca.dtype), (0, cur) + (0,) * (ca.ndim - 2))
            nb = jax.lax.dynamic_update_slice(
                cb, fb.astype(cb.dtype), (0, cur) + (0,) * (cb.ndim - 2))
            return y, (na, nb)
        y, _, new_cache = _layer_fwd(lp, x, cfg, positions,
                                     cache=(ca, cb, cur))
        return y, new_cache

    x, (na, nb) = jax.lax.scan(body, x, (params["layers"], cache.a, cache.b),
                               unroll=cfg.unroll)
    h = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = (h[:, -1] @ params["unembed"].astype(dt)).astype(jnp.float32)
    return logits, KVCache(na, nb, cur + s)


def prefill(params: Params, tokens: jax.Array, cfg: LMConfig,
            max_len: int | None = None) -> tuple[jax.Array, KVCache]:
    """tokens (B, S) -> (last-token logits (B, V), filled cache)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len or s)
    return _block_fwd(params, tokens, cfg, cache)


def decode_step(params: Params, tokens: jax.Array, cache: KVCache,
                cfg: LMConfig) -> tuple[jax.Array, KVCache]:
    """One new token per sequence: tokens (B,) + cache -> logits (B, V)."""
    return _block_fwd(params, tokens[:, None], cfg, cache)


# ---------------------------------------------------------------------------
# train step (optimizer applied by the caller-supplied update fn)
# ---------------------------------------------------------------------------

def make_train_step(cfg: LMConfig, optimizer):
    """Returns ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` — the function the launcher jits/shards."""

    def step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, batch, cfg)
        params, opt_state, gnorm = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, **parts}

    return step
