"""Model zoo: LM transformer (dense/MoE/MLA), GNNs, DeepFM."""
