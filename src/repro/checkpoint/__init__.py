from .store import (save_checkpoint, restore_checkpoint,      # noqa: F401
                    CheckpointManager)
