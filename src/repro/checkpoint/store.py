"""Checkpoint store: atomic, sharded-restore-capable, async-capable.

Format: one ``.npz`` per checkpoint (keyed by flattened pytree paths) plus a
msgpack sidecar with the step, tree structure and original shardings.
Writes go to a temp file and ``os.replace`` into place — a half-written
checkpoint can never be picked up by a restarting job (the fault-tolerance
contract).

``restore_checkpoint(..., shardings=...)`` re-lays leaves onto a *different*
mesh than the one that saved them — the elastic-rescale path (512 -> 256
chips) exercised by the tests.
"""
from __future__ import annotations

import os
import re
import threading
from typing import Any, Callable

import jax
import numpy as np

try:
    import msgpack
except ImportError:                                 # pragma: no cover
    msgpack = None

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)                            # atomic publish
    return path


def _tree_like(tree, flat: dict[str, np.ndarray],
               put: Callable[[str, np.ndarray], Any]):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    tdef = jax.tree_util.tree_structure(tree)
    leaves = []
    for path, _ in paths:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(put(key, flat[key]))
    return jax.tree_util.tree_unflatten(tdef, leaves)


def restore_checkpoint(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``.  If ``shardings`` (a pytree of
    Sharding matching ``like``) is given, each leaf is device_put onto it —
    this is how a checkpoint written on one mesh is resumed on another."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    if shardings is None:
        return _tree_like(like, flat, lambda k, v: jax.numpy.asarray(v))
    shard_flat = {}
    for path_s, leaf in jax.tree_util.tree_flatten_with_path(shardings)[0]:
        shard_flat[_SEP.join(_path_str(p) for p in path_s)] = leaf
    return _tree_like(like, flat,
                      lambda k, v: jax.device_put(v, shard_flat[k]))


class CheckpointManager:
    """Step-indexed manager: rotation, latest lookup, optional async save."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def _existing(self) -> list[tuple[int, str]]:
        out = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d{8})\.npz", f)
            if m:
                out.append((int(m.group(1)),
                            os.path.join(self.directory, f)))
        return sorted(out)

    def latest_step(self) -> int | None:
        ex = self._existing()
        return ex[-1][0] if ex else None

    def save(self, step: int, tree: Any) -> None:
        # snapshot to host BEFORE handing to the writer thread so training
        # can mutate device buffers immediately
        flat_host = _flatten(tree)

        def write():
            path = os.path.join(self.directory, f"ckpt_{step:08d}.npz")
            tmp = path + ".tmp.npz"
            np.savez(tmp, **flat_host)
            os.replace(tmp, path)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any, shardings: Any = None):
        self.wait()
        ex = self._existing()
        if not ex:
            return None, None
        step, path = ex[-1]
        return step, restore_checkpoint(path, like, shardings)

    def _gc(self) -> None:
        ex = self._existing()
        for step, path in ex[:-self.keep] if self.keep else []:
            try:
                os.remove(path)
            except OSError:
                pass
