"""DeepFM: brief training then batched online scoring + retrieval — the
framework's purest late-materialization workload (ids are positions into a
row-sharded table; only hit rows are gathered).

    PYTHONPATH=src python examples/recsys_serve.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.data.recsys_stream import recsys_batch, vocab_sizes
from repro.models.recsys import (field_offsets, init_deepfm,
                                 make_deepfm_train_step, retrieval_scores,
                                 serve_scores, total_rows)
from repro.optim import AdamW, linear_warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=50)
    ap.add_argument("--train-batch", type=int, default=4096)
    ap.add_argument("--serve-batch", type=int, default=512)
    ap.add_argument("--serve-requests", type=int, default=50)
    ap.add_argument("--vocab-scale", type=float, default=0.01,
                    help="1.0 = full 33.8M-row Criteo table")
    args = ap.parse_args()

    cfg = RecsysConfig(name="deepfm", vocab_scale=args.vocab_scale)
    vocabs = vocab_sizes(cfg.vocab_scale)
    print(f"embedding table: {total_rows(cfg):,} rows x {cfg.embed_dim}")
    params = init_deepfm(jax.random.PRNGKey(0), cfg)
    off = jnp.asarray(field_offsets(cfg))
    opt = AdamW(lr=linear_warmup_cosine(1e-3, 10, args.train_steps))
    state = opt.init(params)
    step = jax.jit(make_deepfm_train_step(cfg, opt))

    for s in range(args.train_steps):
        d = recsys_batch(0, s, args.train_batch, vocabs=vocabs)
        batch = {k: jnp.asarray(v) for k, v in d.items()}
        batch["offsets"] = off
        params, state, m = step(params, state, batch)
        if s % 10 == 0:
            print(f"train step {s:3d} loss={float(m['loss']):.4f}")

    # online scoring with latency percentiles
    score = jax.jit(lambda p, d, s: serve_scores(p, cfg, d, s, off))
    lat = []
    for r in range(args.serve_requests):
        d = recsys_batch(1, r, args.serve_batch, vocabs=vocabs)
        dn, sp = jnp.asarray(d["dense"]), jnp.asarray(d["sparse"])
        t0 = time.perf_counter()
        jax.block_until_ready(score(params, dn, sp))
        lat.append((time.perf_counter() - t0) * 1e3)
    lat = np.asarray(lat[3:])                     # drop warmup
    print(f"\nonline scoring B={args.serve_batch}: "
          f"p50={np.percentile(lat,50):.2f}ms p99={np.percentile(lat,99):.2f}ms")

    # retrieval: one query vs 100k candidates
    d = recsys_batch(2, 0, 1, vocabs=vocabs)
    cand = jnp.arange(100_000, dtype=jnp.int32) % total_rows(cfg)
    t0 = time.perf_counter()
    s = jax.block_until_ready(retrieval_scores(
        params, cfg, jnp.asarray(d["dense"]), jnp.asarray(d["sparse"]),
        off, cand))
    print(f"retrieval 100k candidates: {(time.perf_counter()-t0)*1e3:.1f}ms, "
          f"top-5 ids: {np.argsort(np.asarray(s))[-5:][::-1].tolist()}")


if __name__ == "__main__":
    main()
