"""End-to-end driver for the paper's engine: the PLANNER answering a SQL
``WITH RECURSIVE`` query without an engine name (cost-based selection over
all nine pipelines + EXPLAIN), the single-device depth sweep, vmap-BATCHED
multi-root serving (one XLA dispatch answering many users' roots),
direction-aware traversal (outbound / inbound / both), and the DISTRIBUTED
positional BFS on 8 (placeholder) devices — the pattern that runs unchanged
on the 512-chip production mesh.

    PYTHONPATH=src python examples/bfs_traversal.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time                      # noqa: E402

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.core import EngineCaps                            # noqa: E402
from repro.core.distributed_bfs import make_distributed_pbfs  # noqa: E402
from repro.core.engine import (Dataset, RecursiveQuery,      # noqa: E402
                               plan_and_run, plan_repr, run_query,
                               run_query_batch)
from repro.data.treegen import TreeSpec, make_edge_table     # noqa: E402
from repro.launch.mesh import make_mesh                      # noqa: E402
from repro.planner import paper_listing, plan                # noqa: E402


def main():
    spec = TreeSpec(num_vertices=262_145, height=40, payload_cols=8, seed=1)
    table = make_edge_table(spec)
    ds = Dataset.prepare(table, spec.num_vertices)
    caps = EngineCaps(frontier=1 << 16, result=1 << 18)

    print("=== the planner: SQL in, engine choice out ===")
    sql = paper_listing(2, root=0, depth=10, payload_cols=8)
    print(sql)
    report = plan(sql, ds, caps=caps)
    print("ranked:", ", ".join(f"{c.label}~{c.cost.est_us:.0f}us"
                               for c in report.ranked[:4]), "...")
    r = jax.block_until_ready(plan_and_run(sql, ds, caps=caps))
    t0 = time.perf_counter()
    r = jax.block_until_ready(plan_and_run(sql, ds, caps=caps))
    print(f"chose {report.best.label}: {1e3*(time.perf_counter()-t0):7.2f} "
          f"ms  rows={int(r.count)}  depth column 0..",
          int(np.asarray(r.values['depth']).max()), sep="")
    filt = plan_and_run(sql + " WHERE depth <= 3", ds, caps=caps)
    print(f"with WHERE depth <= 3 (pushed into the recursion bound): "
          f"rows={int(filt.count)}")

    print("\n=== single-device PRecursive, depth sweep ===")
    for depth in (5, 10, 20, 40):
        q = RecursiveQuery("precursive", depth, 8, caps)
        r = jax.block_until_ready(run_query(q, ds, 0))
        t0 = time.perf_counter()
        r = jax.block_until_ready(run_query(q, ds, 0))
        print(f"depth {depth:3d}: {1e3*(time.perf_counter()-t0):7.2f} ms  "
              f"rows={int(r.count)} overflow={bool(r.overflow)}")

    print("\n=== batched multi-root serving (one dispatch, 16 users) ===")
    q = RecursiveQuery("precursive", 10, 8, caps)
    roots = jnp.arange(16, dtype=jnp.int32) * 1000
    rb = jax.block_until_ready(run_query_batch(q, ds, roots))   # compile
    t0 = time.perf_counter()
    rb = jax.block_until_ready(run_query_batch(q, ds, roots))
    dt = time.perf_counter() - t0
    print(f"16 roots in one jitted dispatch: {1e3*dt:7.2f} ms "
          f"({1e3*dt/16:6.2f} ms/root), rows per root: "
          f"{np.asarray(rb.count).tolist()}")

    print("\n=== direction-aware traversal (reverse CSR) ===")
    leaf = int(np.asarray(table.column('to'))[-1])
    for direction in ("outbound", "inbound", "both"):
        qd = RecursiveQuery("precursive", 10, 8, caps, direction=direction)
        r = jax.block_until_ready(run_query(qd, ds, leaf))
        print(f"{direction:9s} from vertex {leaf}: rows={int(r.count):6d} "
              f"levels={int(r.depth)} overflow={bool(r.overflow)} "
              f"max_row_depth={int(np.asarray(r.row_depths).max())}")

    print("\n=== the PRecursive plan, derived from the operator pipeline ===")
    print(plan_repr("precursive", 10, 8))

    print("\n=== distributed PRecursive over an 8-device mesh ===")
    mesh = make_mesh((8,), ("data",))
    fn = make_distributed_pbfs(mesh, ("data",), spec.num_vertices,
                               caps=EngineCaps(frontier=1 << 14,
                                               result=1 << 15),
                               max_depth=20, num_payload_cols=8)
    sh = NamedSharding(mesh, P("data"))
    src = jax.device_put(np.asarray(table.column("from")), sh)
    dst = jax.device_put(np.asarray(table.column("to")), sh)
    pay = jax.device_put(
        np.concatenate([np.asarray(table.column("column1"))], axis=1), sh)
    out = jax.block_until_ready(fn(src, dst, pay, jnp.int32(0)))
    t0 = time.perf_counter()
    gpos, vals, counts, depths, ovfs = jax.block_until_ready(
        fn(src, dst, pay, jnp.int32(0)))
    rows = int(np.sum(np.asarray(counts)))
    print(f"20-hop traversal on 8 shards: "
          f"{1e3*(time.perf_counter()-t0):7.2f} ms, rows={rows}")
    print("per-shard result counts:", np.asarray(counts).ravel().tolist())
    print("values materialized shard-locally; only vertex ids crossed the "
          "mesh (one all_gather per level).")


if __name__ == "__main__":
    main()
