"""End-to-end LM training with checkpoint/restart — a ~13M-param qwen2-family
model for a few hundred steps on CPU (crank --d-model/--layers for the ~100M
variant on real hardware; the step code is identical to the production
pjit path).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import LMConfig
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.launch.train import TrainRun
from repro.launch.steps import make_optimizer
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = LMConfig(name="example-lm", n_layers=args.layers,
                   d_model=args.d_model, n_heads=args.d_model // 64,
                   n_kv_heads=max(1, args.d_model // 128),
                   d_ff=args.d_model * 4, vocab=args.vocab, qkv_bias=True,
                   attn_chunk=64, loss_chunk=64)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L x {cfg.d_model}d, vocab {cfg.vocab})")

    opt = make_optimizer()
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    run = TrainRun(cfg, params, opt.init(params),
                   jax.jit(tfm.make_train_step(cfg, opt),
                           donate_argnums=(0, 1)))
    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
    if args.resume:
        like = {"params": run.params, "opt_state": run.opt_state}
        step, restored = mgr.restore_latest(like)
        if restored:
            run.params, run.opt_state = restored["params"], \
                restored["opt_state"]
            run.step = step
            print(f"resumed at step {step}")

    hist = run.run(steps=args.steps, batch=args.batch, seq=args.seq,
                   seed=0, ckpt=mgr, ckpt_every=50,
                   monitor=StragglerMonitor())
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {len(hist)} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
