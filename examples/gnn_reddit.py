"""GraphSAGE minibatch training with the positional neighbor sampler —
the paper's PRecursive engine applied to GNN data loading.

Synthetic graph with Reddit-like statistics (default scaled down for CPU;
--full for 233k nodes / 115M edges).

    PYTHONPATH=src python examples/gnn_reddit.py --steps 100
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core.csr import build_csr
from repro.data.graphgen import make_graph
from repro.data.sampler import gather_block_features, sample_block
from repro.models.gnn import (init_gnn, make_gnn_train_step,
                              sage_block_forward)
from repro.optim import AdamW, linear_warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--edges", type=int, default=400_000)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--full", action="store_true",
                    help="Reddit-scale: 233k nodes / 115M edges")
    args = ap.parse_args()
    if args.full:
        args.nodes, args.edges = 232_965, 114_615_892

    fanout = (15, 10)
    cfg = GNNConfig(name="sage", kind="graphsage", n_layers=2, d_hidden=128,
                    d_feat=64, num_classes=41, sample_sizes=fanout)
    g = make_graph(args.nodes, args.edges, cfg.d_feat,
                   num_classes=cfg.num_classes, seed=0)
    csr = build_csr(jnp.asarray(g.src), args.nodes)
    feats, labels = jnp.asarray(g.feats), jnp.asarray(g.labels)
    dst = jnp.asarray(g.dst)

    params = init_gnn(jax.random.PRNGKey(0), cfg, cfg.d_feat,
                      cfg.num_classes)
    opt = AdamW(lr=linear_warmup_cosine(1e-3, 20, args.steps))
    state = opt.init(params)
    step = jax.jit(make_gnn_train_step(cfg, opt, block=True))

    t0 = time.time()
    for s in range(args.steps):
        key = jax.random.PRNGKey(s)
        seeds = jax.random.randint(key, (args.batch,), 0, args.nodes,
                                   jnp.int32)
        layers = sample_block(key, csr, dst, seeds, fanout)   # positions
        block = {"layer_feats": gather_block_features(feats, layers),
                 "labels": jnp.take(labels, seeds)}           # ONE gather
        params, state, m = step(params, state, block)
        if s % 20 == 0:
            print(f"step {s:4d} loss={float(m['loss']):.4f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps*args.batch/dt:.0f} seeds/s); sampler moved only "
          f"node positions until the final feature gather.")


if __name__ == "__main__":
    main()
