"""Quickstart: the paper's recursive query engines in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import EngineCaps
from repro.core.engine import (ENGINE_NAMES, Dataset, RecursiveQuery,
                               plan_repr, run_query)
from repro.data.treegen import TreeSpec, make_edge_table


def main():
    # a 100k-vertex tree stored as an edge table (id, from, to, name, 4
    # payload columns) — the paper's §5.1 dataset
    spec = TreeSpec(num_vertices=100_000, height=50, payload_cols=4, seed=0)
    ds = Dataset.prepare(make_edge_table(spec), spec.num_vertices)
    caps = EngineCaps(frontier=spec.num_vertices, result=spec.num_vertices)

    print("Query: all edges within 10 hops of vertex 0, all columns.\n")
    print("PRecursive plan (the paper's Fig. 4):")
    print(plan_repr("precursive", 10, 4), "\n")

    for engine in ("precursive", "trecursive", "rowstore", "rowstore_index",
                   "bitmap", "hybrid"):
        q = RecursiveQuery(engine=engine, max_depth=10, payload_cols=4,
                           caps=caps)
        r = jax.block_until_ready(run_query(q, ds, root=0))   # compile
        t0 = time.perf_counter()
        for _ in range(3):
            r = jax.block_until_ready(run_query(q, ds, root=0))
        dt = (time.perf_counter() - t0) / 3
        print(f"{engine:16s} {dt*1e3:8.2f} ms   rows={int(r.count):6d} "
              f"levels={int(r.depth)}")

    # or skip the engine name entirely: the planner prices every pipeline
    # against the graph's statistics and picks one (see docs/planner.md)
    from repro.planner import paper_listing, plan
    report = plan(paper_listing(2, root=0, depth=10, payload_cols=4),
                  ds, caps=caps)
    print("\nplanner ranking: "
          + ", ".join(f"{c.label}~{c.cost.est_us:.0f}us"
                      for c in report.ranked[:3]) + ", ...")


if __name__ == "__main__":
    main()
