"""Per-architecture smoke tests: every assigned (arch x shape) cell runs one
real step on CPU with a reduced same-family config — identical code path to
the production dry-run cell (steps.build_cell)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, cells
from repro.launch.steps import build_cell

SMOKE_CELLS = [(c.arch, c.shape) for c in cells(smoke=True)]


def _finite(tree) -> bool:
    ok = True
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            ok &= bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    return ok


@pytest.mark.parametrize("arch,shape", SMOKE_CELLS)
def test_smoke_cell(arch, shape):
    plan = build_cell(arch, shape, mesh=None, smoke=True, concrete=True)
    fn = jax.jit(plan.fn)
    out = fn(*plan.args)
    if isinstance(out, tuple) and len(out) == 3 and isinstance(out[2], dict):
        params2, opt2, metrics = out           # train step
        assert _finite(metrics), f"{arch}/{shape}: non-finite metrics"
        assert float(metrics["loss"]) > 0
        # params actually changed
        p0 = jax.tree_util.tree_leaves(plan.args[0])[0]
        p1 = jax.tree_util.tree_leaves(params2)[0]
        assert not np.allclose(np.asarray(p0, np.float32),
                               np.asarray(p1, np.float32))
    elif isinstance(out, tuple):
        logits = out[0]                        # prefill/decode
        assert _finite(logits)
        assert logits.ndim == 2
    else:
        assert _finite(out)                    # serve scores


def test_all_assigned_archs_covered():
    archs = {a for a, _ in SMOKE_CELLS}
    assert set(ASSIGNED) <= archs


def test_smoke_grid_is_40_cells_at_full_scale():
    full = list(cells())
    assert len(full) == 40
    skipped = [c for c in full if c.skip]
    # long_500k skipped for the 5 pure full-attention LM archs (DESIGN.md §4)
    assert len(skipped) == 5
    assert all(c.shape == "long_500k" for c in skipped)
