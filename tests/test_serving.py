"""Reach-bucketed batch serving: root-conditional estimates, bucketed
parity, the plan cache, the machine-readable plan, and the batched-driver
early exit.

The load-bearing guarantees:

* bucketed ``run_query_buckets`` is ROW-FOR-ROW identical to a Python loop
  of ``run_query`` over the same roots — all nine engines x every legal
  direction, on random graphs (seeded slice always runs; hypothesis extends
  it when installed);
* bucket caps never exceed the global caps (a bucket can only SHRINK a
  lane's padding, never grow the worst case);
* root-conditional estimates are EXACT for sampled roots and
  degree-conditioned otherwise;
* ``default_caps`` sizes raw UNION ALL walks from the walk profile — a
  cyclic walk legally emitting far more than 4E rows no longer dies with a
  spurious capacity-overflow RuntimeError;
* ``PhysicalChoice.run`` applies one identical root coercion on the kernel
  and non-kernel paths;
* the serving session caches plans per (shape, direction, bucket
  signature) and its JSON plan round-trips through ``json.dumps``;
* the batched fixed-point driver freezes converged lanes (per-lane depth
  is exact, not the bucket's worst).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import (ENGINE_NAMES, Dataset, RecursiveQuery,
                               run_query, run_query_batch,
                               run_query_buckets)
from repro.core.table import ColumnTable
from repro.data.treegen import TreeSpec, make_edge_table
from repro.planner import (ServingSession, bucket_roots, default_caps,
                           paper_listing, plan, root_estimates, to_json)
from repro.planner.ast import LogicalQuery
from repro.planner.optimize import RootBucket

CAPS = EngineCaps(frontier=2048, result=4096)


def _edge_dataset(src, dst, num_vertices, payload_cols=0):
    e = len(src)
    cols = {
        "id": np.arange(e, dtype=np.int32),
        "from": np.asarray(src, np.int32),
        "to": np.asarray(dst, np.int32),
        "name": np.zeros((e, 4), np.float32)}
    for i in range(payload_cols):
        cols[f"column{i + 1}"] = np.full((e,), float(i), np.float32)
    return Dataset.prepare(ColumnTable.from_numpy(cols), num_vertices)


@pytest.fixture(scope="module")
def tree_ds():
    spec = TreeSpec(num_vertices=3000, height=10, payload_cols=2, seed=11)
    return Dataset.prepare(make_edge_table(spec), spec.num_vertices)


def _assert_same_result(got, want, key):
    n = int(want.count)
    assert int(got.count) == n, key
    assert int(got.depth) == int(want.depth), key
    for k in want.values:
        assert np.array_equal(np.asarray(got.values[k])[:n],
                              np.asarray(want.values[k])[:n]), (key, k)
    if want.row_depths is not None:
        assert np.array_equal(np.asarray(got.row_depths)[:n],
                              np.asarray(want.row_depths)[:n]), key


# ---------------------------------------------------------------------------
# root-conditional estimates
# ---------------------------------------------------------------------------

def test_root_estimate_exact_for_sampled_roots(tree_ds):
    stats = tree_ds.stats("outbound")
    assert stats.root_profiles, "sample profiles must be recorded"
    root, profile = stats.root_profiles[0]
    est = stats.estimate_root(root, out_degree=1, max_depth=4)
    assert est.exact
    assert est.reach_rows == float(sum(profile[:5]))
    assert est.max_level_rows == float(max(profile[:5], default=0))


def test_root_estimate_degree_conditioned_for_unsampled(tree_ds):
    stats = tree_ds.stats("outbound")
    sampled = {r for r, _ in stats.root_profiles}
    indptr = np.asarray(tree_ds.context("outbound").csr.indptr)
    unsampled = next(v for v in range(tree_ds.num_vertices)
                     if v not in sampled and indptr[v + 1] - indptr[v] > 0)
    deg = int(indptr[unsampled + 1] - indptr[unsampled])
    est = stats.estimate_root(unsampled, deg, max_depth=6)
    assert not est.exact
    assert est.reach_rows >= deg          # level 0 is the degree, exactly
    # a leaf predicts zero reach, exactly
    leaf = next(v for v in range(tree_ds.num_vertices)
                if indptr[v + 1] - indptr[v] == 0)
    leaf_est = stats.estimate_root(leaf, 0, max_depth=6)
    assert leaf_est.exact and leaf_est.reach_rows == 0.0


def test_root_estimates_batch_helper(tree_ds):
    ests = root_estimates(tree_ds, "outbound", [0, 1, 2999], max_depth=4)
    assert len(ests) == 3
    assert [e.root for e in ests] == [0, 1, 2999]
    assert all(e.reach_rows >= 0 for e in ests)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_caps_never_exceed_global(tree_ds):
    roots = [0, 1, 5, 77, 500, 1500, 2999]
    buckets = bucket_roots(tree_ds, roots, direction="outbound",
                           max_depth=6, dedup=True, caps=CAPS,
                           max_buckets=4)
    assert 1 <= len(buckets) <= 4
    covered = sorted(i for b in buckets for i in b.indices)
    assert covered == list(range(len(roots)))
    for b in buckets:
        assert b.caps.frontier <= CAPS.frontier
        assert b.caps.result <= CAPS.result
        for lane in b.indices:
            assert b.roots[b.indices.index(lane)] == roots[lane]


def test_bucket_roots_union_all_falls_back_to_single_bucket(tree_ds):
    buckets = bucket_roots(tree_ds, [0, 1, 2], direction="outbound",
                           max_depth=3, dedup=False, caps=CAPS)
    assert len(buckets) == 1
    assert buckets[0].caps == CAPS


def test_bucketed_overflow_falls_back_to_global_caps(tree_ds):
    # deliberately absurd bucket caps: the fallback must restore parity
    q = RecursiveQuery(engine="precursive", max_depth=4, payload_cols=0,
                       caps=CAPS)
    roots = (0, 1)
    bad = RootBucket(indices=(0, 1), roots=roots,
                     caps=EngineCaps(frontier=2, result=2),
                     predicted_reach=1.0, predicted_depth=1)
    got = run_query_buckets(q, tree_ds, (bad,))
    for i, r in enumerate(roots):
        _assert_same_result(got[i], run_query(q, tree_ds, r), r)


# ---------------------------------------------------------------------------
# parity property: bucketed batch == sequential loop, all engines
# ---------------------------------------------------------------------------

def _legal(engine, direction, dedup=True):
    if direction != "outbound" and engine.startswith("rowstore"):
        return False
    if not dedup and engine in ("bitmap", "hybrid"):
        return False
    return True


def _check_bucketed_parity(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(6, 60))
    e = int(rng.integers(2, 4 * v))
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    ds = _edge_dataset(src, dst, v)
    depth = int(rng.integers(1, 6))
    nroots = int(rng.integers(2, 7))
    roots = rng.integers(0, v, nroots).tolist()
    caps = EngineCaps(frontier=e + 16, result=e + 16)
    for direction in ("outbound", "inbound", "both"):
        buckets = bucket_roots(ds, roots, direction=direction,
                               max_depth=depth, dedup=True, caps=caps)
        for eng in ENGINE_NAMES:
            if not _legal(eng, direction):
                continue
            q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                               caps=caps, direction=direction)
            got = run_query_buckets(q, ds, buckets)
            assert len(got) == len(roots)
            for i, r in enumerate(roots):
                _assert_same_result(got[i], run_query(q, ds, r),
                                    (eng, direction, r, seed))


@pytest.mark.parametrize("seed", [0, 7])
def test_bucketed_batch_matches_sequential_loop_seeded(seed):
    """Deterministic slice of the property (always runs, even without
    hypothesis)."""
    _check_bucketed_parity(seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    pass
else:
    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_bucketed_batch_matches_sequential_loop_random(seed):
        _check_bucketed_parity(seed)


def test_batched_driver_freezes_converged_lanes(tree_ds):
    """Per-lane depth must be the lane's OWN convergence depth, not the
    bucket's worst — converged lanes are frozen inside the while_loop."""
    q = RecursiveQuery(engine="precursive", max_depth=8, payload_cols=0,
                       caps=CAPS)
    indptr = np.asarray(tree_ds.context("outbound").csr.indptr)
    leaf = next(v for v in range(tree_ds.num_vertices)
                if indptr[v + 1] - indptr[v] == 0)
    roots = [0, leaf]                      # deep hub + depth-0 leaf
    r = run_query_batch(q, tree_ds, roots)
    assert int(r.depth[1]) == 0
    assert int(r.depth[0]) == int(run_query(q, tree_ds, 0).depth)
    assert int(r.depth[0]) > 0


# ---------------------------------------------------------------------------
# satellite: PhysicalChoice.run root coercion, kernel and non-kernel paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("mk_roots", [
    lambda: [1, 2, 5],                               # Python list
    lambda: np.array([1, 2, 5], dtype=np.int64),     # int64 vector
], ids=["pylist", "int64"])
def test_physical_choice_run_coerces_roots(tree_ds, use_kernel, mk_roots):
    sql = paper_listing(1, root=0, depth=3)
    report = plan(sql, tree_ds, caps=CAPS, include_kernel=use_kernel)
    if use_kernel:
        choice = next(c for c in report.ranked if c.use_kernel)
    else:
        choice = next(c for c in report.ranked if not c.use_kernel)
    got = choice.run(tree_ds, mk_roots())
    want = choice.run(tree_ds, np.array([1, 2, 5], dtype=np.int32))
    for i in range(3):
        n = int(np.asarray(want.count)[i])
        assert int(np.asarray(got.count)[i]) == n
        for k in want.values:
            assert np.array_equal(np.asarray(got.values[k])[i][:n],
                                  np.asarray(want.values[k])[i][:n])


# ---------------------------------------------------------------------------
# satellite: cyclic UNION ALL walks are sized from the walk profile
# ---------------------------------------------------------------------------

def _parallel_chain(hops, width=2):
    """A chain of ``hops`` hops with ``width`` parallel edges per hop: a
    depth-d walk emits width^(l+1) rows at level l — far more than 4E."""
    src, dst = [], []
    for h in range(hops):
        for _ in range(width):
            src.append(h)
            dst.append(h + 1)
    return _edge_dataset(src, dst, hops + 1)


def test_union_all_walk_caps_cover_path_blowup():
    hops, depth = 12, 11
    ds = _parallel_chain(hops)
    # rows at level l: 2^(l+1); total over levels 0..11 = 2^13 - 2 = 8190,
    # while 4E = 96 and the old clamp allowed only max(4E, 4096) = 4096
    want_rows = sum(2 ** (l + 1) for l in range(depth + 1))
    lq = LogicalQuery(root=0, max_depth=depth, payload_cols=0, dedup=False,
                      direction="outbound", want_cols=("id", "to"),
                      want_depth=False, union_all=True)
    stats = ds.stats("outbound")
    caps = default_caps(stats, lq)
    assert caps.result >= want_rows
    assert caps.frontier >= 2 ** (depth + 1)
    report = plan(lq, ds)
    r = report.best.run(ds, 0)            # raised RuntimeError before
    assert int(r.count) == want_rows
    assert not bool(np.asarray(r.overflow))


def test_union_all_walk_estimate_extrapolates_past_sample():
    # a doubling RING: walks never die and the sampled walk profile is
    # truncated at its horizon, so a deeper bound must be covered by the
    # geometric extrapolation, not flatline at the sampled sum
    src = [0, 0, 1, 1, 2, 2]
    dst = [1, 1, 2, 2, 0, 0]
    ds = _edge_dataset(src, dst, 3)
    stats = ds.stats("outbound")
    horizon = len(stats.level_walk_edges)
    deeper = horizon + 5
    assert stats.total_walk_rows(deeper) > stats.total_walk_rows(
        horizon - 1) * 8


def test_terminated_walk_does_not_extrapolate(tree_ds):
    """Regression: a walk whose frontier DIED inside the sample horizon
    (e.g. any acyclic graph) must not be geometrically extrapolated — a
    deep depth bound adds nothing past the walk's last live level, so
    non-dedup caps stay proportional to the true walk size."""
    stats = tree_ds.stats("outbound")
    horizon = len(stats.level_walk_edges)
    assert stats.total_walk_rows(horizon + 50) == \
        stats.total_walk_rows(horizon - 1)
    lq = LogicalQuery(root=0, max_depth=horizon + 50, payload_cols=0,
                      dedup=False, direction="outbound",
                      want_cols=("id",), want_depth=False, union_all=True)
    caps = default_caps(stats, lq)
    assert caps.result <= 8 * stats.total_walk_rows(horizon - 1) + 4096


def test_dedup_caps_unchanged_by_walk_sizing(tree_ds):
    lq = LogicalQuery(root=0, max_depth=5, payload_cols=0, dedup=True,
                      direction="outbound", want_cols=("id",),
                      want_depth=False, union_all=False)
    stats = tree_ds.stats("outbound")
    caps = default_caps(stats, lq)
    assert caps.result == stats.num_edges + 8


# ---------------------------------------------------------------------------
# the serving session + plan cache + machine-readable plan
# ---------------------------------------------------------------------------

def _row_set(r):
    """Order-insensitive view of a dressed result: sorted (id, depth)
    pairs.  Session-level parity is row-SET parity — each bucket runs its
    own chosen engine, and engines are free to order rows differently."""
    n = int(r.count)
    ids = np.asarray(r.values["id"])[:n].tolist()
    depths = (np.asarray(r.values["depth"])[:n].tolist()
              if "depth" in r.values else
              np.asarray(r.row_depths)[:n].tolist())
    return sorted(zip(ids, depths))


def test_serving_session_caches_plans(tree_ds):
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    roots = [0, 1, 2, 3]
    first = session.submit(sql, roots)
    again = session.submit(sql, roots)
    assert session.stats["plan_misses"] == 1
    assert session.stats["plan_hits"] == 1
    assert session.stats["cached_shapes"] == 1
    for a, b in zip(first, again):
        _assert_same_result(a, b, "cache hit changed the answer")
    # per-root row-set parity with the planner's single-root path
    for i, r in enumerate(roots):
        want = plan(sql, tree_ds, caps=CAPS).best.run(tree_ds, r)
        assert _row_set(again[i]) == _row_set(want), r


def test_serving_session_rebinds_same_signature(tree_ds):
    """Same shape + same bucket signature with DIFFERENT roots must reuse
    the cached plan (hit) and still answer for the new roots."""
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    session.submit(sql, [10, 11])
    got = session.submit(sql, [12, 13])
    if session.stats["plan_misses"] == 1:      # identical signature
        assert session.stats["plan_hits"] == 1
    for i, r in enumerate([12, 13]):
        want = plan(sql, tree_ds, caps=CAPS).best.run(tree_ds, r)
        assert _row_set(got[i]) == _row_set(want), r


def test_serving_permuted_roots_keep_request_order(tree_ds):
    """Regression: a repeat request whose roots are a PERMUTATION of a
    cached entry's roots (same bucket signature) must still return results
    in ITS OWN request order, not the cached lane mapping's."""
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    fwd = [0, 1]                 # hub first
    rev = [1, 0]                 # hub last — likely the same signature
    a = session.submit(sql, fwd)
    b = session.submit(sql, rev)
    for i in range(2):
        want = plan(sql, tree_ds, caps=CAPS).best.run(tree_ds, rev[i])
        assert _row_set(b[i]) == _row_set(want), rev[i]
    assert _row_set(a[0]) == _row_set(b[1])
    assert _row_set(a[1]) == _row_set(b[0])
    # and an identical repeat is a true hit (no rebind)
    before = session.plan_for(sql, rev).roots
    session.submit(sql, rev)
    assert session.plan_for(sql, rev).roots == before == tuple(rev)


def test_serving_per_bucket_engine_choice(tree_ds):
    """Buckets are re-costed with their own caps AND lane counts: the
    cached plan records one engine per bucket, and every per-bucket
    engine is a legal candidate of the shape-level report — or the
    batch-only bit-parallel ``multiquery`` engine, which only a
    multi-lane bucket can admit (``lanes == len(bucket.roots) > 1``)."""
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    roots = [0, 1, 2, 3]
    session.submit(sql, roots)
    entry = session.plan_for(sql, roots)
    assert len(entry.bucket_choices) == len(entry.buckets)
    legal = {c.label for c in entry.report.ranked}
    for c, b in zip(entry.bucket_choices, entry.buckets):
        if c.label == "multiquery":
            assert c.query.lanes == len(b.roots) > 1
        else:
            assert c.label in legal
    for bj, c in zip(entry.plan_json["buckets"], entry.bucket_choices):
        assert bj["engine"] == c.label


def test_plan_json_schema_and_roundtrip(tree_ds):
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    doc = session.plan_json(sql, [0, 1, 2])
    text = json.dumps(doc)                     # strict-JSON serializable
    doc2 = json.loads(text)
    assert doc2["schema_version"] == 6
    assert doc2["analyze"] is None      # v4: filled by explain_analyze only
    assert doc2["admission"] is None    # v6: stamped by a guarded submit
    assert doc2["chosen"] in [c["label"] for c in doc2["candidates"]]
    assert sum(c["chosen"] for c in doc2["candidates"]) == 1
    assert doc2["logical"]["max_depth"] == 4
    assert doc2["stats"]["num_vertices"] == tree_ds.num_vertices
    # v2: full stats (rehydratable) + the constants the pass priced with
    assert doc2["stats"]["root_profiles"]
    assert "level_walk_edges" in doc2["stats"]
    assert doc2["cost_constants"]["bytes_per_us"] > 0
    for c in doc2["candidates"]:
        assert {"label", "engine", "caps", "cost", "ops"} <= set(c)
        assert c["cost"]["est_us"] > 0
        # the factor-independent byte split is consistent with the total
        kf = doc2["cost_constants"]["kernel_factor"] or 0.0
        assert c["cost"]["total_bytes"] == pytest.approx(
            c["cost"]["plain_bytes"] + kf * c["cost"]["kernel_bytes"])
    lanes = sorted(l for b in doc2["buckets"] for l in b["lanes"])
    assert lanes == [0, 1, 2]
    for b in doc2["buckets"]:
        assert b["caps"]["frontier"] <= CAPS.frontier
        assert b["caps"]["result"] <= CAPS.result


def test_to_json_without_buckets(tree_ds):
    report = plan(paper_listing(1, root=0, depth=4), tree_ds, caps=CAPS)
    doc = to_json(report)
    json.dumps(doc)
    assert "buckets" not in doc
    assert len(doc["candidates"]) == len(report.ranked)


def test_run_bucketed_matches_run(tree_ds):
    sql = paper_listing(2, root=0, depth=5, payload_cols=2)
    report = plan(sql, tree_ds, caps=CAPS)
    roots = [0, 1, 4, 2999]
    per_root = report.best.run_bucketed(tree_ds, roots)
    for i, r in enumerate(roots):
        want = report.best.run(tree_ds, r)
        _assert_same_result(per_root[i], want, r)
