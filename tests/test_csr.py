import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.csr import build_csr, csr_degrees, expand_frontier


def _python_expand(src, targets, valid):
    out = []
    for t, v in zip(targets, valid):
        if not v or t < 0:
            continue
        out.extend(int(i) for i in np.nonzero(src == t)[0])
    return out


def test_csr_structure():
    src = np.array([2, 0, 1, 2, 0, 2], dtype=np.int32)
    csr = build_csr(jnp.asarray(src), 4)
    indptr = np.asarray(csr.indptr)
    perm = np.asarray(csr.perm)
    assert indptr.tolist() == [0, 2, 3, 6, 6]
    for v in range(4):
        got = sorted(perm[indptr[v]:indptr[v + 1]].tolist())
        assert got == sorted(np.nonzero(src == v)[0].tolist())


def test_degrees_invalid_masked():
    src = np.array([0, 0, 1], dtype=np.int32)
    csr = build_csr(jnp.asarray(src), 3)
    deg = csr_degrees(csr, jnp.asarray([0, 1, 2, -5, 99], jnp.int32),
                      jnp.asarray([True, True, True, True, True]))
    assert deg.tolist() == [2, 1, 0, 0, 0]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 123456))
def test_expand_matches_python(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(3, 40))
    e = int(rng.integers(1, 200))
    src = rng.integers(0, v, e).astype(np.int32)
    csr = build_csr(jnp.asarray(src), v)
    f = int(rng.integers(1, 20))
    targets = rng.integers(-1, v, f).astype(np.int32)
    valid = rng.random(f) < 0.8
    ref = _python_expand(src, targets, valid)
    cap = len(ref) + 8           # duplicates in targets re-emit edges
    epos, total, ovf = expand_frontier(csr, jnp.asarray(targets),
                                       jnp.asarray(valid), cap)
    assert int(total) == len(ref)
    assert not bool(ovf)
    got = np.asarray(epos)[:len(ref)]
    # order within each target's range is CSR order; compare as multisets
    # per-target to keep the check strict but order-stable overall
    assert sorted(got.tolist()) == sorted(ref)
    assert np.all(np.asarray(epos)[len(ref):] == e)     # sentinel padding


def test_expand_overflow_flag():
    src = np.zeros(50, dtype=np.int32)                   # all edges from 0
    csr = build_csr(jnp.asarray(src), 2)
    epos, total, ovf = expand_frontier(
        csr, jnp.asarray([0], jnp.int32), jnp.asarray([True]), 10)
    assert bool(ovf)
    assert int(total) == 10                              # clamped
