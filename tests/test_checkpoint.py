import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, restore_checkpoint,
                              save_checkpoint)


def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "layers": [jnp.ones((2,)), jnp.zeros((3,))]},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 7, t)
    like = jax.tree_util.tree_map(jnp.zeros_like, t)
    r = restore_checkpoint(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    files = os.listdir(tmp_path)
    assert files == ["ckpt_00000001.npz"]


def test_manager_rotation_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, {"x": jnp.asarray(float(s))})
    assert m.latest_step() == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["ckpt_00000003.npz", "ckpt_00000004.npz"]
    step, tree = m.restore_latest({"x": jnp.asarray(0.0)})
    assert step == 4 and float(tree["x"]) == 4.0


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=True)
    m.save(5, {"x": jnp.arange(1000.0)})
    m.wait()
    step, tree = m.restore_latest({"x": jnp.zeros(1000)})
    assert step == 5
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.arange(1000.0))


def test_restore_missing_key_raises(tmp_path):
    path = save_checkpoint(str(tmp_path), 1, {"a": jnp.asarray(1.0)})
    with pytest.raises(KeyError):
        restore_checkpoint(path, {"b": jnp.asarray(0.0)})


def test_empty_dir_restore(tmp_path):
    m = CheckpointManager(str(tmp_path))
    step, tree = m.restore_latest({"x": jnp.asarray(0.0)})
    assert step is None and tree is None
