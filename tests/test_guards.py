"""Admission guard ladder + front-door validation.

The load-bearing guarantees:

* the ladder is a PURE function of (estimate, constants, max_depth):
  deterministic for a fixed (graph digest, constants) pair;
* MONOTONE: tightening either budget can only move a root DOWN the ladder
  (traverse -> degrade -> reject) — never reject -> traverse;
* a DEGRADED answer is a depth-truncation PREFIX of the full traversal:
  exactly the rows an unguarded run of the same query at ``max_depth =
  clamp_depth`` returns, never a different row set;
* the front door rejects malformed input (bad roots, non-positive depth,
  unknown columns, oversized enqueue batches) with TYPED errors before
  tracing or JIT — not as opaque shape errors deep inside a dispatch;
* default budgets admit every root of the test graphs (guards are
  invisible until a root is actually expensive).
"""
import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import Dataset, WORD_LANES
from repro.data.treegen import TreeSpec, make_edge_table
from repro.planner import ServingSession, paper_listing
from repro.planner.ast import ParseError
from repro.planner.calibrate import Calibrator
from repro.planner.cost import CostConstants, DEFAULT_CONSTANTS
from repro.planner.guards import (AdmissionError, GuardResult,
                                  InvalidRequestError, admit_roots, decide,
                                  guard_cost_us)
from repro.planner.stats import RootEstimate

CAPS = EngineCaps(frontier=2048, result=4096)
RANK = {"traverse": 0, "degrade": 1, "reject": 2}


@pytest.fixture(scope="module")
def tree_ds():
    spec = TreeSpec(num_vertices=3000, height=10, payload_cols=2, seed=11)
    return Dataset.prepare(make_edge_table(spec), spec.num_vertices)


def _ids(r):
    return sorted(np.asarray(r.values["id"])[:int(r.count)].tolist())


def _tight(degrade_us, reject_us):
    return DEFAULT_CONSTANTS._replace(guard_degrade_us=float(degrade_us),
                                      guard_reject_us=float(reject_us))


# ---------------------------------------------------------------------------
# the ladder as a pure function
# ---------------------------------------------------------------------------

def test_default_budgets_admit_test_graphs(tree_ds):
    decisions = admit_roots(tree_ds, "outbound", list(range(64)), 7,
                            DEFAULT_CONSTANTS)
    assert [d.decision for d in decisions] == ["traverse"] * 64


def test_ladder_decisions_by_budget():
    est = RootEstimate(root=5, reach_rows=10_000.0, max_level_rows=4000.0,
                       depth=6, exact=True)
    full = guard_cost_us(est, DEFAULT_CONSTANTS, depth=6)
    r = decide(est, _tight(full + 1, full + 2), max_depth=6)
    assert r.decision == "traverse" and r.clamp_depth is None
    r = decide(est, _tight(full - 1, full + 1), max_depth=6)
    assert r.decision == "degrade" and 1 <= r.clamp_depth < 6
    r = decide(est, _tight(full / 4, full - 1), max_depth=6)
    assert r.decision == "reject"


def test_degrade_clamp_is_deepest_fitting_prefix():
    est = RootEstimate(root=0, reach_rows=50_000.0, max_level_rows=9000.0,
                       depth=8, exact=False)
    mid = guard_cost_us(est, DEFAULT_CONSTANTS, depth=5)
    c = _tight(mid, guard_cost_us(est, DEFAULT_CONSTANTS, depth=8) + 1)
    r = decide(est, c, max_depth=8)
    assert r.decision == "degrade"
    assert r.clamp_depth == 5          # cost(5) == budget fits, cost(6) > it
    # a request whose own depth bound already fits the budget traverses
    r2 = decide(est, c, max_depth=3)
    assert r2.decision == "traverse"


def test_guard_cost_monotone_in_depth():
    est = RootEstimate(root=0, reach_rows=7777.0, max_level_rows=900.0,
                       depth=9, exact=False)
    costs = [guard_cost_us(est, DEFAULT_CONSTANTS, depth=d)
             for d in range(1, 10)]
    assert costs == sorted(costs)


def test_reject_carries_the_estimate(tree_ds):
    c = _tight(1e-6, 1e-3)
    session = ServingSession(tree_ds, calibrator=Calibrator(prior=c))
    with pytest.raises(AdmissionError) as ei:
        session.submit(paper_listing(1, root=0, depth=6), [0])
    res = ei.value.result
    assert isinstance(res, GuardResult) and res.decision == "reject"
    assert res.root == 0 and res.est_us > res.threshold_us
    assert session.stats["admission_reject"] == 1


# ---------------------------------------------------------------------------
# properties: monotonicity, determinism (hypothesis or the fallback engine)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                        # pragma: no cover
    pytest.skip("hypothesis unavailable", allow_module_level=True)

# integer-only strategies so the suite ALSO runs under the deterministic
# fallback engine (tests/_hypothesis_fallback.py has no st.floats/builds)
reach_st = st.integers(0, 10**9)
budget_st = st.integers(1, 10**9)          # µs, scaled by 1e-3 below
pct_st = st.integers(0, 100)


def _est(root, reach, depth, exact):
    return RootEstimate(root=root, reach_rows=float(reach),
                        max_level_rows=float(reach) / max(depth, 1),
                        depth=depth, exact=exact)


@settings(max_examples=120, deadline=None)
@given(root=st.integers(0, 999), reach=reach_st,
       depth=st.integers(0, 16), exact=st.booleans(),
       degrade=budget_st, reject=budget_st,
       tighten_d=pct_st, tighten_r=pct_st, max_depth=st.integers(1, 16))
def test_tightening_budgets_never_relaxes_decision(
        root, reach, depth, exact, degrade, reject, tighten_d, tighten_r,
        max_depth):
    est = _est(root, reach, depth, exact)
    loose = _tight(degrade * 1e-3, reject * 1e-3)
    tight = _tight(degrade * 1e-3 * tighten_d / 100.0,
                   reject * 1e-3 * tighten_r / 100.0)
    a = decide(est, loose, max_depth=max_depth)
    b = decide(est, tight, max_depth=max_depth)
    assert RANK[b.decision] >= RANK[a.decision]
    if a.decision == "degrade" and b.decision == "degrade":
        # a tighter degrade budget admits at most the same depth
        assert b.clamp_depth <= a.clamp_depth


@settings(max_examples=60, deadline=None)
@given(root=st.integers(0, 999), reach=reach_st,
       depth=st.integers(0, 16), exact=st.booleans(),
       degrade=budget_st, reject=budget_st, max_depth=st.integers(1, 16))
def test_decision_is_deterministic(root, reach, depth, exact, degrade,
                                   reject, max_depth):
    est = _est(root, reach, depth, exact)
    c = _tight(degrade * 1e-3, reject * 1e-3)
    assert decide(est, c, max_depth=max_depth) \
        == decide(est, c, max_depth=max_depth)


# ---------------------------------------------------------------------------
# degraded answers are depth-truncation prefixes
# ---------------------------------------------------------------------------

def test_degraded_answer_is_depth_prefix(tree_ds):
    sql = paper_listing(1, root=0, depth=6)
    full = ServingSession(tree_ds, caps=CAPS, guards=False)
    want_full = full.submit(sql, [0])[0]

    est = admit_roots(tree_ds, "outbound", [0], 6, DEFAULT_CONSTANTS)[0]
    # budget between cost(1) and full cost -> root 0 degrades
    lo = guard_cost_us(est.estimate, DEFAULT_CONSTANTS, depth=1)
    c = _tight((lo + est.est_us) / 2, est.est_us + 1)
    guarded = ServingSession(tree_ds, caps=CAPS,
                             calibrator=Calibrator(prior=c))
    got = guarded.submit(sql, [0])[0]
    rep = guarded.last_report
    assert rep.degraded_roots and rep.degraded_roots[0][0] == 0
    clamp = rep.degraded_roots[0][1]
    assert 1 <= clamp < 6

    # the degraded rows are EXACTLY the unguarded rows at max_depth=clamp
    want_clamped = full.submit(paper_listing(1, root=0, depth=clamp),
                               [0])[0]
    assert _ids(got) == _ids(want_clamped)
    # ...and a SUBSET (prefix) of the full traversal's rows
    assert set(_ids(got)) <= set(_ids(want_full))
    # stamped into the plan doc (schema v6)
    entry = next(iter(guarded._plans.values()))
    adm = entry.plan_json["admission"]
    assert adm is not None and adm["decisions"][0]["decision"] == "degrade"


# ---------------------------------------------------------------------------
# front-door validation: typed errors before tracing / JIT
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("roots", [[-1], [-7, 0], [3000], [0, 99_999]])
def test_out_of_range_roots_raise(tree_ds, roots):
    session = ServingSession(tree_ds, caps=CAPS)
    with pytest.raises(InvalidRequestError, match="out of range"):
        session.submit(paper_listing(1, root=0, depth=4), roots)


def test_non_integer_roots_raise(tree_ds):
    session = ServingSession(tree_ds, caps=CAPS)
    with pytest.raises(InvalidRequestError, match="integers"):
        session.submit(paper_listing(1, root=0, depth=4), [0.5])


def test_non_positive_depth_raises(tree_ds):
    session = ServingSession(tree_ds, caps=CAPS)
    with pytest.raises(InvalidRequestError, match="max_depth"):
        session.submit(paper_listing(1, root=0, depth=0), [0])


def test_unknown_column_raises_parse_error(tree_ds):
    session = ServingSession(tree_ds, caps=CAPS)
    with pytest.raises(ParseError, match="unknown column"):
        session.submit(paper_listing(2, root=0, depth=4, payload_cols=5),
                       [0])


def test_empty_root_batch_is_a_noop(tree_ds):
    session = ServingSession(tree_ds, caps=CAPS)
    assert session.submit(paper_listing(1, root=0, depth=4), []) == []


def test_enqueue_validates_and_bounds_the_word(tree_ds):
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    with pytest.raises(InvalidRequestError):
        session.enqueue(sql, -1)
    for r in range(WORD_LANES):
        session.enqueue(sql, r)
    with pytest.raises(InvalidRequestError, match="pending"):
        session.enqueue(sql, WORD_LANES)
    assert session.flush() == 1
