"""Roofline machinery: HLO collective parsing, term math, and the affine
trip-count probe algebra validated against a fully-unrolled compile."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (Roofline, parse_collectives,
                                   _bytes_of_type)

HLO_SNIPPET = """
HloModule test
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}
  %ag.1 = bf16[512]{0} all-gather(%p1), dimensions={0}
  %a2a = f32[128,256] all-to-all(%ar), dimensions={0}
  %cp-start = f32[128,256] collective-permute-start(%a2a)
  %cp-done = f32[128,256] collective-permute-done(%cp-start)
  %rs = f32[16,256] reduce-scatter(%a2a), dimensions={0}
}
"""


def test_bytes_of_type():
    assert _bytes_of_type("f32[128,256]") == 128 * 256 * 4
    assert _bytes_of_type("bf16[64]") == 128
    assert _bytes_of_type("(f32[2,2], s32[3])") == 16 + 12
    assert _bytes_of_type("token[]") == 0


def test_parse_collectives_snippet():
    st = parse_collectives(HLO_SNIPPET)
    fb = 128 * 256 * 4
    assert st.bytes_by_kind["all-reduce"] == fb
    assert st.bytes_by_kind["all-gather"] == 128          # operand, not result
    assert st.bytes_by_kind["all-to-all"] == fb
    assert st.bytes_by_kind["collective-permute"] == fb   # start counted once
    assert st.bytes_by_kind["reduce-scatter"] == fb
    assert st.count_by_kind["collective-permute"] == 1


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12 * 512, hbm_bytes=1e9, collective_bytes=1e9,
                 chips=512)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert r.dominant == "compute"
    assert abs(r.fraction_of_roofline() - 1.0) < 1e-9
    r2 = Roofline(flops=1e12, hbm_bytes=819e9 * 512 * 2.0,
                  collective_bytes=0, chips=512)
    assert r2.dominant == "memory"
    assert r2.fraction_of_roofline() < 0.01


def test_affine_probe_algebra_recovers_full_unroll():
    """T(L,C,K) affine fit on a tiny LM must predict the fully-unrolled
    compile's flops within 10%."""
    from repro.configs.base import LMConfig
    from repro.models.transformer import make_train_step, init_lm
    from repro.optim import AdamW, constant

    seq, batch = 128, 2

    def measure(l, c, k):
        cfg = LMConfig(name="t", n_layers=l, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, vocab=256,
                       attn_chunk=max(1, seq // c),
                       loss_chunk=max(1, seq // k), unroll=True,
                       dtype="float32")
        params = jax.eval_shape(lambda key: init_lm(key, cfg),
                                jax.random.PRNGKey(0))
        opt = AdamW(lr=constant(1e-3))
        st = jax.eval_shape(opt.init, params)
        b = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        comp = jax.jit(make_train_step(cfg, opt)).lower(params, st,
                                                        b).compile()
        ca = comp.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return float(ca.get("flops", 0.0))

    t211, t411, t221, t212 = (measure(2, 1, 1), measure(4, 1, 1),
                              measure(2, 2, 1), measure(2, 1, 2))
    d = (t221 - t211) / 2
    e = t212 - t211
    c = (t411 - t211) / 2 - d
    a = t211 - 2 * c - 2 * d - e
    L, C, K = 6, 4, 8
    predicted = a + L * c + L * C * d + K * e
    actual = measure(L, C, K)
    assert abs(predicted - actual) / actual < 0.10, (predicted, actual)
