import pytest

from repro.distributed.fault_tolerance import ElasticPlan, StragglerMonitor


def test_straggler_detection():
    m = StragglerMonitor(warmup_steps=3, deadline_factor=2.0)
    for _ in range(10):
        assert not m.record(1.0)
    assert m.record(5.0)          # 5x the EMA -> straggler
    assert m.stragglers == 1
    # the straggler must not poison the EMA
    assert not m.record(1.1)
    assert abs(m.deadline - 2.0) < 0.3


def test_straggler_warmup_never_flags():
    m = StragglerMonitor(warmup_steps=5)
    assert not m.record(100.0)
    assert not m.record(0.001)


def test_expected_is_zero_until_warm():
    """The serving deadline budget reads ``expected`` for skip-vs-launch:
    a COLD monitor must predict 0.0 (never veto a launch); a warm one
    predicts the EMA."""
    m = StragglerMonitor(warmup_steps=3)
    assert m.expected == 0.0
    m.record(100.0)
    assert m.expected == 0.0          # still warming: no veto
    m.record(100.0)
    m.record(100.0)
    assert m.expected > 0.0
    for _ in range(20):
        m.record(10.0)
    assert 10.0 <= m.expected < 100.0  # tracks the recent regime


def test_elastic_plan_512_to_256():
    p = ElasticPlan(old_devices=512, new_devices=256, model_parallel=16)
    assert p.old_dp == 32 and p.new_dp == 16
    assert p.new_grad_accum == 2            # global batch preserved
    assert p.new_mesh_shape() == (16, 16)
    assert p.new_mesh_shape(multi_pod_pods=1) == (1, 16, 16)


def test_elastic_plan_rejects_impossible():
    with pytest.raises(ValueError):
        ElasticPlan(old_devices=512, new_devices=100, model_parallel=16)


def test_elastic_upscale():
    p = ElasticPlan(old_devices=256, new_devices=512, model_parallel=16)
    assert p.new_grad_accum == 1            # never shrinks below 1
