"""The persistent plan store + calibration state.

The load-bearing guarantees:

* serialize -> rehydrate -> serialize is a FIXED POINT (the store document
  fully determines the rehydrated session's serializable state);
* schema_version 1 AND 2 plan documents still load under the v3 reader
  (``migrate_plan_doc`` fills the newer fields conservatively — v2 docs
  gain empty ``level_dirs``: a v2 writer knew no diropt engines);
* a cold session and a plan-store-rehydrated session replaying IDENTICAL
  traffic produce identical plans and identical result rows — and the
  rehydrated one pays ZERO parse / statistics / costing passes
  (``session.counters`` + the ``compute_stats.calls`` probe);
* a store written for one graph refuses to warm a session over another.
"""
import json

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import Dataset
from repro.data.treegen import TreeSpec, make_edge_table
from repro.planner import ServingSession, paper_listing
from repro.planner.plan_store import (load_store, migrate_plan_doc,
                                      rehydrate_session, report_from_json,
                                      save_session, session_to_json)
from repro.planner.stats import compute_stats

CAPS = EngineCaps(frontier=1024, result=2048)
SPEC = TreeSpec(num_vertices=300, height=6, payload_cols=2, seed=5)


def _dataset(spec=SPEC):
    return Dataset.prepare(make_edge_table(spec), spec.num_vertices)


def _serve_traffic(session, sql, batches):
    return [session.submit(sql, roots) for roots in batches]


def _row_sets(results):
    out = []
    for r in results:
        n = int(r.count)
        out.append(sorted(zip(np.asarray(r.values["id"])[:n].tolist(),
                              np.asarray(r.row_depths)[:n].tolist())))
    return out


TRAFFIC = [[0, 1, 2], [0, 5, 17, 40], [0, 1, 2]]


# ---------------------------------------------------------------------------
# fixed point: serialize -> rehydrate -> serialize
# ---------------------------------------------------------------------------

def _check_fixed_point(seed):
    spec = SPEC._replace(seed=seed)
    ds = _dataset(spec)
    sql = paper_listing(1, root=0, depth=3)
    session = ServingSession(ds, caps=CAPS)
    rng = np.random.default_rng(seed)
    batches = [rng.integers(0, spec.num_vertices, 3).tolist()
               for _ in range(2)]
    _serve_traffic(session, sql, batches)
    doc1 = json.loads(json.dumps(session_to_json(session),
                                 sort_keys=True))

    ds2 = _dataset(spec)
    session2 = ServingSession(ds2, caps=CAPS)
    import repro.planner.plan_store as ps
    # rehydrate from the DOCUMENT (what save_session writes)
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "store.json")
        save_session(session, path)
        ps.rehydrate_into(session2, path)
    doc2 = json.loads(json.dumps(session_to_json(session2),
                                 sort_keys=True))
    assert doc1 == doc2


@pytest.mark.parametrize("seed", [0, 9])
def test_store_roundtrip_is_fixed_point_seeded(seed):
    _check_fixed_point(seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    pass
else:
    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_store_roundtrip_is_fixed_point_random(seed):
        _check_fixed_point(seed % 10_000)


# ---------------------------------------------------------------------------
# v1 documents load under the v2 reader
# ---------------------------------------------------------------------------

def _as_v2(doc):
    """Strip a v3 plan document down to what the PR-4 (v2) writer emitted."""
    v2 = json.loads(json.dumps(doc))
    v2["schema_version"] = 2
    cc = v2.get("cost_constants", {})
    cc.pop("pull_alpha", None)
    cc.pop("pull_beta", None)
    for c in v2["candidates"]:
        c["cost"].pop("level_dirs", None)
    return v2


def _as_v1(doc):
    """Strip a plan document down to what the PR-3 (v1) writer emitted."""
    v1 = _as_v2(doc)
    v1["schema_version"] = 1
    v1.pop("cost_constants", None)
    for k in ("degree_histogram", "level_vertices", "max_level_edges",
              "root_profiles", "level_walk_edges"):
        v1["stats"].pop(k, None)
    for c in v1["candidates"]:
        c["cost"].pop("plain_bytes", None)
        c["cost"].pop("kernel_bytes", None)
    return v1


def test_v1_plan_doc_loads_under_v3_reader(tmp_path):
    from repro.planner.explain import PLAN_SCHEMA_VERSION

    ds = _dataset()
    sql = paper_listing(1, root=0, depth=3)
    session = ServingSession(ds, caps=CAPS)
    session.submit(sql, [0, 1])
    v3 = session.plan_json(sql, [0, 1])
    v1 = _as_v1(v3)

    migrated = migrate_plan_doc(v1)
    assert migrated["schema_version"] == PLAN_SCHEMA_VERSION
    # conservative fills: statically-factored bytes fold into plain, and
    # a v1 writer knew no direction-optimizing plans
    for c in migrated["candidates"]:
        assert c["cost"]["plain_bytes"] == c["cost"]["total_bytes"]
        assert c["cost"]["kernel_bytes"] == 0.0
        assert c["cost"]["level_dirs"] == []
    # and it rebuilds into a live report with the v1 ranking preserved
    report = report_from_json(v1)
    assert [c.label for c in report.ranked] \
        == [c["label"] for c in v3["candidates"]]
    assert report.best.label == v3["chosen"]

    # a v1-shaped STORE (v1 inner docs) also loads
    store_path = tmp_path / "store.json"
    save_session(session, str(store_path))
    doc = json.loads(store_path.read_text())
    doc["schema_version"] = 1
    doc["shapes"] = [_as_v1(s) for s in doc["shapes"]]
    for e in doc["entries"]:
        e["plan_json"] = _as_v1(e["plan_json"])
        for c in e["bucket_choices"]:
            for k in ("plain_bytes", "kernel_bytes", "level_dirs"):
                c["cost"].pop(k, None)
    store_path.write_text(json.dumps(doc))
    loaded = load_store(str(store_path))
    assert loaded["schema_version"] == PLAN_SCHEMA_VERSION
    ds2 = _dataset()
    session2 = rehydrate_session(ds2, str(store_path), caps=CAPS)
    assert session2.plan_json(sql, [0, 1])["schema_version"] \
        == PLAN_SCHEMA_VERSION
    assert session2.counters == {"parse_calls": 0, "stats_calls": 0,
                                 "cost_calls": 0}


def test_v2_plan_doc_and_store_load_under_v3_reader(tmp_path):
    """The PR-5 migration note's contract: a schema-version-2 store (the
    PR-4 writer — full stats and byte splits, but no per-level direction
    decisions and no pull thresholds) loads under the v3 reader with
    ``level_dirs`` conservatively empty and the default thresholds."""
    from repro.planner.cost import PULL_ALPHA, PULL_BETA
    from repro.planner.explain import PLAN_SCHEMA_VERSION

    ds = _dataset()
    sql = paper_listing(1, root=0, depth=3)
    session = ServingSession(ds, caps=CAPS)
    session.submit(sql, [0, 1])
    v3 = session.plan_json(sql, [0, 1])
    v2 = _as_v2(v3)

    migrated = migrate_plan_doc(v2)
    assert migrated["schema_version"] == PLAN_SCHEMA_VERSION
    for c in migrated["candidates"]:
        assert c["cost"]["level_dirs"] == []
        # v2 fields survive untouched (no lossy refill)
        assert c["cost"]["plain_bytes"] == \
            next(x for x in v3["candidates"]
                 if x["label"] == c["label"])["cost"]["plain_bytes"]
    report = report_from_json(v2)
    assert [c.label for c in report.ranked] \
        == [c["label"] for c in v3["candidates"]]
    assert (report.constants.pull_alpha, report.constants.pull_beta) \
        == (PULL_ALPHA, PULL_BETA)

    # a v2-shaped STORE (v2 inner docs, un-keyed measured kernel factor)
    store_path = tmp_path / "store.json"
    save_session(session, str(store_path))
    doc = json.loads(store_path.read_text())
    doc["schema_version"] = 2
    doc["shapes"] = [_as_v2(s) for s in doc["shapes"]]
    doc.pop("kernel_factors_measured", None)
    doc["kernel_factor_measured"] = 2.5          # the v2 un-keyed field
    for e in doc["entries"]:
        e["plan_json"] = _as_v2(e["plan_json"])
        for c in e["bucket_choices"]:
            c["cost"].pop("level_dirs", None)
    store_path.write_text(json.dumps(doc))
    loaded = load_store(str(store_path))
    assert loaded["schema_version"] == PLAN_SCHEMA_VERSION
    from repro.planner import calibrate
    calibrate.set_measured_kernel_factor(None)   # empty cell: legacy fills
    ds2 = _dataset()
    session2 = rehydrate_session(ds2, str(store_path), caps=CAPS)
    assert session2.plan_json(sql, [0, 1])["schema_version"] \
        == PLAN_SCHEMA_VERSION
    assert session2.counters == {"parse_calls": 0, "stats_calls": 0,
                                 "cost_calls": 0}
    # the un-keyed v2 factor landed in the (current backend, expand) cell
    assert calibrate.measured_kernel_factor() == 2.5
    # ...but must NOT clobber a fresher current-process measurement
    calibrate.set_measured_kernel_factor(9.9)
    from repro.planner.plan_store import rehydrate_into
    rehydrate_into(ServingSession(_dataset(), caps=CAPS), str(store_path))
    assert calibrate.measured_kernel_factor() == 9.9
    calibrate.set_measured_kernel_factor(None)   # drop the injected cell


def test_v3_plan_doc_and_store_load_under_v4_reader(tmp_path):
    """The PR-7 migration contract: a schema-version-3 document (the PR-5
    writer — everything but the ``analyze`` slot) migrates to v4 with
    ``analyze`` conservatively null, and a v3-shaped store loads."""
    from repro.planner.explain import PLAN_SCHEMA_VERSION

    ds = _dataset()
    sql = paper_listing(1, root=0, depth=3)
    session = ServingSession(ds, caps=CAPS)
    session.submit(sql, [0, 1])
    v4 = session.plan_json(sql, [0, 1])
    v3 = json.loads(json.dumps(v4))
    v3["schema_version"] = 3
    del v3["analyze"]

    migrated = migrate_plan_doc(v3)
    assert migrated["schema_version"] == PLAN_SCHEMA_VERSION == 6
    assert migrated["analyze"] is None
    # everything else survives untouched (the v4 writer added one slot)
    assert {k: v for k, v in migrated.items()
            if k not in ("schema_version", "analyze")} \
        == {k: v for k, v in v4.items()
            if k not in ("schema_version", "analyze")}
    report = report_from_json(v3)
    assert [c.label for c in report.ranked] \
        == [c["label"] for c in v4["candidates"]]

    store_path = tmp_path / "store.json"
    save_session(session, str(store_path))
    doc = json.loads(store_path.read_text())
    doc["schema_version"] = 3
    for s in doc["shapes"]:
        s["schema_version"] = 3
        s.pop("analyze", None)
    for e in doc["entries"]:
        e["plan_json"]["schema_version"] = 3
        e["plan_json"].pop("analyze", None)
    store_path.write_text(json.dumps(doc))
    loaded = load_store(str(store_path))
    assert loaded["schema_version"] == PLAN_SCHEMA_VERSION
    session2 = rehydrate_session(_dataset(), str(store_path), caps=CAPS)
    assert session2.plan_json(sql, [0, 1])["schema_version"] \
        == PLAN_SCHEMA_VERSION
    assert session2.counters == {"parse_calls": 0, "stats_calls": 0,
                                 "cost_calls": 0}


def test_migrate_rejects_unknown_versions():
    with pytest.raises(ValueError, match="schema_version"):
        migrate_plan_doc({"schema_version": 99})


def test_v5_plan_doc_and_store_load_under_v6_reader(tmp_path):
    """The PR-10 migration contract: a schema-version-5 document (the PR-8
    writer — everything but the ``admission`` slot and the guard budgets)
    migrates to v6 with ``admission`` conservatively null and the default
    guard budgets, and a v5-shaped store loads."""
    from repro.planner.cost import CostConstants, DEFAULT_CONSTANTS
    from repro.planner.explain import PLAN_SCHEMA_VERSION

    ds = _dataset()
    sql = paper_listing(1, root=0, depth=3)
    session = ServingSession(ds, caps=CAPS)
    session.submit(sql, [0, 1])
    v6 = session.plan_json(sql, [0, 1])
    v5 = json.loads(json.dumps(v6))
    v5["schema_version"] = 5
    del v5["admission"]
    for k in ("guard_degrade_us", "guard_reject_us"):
        del v5["cost_constants"][k]

    migrated = migrate_plan_doc(v5)
    assert migrated["schema_version"] == PLAN_SCHEMA_VERSION == 6
    assert migrated["admission"] is None
    constants = CostConstants.from_json(migrated["cost_constants"])
    assert constants.guard_degrade_us == DEFAULT_CONSTANTS.guard_degrade_us
    assert constants.guard_reject_us == DEFAULT_CONSTANTS.guard_reject_us
    report = report_from_json(v5)
    assert [c.label for c in report.ranked] \
        == [c["label"] for c in v6["candidates"]]

    store_path = tmp_path / "store.json"
    save_session(session, str(store_path))
    doc = json.loads(store_path.read_text())
    doc["schema_version"] = 5
    for s in doc["shapes"]:
        s["schema_version"] = 5
        s.pop("admission", None)
    for e in doc["entries"]:
        e["plan_json"]["schema_version"] = 5
        e["plan_json"].pop("admission", None)
    store_path.write_text(json.dumps(doc))
    loaded = load_store(str(store_path))
    assert loaded["schema_version"] == PLAN_SCHEMA_VERSION
    session2 = rehydrate_session(_dataset(), str(store_path), caps=CAPS)
    assert session2.plan_json(sql, [0, 1])["schema_version"] \
        == PLAN_SCHEMA_VERSION


# ---------------------------------------------------------------------------
# cold vs rehydrated replay: identical plans, identical rows, zero work
# ---------------------------------------------------------------------------

def test_cold_and_rehydrated_sessions_replay_identically(tmp_path):
    sql = paper_listing(1, root=0, depth=4)
    path = str(tmp_path / "store.json")

    cold = ServingSession(_dataset(), caps=CAPS)
    cold_out = _serve_traffic(cold, sql, TRAFFIC)
    cold_plans = [cold.plan_json(sql, roots) for roots in TRAFFIC]
    save_session(cold, path)

    warm = ServingSession(_dataset(), caps=CAPS, plan_store=path)
    before = compute_stats.calls
    warm_out = _serve_traffic(warm, sql, TRAFFIC)
    warm_plans = [warm.plan_json(sql, roots) for roots in TRAFFIC]

    # zero parse / statistics / costing passes on the warm side
    assert warm.counters == {"parse_calls": 0, "stats_calls": 0,
                             "cost_calls": 0}
    assert compute_stats.calls == before
    # identical plans ...
    assert warm_plans == cold_plans
    # ... and identical result rows, per request, per root
    for a_batch, b_batch in zip(cold_out, warm_out):
        assert _row_sets(a_batch) == _row_sets(b_batch)


def test_first_query_after_rehydrate_pays_zero_planning(tmp_path):
    """The acceptance bar, stated directly: the FIRST query of a
    rehydrated session performs no parse, no stats pass, no costing."""
    sql = paper_listing(1, root=0, depth=4)
    path = str(tmp_path / "store.json")
    cold = ServingSession(_dataset(), caps=CAPS)
    cold.submit(sql, TRAFFIC[0])
    save_session(cold, path)

    warm = ServingSession(_dataset(), caps=CAPS, plan_store=path)
    warm.submit(sql, TRAFFIC[0])
    assert warm.counters == {"parse_calls": 0, "stats_calls": 0,
                             "cost_calls": 0}
    # and the calibration state survived the process boundary
    assert warm.calibrator.count >= cold.calibrator.count - 1


def test_rehydrate_refuses_a_different_graph(tmp_path):
    sql = paper_listing(1, root=0, depth=3)
    path = str(tmp_path / "store.json")
    session = ServingSession(_dataset(), caps=CAPS)
    session.submit(sql, [0, 1])
    save_session(session, path)

    other = _dataset(TreeSpec(num_vertices=301, height=6, payload_cols=2,
                              seed=6))
    with pytest.raises(ValueError, match="different graph"):
        rehydrate_session(other, path, caps=CAPS)
