"""Bit-parallel multi-query traversal (MS-BFS): lane-exact parity and the
serving-side coalescing built on it.

The multiquery engine packs up to 32 roots into the BITS of one (V,)
uint32 frontier/visited word and advances every lane with ONE segment-OR
sweep per level.  Its contract is strict: lane i of a coalesced dispatch
is ROW-FOR-ROW identical (rows, row_depths, order — the deferred-emission
compact layout, sentinel padding included) to a sequential deferred-emit
BFS on that root alone.  The tests here hold that contract across:

* every legal direction (outbound / inbound / both),
* partial words (5 roots in a 32-lane word) and full words,
* mixed convergence (a leaf lane frozen at depth 0 next to a hub lane
  still sweeping) — per-lane freezing must not bleed between bits,
* per-lane depth caps (a capped lane equals a sequential run at that
  ``max_depth``),
* per-lane overflow flags, and the bucket executor's per-lane EVICTION
  (only the overflowing lane re-dispatches solo at fallback caps),
* the planner registration (``lanes > 1`` admits the candidate, ranked
  per-root amortized; an over-wide batch records a skip reason), and
* the serving session's enqueue/flush coalescing (grouped by query
  shape, scattered back to tickets in enqueue order).

The deterministic seeded slice always runs; the hypothesis property (real
package or the vendored fallback engine) extends the seed set.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import (ENGINE_NAMES, MULTIQUERY_ENGINE,
                               PLAN_BUILDERS, WORD_LANES, Dataset,
                               RecursiveQuery, dispatch_buckets,
                               lane_eviction_count, result_lane, run_query,
                               run_query_multi)
from repro.core.table import ColumnTable
from repro.planner import plan
from repro.planner.optimize import RootBucket
from repro.planner.serving import ServingSession

DIRECTIONS = ("outbound", "inbound", "both")


def _edge_dataset(src, dst, num_vertices):
    e = len(src)
    cols = {
        "id": np.arange(e, dtype=np.int32),
        "from": np.asarray(src, np.int32),
        "to": np.asarray(dst, np.int32),
        "name": np.zeros((e, 4), np.float32)}
    return Dataset.prepare(ColumnTable.from_numpy(cols), num_vertices)


def _random_case(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(6, 40))
    e = int(rng.integers(2, 3 * v))
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    depth = int(rng.integers(1, 5))
    n_roots = int(rng.integers(1, 9))
    roots = rng.integers(0, v, n_roots).astype(np.int32)
    return _edge_dataset(src, dst, v), roots, depth, e


def _exact_rows(r):
    """The FULL compact layout of one result: count, positions (sentinel
    padding included), per-row depths and the id column — order-sensitive,
    the row-for-row contract, not just a row multiset."""
    n = int(r.count)
    return (n,
            np.asarray(r.positions).tolist(),
            np.asarray(r.row_depths)[:n].tolist(),
            np.asarray(r.values["id"])[:n].tolist())


def _mq_query(depth, caps, direction):
    return RecursiveQuery(engine="multiquery", max_depth=depth,
                          payload_cols=0, caps=caps, direction=direction)


def _seq_query(depth, caps, direction):
    # diropt is the sequential deferred-emission engine the multiquery
    # finish shares its exact compact layout with
    return RecursiveQuery(engine="diropt", max_depth=depth, payload_cols=0,
                          caps=caps, direction=direction)


def _check_lane_parity(seed):
    ds, roots, depth, e = _random_case(seed)
    caps = EngineCaps(frontier=e + 16, result=e + 16)
    for direction in DIRECTIONS:
        r = run_query_multi(_mq_query(depth, caps, direction), ds, roots)
        for lane, root in enumerate(roots):
            got = _exact_rows(result_lane(r, lane))
            want = _exact_rows(
                run_query(_seq_query(depth, caps, direction), ds,
                          int(root)))
            assert got == want, (
                f"lane {lane} (root {int(root)}, {direction}, seed {seed}) "
                f"diverged from the sequential deferred-emit BFS")


@pytest.mark.parametrize("seed", [3, 7, 21, 48])
def test_multiquery_lane_parity_seeded(seed):
    _check_lane_parity(seed)


def test_partial_word_and_mixed_convergence(tree_dataset):
    """5 roots in a 32-lane word, deliberately mixing a deep lane (the
    tree root) with leaf lanes that converge at depth 0 — per-lane
    freezing must not disturb the still-active lanes' bits."""
    _, ds, levels = tree_dataset
    e = ds.table.num_rows
    caps = EngineCaps(frontier=e + 8, result=e + 8)
    dst = np.asarray(ds.table.column("to"))
    # levels are per-level EDGE position sets; the deepest level's targets
    # are leaf vertices (height-limited: no out-edges, converge at once)
    deepest = [lv for lv in levels if lv][-1]
    leaves = sorted({int(dst[i]) for i in deepest})[:3]
    mid = int(dst[min(levels[1])])               # a depth-2 vertex
    roots = np.asarray([0, *leaves, mid], np.int32)
    assert len(roots) == 5 < WORD_LANES
    depth = 6
    r = run_query_multi(_mq_query(depth, caps, "outbound"), ds, roots)
    assert int(np.asarray(r.count).shape[0]) == 5      # no padding lanes
    for lane, root in enumerate(roots):
        got = _exact_rows(result_lane(r, lane))
        want = _exact_rows(
            run_query(_seq_query(depth, caps, "outbound"), ds, int(root)))
        assert got == want
    # the leaf lanes really did converge immediately while lane 0 ran deep
    counts = np.asarray(r.count)
    assert counts[0] > 0 and all(int(counts[1 + i]) == 0
                                 for i in range(len(leaves)))


def test_per_lane_depth_caps(tree_dataset):
    """A lane capped at depth d is row-for-row a sequential run with
    ``max_depth=d``; uncapped lanes are unaffected by their neighbor's
    cap."""
    _, ds, _ = tree_dataset
    e = ds.table.num_rows
    caps = EngineCaps(frontier=e + 8, result=e + 8)
    roots = np.asarray([0, 0, 1], np.int32)
    lane_limits = np.asarray([2, 5, 5], np.int32)
    r = run_query_multi(_mq_query(5, caps, "outbound"), ds, roots,
                        lane_limits)
    for lane, cap in enumerate(lane_limits):
        want = _exact_rows(
            run_query(_seq_query(int(cap), caps, "outbound"), ds,
                      int(roots[lane])))
        assert _exact_rows(result_lane(r, lane)) == want


def test_per_lane_overflow_flags(tree_dataset):
    """Overflow is PER LANE: a tiny result cap truncates the hub lane and
    flags exactly it, leaving converged-early lanes clean."""
    _, ds, levels = tree_dataset
    e = ds.table.num_rows
    dst = np.asarray(ds.table.column("to"))
    leaf = int(dst[min([lv for lv in levels if lv][-1])])
    tiny = EngineCaps(frontier=e + 8, result=4)
    r = run_query_multi(_mq_query(5, tiny, "outbound"), ds,
                        np.asarray([0, leaf], np.int32))
    ovf = np.asarray(r.overflow)
    assert bool(ovf[0]) and not bool(ovf[1])


def test_bucket_executor_evicts_only_overflowing_lanes(tree_dataset):
    """The shared bucket executor's per-lane overflow handling: when one
    lane of a coalesced bucket overflows the bucket caps, ONLY that lane
    is evicted to a solo fallback-caps re-dispatch — the other lanes keep
    their bucket-caps results, and the timing reports the eviction."""
    _, ds, levels = tree_dataset
    e = ds.table.num_rows
    dst = np.asarray(ds.table.column("to"))
    leaves = sorted({int(dst[i])
                     for i in [lv for lv in levels if lv][-1]})[:3]
    bucket = RootBucket(indices=(0, 1, 2, 3),
                        roots=(0, *leaves),
                        caps=EngineCaps(frontier=e + 8, result=4),
                        predicted_reach=4.0, predicted_depth=5)
    fallback = EngineCaps(frontier=e + 8, result=e + 8)
    base = _mq_query(5, bucket.caps, "outbound")

    def _dispatch(i, b, caps):
        qb = dataclasses.replace(base, caps=caps, lanes=len(b.roots))
        return run_query_multi(qb, ds, np.asarray(b.roots, np.int32))

    before = lane_eviction_count()
    timings = []
    out = dispatch_buckets([bucket], _dispatch, fallback_caps=fallback,
                           observer=timings.append)
    assert lane_eviction_count() == before + 1
    assert timings[0].evicted_lanes == 1 and not timings[0].retried
    # the evicted hub lane matches a solo run at the FALLBACK caps...
    assert _exact_rows(out[0]) == _exact_rows(
        run_query(_seq_query(5, fallback, "outbound"), ds, 0))
    # ...and the leaf lanes kept their bucket-caps results
    for i, leaf in enumerate(leaves):
        assert _exact_rows(out[1 + i]) == _exact_rows(
            run_query(_seq_query(5, bucket.caps, "outbound"), ds,
                      int(leaf)))


# ---------------------------------------------------------------------------
# planner registration
# ---------------------------------------------------------------------------

SQL = """
    WITH RECURSIVE t (id, "from", "to", depth) AS (
      SELECT id, "from", "to", 0 FROM edges WHERE "from" = 0
      UNION
      SELECT e.id, e."from", e."to", t.depth + 1
      FROM edges e JOIN t ON e."from" = t."to" WHERE t.depth < 4
    ) SELECT id, depth FROM t"""


def test_multiquery_is_a_builder_not_an_engine_name():
    """The bit-parallel engine is a first-class PLAN_BUILDERS citizen but
    stays OUT of ENGINE_NAMES: every all-engines enumeration (tests,
    benches, forced-engine sweeps) iterates one-root-at-a-time engines,
    and multiquery only makes sense with a coalesced lane count."""
    assert MULTIQUERY_ENGINE == "multiquery"
    assert MULTIQUERY_ENGINE in PLAN_BUILDERS
    assert MULTIQUERY_ENGINE not in ENGINE_NAMES


def test_plan_lanes_axis(tree_dataset):
    """Single-root planning neither ranks multiquery nor clutters the
    skip list with it (nothing was requested); ``lanes=8`` admits it,
    prices the WHOLE coalesced batch, and ranks per-root amortized (on
    this profile one word sweep answering 8 roots wins)."""
    _, ds, _ = tree_dataset
    caps = EngineCaps(2048, 4096)
    single = plan(SQL, ds, caps=caps)
    assert all(c.engine != "multiquery" for c in single.ranked)
    assert all(e != "multiquery" for e, _ in single.skipped)

    batched = plan(SQL, ds, caps=caps, lanes=8)
    mq = next(c for c in batched.ranked if c.engine == "multiquery")
    assert mq.query.lanes == 8
    # amortized ranking: the batch estimate is compared per root
    best_solo = min(c.cost.est_us for c in batched.ranked
                    if c.engine != "multiquery")
    assert mq.cost.est_us / 8 < best_solo
    assert batched.best.engine == "multiquery"
    # and the chosen plan executes: lane parity against the solo engines
    r = batched.best.run(ds, list(range(8)))
    solo = plan(SQL, ds, caps=caps).best
    for lane in range(8):
        got = result_lane(r, lane)
        want = solo.run(ds, lane)
        n = int(got.count)
        assert n == int(want.count)
        assert (sorted(np.asarray(got.values["id"])[:n].tolist())
                == sorted(np.asarray(want.values["id"])[:n].tolist()))


def test_plan_lanes_over_word_width_is_skipped(tree_dataset):
    _, ds, _ = tree_dataset
    report = plan(SQL, ds, caps=EngineCaps(2048, 4096),
                  lanes=WORD_LANES + 1)
    assert all(c.engine != "multiquery" for c in report.ranked)
    reason = dict(report.skipped)["multiquery"]
    assert str(WORD_LANES) in reason


# ---------------------------------------------------------------------------
# serving-side coalescing
# ---------------------------------------------------------------------------

def _row_set(r):
    n = int(r.count)
    return sorted(zip(np.asarray(r.values["id"])[:n].tolist(),
                      np.asarray(r.values["depth"])[:n].tolist()))


def test_serving_coalesces_and_scatters_back(tree_dataset):
    """enqueue/flush: requests on one query shape coalesce into ONE
    batched dispatch whose multi-lane buckets plan the multiquery engine;
    every ticket's result matches an uncoalesced single-root submit."""
    _, ds, _ = tree_dataset
    session = ServingSession(ds, caps=EngineCaps(2048, 4096))
    roots = list(range(10))
    tickets = [session.enqueue(SQL, r) for r in roots]
    assert session.stats["pending_requests"] == len(roots)
    assert not tickets[0].done
    with pytest.raises(RuntimeError):
        tickets[0].result()

    assert session.flush() == 1          # one shape -> one dispatch
    assert all(t.done for t in tickets)
    st = session.stats
    assert st["coalesced_dispatches"] == 1
    assert st["coalesced_roots"] == len(roots)
    assert st["pending_requests"] == 0

    # the coalesced batch's multi-lane buckets picked the word engine
    entry = session.plan_for(SQL, roots)
    multi = [c for b, c in zip(entry.buckets, entry.bucket_choices)
             if len(b.roots) > 1]
    assert multi and all(c.engine == "multiquery" for c in multi)
    assert all(c.query.lanes == len(b.roots)
               for b, c in zip(entry.buckets, entry.bucket_choices)
               if c.engine == "multiquery")

    ref = ServingSession(ds, caps=EngineCaps(2048, 4096))
    for root, t in zip(roots, tickets):
        assert _row_set(t.result()) == _row_set(ref.submit(SQL, [root])[0])


def test_coalescing_groups_by_shape(tree_dataset):
    """Two different query shapes pending at once flush as TWO dispatches;
    textually different SQL of the SAME shape coalesces into one."""
    _, ds, _ = tree_dataset
    session = ServingSession(ds, caps=EngineCaps(2048, 4096))
    same_shape = SQL.replace("SELECT id, depth", "SELECT  id,  depth")
    other = SQL.replace("t.depth < 4", "t.depth < 3")
    t1 = session.enqueue(SQL, 0)
    t2 = session.enqueue(same_shape, 1)
    t3 = session.enqueue(other, 0)
    assert session.flush() == 2
    assert t1.done and t2.done and t3.done
    ref = ServingSession(ds, caps=EngineCaps(2048, 4096))
    assert _row_set(t1.result()) == _row_set(ref.submit(SQL, [0])[0])
    assert _row_set(t2.result()) == _row_set(ref.submit(SQL, [1])[0])
    assert _row_set(t3.result()) == _row_set(ref.submit(other, [0])[0])


# ---------------------------------------------------------------------------
# hypothesis extension (real package, or the vendored fallback engine)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    pass
else:
    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_multiquery_lane_parity_random(seed):
        _check_lane_parity(seed)
