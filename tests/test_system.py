"""End-to-end system tests: train -> checkpoint -> restart == uninterrupted
run (the fault-tolerance contract), plus the serving path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.launch.serve import serve_batch
from repro.launch.train import TrainRun, build_run


def test_train_resume_is_bitexact(tmp_path):
    """Run A: 8 steps straight.  Run B: 4 steps, checkpoint, 'crash',
    restore, 4 more.  Same data stream (seed, step) -> identical params."""
    kw = dict(batch=2, seq=32, seed=5, ckpt_every=4)

    run_a = build_run("qwen2-0.5b", smoke=True)
    run_a.run(steps=8, ckpt=None, **kw)

    ckpt_dir = str(tmp_path / "ck")
    mgr = CheckpointManager(ckpt_dir)
    run_b = build_run("qwen2-0.5b", smoke=True)
    run_b.run(steps=4, ckpt=mgr, **kw)
    del run_b                                        # "crash"

    run_c = build_run("qwen2-0.5b", smoke=True, resume_dir=ckpt_dir)
    assert run_c.step == 4
    run_c.run(steps=8, ckpt=None, **kw)

    for a, c in zip(jax.tree_util.tree_leaves(run_a.params),
                    jax.tree_util.tree_leaves(run_c.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(c, np.float32))


def test_training_monitor_integration(tmp_path):
    run = build_run("stablelm-1.6b", smoke=True)
    hist = run.run(steps=6, batch=2, seq=16, seed=1, ckpt=None,
                   monitor=StragglerMonitor())
    assert len(hist) == 6
    assert all(np.isfinite(m["loss"]) for m in hist)


def test_serve_batch_generates():
    from repro.configs.registry import get_config
    cfg, _ = get_config("qwen2-0.5b", smoke=True)
    from repro.models.transformer import init_lm
    params = init_lm(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                 cfg.vocab, jnp.int32)
    toks, stats = serve_batch(cfg, params, prompts, gen=5)
    assert toks.shape == (3, 5)
    assert stats["tok_per_s"] > 0
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))
