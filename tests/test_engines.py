"""Cross-engine equivalence: every engine must produce the same BFS result
set as the python oracle — the paper's engines differ only in cost."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import (ENGINE_NAMES, Dataset, RecursiveQuery,
                               plan_repr, run_query)
from repro.data.treegen import TreeSpec, bfs_reference, make_edge_table

CAPS = EngineCaps(frontier=2048, result=4096)


def _ref_ids(ds, levels, depth):
    ref_set = set().union(*levels[:depth + 1])
    ids = np.asarray(ds.table.column("id"))
    return sorted(int(ids[p]) for p in ref_set), ref_set


@pytest.mark.parametrize("engine", ENGINE_NAMES)
@pytest.mark.parametrize("depth", [0, 3, 7])
def test_engine_matches_oracle(tree_dataset, engine, depth):
    spec, ds, levels = tree_dataset
    q = RecursiveQuery(engine=engine, max_depth=depth, payload_cols=4,
                       caps=CAPS)
    r = run_query(q, ds, root=0)
    ids_ref, ref_set = _ref_ids(ds, levels, depth)
    got = np.asarray(r.values["id"])[:int(r.count)]
    assert sorted(int(x) for x in got) == ids_ref
    assert not bool(r.overflow)
    # positional engines also expose the surviving positions
    if engine in ("precursive", "bitmap", "hybrid", "trecursive_rewrite"):
        pos = set(np.asarray(r.positions)[:int(r.count)].tolist())
        assert pos == ref_set


def test_payload_materialization_values(tree_dataset):
    spec, ds, levels = tree_dataset
    q = RecursiveQuery(engine="precursive", max_depth=4, payload_cols=4,
                       caps=CAPS)
    r = run_query(q, ds, root=0)
    n = int(r.count)
    pos = np.asarray(r.positions)[:n]
    ref_payload = np.asarray(ds.table.column("column2"))[pos]
    assert np.allclose(np.asarray(r.values["column2"])[:n], ref_payload)


def test_union_all_on_tree_equals_bfs(tree_dataset):
    spec, ds, levels = tree_dataset
    a = run_query(RecursiveQuery("precursive", 5, 4, CAPS, dedup=True),
                  ds, 0)
    b = run_query(RecursiveQuery("precursive", 5, 4, CAPS, dedup=False),
                  ds, 0)
    assert int(a.count) == int(b.count)      # a tree has no rediscoveries


def test_overflow_flag_set():
    spec = TreeSpec(num_vertices=500, height=4, payload_cols=0, seed=3)
    ds = Dataset.prepare(make_edge_table(spec), spec.num_vertices)
    tiny = EngineCaps(frontier=8, result=16)
    r = run_query(RecursiveQuery("precursive", 4, 0, tiny), ds, 0)
    assert bool(r.overflow)


def test_cyclic_graph_terminates():
    """BFS semantics must terminate on a cycle (dedup via visited)."""
    import jax.numpy as jnp
    from repro.core.table import ColumnTable
    src = np.array([0, 1, 2, 3], dtype=np.int32)
    dst = np.array([1, 2, 3, 0], dtype=np.int32)
    t = ColumnTable.from_numpy({
        "id": np.arange(4, dtype=np.int32), "from": src, "to": dst,
        "name": np.zeros((4, 4), np.float32)})
    ds = Dataset.prepare(t, 4)
    r = run_query(RecursiveQuery("precursive", 100, 0,
                                 EngineCaps(16, 32)), ds, 0)
    assert int(r.count) == 4                  # each edge exactly once
    assert int(r.depth) <= 5


def test_plan_repr_mentions_operators():
    s = plan_repr("precursive", 4, 2)
    assert "PRecursive" in s and "Materialize" in s
    s2 = plan_repr("rowstore", 4, 2)
    assert "SeqScan" in s2 and "HashJoin" in s2
