"""§Perf optimizations must not change semantics: q-blocked triangular
attention, MoE sharding constraints, and the lazy positional optimizer all
agree with their baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig, RecsysConfig
from repro.models.layers import blocked_causal_attention, chunked_attention
from repro.models.transformer import init_lm, lm_loss


def test_blocked_attention_matches_chunked():
    rng = np.random.default_rng(0)
    b, hkv, g, s, dk, dv = 2, 2, 2, 64, 16, 16
    q = jnp.asarray(rng.standard_normal((b, hkv, g, s, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, dv)), jnp.float32)
    ref = chunked_attention(q, k, v, causal=True, chunk=16, q_start=0,
                            kv_len=s)
    for qb in (8, 16, 32, 64):
        got = blocked_causal_attention(q, k, v, q_block=qb, chunk=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5), qb
    # ragged final block
    got = blocked_causal_attention(q, k, v, q_block=48, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_qblock_config_equivalent_loss():
    base = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab=97, attn_chunk=16,
                    loss_chunk=8, dtype="float32")
    blocked = dataclasses.replace(base, attn_q_block=16)
    params = init_lm(jax.random.PRNGKey(0), base)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, 97),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32),
                                          0, 97)}
    l1, _ = lm_loss(params, batch, base)
    l2, _ = lm_loss(params, batch, blocked)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_remat_flag_equivalent_loss():
    base = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, d_ff=128, vocab=97, attn_chunk=16,
                    loss_chunk=8, dtype="float32")
    norem = dataclasses.replace(base, remat=False)
    params = init_lm(jax.random.PRNGKey(0), base)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, 97),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32),
                                          0, 97)}
    g1 = jax.grad(lambda p: lm_loss(p, batch, base)[0])(params)
    g2 = jax.grad(lambda p: lm_loss(p, batch, norem)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_lazy_optimizer_matches_dense_on_touched_rows():
    from repro.data.recsys_stream import recsys_batch, vocab_sizes
    from repro.models.recsys import (featurize, field_offsets, init_deepfm,
                                     make_deepfm_train_step,
                                     make_deepfm_train_step_lazy)
    from repro.optim import AdamW, constant

    cfg = RecsysConfig(name="t", vocab_scale=1e-4, embed_dim=8,
                       mlp_dims=(16,))
    opt = AdamW(lr=constant(1e-2), weight_decay=0.01, max_grad_norm=1e9)
    p0 = init_deepfm(jax.random.PRNGKey(0), cfg)
    off = jnp.asarray(field_offsets(cfg))
    d = recsys_batch(0, 0, 32, vocabs=vocab_sizes(1e-4))
    batch = {k: jnp.asarray(v) for k, v in d.items()}
    batch["offsets"] = off

    pd, _, md = jax.jit(make_deepfm_train_step(cfg, opt))(
        p0, opt.init(p0), batch)
    pl, _, ml = jax.jit(make_deepfm_train_step_lazy(cfg, opt))(
        p0, opt.init(p0), batch)
    assert abs(float(md["loss"]) - float(ml["loss"])) < 1e-6
    pos = np.unique(np.asarray(featurize(cfg, batch["dense"],
                                         batch["sparse"], off)).ravel())
    np.testing.assert_allclose(np.asarray(pd["table"])[pos],
                               np.asarray(pl["table"])[pos], atol=1e-6)
    untouched = np.setdiff1d(np.arange(p0["table"].shape[0]), pos)[:200]
    np.testing.assert_array_equal(np.asarray(pl["table"])[untouched],
                                  np.asarray(p0["table"])[untouched])
    # dense params identical treatment
    np.testing.assert_allclose(np.asarray(pd["mlp"][0]["w"]),
                               np.asarray(pl["mlp"][0]["w"]), atol=1e-6)
