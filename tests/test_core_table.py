import jax.numpy as jnp
import numpy as np

from repro.core.table import ColumnTable, RowTable, payload_names


def _table():
    return ColumnTable.from_numpy({
        "id": np.arange(10, dtype=np.int32),
        "from": np.arange(10, dtype=np.int32) % 3,
        "to": (np.arange(10, dtype=np.int32) * 7) % 10,
        "name": np.arange(40, dtype=np.float32).reshape(10, 4),
    })


def test_take_masks_sentinel():
    t = _table()
    out = t.take(jnp.asarray([0, 5, 10, 12], jnp.int32))   # 10+ = padding
    assert out["id"].tolist() == [0, 5, 0, 0]
    assert np.all(np.asarray(out["name"][2:]) == 0.0)
    assert np.allclose(np.asarray(out["name"][1]), [20, 21, 22, 23])


def test_select_and_width():
    t = _table()
    sel = t.select(["id", "name"])
    assert sel.names == ("id", "name")
    assert t.width_bytes(["id"]) == 4
    assert t.width_bytes(["name"]) == 16


def test_rowtable_roundtrip():
    t = _table()
    rt = RowTable.from_column_table(t)
    assert rt.width == 3 + 4
    assert np.allclose(np.asarray(rt.column("to")),
                       np.asarray(t.column("to")).astype(np.float32))
    rows = rt.take_rows(jnp.asarray([3, 11], jnp.int32))
    assert rows.shape == (2, 7)
    assert np.all(np.asarray(rows[1]) == 0.0)            # padding row
    proj = rt.project(rows, ["id", "from"])
    assert proj["id"][0] == 3.0


def test_payload_names():
    assert payload_names(3) == ["column1", "column2", "column3"]
