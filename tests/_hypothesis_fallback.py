"""Minimal hypothesis-compatible fallback so the property suites RUN when
the real package cannot be installed (offline CI containers).

``conftest.py`` registers this as ``sys.modules["hypothesis"]`` ONLY when
importing the real package fails; with it in place the three
``pytest.importorskip("hypothesis")`` suites (test_property, test_csr,
test_kernels) execute instead of perpetually skipping.  It covers exactly
the API surface this repo's tests use:

* ``@settings(max_examples=..., deadline=...)`` (deadline ignored),
* ``@given(st.integers(lo, hi), st.booleans(), st.lists(...))``,
* boundary-first, deterministically seeded example generation (seed
  derived from the test name, so failures reproduce run-to-run),
* hypothesis-style falsifying-example reporting on failure.

It does NOT shrink, track a database, or implement the full strategy
algebra — install the pinned real package (requirements-dev.txt) for
that.  When the real hypothesis is importable this module is never
registered.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

__all__ = ["given", "settings", "assume", "strategies", "install"]

_DEFAULT_MAX_EXAMPLES = 20


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class _Strategy:
    """A draw function (rng, example_index) -> value.  ``example_index``
    lets strategies emit boundary values first, like hypothesis does."""

    def __init__(self, draw, repr_):
        self._draw = draw
        self._repr = repr_

    def example(self, rng: random.Random, index: int):
        return self._draw(rng, index)

    def __repr__(self):
        return self._repr


def integers(min_value: int, max_value: int) -> _Strategy:
    bounds = []
    for b in (min_value, max_value, 0, 1):
        if min_value <= b <= max_value and b not in bounds:
            bounds.append(b)

    def draw(rng, index):
        if index < len(bounds):
            return bounds[index]
        return rng.randint(min_value, max_value)

    return _Strategy(draw, f"integers({min_value}, {max_value})")


def booleans() -> _Strategy:
    def draw(rng, index):
        if index < 2:
            return bool(index)
        return rng.random() < 0.5

    return _Strategy(draw, "booleans()")


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int | None = None) -> _Strategy:
    hi = max_size if max_size is not None else min_size + 16

    def draw(rng, index):
        n = min_size if index == 0 else rng.randint(min_size, hi)
        # large element index -> the element strategy's random regime
        return [elements.example(rng, 1000 + i) for i in range(n)]

    return _Strategy(draw, f"lists({elements!r}, {min_size}, {max_size})")


def sampled_from(options) -> _Strategy:
    options = list(options)

    def draw(rng, index):
        if index < len(options):
            return options[index]
        return rng.choice(options)

    return _Strategy(draw, f"sampled_from({options!r})")


# ---------------------------------------------------------------------------
# the runner: @settings + @given
# ---------------------------------------------------------------------------

class _Assumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Assumption()
    return True


class settings:
    """Decorator form only (the only form the suites use)."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_max_examples = self.max_examples
        return fn


def given(*arg_strategies, **kw_strategies):
    def decorate(test):
        @functools.wraps(test)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", None)
            if n is None:
                n = getattr(test, "_fallback_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(test.__qualname__.encode())
            for i in range(n):
                # integer seed: tuple (hash-based) seeding is deprecated
                rng = random.Random(seed * 1_000_003 + i)
                drawn = tuple(s.example(rng, i) for s in arg_strategies)
                kw = {k: s.example(rng, i)
                      for k, s in kw_strategies.items()}
                try:
                    test(*args, *drawn, **kw, **kwargs)
                except _Assumption:
                    continue
                except Exception:
                    print(f"Falsifying example (fallback engine, "
                          f"example {i}): {test.__qualname__}"
                          f"{drawn + tuple(kw.values())!r}",
                          file=sys.stderr)
                    raise

        # pytest must not mistake the drawn parameters for fixtures: hide
        # the wrapped signature and expose only the parameters NOT filled
        # by a strategy (positional strategies fill the rightmost ones,
        # matching hypothesis' convention)
        del wrapper.__wrapped__
        params = list(inspect.signature(test).parameters.values())
        if arg_strategies:
            params = params[: -len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper.is_hypothesis_test = True
        return wrapper

    return decorate


# ---------------------------------------------------------------------------
# module registration
# ---------------------------------------------------------------------------

def install() -> None:
    """Register the fallback under ``sys.modules['hypothesis']`` (and
    ``hypothesis.strategies``).  No-op if a ``hypothesis`` module — real or
    fallback — is already registered."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    mod.__version__ = "0.0.fallback"
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.booleans = booleans
    strat.lists = lists
    strat.sampled_from = sampled_from
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
