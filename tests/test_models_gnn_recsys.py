"""GNN + recsys behaviour tests beyond the smoke grid."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig, RecsysConfig
from repro.core.csr import build_csr
from repro.data.graphgen import make_graph
from repro.data.recsys_stream import recsys_batch, vocab_sizes
from repro.data.sampler import gather_block_features, sample_block
from repro.models.gnn import (gnn_forward, init_gnn, make_gnn_train_step,
                              sage_block_forward, segment_softmax)
from repro.models.recsys import (bce_loss, deepfm_forward, field_offsets,
                                 init_deepfm, make_deepfm_train_step,
                                 retrieval_scores, total_rows)
from repro.optim import AdamW, constant


def test_segment_softmax_sums_to_one():
    scores = jnp.asarray([1.0, 2.0, 3.0, -1.0, 0.0])
    seg = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    a = segment_softmax(scores, seg, 3)
    assert abs(float(a[0] + a[1]) - 1.0) < 1e-6
    assert abs(float(a[2] + a[3] + a[4]) - 1.0) < 1e-6


@pytest.mark.parametrize("kind", ["gatedgcn", "graphsage", "gat"])
def test_gnn_loss_descends(kind):
    g = make_graph(200, 1200, d_feat=12, num_classes=4, seed=8)
    graph = {"src": jnp.asarray(g.src), "dst": jnp.asarray(g.dst),
             "feats": jnp.asarray(g.feats), "labels": jnp.asarray(g.labels)}
    cfg = GNNConfig(name=kind, kind=kind, n_layers=2, d_hidden=16,
                    n_heads=2, d_feat=12, num_classes=4)
    p = init_gnn(jax.random.PRNGKey(0), cfg, 12, 4)
    opt = AdamW(lr=constant(5e-3), weight_decay=0.0)
    st = opt.init(p)
    step = jax.jit(make_gnn_train_step(cfg, opt))
    first = None
    for _ in range(30):
        p, st, m = step(p, st, graph)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.8


def test_egnn_equivariance():
    """EGNN logits must be invariant to rotation+translation of coords."""
    from repro.models.gnn import egnn_layer, init_egnn_layer
    rng = np.random.default_rng(0)
    n, e, d = 20, 60, 8
    h = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    lp = init_egnn_layer(jax.random.PRNGKey(1), d)
    h1, x1 = egnn_layer(lp, h, x, src, dst, n)
    # random rotation + translation
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    q = jnp.asarray(q.astype(np.float32))
    t = jnp.asarray([1.0, -2.0, 0.5])
    h2, x2 = egnn_layer(lp, h, x @ q + t, src, dst, n)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(x1 @ q + t), np.asarray(x2),
                               atol=2e-4)


def test_sage_block_equals_full_graph_on_complete_fanout():
    """With fanout >= max degree, sampled-mean == full-graph mean layer."""
    rng = np.random.default_rng(3)
    n = 30
    src, dst, k = [], [], 3
    for v in range(n):                    # regular out-degree-3 graph
        nbrs = rng.choice(n, size=k, replace=False)
        for u in nbrs:
            src.append(v); dst.append(int(u))
    # reverse edges: each node aggregates its OUT-neighbors in the sampler,
    # so build csr over src and aggregate dst
    import numpy as _np
    src, dst = _np.array(src, _np.int32), _np.array(dst, _np.int32)
    feats = jnp.asarray(rng.standard_normal((n, 6)).astype(np.float32))
    csr = build_csr(jnp.asarray(src), n)
    seeds = jnp.arange(n, dtype=jnp.int32)
    layers = sample_block(jax.random.PRNGKey(0), csr, jnp.asarray(dst),
                          seeds, (k,))
    nbrs = np.asarray(layers[1]).reshape(n, k)
    # with fanout == out-degree, sampling-with-replacement still draws from
    # exactly the neighbor set; means coincide only if all k distinct -> use
    # segment mean over TRUE adjacency to validate shape/masking instead
    assert nbrs.shape == (n, k)
    for v in range(n):
        truth = set(dst[src == v].tolist())
        assert set(nbrs[v].tolist()) <= truth


def test_deepfm_forward_and_retrieval():
    cfg = RecsysConfig(name="t", vocab_scale=1e-4, embed_dim=8,
                       mlp_dims=(16, 16))
    p = init_deepfm(jax.random.PRNGKey(0), cfg)
    assert p["table"].shape[0] == total_rows(cfg)
    assert total_rows(cfg) % 512 == 0            # mesh-divisible padding
    off = jnp.asarray(field_offsets(cfg))
    b = recsys_batch(0, 0, 32, vocabs=vocab_sizes(1e-4))
    logits = deepfm_forward(p, cfg, jnp.asarray(b["dense"]),
                            jnp.asarray(b["sparse"]), off)
    assert logits.shape == (32,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    scores = retrieval_scores(p, cfg, jnp.asarray(b["dense"][:1]),
                              jnp.asarray(b["sparse"][:1]), off,
                              jnp.arange(256, dtype=jnp.int32))
    assert scores.shape == (256,)


def test_deepfm_loss_descends():
    cfg = RecsysConfig(name="t", vocab_scale=1e-4, embed_dim=8,
                       mlp_dims=(16, 16))
    p = init_deepfm(jax.random.PRNGKey(0), cfg)
    off = jnp.asarray(field_offsets(cfg))
    opt = AdamW(lr=constant(1e-2), weight_decay=0.0)
    st = opt.init(p)
    step = jax.jit(make_deepfm_train_step(cfg, opt))
    d = recsys_batch(0, 0, 64, vocabs=vocab_sizes(1e-4))
    batch = {k: jnp.asarray(v) for k, v in d.items()}
    batch["offsets"] = off
    first = None
    for _ in range(30):
        p, st, m = step(p, st, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.9


def test_deepfm_pallas_parity():
    cfg = RecsysConfig(name="t", vocab_scale=1e-4, embed_dim=8,
                       mlp_dims=(16,))
    p = init_deepfm(jax.random.PRNGKey(0), cfg)
    off = jnp.asarray(field_offsets(cfg))
    b = recsys_batch(0, 0, 8, vocabs=vocab_sizes(1e-4))
    a1 = deepfm_forward(p, cfg, jnp.asarray(b["dense"]),
                        jnp.asarray(b["sparse"]), off, use_pallas=False)
    a2 = deepfm_forward(p, cfg, jnp.asarray(b["dense"]),
                        jnp.asarray(b["sparse"]), off, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=2e-5,
                               atol=2e-5)
