"""Chaos suite: every injected fault ends in a CLASSIFIED degraded answer
or a typed error — never a crash, a hang, or silently-wrong rows.

Each test arms one :mod:`repro.obs.faultinject` point (or feeds garbage
input, which needs no seam), drives the serving front door through it, and
asserts three things: (1) the session stays alive and keeps answering,
(2) the fault is VISIBLE — a typed exception, a ``RequestReport`` flag, a
metric, or a warning, and (3) rows on non-faulted lanes are bit-identical
to a fault-free baseline (row parity — a fault may truncate an answer,
never corrupt one).

The seam itself is also under test: ``injected()`` must disarm on every
exit path, so one chaos test can never leak a fault into the next.
"""
import json
import math
import os
import warnings

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import Dataset
from repro.data.treegen import TreeSpec, make_edge_table
from repro.obs import faultinject
from repro.planner import ServingSession, paper_listing
from repro.planner.guards import AdmissionError, InvalidRequestError
from repro.planner.plan_store import load_store, save_session

CAPS = EngineCaps(frontier=2048, result=4096)
ROOTS = [0, 1, 5, 77, 500, 1500, 2999]


@pytest.fixture(scope="module")
def tree_ds():
    spec = TreeSpec(num_vertices=3000, height=10, payload_cols=2, seed=11)
    return Dataset.prepare(make_edge_table(spec), spec.num_vertices)


@pytest.fixture(scope="module")
def sql():
    return paper_listing(1, root=0, depth=6)


@pytest.fixture(scope="module")
def baseline(tree_ds, sql):
    session = ServingSession(tree_ds, caps=CAPS)
    return session.submit(sql, ROOTS)


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    faultinject.clear()
    yield
    assert not faultinject.armed(), "a chaos test leaked an armed fault"
    faultinject.clear()


def _assert_parity(got, want):
    n = int(want.count)
    assert int(got.count) == n
    assert np.array_equal(np.asarray(got.values["id"])[:n],
                          np.asarray(want.values["id"])[:n])


# ---------------------------------------------------------------------------
# the seam
# ---------------------------------------------------------------------------

def test_seam_disarms_on_every_exit_path():
    with faultinject.injected("bucket_overflow"):
        assert faultinject.armed()
    assert not faultinject.armed()
    with pytest.raises(RuntimeError, match="boom"):
        with faultinject.injected("straggler_sleep", 0.5):
            raise RuntimeError("boom")
    assert not faultinject.armed()
    with pytest.raises(ValueError, match="unknown fault point"):
        faultinject.inject("not_a_point")


def test_consume_decrements_times():
    faultinject.inject("bucket_overflow", times=2)
    assert faultinject.consume("bucket_overflow")
    assert faultinject.consume("bucket_overflow")
    assert faultinject.consume("bucket_overflow") is None
    assert not faultinject.armed()


# ---------------------------------------------------------------------------
# fault class 1: bucket overflow -> bounded retry, identical rows
# ---------------------------------------------------------------------------

def test_forced_overflow_retries_and_keeps_row_parity(
        tree_ds, sql, baseline):
    session = ServingSession(tree_ds, caps=CAPS)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with faultinject.injected("bucket_overflow", times=1):
            out = session.submit(sql, ROOTS)
    rep = session.last_report
    assert rep.retries >= 1                       # the fault was VISIBLE
    assert session.stats["retry_budget_spent"] >= 1
    for got, want in zip(out, baseline):          # ...and harmless
        _assert_parity(got, want)


# ---------------------------------------------------------------------------
# fault class 2: stragglers under a deadline -> truncated, never hung
# ---------------------------------------------------------------------------

def test_straggler_under_deadline_truncates_with_parity(
        tree_ds, sql, baseline):
    session = ServingSession(tree_ds, caps=CAPS)
    session.submit(sql, ROOTS)                    # warm the plan + jit
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faultinject.injected("straggler_sleep", 0.05, times=None):
            out = session.submit(sql, ROOTS, deadline_us=20_000.0)
    rep = session.last_report
    assert rep.truncated                          # classified, not silent
    assert rep.skipped_buckets >= 1
    assert rep.skipped_roots                      # named, per root
    assert session.stats["deadline_skipped_buckets"] >= 1
    assert any("deadline" in str(x.message).lower() for x in w)
    skipped = set(rep.skipped_roots)
    for r, got, want in zip(ROOTS, out, baseline):
        if r in skipped:
            assert int(got.count) == 0            # degraded: empty, typed
        else:
            _assert_parity(got, want)             # non-faulted lane parity


def test_no_deadline_means_no_truncation(tree_ds, sql, baseline):
    session = ServingSession(tree_ds, caps=CAPS)
    with faultinject.injected("straggler_sleep", 0.01, times=2):
        out = session.submit(sql, ROOTS)
    assert not session.last_report.truncated
    for got, want in zip(out, baseline):
        _assert_parity(got, want)


# ---------------------------------------------------------------------------
# fault class 3: corrupted plan store -> warn + cold start + re-save
# ---------------------------------------------------------------------------

def test_corrupt_plan_store_cold_starts_and_recovers(
        tree_ds, sql, baseline, tmp_path):
    path = str(tmp_path / "store.json")
    writer = ServingSession(tree_ds, caps=CAPS)
    writer.submit(sql, ROOTS)
    save_session(writer, path)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with faultinject.injected("plan_store_corrupt"):
            session = ServingSession(tree_ds, caps=CAPS, plan_store=path)
    assert any("cold-start" in str(x.message) for x in w)
    assert not session._plans                     # nothing half-loaded
    out = session.submit(sql, ROOTS)              # ...and it still serves
    for got, want in zip(out, baseline):
        _assert_parity(got, want)
    # the recovered session re-saves a VALID store over the corpse
    save_session(session, path)
    assert load_store(path)["schema_version"] >= 6


@pytest.mark.parametrize("garbage", [
    "", "{not json", '{"kind": "plan_store"',
    json.dumps({"kind": "something_else"}),
    json.dumps({"kind": "plan_store", "schema_version": 99}),
])
def test_garbage_store_bytes_cold_start(tree_ds, sql, tmp_path, garbage):
    path = str(tmp_path / "store.json")
    with open(path, "w") as f:
        f.write(garbage)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        session = ServingSession(tree_ds, caps=CAPS, plan_store=path)
    assert any("cold-start" in str(x.message) for x in w)
    assert int(session.submit(sql, [0])[0].count) > 0


def test_direct_load_still_raises_typed(tmp_path):
    """The HARDENING lives in the session front door; the plan-store API
    itself keeps raising typed errors for tooling that wants them."""
    path = str(tmp_path / "store.json")
    with open(path, "w") as f:
        f.write("{definitely not json")
    with pytest.raises(json.JSONDecodeError):
        load_store(path)


# ---------------------------------------------------------------------------
# fault class 4: poisoned calibrator observations -> discarded, finite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("poison", [float("nan"), float("inf"), -5.0])
def test_poisoned_observations_never_corrupt_constants(
        tree_ds, sql, baseline, poison):
    session = ServingSession(tree_ds, caps=CAPS, calibrate_every=4)
    with faultinject.injected("calibrator_poison", poison, times=None):
        for _ in range(3):
            out = session.submit(sql, ROOTS)
    cal = session.calibrator
    assert cal.discarded > 0                      # the defense fired
    assert cal.count == 0                         # nothing poisoned entered
    c = cal.constants
    for v in (c.base_us, c.level_us, c.bytes_per_us, c.kernel_factor):
        assert v is None or (math.isfinite(v) and v > 0)
    for got, want in zip(out, baseline):
        _assert_parity(got, want)


def test_huge_but_finite_poison_cannot_flip_constants_sign(tree_ds, sql):
    session = ServingSession(tree_ds, caps=CAPS, calibrate_every=4)
    with faultinject.injected("calibrator_poison", 1e12, times=None):
        for _ in range(8):
            session.submit(sql, ROOTS)
    c = session.calibrator.constants
    for v in (c.base_us, c.level_us, c.bytes_per_us, c.kernel_factor):
        assert v is None or (math.isfinite(v) and v > 0)


# ---------------------------------------------------------------------------
# fault class 5: garbage requests -> typed errors, session stays alive
# ---------------------------------------------------------------------------

def test_garbage_roots_typed_then_session_still_serves(
        tree_ds, sql, baseline):
    session = ServingSession(tree_ds, caps=CAPS)
    for bad in ([-1], [tree_ds.num_vertices], [1.5], np.array(["x"])):
        with pytest.raises(InvalidRequestError):
            session.submit(sql, bad)
    out = session.submit(sql, ROOTS)
    for got, want in zip(out, baseline):
        _assert_parity(got, want)


def test_rejected_root_leaves_other_requests_untouched(
        tree_ds, sql, baseline):
    from repro.planner.calibrate import Calibrator
    from repro.planner.cost import DEFAULT_CONSTANTS
    tight = DEFAULT_CONSTANTS._replace(guard_degrade_us=1e-6,
                                       guard_reject_us=1e-3)
    session = ServingSession(tree_ds, caps=CAPS,
                             calibrator=Calibrator(prior=tight))
    with pytest.raises(AdmissionError):
        session.submit(sql, ROOTS)
    # same session, guards off the hook for cheap traffic: still alive
    session.guards = False
    out = session.submit(sql, ROOTS)
    for got, want in zip(out, baseline):
        _assert_parity(got, want)
