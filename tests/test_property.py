"""Hypothesis property suites over the engine's core invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import EngineCaps, build_csr
from repro.core.engine import Dataset, RecursiveQuery, run_query
from repro.core.positions import (append_block, block_from_mask,
                                  compact_mask, sort_positions_by_key,
                                  PosBlock)
from repro.core.table import ColumnTable
from repro.data.treegen import TreeSpec, bfs_reference, make_edge_table


@settings(max_examples=30, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=60),
       st.integers(1, 80))
def test_compact_mask_invariants(mask, cap):
    m = np.array(mask)
    blk = compact_mask(jnp.asarray(m), cap, sentinel=999)
    n = int(blk.count)
    assert n == min(int(m.sum()), cap)
    got = np.asarray(blk.positions)[:n]
    assert got.tolist() == list(np.nonzero(m)[0][:cap])    # ordered
    assert np.all(np.asarray(blk.positions)[n:] == 999)    # sentinel


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_append_block_never_wraps(seed):
    rng = np.random.default_rng(seed)
    cap_r = int(rng.integers(4, 40))
    buf = jnp.full((cap_r,), -1, jnp.int32)
    count = jnp.zeros((), jnp.int32)
    total = 0
    overflowed = False
    for _ in range(3):
        k = int(rng.integers(0, 20))
        pos = jnp.asarray(rng.integers(0, 100, max(k, 1)).astype(np.int32))
        blk = PosBlock(pos, jnp.asarray(min(k, pos.shape[0]), jnp.int32))
        buf, count, ovf = append_block(buf, count, blk)
        total += int(blk.count)
        overflowed |= bool(ovf)
    assert int(count) == min(total, cap_r)
    assert overflowed == (total > cap_r)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 8))
def test_sort_positions_groups_by_bucket(seed, nb):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, nb, int(rng.integers(1, 100))).astype(np.int32)
    order, counts = sort_positions_by_key(jnp.asarray(keys), nb)
    sorted_keys = keys[np.asarray(order)]
    assert np.all(np.diff(sorted_keys) >= 0)               # grouped
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.bincount(keys, minlength=nb))
    # a permutation: every position exactly once
    assert sorted(np.asarray(order).tolist()) == list(range(len(keys)))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_engine_equivalence_random_trees(seed):
    """PRecursive == TRecursive == bitmap == oracle on random trees."""
    rng = np.random.default_rng(seed)
    v = int(rng.integers(20, 300))
    h = int(rng.integers(2, 10))
    depth = int(rng.integers(0, h + 2))
    spec = TreeSpec(num_vertices=v, height=h, payload_cols=1,
                    seed=seed % 10_000)
    ds = Dataset.prepare(make_edge_table(spec), v)
    caps = EngineCaps(frontier=v + 8, result=v + 8)
    ref = bfs_reference(np.asarray(ds.table.column("from")),
                        np.asarray(ds.table.column("to")), 0, depth, v)
    ref_ids = sorted(
        int(np.asarray(ds.table.column("id"))[p])
        for p in set().union(*ref[:depth + 1]))
    for eng in ("precursive", "trecursive", "bitmap", "rowstore"):
        r = run_query(RecursiveQuery(eng, depth, 1, caps), ds, 0)
        got = sorted(int(x) for x in
                     np.asarray(r.values["id"])[:int(r.count)])
        assert got == ref_ids, eng


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_block_from_mask_matches_nonzero(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 80))
    vals = rng.integers(0, 1000, n).astype(np.int32)
    mask = rng.random(n) < 0.5
    cap = int(rng.integers(1, 100))
    blk, ovf = block_from_mask(jnp.asarray(vals), jnp.asarray(mask), cap, -1)
    expect = vals[mask][:cap]
    got = np.asarray(blk.positions)[:int(blk.count)]
    np.testing.assert_array_equal(got, expect)
    assert bool(ovf) == (int(mask.sum()) > cap)
