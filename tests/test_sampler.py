import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import build_csr
from repro.data.graphgen import make_graph
from repro.data.sampler import gather_block_features, sample_block


def _setup():
    g = make_graph(300, 2400, d_feat=8, seed=5)
    csr = build_csr(jnp.asarray(g.src), 300)
    return g, csr


def test_block_shapes():
    g, csr = _setup()
    seeds = jnp.arange(16, dtype=jnp.int32)
    layers = sample_block(jax.random.PRNGKey(0), csr,
                          jnp.asarray(g.dst), seeds, (5, 3))
    assert [l.shape[0] for l in layers] == [16, 80, 240]
    feats = gather_block_features(jnp.asarray(g.feats), layers)
    assert feats[0].shape == (240, 8)       # deepest first
    assert feats[-1].shape == (16, 8)


def test_sampled_neighbors_are_adjacent():
    g, csr = _setup()
    adj = {}
    for s, d in zip(g.src, g.dst):
        adj.setdefault(int(s), set()).add(int(d))
    seeds = jnp.arange(32, dtype=jnp.int32)
    layers = sample_block(jax.random.PRNGKey(1), csr,
                          jnp.asarray(g.dst), seeds, (4,))
    nbrs = np.asarray(layers[1]).reshape(32, 4)
    for i in range(32):
        options = adj.get(i, set())
        for nb in nbrs[i]:
            if options:
                assert int(nb) in options
            else:
                assert int(nb) == i         # isolated: self-loop fallback


def test_isolated_vertex_self_loop():
    src = jnp.asarray([0, 0], jnp.int32)
    dst = jnp.asarray([1, 2], jnp.int32)
    csr = build_csr(src, 5)
    layers = sample_block(jax.random.PRNGKey(0), csr, dst,
                          jnp.asarray([4], jnp.int32), (3,))
    assert np.all(np.asarray(layers[1]) == 4)


def test_sampler_deterministic_in_key():
    g, csr = _setup()
    seeds = jnp.arange(8, dtype=jnp.int32)
    a = sample_block(jax.random.PRNGKey(7), csr, jnp.asarray(g.dst), seeds,
                     (4, 2))
    b = sample_block(jax.random.PRNGKey(7), csr, jnp.asarray(g.dst), seeds,
                     (4, 2))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
