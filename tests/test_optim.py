import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamW, clip_by_global_norm, constant, cosine_decay,
                         global_norm, linear_warmup_cosine, sgd_momentum)


def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 5.0]), "b": jnp.asarray(4.0)}


def _loss(p):
    return jnp.sum(p["w"] ** 2) + p["b"] ** 2


def test_adamw_converges_quadratic():
    p = _quadratic_params()
    opt = AdamW(lr=constant(0.1), weight_decay=0.0)
    st = opt.init(p)
    for _ in range(200):
        g = jax.grad(_loss)(p)
        p, st, _ = opt.update(p, g, st)
    assert float(_loss(p)) < 1e-3


def test_sgd_momentum_converges():
    p = _quadratic_params()
    opt = sgd_momentum(lr=constant(0.05))
    st = opt.init(p)
    for _ in range(200):
        g = jax.grad(_loss)(p)
        p, st, _ = opt.update(p, g, st)
    assert float(_loss(p)) < 1e-3


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((2, 2)) * 4.0}
    clipped, g = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(g) > 1.0
    small, g2 = clip_by_global_norm({"a": jnp.asarray([0.1])}, 1.0)
    assert abs(float(small["a"][0]) - 0.1) < 1e-7   # untouched below max


def test_schedules():
    s = jnp.asarray
    warm = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(warm(s(0))) == 0.0
    assert abs(float(warm(s(10))) - 1.0) < 1e-6
    assert float(warm(s(90))) < float(warm(s(20)))
    cd = cosine_decay(1.0, 100, final_frac=0.1)
    assert abs(float(cd(s(0))) - 1.0) < 1e-6
    assert abs(float(cd(s(100))) - 0.1) < 1e-6


def test_adamw_weight_decay_shrinks():
    p = {"w": jnp.asarray([10.0])}
    opt = AdamW(lr=constant(0.1), weight_decay=0.5)
    st = opt.init(p)
    zero_g = {"w": jnp.asarray([0.0])}
    p2, _, _ = opt.update(p, zero_g, st)
    assert float(p2["w"][0]) < 10.0
