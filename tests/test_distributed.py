"""Multi-device behaviour (subprocess: host-platform device count is fixed
at first jax init, so sharded tests get their own interpreter)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import subprocess_env


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=subprocess_env(devices))
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_distributed_bfs_matches_oracle():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EngineCaps
        from repro.core.distributed_bfs import make_distributed_pbfs
        from repro.data.treegen import TreeSpec, make_edge_table, bfs_reference
        from repro.launch.mesh import make_mesh

        spec = TreeSpec(num_vertices=2049, height=9, payload_cols=2, seed=3)
        table = make_edge_table(spec)
        src = np.asarray(table.column("from")); dst = np.asarray(table.column("to"))
        mesh = make_mesh((8,), ("data",))
        caps = EngineCaps(frontier=1024, result=1024)
        fn = make_distributed_pbfs(mesh, ("data",), spec.num_vertices,
                                   caps=caps, max_depth=6, num_payload_cols=2)
        pay = np.asarray(table.column("column1"))
        sh = NamedSharding(mesh, P("data"))
        gpos, vals, counts, depths, ovfs = fn(
            jax.device_put(src, sh), jax.device_put(dst, sh),
            jax.device_put(pay, sh), jnp.int32(0))
        gpos = np.asarray(gpos)
        got = set(int(x) for x in gpos if x >= 0)
        ref = set().union(*bfs_reference(src, dst, 0, 6, spec.num_vertices)[:7])
        assert got == ref, (len(got), len(ref))
        # late materialization: values match the gathered positions
        vals = np.asarray(vals); e_loc = src.shape[0] // 8
        for s in range(8):
            for j in range(1024):
                g = gpos[s*1024 + j]
                if g >= 0:
                    lp = g - s*e_loc
                    assert np.allclose(vals[s*1024 + j], pay[s*e_loc + lp])
        print("OK")
    """)
    assert "OK" in out


def test_shard_map_dp_with_grad_compression():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import psum_compressed
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4,), ("data",))
        g_local = {"w": jnp.arange(4.0)[:, None] + jnp.arange(3.0)[None, :]}

        def body(gs, scheme, res):
            red, res2 = psum_compressed(gs, "data", scheme, res)
            return red["w"]

        from repro.core.distributed_bfs import shard_map_compat
        x = jax.device_put(jnp.stack([g_local["w"]]*4),
                           jax.sharding.NamedSharding(mesh, P("data")))
        for scheme in ("none", "bf16", "int8_ef"):
            res = {"w": jnp.zeros((4, 3))} if scheme == "int8_ef" else None
            fn = shard_map_compat(
                lambda xs: body({"w": xs[0]}, scheme, res),
                mesh, P("data"), P())
            out = fn(x)
            err = float(jnp.max(jnp.abs(out - g_local["w"])))
            tol = {"none": 1e-6, "bf16": 0.05, "int8_ef": 0.1}[scheme]
            assert err <= tol, (scheme, err)
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_checkpoint_elastic_remesh():
    """Save params sharded on 8 devices; restore onto a 4-device mesh."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.launch.mesh import make_mesh

        d = tempfile.mkdtemp()
        mesh8 = make_mesh((8,), ("data",))
        w = jnp.arange(64.0).reshape(8, 8)
        ws = jax.device_put(w, NamedSharding(mesh8, P("data")))
        path = save_checkpoint(d, 3, {"w": ws})

        mesh4 = make_mesh((4,), ("data",))
        sh4 = {"w": NamedSharding(mesh4, P("data"))}
        r = restore_checkpoint(path, {"w": w}, shardings=sh4)
        assert r["w"].sharding.mesh.shape["data"] == 4
        np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(w))
        print("OK")
    """)
    assert "OK" in out


def test_int8_error_feedback_reduces_bias():
    out = _run("""
        import jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import compress_int8_ef, decompress_int8
        g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(512) * 1e-3)}
        res = {"w": jnp.zeros(512)}
        # accumulate the same gradient repeatedly; EF keeps the mean unbiased
        acc, n = jnp.zeros(512), 24
        for _ in range(n):
            q, s, res = compress_int8_ef(g, res)
            acc = acc + decompress_int8(q, s)["w"]
        err = float(jnp.max(jnp.abs(acc / n - g["w"])))
        raw_q, raw_s, _ = compress_int8_ef(g, {"w": jnp.zeros(512)})
        raw_err = float(jnp.max(jnp.abs(decompress_int8(raw_q, raw_s)["w"] - g["w"])))
        assert err < raw_err * 0.5, (err, raw_err)
        print("OK")
    """, devices=1)
    assert "OK" in out
