"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.csr import build_csr, expand_frontier
from repro.kernels.embedding_bag import (embedding_bag, embedding_bag_ref,
                                         fixed_hot_lookup)
from repro.kernels.frontier_expand import frontier_expand_fused
from repro.kernels.frontier_pull import (frontier_pull_fused,
                                         frontier_pull_ref)
from repro.kernels.late_gather import (late_gather_pallas, late_gather_ref,
                                       materialize)
from repro.kernels.spmm_segment import (gcn_norm_spmm, spmm_segment,
                                        spmm_segment_ref)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("r,w,p", [(8, 1, 4), (64, 37, 25), (128, 128, 200),
                                   (33, 260, 7)])
def test_late_gather_sweep(dtype, r, w, p):
    tab = jnp.asarray(RNG.standard_normal((r, w)) * 10).astype(dtype)
    pos = jnp.asarray(RNG.integers(0, r + 5, p).astype(np.int32))
    a = late_gather_pallas(tab, pos)
    b = late_gather_ref(tab, pos)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_materialize_fused_multicolumn():
    cols = {"a": jnp.asarray(RNG.standard_normal(50).astype(np.float32)),
            "b": jnp.asarray(RNG.standard_normal((50, 3)).astype(np.float32)),
            "i": jnp.arange(50, dtype=jnp.int32)}
    pos = jnp.asarray([0, 7, 49, 50, 60], jnp.int32)
    out = materialize(cols, pos, ["a", "b", "i"], use_pallas=True)
    assert out["a"].shape == (5,)
    assert out["b"].shape == (5, 3)
    assert int(out["i"][2]) == 49 and int(out["i"][3]) == 0
    ref = materialize(cols, pos, ["a", "b", "i"], use_pallas=False)
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-6)


@pytest.mark.parametrize("d", [1, 7, 10, 128, 200])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag_sweep(d, weighted):
    r, i, b = 40, 70, 9
    tab = jnp.asarray(RNG.standard_normal((r, d)).astype(np.float32))
    idx = jnp.asarray(RNG.integers(0, r + 3, i).astype(np.int32))
    seg = jnp.asarray(RNG.integers(0, b, i).astype(np.int32))  # unsorted,
    w = jnp.asarray(RNG.standard_normal(i).astype(np.float32)) \
        if weighted else None                                   # empty bags
    a = embedding_bag(tab, idx, seg, b, w, use_pallas=True)
    ref = embedding_bag_ref(tab, idx, seg, b, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref), atol=1e-4)


def test_embedding_bag_mean_combiner():
    tab = jnp.eye(6, dtype=jnp.float32)
    idx = jnp.asarray([0, 1, 2, 3], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1], jnp.int32)
    out = embedding_bag(tab, idx, seg, 3, combiner="mean", use_pallas=True)
    assert np.allclose(np.asarray(out[0]), [0.5, 0.5, 0, 0, 0, 0])
    assert np.allclose(np.asarray(out[2]), 0.0)


def test_fixed_hot_lookup():
    tab = jnp.asarray(RNG.standard_normal((30, 8)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 30, (4, 5)).astype(np.int32))
    a = fixed_hot_lookup(tab, ids, use_pallas=True)
    b = fixed_hot_lookup(tab, ids, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("n,e,d", [(10, 30, 4), (50, 200, 17),
                                   (30, 100, 128)])
def test_spmm_sweep(n, e, d):
    x = jnp.asarray(RNG.standard_normal((n, d)).astype(np.float32))
    src = jnp.asarray(RNG.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(RNG.integers(0, n, e).astype(np.int32))
    w = jnp.asarray(RNG.standard_normal(e).astype(np.float32))
    a = spmm_segment(x, src, dst, w, n, use_pallas=True)
    b = spmm_segment_ref(x, src, dst, w, n)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_gcn_norm_parity():
    n, e, d = 20, 80, 9
    x = jnp.asarray(RNG.standard_normal((n, d)).astype(np.float32))
    src = jnp.asarray(RNG.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(RNG.integers(0, n, e).astype(np.int32))
    a = gcn_norm_spmm(x, src, dst, n, use_pallas=True)
    b = gcn_norm_spmm(x, src, dst, n, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_frontier_kernel_property(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(4, 60))
    e = int(rng.integers(2, 300))
    src = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    csr = build_csr(src, v)
    f = int(rng.integers(1, 30))
    targets = jnp.asarray(rng.integers(-1, v, f).astype(np.int32))
    valid = jnp.asarray(rng.random(f) < 0.8)
    cap = int(rng.integers(8, e + 16))
    ea, ta, oa = expand_frontier(csr, targets, valid, cap)
    eb, tb, ob = frontier_expand_fused(csr, targets, valid, cap)
    np.testing.assert_array_equal(np.asarray(ea), np.asarray(eb))
    assert int(ta) == int(tb) and bool(oa) == bool(ob)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_frontier_pull_kernel_property(seed):
    """The Pallas bottom-up membership kernel == the XLA reverse-CSR pull
    on random graphs, frontiers and visited sets."""
    rng = np.random.default_rng(seed)
    v = int(rng.integers(4, 60))
    e = int(rng.integers(2, 300))
    src = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    rcsr = build_csr(dst, v)
    frontier = jnp.asarray(rng.random(v) < 0.3)
    visited = jnp.asarray(rng.random(v) < 0.4) | frontier
    a = frontier_pull_ref(rcsr, src, dst, frontier, visited)
    b = frontier_pull_fused(rcsr, src, dst, frontier, visited)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
