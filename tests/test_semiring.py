"""The semiring value plane, end to end.

The refactor's contract has two halves, and this suite pins both:

* **reach is bit-identical** — the boolean workload now runs through the
  same split ⊗-propagate / ⊕-combine operators as the weighted ones, with
  ``or_combine`` as its ⊕.  ``tests/golden/reach_parity.json`` froze the
  EXACT pre-refactor output (positions in emission order, ids, depths,
  overflow) of every engine x direction on two seeded graphs;
  ``test_reach_golden_parity`` replays all of it and compares bytes, not
  row sets.
* **weighted workloads are correct** — (min, +) shortest path keeps the
  MINIMUM distance over competing paths (the satellite-1 regression: a
  2-hop detour must beat a heavier direct edge), walk aggregations fold
  ``⊕ over paths of ⊗ over edges`` exactly like the UNION ALL reference,
  and the whole planner/serving/plan-store stack carries the workload
  axis: SQL with ``t.depth + e.w`` or ``SUM(t.value * e.qty)`` plans onto
  the weighted engines, buckets through the shared executor, survives an
  EXPLAIN round trip at schema v5 and a plan-store rehydration.

The ``spmm_segment`` cells check the dense ⊕-combine kernel (satellite
2): interpret-mode parity against the jnp reference and a finite measured
kernel factor for the cost model.
"""
import json
import os

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import (ENGINE_NAMES, WEIGHTED_ENGINE_NAMES, Dataset,
                               RecursiveQuery, build_plan, run_query,
                               run_query_batch)
from repro.core.semiring import (SEMIRINGS, WORKLOADS, get_semiring,
                                 or_combine)
from repro.core.table import ColumnTable

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
DIRECTIONS = ("outbound", "inbound", "both")


def _edge_dataset(src, dst, num_vertices, w=None, payload=4):
    e = len(src)
    cols = {
        "id": np.arange(e, dtype=np.int32),
        "from": np.asarray(src, np.int32),
        "to": np.asarray(dst, np.int32),
        "name": np.zeros((e, payload), np.float32)}
    if w is not None:
        cols["w"] = np.asarray(w, np.float32)
    return Dataset.prepare(ColumnTable.from_numpy(cols), num_vertices)


def _weighted_query(engine, workload, *, max_depth, caps,
                    direction="outbound"):
    return RecursiveQuery(engine=engine, max_depth=max_depth,
                          payload_cols=0, caps=caps, dedup=False,
                          direction=direction, workload=workload,
                          weight_col="w")


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

def test_semiring_registry():
    assert set(SEMIRINGS) == {"shortest_path", "aggregate_sum",
                              "aggregate_max", "aggregate_min",
                              "aggregate_mul"}
    assert WORKLOADS == ("reach", *SEMIRINGS)
    sp = get_semiring("shortest_path")
    assert sp.improving and np.isinf(sp.identity)
    assert get_semiring("aggregate_sum").identity == 0.0
    # 'reach' deliberately has NO registry entry: boolean BFS never goes
    # through the generic ⊕-scatter, so asking for it is a bug
    with pytest.raises(ValueError):
        get_semiring("reach")
    with pytest.raises(ValueError):
        get_semiring("nope")


def test_or_combine_is_the_boolean_plus():
    import jax.numpy as jnp
    acc = jnp.zeros(4, jnp.int32)
    out = or_combine(acc, jnp.asarray([1, 1, 3]), jnp.asarray([1, 1, 1]))
    assert out.tolist() == [0, 1, 0, 1]


# ---------------------------------------------------------------------------
# satellite 1: minimum distance survives competing paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", WEIGHTED_ENGINE_NAMES)
def test_sssp_min_distance_regression(engine):
    """0→1 direct costs 10; 0→2→1 costs 2.  A max/last-writer dedup
    scatter (the pre-refactor ``.at[...].max``) would keep 10."""
    ds = _edge_dataset([0, 0, 2], [1, 2, 1], 3, w=[10.0, 1.0, 1.0])
    caps = EngineCaps(frontier=32, result=64)
    q = _weighted_query(engine, "shortest_path", max_depth=4, caps=caps)
    r = run_query(q, ds, 0)
    vv = np.asarray(r.vertex_values)
    assert vv[0] == 0.0
    assert vv[2] == 1.0
    assert vv[1] == 2.0, f"{engine} kept {vv[1]}, not the min-distance 2.0"


@pytest.mark.parametrize("engine", WEIGHTED_ENGINE_NAMES)
def test_sssp_label_correcting_convergence(engine):
    """A longer-hop cheaper path found AFTER a shorter-hop expensive one
    must still win: fixed_point converges on value stabilization, not on
    first visit (1-hop w=9 vs 3-hop w=3)."""
    ds = _edge_dataset([0, 0, 2, 3], [1, 2, 3, 1], 4,
                       w=[9.0, 1.0, 1.0, 1.0])
    caps = EngineCaps(frontier=32, result=64)
    q = _weighted_query(engine, "shortest_path", max_depth=6, caps=caps)
    vv = np.asarray(run_query(q, ds, 0).vertex_values)
    assert vv.tolist() == [0.0, 3.0, 1.0, 2.0]


# ---------------------------------------------------------------------------
# walk aggregation == the UNION ALL per-path fold
# ---------------------------------------------------------------------------

def _reference_fold(src, dst, w, root, max_depth, combine, prop, init, seed):
    """Per-vertex fold of ⊗-products over ALL depth-bounded walks from the
    root — the semantics of the UNION ALL recursive CTE the aggregate
    workloads replace."""
    vals = {root: seed}          # walk-value mass arriving at each vertex
    total = {root: seed}
    frontier = {root: seed}
    for _ in range(max_depth + 1):
        nxt = {}
        for s, d, wt in zip(src, dst, w):
            if s in frontier:
                x = prop(frontier[s], wt)
                nxt[d] = combine(nxt.get(d, init), x)
        if not nxt:
            break
        for k, v in nxt.items():
            total[k] = combine(total.get(k, init), v)
        frontier = nxt
    return total


@pytest.mark.parametrize("engine", WEIGHTED_ENGINE_NAMES)
@pytest.mark.parametrize("workload,combine,prop,init,seedv", [
    ("aggregate_sum", lambda a, b: a + b, lambda a, b: a * b, 0.0, 1.0),
    ("aggregate_max", max, lambda a, b: a * b, -np.inf, 1.0),
    ("aggregate_min", min, lambda a, b: a * b, np.inf, 1.0),
])
def test_aggregate_matches_reference_fold(engine, workload, combine, prop,
                                          init, seedv):
    rng = np.random.default_rng(5)
    v, e = 12, 20
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = rng.uniform(0.5, 2.0, e)
    depth = 3
    ds = _edge_dataset(src, dst, v, w=w)
    want = _reference_fold(src, dst, w, 0, depth, combine, prop, init, seedv)
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    q = _weighted_query(engine, workload, max_depth=depth, caps=caps)
    vv = np.asarray(run_query(q, ds, 0).vertex_values)
    for vertex, val in want.items():
        assert vv[vertex] == pytest.approx(val, rel=1e-5), (engine, vertex)


# ---------------------------------------------------------------------------
# the bit-identity anchor: pre-refactor reach golden, all engines x dirs
# ---------------------------------------------------------------------------

_GOLDEN_GRAPHS = (
    dict(seed=3, num_vertices=17, num_edges=40, max_depth=4),
    dict(seed=12, num_vertices=29, num_edges=70, max_depth=6),
)


def _golden_dataset(g):
    rng = np.random.default_rng(g["seed"])
    src = rng.integers(0, g["num_vertices"], size=g["num_edges"])
    dst = rng.integers(0, g["num_vertices"], size=g["num_edges"])
    table = ColumnTable.from_numpy({
        "id": np.arange(g["num_edges"], dtype=np.int32),
        "from": src.astype(np.int32),
        "to": dst.astype(np.int32),
        "name": rng.standard_normal(
            (g["num_edges"], 4)).astype(np.float32),
    })
    return Dataset.prepare(table, g["num_vertices"])


@pytest.mark.parametrize("g", _GOLDEN_GRAPHS,
                         ids=[f"g{g['seed']}" for g in _GOLDEN_GRAPHS])
def test_reach_golden_parity(g):
    """Every engine x legal direction reproduces the pre-refactor snapshot
    EXACTLY — counts, final depth, overflow, positions in emission order,
    ids, row depths.  Regenerate only for an intended output change:
    ``PYTHONPATH=src python scripts/gen_reach_golden.py``."""
    with open(os.path.join(GOLDEN_DIR, "reach_parity.json")) as f:
        golden = json.load(f)
    ds = _golden_dataset(g)
    caps = EngineCaps(frontier=g["num_edges"] + 16,
                      result=4 * g["num_edges"] + 16)
    compared = 0
    for engine in ENGINE_NAMES:
        for direction in DIRECTIONS:
            key = f"g{g['seed']}/{engine}/{direction}"
            if key not in golden:
                continue
            q = RecursiveQuery(engine=engine, max_depth=g["max_depth"],
                               payload_cols=0, caps=caps,
                               direction=direction)
            r = run_query(q, ds, root=0)
            want = golden[key]
            assert int(r.count) == want["count"], key
            assert int(r.depth) == want["depth"], key
            assert bool(r.overflow) == want["overflow"], key
            assert np.asarray(r.positions).tolist() == want["positions"], key
            assert (np.asarray(r.values["id"]).tolist()
                    == want["ids"]), key
            if "row_depths" in want:
                assert (np.asarray(r.row_depths).tolist()
                        == want["row_depths"]), key
            compared += 1
    assert compared >= 20    # both graphs together cover all 50 cells


def test_reach_has_no_value_plane():
    ds = _golden_dataset(_GOLDEN_GRAPHS[0])
    caps = EngineCaps(frontier=64, result=176)
    q = RecursiveQuery(engine="precursive", max_depth=4, payload_cols=0,
                       caps=caps)
    r = run_query(q, ds, 0)
    assert r.vertex_values is None


# ---------------------------------------------------------------------------
# satellite 2: the dense ⊕-combine kernel
# ---------------------------------------------------------------------------

def test_spmm_segment_interpret_parity():
    from repro.kernels.spmm_segment import spmm_segment, spmm_segment_ref
    rng = np.random.default_rng(9)
    n, e, d = 37, 90, 4
    x = rng.standard_normal((n, d)).astype(np.float32)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    w = rng.random(e).astype(np.float32)
    # disable a few edges the WeightedDenseStep way: src index == n
    src[::7] = n
    ref = spmm_segment_ref(x, src, dst, w, n)
    got = spmm_segment(x, src, dst, w, n, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_weighted_dense_kernel_path_matches_plain():
    """The bitmap plan with ``use_kernel=True`` (spmm_segment ⊕-combine in
    interpret mode) returns the same distances as the plain scatter."""
    from repro.core.bitmap import weighted_bitmap_plan
    from repro.core.operators import execute
    rng = np.random.default_rng(4)
    v, e = 24, 60
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = rng.uniform(0.5, 2.0, e)
    ds = _edge_dataset(src, dst, v, w=w)
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    q = _weighted_query("bitmap", "shortest_path", max_depth=5, caps=caps)
    plain = run_query(q, ds, 0)
    kplan = weighted_bitmap_plan(caps, 5, q.out_cols, "shortest_path",
                                 use_kernel=True)
    ctx = ds.context("outbound", weight_col="w")
    kern = execute(kplan, ctx, 0, ds.num_vertices)
    np.testing.assert_allclose(np.asarray(kern.vertex_values),
                               np.asarray(plain.vertex_values),
                               rtol=1e-5, atol=1e-5)


def test_measured_spmm_kernel_factor():
    from repro.planner.calibrate import KERNEL_NAMES, measured_kernel_factor
    assert "spmm_segment" in KERNEL_NAMES
    f = measured_kernel_factor(kernel="spmm_segment")
    assert np.isfinite(f) and f > 0.0
    # cached per (backend, kernel)
    assert measured_kernel_factor(kernel="spmm_segment") == f


# ---------------------------------------------------------------------------
# planner + serving + plan store: the workload axis end to end
# ---------------------------------------------------------------------------

def _weighted_graph_dataset():
    rng = np.random.default_rng(21)
    v, e = 50, 140
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = rng.uniform(0.5, 3.0, e)
    return _edge_dataset(src, dst, v, w=w), v, e


def test_weighted_sql_plans_onto_weighted_engines():
    from repro.planner import plan
    from repro.planner.ast import parse, weighted_listing
    ds, v, e = _weighted_graph_dataset()
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    sql = weighted_listing("shortest_path", root=0, depth=6, weight_col="w")
    ast = parse(sql)
    assert ast.workload == "shortest_path" and ast.weight_col == "w"
    report = plan(sql, ds, caps=caps)
    ranked = {c.label for c in report.ranked}
    assert ranked == set(WEIGHTED_ENGINE_NAMES)
    reasons = dict(report.skipped)
    for eng in ENGINE_NAMES:
        if eng not in WEIGHTED_ENGINE_NAMES:
            assert "value plane" in reasons[eng], eng
    # dressed rows carry the value column, min-folded per vertex
    r = report.best.run(ds, 0)
    n = int(r.count)
    vals = np.asarray(r.values["value"])[:n]
    tos = np.asarray(r.values["to"])[:n]
    vv = np.asarray(r.vertex_values)
    for t, val in zip(tos, vals):
        assert val >= vv[int(t)] - 1e-6


def test_weighted_aggregate_sql_round_trip():
    from repro.planner import plan
    from repro.planner.ast import parse, weighted_listing
    ds, v, e = _weighted_graph_dataset()
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    sql = weighted_listing("aggregate_sum", root=0, depth=3, weight_col="w")
    ast = parse(sql)
    assert ast.workload == "aggregate_sum" and ast.union_all
    report = plan(sql, ds, caps=caps)
    assert report.best.label in WEIGHTED_ENGINE_NAMES
    r = report.best.run(ds, 0)
    assert r.vertex_values is not None and int(r.count) > 0


def test_weighted_serving_and_plan_store_round_trip(tmp_path):
    from repro.planner.ast import weighted_listing
    from repro.planner.explain import PLAN_SCHEMA_VERSION
    from repro.planner.plan_store import migrate_plan_doc
    from repro.planner.serving import ServingSession, shape_key
    ds, v, e = _weighted_graph_dataset()
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    sql = weighted_listing("shortest_path", root=0, depth=6, weight_col="w")
    roots = [0, 3, 7]

    sess = ServingSession(ds, caps=caps)
    out = sess.submit(sql, roots)
    assert len(out) == len(roots)
    entry = sess.plan_for(sql, roots)
    assert shape_key(entry.report.logical)[-2:] == ("shortest_path", "w")

    doc = sess.explain_analyze(sql, roots)
    assert doc["schema_version"] == PLAN_SCHEMA_VERSION
    assert doc["logical"]["workload"] == "shortest_path"
    assert doc["logical"]["weight_col"] == "w"
    assert doc["analyze"]["mode"] == "serving"

    def _min_fold(r):
        n = int(r.count)
        out = {}
        for t, val in zip(np.asarray(r.values["to"])[:n],
                          np.asarray(r.values["value"])[:n]):
            t = int(t)
            out[t] = min(out.get(t, np.inf), float(val))
        return out

    path = str(tmp_path / "store.json")
    sess.save_plan_store(path)
    warm = ServingSession(ds, caps=caps, plan_store=path)
    out2 = warm.submit(sql, roots)
    assert warm.counters["parse_calls"] == 0
    assert warm.counters["cost_calls"] == 0
    for a, b in zip(out, out2):
        fa, fb = _min_fold(a), _min_fold(b)
        assert set(fa) == set(fb)
        for k in fa:
            assert fa[k] == pytest.approx(fb[k], rel=1e-6)


def test_plan_doc_v4_migrates_with_reach_defaults():
    from repro.planner.ast import weighted_listing
    from repro.planner.explain import PLAN_SCHEMA_VERSION
    from repro.planner.plan_store import migrate_plan_doc
    from repro.planner.serving import ServingSession
    ds, v, e = _weighted_graph_dataset()
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    sess = ServingSession(ds, caps=caps)
    doc = sess.plan_json(
        weighted_listing("shortest_path", root=0, depth=4, weight_col="w"),
        [0])
    v4 = json.loads(json.dumps(doc))
    v4["schema_version"] = 4
    v4["logical"].pop("workload", None)
    v4["logical"].pop("weight_col", None)
    for c in v4.get("candidates", []):
        c.pop("semiring", None)
    m = migrate_plan_doc(v4)
    assert m["schema_version"] == PLAN_SCHEMA_VERSION
    assert m["logical"]["workload"] == "reach"
    assert m["logical"]["weight_col"] is None
    assert all(c.get("semiring") == "reach"
               for c in m.get("candidates", []))


def test_plan_signature_carries_workload():
    from repro.planner.calibrate import plan_signature
    caps = EngineCaps(frontier=64, result=64)
    a = plan_signature("precursive", "outbound", caps, "digest",
                       workload="shortest_path")
    b = plan_signature("precursive", "outbound", caps, "digest")
    assert a != b
    assert a[-1] == "shortest_path" and b[-1] == "reach"


def test_weighted_plan_golden_snapshot():
    """The weighted plan document (schema v5) is golden-snapshotted like
    the three reach listings: an unintended costing or schema change for
    the weighted path must show up as a diff.  Regenerate with
    ``PYTHONPATH=src python scripts/gen_plan_weighted_golden.py`` after an
    INTENDED change."""
    from repro.planner import explain_json
    from repro.planner.ast import weighted_listing
    ds, v, e = _weighted_graph_dataset()
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    sql = weighted_listing("shortest_path", root=0, depth=6, weight_col="w")
    got = explain_json(sql, ds, caps=caps)
    with open(os.path.join(GOLDEN_DIR, "plan_weighted.json")) as f:
        want = json.load(f)
    assert got == want
    assert json.loads(json.dumps(got)) == want


# ---------------------------------------------------------------------------
# weighted buckets through the shared executor
# ---------------------------------------------------------------------------

def test_weighted_bucketed_dispatch_matches_lockstep():
    from repro.core.engine import dispatch_buckets
    from repro.planner.ast import normalize, parse, weighted_listing
    from repro.planner.optimize import bucket_roots, plan
    ds, v, e = _weighted_graph_dataset()
    caps = EngineCaps(frontier=e + 16, result=4 * e + 16)
    sql = weighted_listing("shortest_path", root=0, depth=6, weight_col="w")
    lg = normalize(parse(sql), ds)
    best = plan(lg, ds, caps=caps).best
    roots = [0, 5, 9, 14, 20]
    buckets = bucket_roots(ds, roots, direction="outbound", max_depth=6,
                           dedup=best.query.dedup, caps=caps, max_buckets=3)

    import dataclasses as _dc

    def _dispatch(i, b, bcaps):
        q = (best.query if bcaps == best.query.caps
             else _dc.replace(best.query, caps=bcaps))
        return run_query_batch(q, ds, list(b.roots))

    out = dispatch_buckets(buckets, _dispatch, fallback_caps=caps,
                           to_host=False)
    lockstep = run_query_batch(best.query, ds, roots)
    for i, r in enumerate(out):
        np.testing.assert_allclose(
            np.asarray(r.vertex_values),
            np.asarray(lockstep.vertex_values[i]), rtol=1e-5, atol=1e-5)
