"""LM behaviour tests: loss descent, decode/prefill consistency, MLA cache
shape advantage, MoE dispatch conservation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig, MLAConfig, MoEConfig
from repro.models import transformer as tfm
from repro.models.layers import moe_ffn
from repro.optim import AdamW, constant

TINY = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=97, attn_chunk=16, loss_chunk=8,
                dtype="float32")
TINY_MOE = dataclasses.replace(
    TINY, n_kv_heads=4,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, d_expert=32,
                  capacity_factor=8.0))
TINY_MLA = dataclasses.replace(
    TINY_MOE,
    mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16,
                  v_head_dim=16))


@pytest.mark.parametrize("cfg", [TINY, TINY_MOE, TINY_MLA],
                         ids=["gqa", "moe", "mla-moe"])
def test_loss_descends_on_fixed_batch(cfg):
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=constant(3e-3), weight_decay=0.0)
    state = opt.init(params)
    step = jax.jit(tfm.make_train_step(cfg, opt))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                          0, cfg.vocab)}
    first = None
    for i in range(25):
        params, state, m = step(params, state, batch)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first * 0.7, (first, float(m["loss"]))


@pytest.mark.parametrize("cfg", [TINY, TINY_MOE, TINY_MLA],
                         ids=["gqa", "moe", "mla-moe"])
def test_decode_matches_prefill(cfg):
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab)
    logits, cache = jax.jit(
        lambda p, t: tfm.prefill(p, t, cfg, max_len=16))(params, toks[:, :8])
    dec = jax.jit(lambda p, t, c: tfm.decode_step(p, t, c, cfg))
    for i in range(3):
        logits, cache = dec(params, toks[:, 8 + i], cache)
    full, _ = jax.jit(
        lambda p, t: tfm.prefill(p, t, cfg, max_len=16))(params,
                                                         toks[:, :11])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                               atol=2e-4)
    assert int(cache.length) == 11


def test_mla_cache_is_latent_sized():
    cache = tfm.init_cache(TINY_MLA, batch=2, max_len=64)
    assert cache.a.shape == (2, 2, 64, 32)      # (L, B, S, kv_lora)
    assert cache.b.shape == (2, 2, 64, 8)       # (L, B, S, rope_dim)
    gqa_cache = tfm.init_cache(TINY, batch=2, max_len=64)
    assert gqa_cache.a.shape == (2, 2, 64, 2, 16)
    mla_bytes = cache.a.size + cache.b.size
    gqa_bytes = gqa_cache.a.size + gqa_cache.b.size
    assert mla_bytes < gqa_bytes                # the MLA cache saving


def test_moe_dispatch_conserves_tokens():
    """Every token's MoE output = weighted sum of its top-k expert outputs;
    with identity-ish experts and cf large, output magnitude is bounded and
    aux loss is near the uniform-routing value (= aux_weight for E·f·p)."""
    cfg = TINY_MOE
    key = jax.random.PRNGKey(0)
    from repro.models.layers import init_moe
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert 0 < float(aux) < 0.1


def test_sliding_window_masks_long_context():
    cfg = dataclasses.replace(TINY, attn_window=4)
    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 24), 0, cfg.vocab)
    # changing tokens OUTSIDE the window must not change the last logits
    toks2 = toks.at[0, 0:8].set((toks[0, 0:8] + 1) % cfg.vocab)
    h1, _ = tfm.prefill(params, toks, cfg)
    h2, _ = tfm.prefill(params, toks2, cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)
