"""Observability layer: metrics, structured tracing, EXPLAIN ANALYZE, and
the serving surface.

The load-bearing guarantees:

* the JSONL trace round-trips (``write_jsonl`` -> ``read_jsonl`` is the
  identity on records) and passes the CI checker
  (``scripts/check_trace.py``: header, span fields, id/parent forest, time
  nesting);
* spans NEST: every child span's interval sits inside its parent's, and
  the Chrome-trace export is loadable trace-event JSON;
* a DISABLED tracer records nothing, and the uninstalled-tracer path
  returns one shared no-op context manager (the hot-path cost is an
  attribute read — the perf gate's ``disabled_tracer_ratio`` cell holds
  the measured cost at parity);
* EXPLAIN ANALYZE's actual per-operator rows are EXACT: derived from the
  executed ``BFSResult`` (``row_depths`` histogram == the fixed point's
  per-level emissions), and on graphs whose sampled stats are exact (a
  star: the only source vertex IS the sampled root) predicted == actual
  for every engine, including the per-level push/pull directions the
  direction-optimizing engines took;
* the serving session surfaces overflow retries (metrics counter +
  ``stats['overflow_retries']`` + a once-per-session warning) instead of
  absorbing them silently, and ``stats`` keeps every pre-observability
  key while adding histogram-backed latency quantiles.
"""
import json
import warnings

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import (DIROPT_ENGINE_NAMES, ENGINE_NAMES,
                               BucketTiming, Dataset, RecursiveQuery,
                               overflow_retry_count, run_query,
                               run_query_buckets)
from repro.core.table import ColumnTable
from repro.data.treegen import TreeSpec, make_edge_table
from repro.obs import (MetricsRegistry, Tracer, current_tracer, read_jsonl,
                       set_tracer, trace_span)
from repro.obs.metrics import Histogram
from repro.planner import (ServingSession, explain_analyze, paper_listing,
                           render_analyze)
from repro.planner.optimize import RootBucket

CAPS = EngineCaps(frontier=2048, result=4096)


def _edge_dataset(src, dst, num_vertices, payload_cols=0):
    e = len(src)
    cols = {
        "id": np.arange(e, dtype=np.int32),
        "from": np.asarray(src, np.int32),
        "to": np.asarray(dst, np.int32),
        "name": np.zeros((e, 4), np.float32)}
    for i in range(payload_cols):
        cols[f"column{i + 1}"] = np.full((e,), float(i), np.float32)
    return Dataset.prepare(ColumnTable.from_numpy(cols), num_vertices)


def _star_dataset(spokes, payload_cols=0):
    """Vertex 0 -> 1..spokes.  The ONLY source vertex is 0, so the stats
    sampler's roots are exactly {0} and the frontier profile is EXACT —
    the graph where predicted must equal actual to the row."""
    src = np.zeros(spokes, np.int32)
    dst = np.arange(1, spokes + 1, dtype=np.int32)
    return _edge_dataset(src, dst, spokes + 1, payload_cols)


@pytest.fixture(scope="module")
def tree_ds():
    spec = TreeSpec(num_vertices=3000, height=10, payload_cols=4, seed=11)
    return Dataset.prepare(make_edge_table(spec), spec.num_vertices)


def _load_check_trace():
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "check_trace.py")
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_and_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g", "a gauge")
    g.set(7)
    g.inc(-2)
    assert g.value == 5
    # get-or-create returns the SAME instrument; kind mismatch is an error
    assert reg.counter("c_total") is c
    with pytest.raises(TypeError):
        reg.gauge("c_total")


def test_histogram_quantiles_bounded_memory():
    h = Histogram("h_us")
    for v in range(1, 1001):
        h.observe(float(v))
    snap = h.snapshot()
    assert snap["count"] == 1000
    assert snap["sum"] == pytest.approx(500500.0)
    assert snap["min"] == 1.0 and snap["max"] == 1000.0
    # log-bucketed: quantiles are approximate but bucket-bounded
    assert 350 <= snap["p50"] <= 700
    assert 800 <= snap["p95"] <= 1000
    assert 900 <= snap["p99"] <= 1000
    assert snap["p50"] <= snap["p95"] <= snap["p99"]
    # memory is the FIXED bucket vector, not the observation count
    assert len(h.counts) == len(h.bounds) + 1
    h.observe(1e12)                      # beyond the top bound -> overflow
    assert h.snapshot()["max"] == 1e12


def test_registry_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("repro_x_total", "help text").inc(2)
    reg.histogram("repro_lat_us", "latency").observe(5.0)
    text = reg.render_text()
    assert "# HELP repro_x_total help text" in text
    assert "# TYPE repro_x_total counter" in text
    assert "repro_x_total 2" in text
    assert "# TYPE repro_lat_us histogram" in text
    assert 'repro_lat_us_bucket{le="+Inf"} 1' in text
    assert "repro_lat_us_count 1" in text
    # cumulative buckets are monotone nondecreasing
    counts = [float(line.rsplit(" ", 1)[1]) for line in text.splitlines()
              if line.startswith("repro_lat_us_bucket")]
    assert counts == sorted(counts)


# ---------------------------------------------------------------------------
# tracer: roundtrip, nesting, chrome export, disabled path
# ---------------------------------------------------------------------------

def test_trace_jsonl_roundtrip_and_checker(tmp_path):
    tr = Tracer(meta={"suite": "test_obs"})
    with tr.span("request", n=1):
        with tr.span("parse"):
            pass
        with tr.span("dispatch", engine="bitmap") as attrs:
            tr.event("level", level=0, dir="push", edges=4, frontier=1)
            attrs["rows"] = 4
    path = str(tmp_path / "trace.jsonl")
    tr.write_jsonl(path)
    back = read_jsonl(path)
    assert back == list(tr.iter_records())
    assert back[0]["type"] == "header"
    assert back[0]["meta"] == {"suite": "test_obs"}
    # the attrs dict mutated mid-span landed in the record
    disp = next(r for r in back if r.get("name") == "dispatch")
    assert disp["attrs"] == {"engine": "bitmap", "rows": 4}
    # the CI checker accepts it
    mod = _load_check_trace()
    assert mod.check_trace(back, min_spans=3) == []
    # ...and rejects a corrupted parent and a broken nesting
    bad = json.loads(json.dumps(back))
    next(r for r in bad if r.get("name") == "parse")["parent"] = 999
    assert any("parent 999" in e for e in mod.check_trace(bad))
    bad2 = json.loads(json.dumps(back))
    next(r for r in bad2 if r.get("name") == "parse")["ts_us"] = 1e9
    assert any("does not nest" in e for e in mod.check_trace(bad2))


def test_spans_nest_in_time():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    spans = {r["id"]: r for r in tr.records if r["type"] == "span"}
    inner = next(r for r in spans.values() if r["name"] == "inner")
    outer = next(r for r in spans.values() if r["name"] == "outer")
    assert inner["parent"] == outer["id"] and outer["parent"] is None
    assert inner["ts_us"] >= outer["ts_us"]
    assert inner["ts_us"] + inner["dur_us"] \
        <= outer["ts_us"] + outer["dur_us"]


def test_chrome_trace_export(tmp_path):
    tr = Tracer()
    with tr.span("a"):
        tr.event("tick", k=1)
    doc = tr.chrome_trace()
    assert json.loads(json.dumps(doc)) == doc        # strict JSON
    assert doc["otherData"]["schema_version"] == 1
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert phs == {"X", "i"}
    for e in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0
    p = str(tmp_path / "trace.json")
    tr.write_chrome_trace(p)
    assert json.load(open(p)) == doc


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x"):
        tr.event("y")
    assert tr.records == []
    prev = set_tracer(tr)
    try:
        assert current_tracer() is None      # disabled == not installed
        # the uninstalled/disabled hot path: ONE shared no-op context
        assert trace_span("a") is trace_span("b")
    finally:
        set_tracer(prev)


def test_engine_emits_dispatch_span_and_level_events(tree_ds):
    tr = Tracer()
    prev = set_tracer(tr)
    try:
        r = run_query(RecursiveQuery("bitmap", 5, 0, CAPS), tree_ds, 0)
    finally:
        set_tracer(prev)
    spans = [x for x in tr.records if x["type"] == "span"]
    assert any(s["name"] == "dispatch" for s in spans)
    levels = [x for x in tr.records
              if x["type"] == "event" and x["name"] == "level"]
    assert levels, "enabled tracer must emit per-level events"
    # the traced per-level edge counts ARE the executed result's rows
    assert sum(e["attrs"]["edges"] for e in levels) == int(r.count)
    assert [e["attrs"]["level"] for e in levels] \
        == list(range(len(levels)))
    for e in levels:
        assert e["attrs"]["dir"] in (None, "push", "pull", "mixed")


# ---------------------------------------------------------------------------
# EXPLAIN ANALYZE: actuals are exact; predictions exact on exact stats
# ---------------------------------------------------------------------------

def _assert_exact(doc):
    a = doc["analyze"]
    assert doc["schema_version"] == 6
    assert a["actual"]["rows"] == a["result_count"]
    assert a["predicted"]["rows"] == pytest.approx(a["actual"]["rows"])
    assert a["predicted"]["levels"] == a["actual"]["levels"]
    for op in a["ops"]:
        assert {"label", "rows_predicted", "bytes_predicted",
                "rows_actual", "bytes_actual"} <= set(op)
        assert op["rows_predicted"] == pytest.approx(op["rows_actual"])
        assert op["bytes_predicted"] == pytest.approx(op["bytes_actual"])
    for lv in a["levels"]:
        assert lv["edges_predicted"] == pytest.approx(lv["edges_actual"])
    return a


@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_explain_analyze_exact_on_star_every_engine(engine):
    ds = _star_dataset(48)
    sql = paper_listing(1, root=0, depth=3)
    doc = explain_analyze(sql, ds, engine=engine, caps=CAPS)
    a = _assert_exact(doc)
    assert a["engine"] == engine
    assert a["result_count"] == 48
    assert not a["overflow"]


@pytest.mark.parametrize("engine", DIROPT_ENGINE_NAMES)
def test_explain_analyze_direction_reconciliation(engine, tree_ds):
    """Direction-optimizing engines: the analyze doc reports BOTH the
    predicted and the taken per-level push/pull, decoded from the executed
    result's ``level_dirs``."""
    sql = paper_listing(1, root=0, depth=6)
    doc = explain_analyze(sql, tree_ds, engine=engine, caps=CAPS)
    a = doc["analyze"]
    assert a["actual"]["rows"] == a["result_count"]
    taken = [lv["dir_taken"] for lv in a["levels"]]
    predicted = [lv["dir_predicted"] for lv in a["levels"]]
    assert any(d in ("push", "pull") for d in taken)
    assert all(d in (None, "push", "pull") for d in taken + predicted)
    assert a["actual"]["level_dirs"] == taken


@pytest.mark.parametrize("listing", [1, 2, 3])
def test_explain_analyze_listings_actuals_exact(listing, tree_ds):
    """The acceptance bar: on Listings 1.1-1.3 the per-op actual rows are
    EXACTLY the executed BFSResult's counts (sampled tree stats make the
    PREDICTIONS approximate; the ACTUALS are derived from the result)."""
    from repro.planner import plan

    n_pay = 0 if listing == 1 else 4
    sql = paper_listing(listing, root=0, depth=7, payload_cols=n_pay)
    doc = explain_analyze(sql, tree_ds, caps=CAPS)
    a = doc["analyze"]
    report = plan(sql, tree_ds, caps=CAPS)     # the same chosen plan
    assert report.best.label == a["engine"]
    r = report.best.run(tree_ds, 0)
    n = int(r.count)
    assert a["result_count"] == n
    assert a["actual"]["rows"] == n
    rd = np.asarray(r.row_depths)[:n]
    want_levels = np.bincount(rd[rd >= 0]).tolist()
    got_levels = [lv["edges_actual"] for lv in a["levels"]]
    assert got_levels[:len(want_levels)] == want_levels
    assert all(e == 0 for e in got_levels[len(want_levels):])
    for op in a["ops"]:
        assert op["rows_actual"] >= 0
    text = render_analyze(doc)
    assert "predicted" in text and a["engine"] in text


def _check_star_seed(seed):
    rng = np.random.RandomState(seed)
    spokes = int(rng.randint(4, 200))
    ds = _star_dataset(spokes)
    doc = explain_analyze(paper_listing(1, root=0, depth=2), ds, caps=CAPS)
    a = _assert_exact(doc)
    assert a["result_count"] == spokes


@pytest.mark.parametrize("seed", [0, 3, 17, 255])
def test_explain_analyze_exact_star_seeded(seed):
    _check_star_seed(seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    pass
else:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_explain_analyze_exact_star_random(seed):
        _check_star_seed(seed)


# ---------------------------------------------------------------------------
# overflow-retry surfacing (engine executor + serving session)
# ---------------------------------------------------------------------------

def test_dispatch_retry_counted_and_stamped(tree_ds):
    from repro.core import engine as eng_mod

    q = RecursiveQuery("bitmap", 6, 0, CAPS)
    tiny = EngineCaps(frontier=4, result=8)       # guaranteed overflow
    buckets = [RootBucket(indices=(0,), roots=(0,), caps=tiny,
                          predicted_reach=8, predicted_depth=6)]
    eng_mod._overflow_state["warned"] = False     # arm the one-shot warn
    before = overflow_retry_count()
    with pytest.warns(RuntimeWarning, match="overflow"):
        out = run_query_buckets(q, tree_ds, buckets)
    assert overflow_retry_count() == before + 1
    # the retry is TRANSPARENT: the result matches an unbucketed run
    want = run_query(q, tree_ds, 0)
    assert int(out[0].count) == int(want.count)
    # ...and a second retry does not warn again (once per process)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        run_query_buckets(q, tree_ds, buckets)
    assert overflow_retry_count() == before + 2


def test_bucket_timing_carries_predicted_caps(tree_ds):
    from repro.core.engine import dispatch_buckets, run_query_batch

    q = RecursiveQuery("bitmap", 6, 0, CAPS)
    tiny = EngineCaps(frontier=4, result=8)
    buckets = [RootBucket(indices=(0,), roots=(0,), caps=tiny,
                          predicted_reach=8, predicted_depth=6)]
    import dataclasses as dc
    timings = []

    def _dispatch(i, b, caps):
        qb = dc.replace(q, caps=caps) if caps != q.caps else q
        return run_query_batch(qb, tree_ds, b.roots)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        dispatch_buckets(buckets, _dispatch, fallback_caps=CAPS,
                         observer=timings.append)
    (t,) = timings
    assert isinstance(t, BucketTiming)
    assert t.retried
    assert t.predicted_caps == tiny               # what bucketing PRICED
    assert t.caps == CAPS                         # what the retry RAN with


def test_serving_surfaces_overflow_retry(tree_ds):
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    session.submit(sql, [0, 1])
    entry = session.plan_for(sql, [0, 1])
    observe = session._observer(entry, calibrate=False)
    tiny = EngineCaps(frontier=4, result=8)
    timing = BucketTiming(index=0, lanes=1, padded_lanes=1, caps=CAPS,
                          retried=True, elapsed_us=123.0,
                          predicted_caps=tiny)
    with pytest.warns(RuntimeWarning, match="overflowed its predicted"):
        observe(timing)
    observe(timing)                    # second retry: counted, NOT rewarned
    st = session.stats
    assert st["overflow_retries"] == 2
    assert session.metrics()["repro_overflow_retries_total"] == 2


# ---------------------------------------------------------------------------
# serving session: stats compatibility + metrics + explain_analyze
# ---------------------------------------------------------------------------

def test_serving_stats_keeps_old_keys_adds_quantiles(tree_ds):
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    for _ in range(3):
        session.submit(sql, [0, 1, 2])
    st = session.stats
    # every pre-observability key survives
    for k in ("requests", "plan_hits", "plan_misses", "cached_shapes",
              "cached_plans", "last_latency_us", "parse_calls",
              "stats_calls", "cost_calls", "calibration_observations",
              "calibration_refits"):
        assert k in st, k
    assert st["requests"] == 3
    # ...plus the histogram-backed view
    assert 0.0 <= st["plan_hit_rate"] <= 1.0
    assert st["latency_us_p50"] > 0
    assert st["latency_us_p50"] <= st["latency_us_p95"] \
        <= st["latency_us_p99"]
    assert st["overflow_retries"] == 0
    assert st["calibration_refits_rejected"] >= 0


def test_serving_metrics_registry_and_text(tree_ds):
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    session.submit(sql, [0, 1])
    session.submit(sql, [0, 1])
    m = session.metrics()
    assert m["repro_requests_total"] == 2
    assert m["repro_roots_served_total"] == 4
    assert m["repro_request_latency_us"]["count"] == 2
    assert m["repro_plan_cache_hits_total"] \
        + m["repro_plan_cache_misses_total"] > 0
    text = session.metrics_text()
    assert "# TYPE repro_request_latency_us histogram" in text
    assert "repro_requests_total 2" in text
    assert "repro_calibration_refits_total" in text


def test_serving_session_tracer_traces_requests(tree_ds):
    tr = Tracer()
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS, tracer=tr)
    session.submit(sql, [0, 1])
    session.submit(sql, [0, 1])
    assert current_tracer() is None          # restored after each request
    spans = [r for r in tr.records if r["type"] == "span"]
    names = [s["name"] for s in spans]
    assert names.count("request") == 2
    assert "parse" in names and "plan" in names
    assert "compile" in names                # the cold first serve
    assert "dispatch" in names and "transfer" in names
    # warm flag flips between the two requests
    reqs = [s for s in spans if s["name"] == "request"]
    assert [r["attrs"]["warm"] for r in reqs] == [False, True]
    # every span parents back to a request span (forest nesting)
    mod = _load_check_trace()
    assert mod.check_trace(list(tr.iter_records()), min_spans=5) == []
    levels = [r for r in tr.records
              if r["type"] == "event" and r["name"] == "level"]
    assert levels


def test_serving_explain_analyze_groups_by_bucket(tree_ds):
    sql = paper_listing(1, root=0, depth=4)
    session = ServingSession(tree_ds, caps=CAPS)
    roots = [0, 1, 2, 7]
    doc = session.explain_analyze(sql, roots)
    assert doc["schema_version"] == 6
    an = doc["analyze"]
    assert an["mode"] == "serving"
    seen_roots = []
    for b in an["buckets"]:
        assert b["engine"]
        for root, a in zip(b["roots"], b["analyze"]):
            assert a["root"] == root
            assert a["actual"]["rows"] == a["result_count"]
            seen_roots.append(root)
    assert sorted(seen_roots) == sorted(roots)
    # per-root actuals reconcile against direct single-root runs (a
    # multi-lane bucket may plan the batch-only bit-parallel engine,
    # which has no single-root form — every engine is row-count
    # identical, so reconcile those against the bitmap reference)
    eng = an["buckets"][0]["engine"]
    if eng == "multiquery":
        eng = "bitmap"
    want = {r: int(run_query(
        RecursiveQuery(eng, 4, 0, CAPS),
        tree_ds, r).count) for r in (0,)}
    a0 = next(a for b in an["buckets"] for r, a in zip(b["roots"],
              b["analyze"]) if r == 0)
    assert a0["result_count"] == want[0]
