"""Direction-optimizing traversal + the fused bidirectional CSR.

The load-bearing guarantees:

* **diropt parity** — the direction-optimizing engines are row-for-row
  IDENTICAL (positions, depths, counts, loop accounting) to their
  push-only counterparts (``diropt`` vs ``bitmap``, ``diropt_hybrid`` vs
  ``hybrid``) on random graphs, every legal direction, regardless of what
  the per-level switch decides — the push and pull branches compute the
  same level, so thresholds steer performance only;
* **forced pull** — pinning the switch to the pull side (huge alpha/beta)
  exercises :class:`PullStep`/:class:`HybridPullStep` on every level and
  must still match the push-only engines, with ``level_dirs`` recording
  all-pull;
* **fused == doubled** — the fused bidirectional view (E-sized columns,
  out/in CSRs + merged indptr, virtual 2E join space) produces results
  bit-identical to the OLD materialized doubled view (2E concat columns +
  2E CSR) for every engine on ``direction='both'``, and the fused view's
  added arrays are E-scale;
* the switch decision surfaces in ``BFSResult.level_dirs`` and in the
  planner's predicted ``PlanCost.level_dirs``.

The deterministic seeded slice always runs; the hypothesis property (real
package or the vendored fallback engine) extends the seed set.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.bitmap import diropt_hybrid_plan, diropt_plan
from repro.core.csr import build_csr
from repro.core.engine import (DIROPT_ENGINE_NAMES, ENGINE_NAMES,
                               PUSH_COUNTERPART, Dataset, RecursiveQuery,
                               build_plan, run_query)
from repro.core.operators import Context, execute
from repro.core.table import ColumnTable

DIRECTIONS = ("outbound", "inbound", "both")
OUT_COLS = ("id", "from", "to", "name")


def _edge_dataset(src, dst, num_vertices):
    e = len(src)
    cols = {
        "id": np.arange(e, dtype=np.int32),
        "from": np.asarray(src, np.int32),
        "to": np.asarray(dst, np.int32),
        "name": np.zeros((e, 4), np.float32)}
    return Dataset.prepare(ColumnTable.from_numpy(cols), num_vertices)


def _random_graph(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(6, 48))
    e = int(rng.integers(2, 3 * v))
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    depth = int(rng.integers(1, 6))
    root = int(rng.integers(0, v))
    return src, dst, v, root, depth


def _caps(e, direction):
    n = 2 * e if direction == "both" else e
    return EngineCaps(frontier=n + 16, result=n + 16)


def _assert_same(a, b, tag):
    assert int(a.count) == int(b.count), tag
    assert int(a.depth) == int(b.depth), tag
    assert bool(a.overflow) == bool(b.overflow), tag
    assert np.array_equal(np.asarray(a.positions),
                          np.asarray(b.positions)), tag
    assert np.array_equal(np.asarray(a.row_depths),
                          np.asarray(b.row_depths)), tag
    for k in b.values:
        assert np.array_equal(np.asarray(a.values[k]),
                              np.asarray(b.values[k])), (tag, k)


# ---------------------------------------------------------------------------
# 1. diropt engines == their push-only counterparts, every direction
# ---------------------------------------------------------------------------

def _check_diropt_parity(seed):
    src, dst, v, root, depth = _random_graph(seed)
    ds = _edge_dataset(src, dst, v)
    for direction in DIRECTIONS:
        caps = _caps(len(src), direction)
        for eng in DIROPT_ENGINE_NAMES:
            ref = run_query(RecursiveQuery(PUSH_COUNTERPART[eng], depth, 0,
                                           caps, direction=direction),
                            ds, root)
            got = run_query(RecursiveQuery(eng, depth, 0, caps,
                                           direction=direction), ds, root)
            _assert_same(got, ref, (eng, direction, seed))
            dirs = np.asarray(got.level_dirs)
            assert dirs.shape[0] >= int(got.depth)
            assert set(dirs.tolist()) <= {-1, 0, 1}, (eng, direction)


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_diropt_matches_push_only_seeded(seed):
    _check_diropt_parity(seed)


# ---------------------------------------------------------------------------
# 2. forced pull: every level bottom-up, same rows
# ---------------------------------------------------------------------------

def _check_forced_pull(seed):
    src, dst, v, root, depth = _random_graph(seed)
    ds = _edge_dataset(src, dst, v)
    for direction in DIRECTIONS:
        caps = _caps(len(src), direction)
        ref_b = run_query(RecursiveQuery("bitmap", depth, 0, caps,
                                         direction=direction), ds, root)
        plan = diropt_plan(caps, depth, OUT_COLS, direction=direction,
                           alpha=1e9, beta=1e9)
        got = execute(plan, ds.context(direction), root, v)
        _assert_same(got, ref_b, ("diropt-pull", direction, seed))
        dirs = np.asarray(got.level_dirs)
        assert (dirs[: int(got.depth)] == 1).all(), (direction, seed)

        ref_h = run_query(RecursiveQuery("hybrid", depth, 0, caps,
                                         direction=direction), ds, root)
        hplan = diropt_hybrid_plan(caps, depth, OUT_COLS,
                                   direction=direction, alpha=1e9,
                                   beta=1e9)
        goth = execute(hplan, ds.context(direction), root, v)
        _assert_same(goth, ref_h, ("hybrid-pull", direction, seed))


@pytest.mark.parametrize("seed", [1, 7])
def test_forced_pull_matches_push_seeded(seed):
    _check_forced_pull(seed)


def test_pull_kernel_plugs_into_diropt():
    """The Pallas frontier_pull kernel (interpret mode) as PullStep's
    expand_fn: same rows as the XLA pull and the push baseline."""
    from repro.planner.calibrate import kernel_pull_fn

    src, dst, v, root, depth = _random_graph(23)
    ds = _edge_dataset(src, dst, v)
    ds.ensure_reverse()                     # the pull kernel walks it
    caps = _caps(len(src), "outbound")
    ref = run_query(RecursiveQuery("bitmap", depth, 0, caps), ds, root)
    plan = diropt_plan(caps, depth, OUT_COLS, alpha=1e9, beta=1e9,
                       pull_fn=kernel_pull_fn())
    got = execute(plan, ds.context("outbound"), root, v)
    _assert_same(got, ref, "kernel-pull")


# ---------------------------------------------------------------------------
# 3. fused bidirectional CSR == the old doubled 2E view, every engine
# ---------------------------------------------------------------------------

def _doubled_context(ds: Dataset) -> Context:
    """The PRE-FUSION 'both' view, reconstructed: materialized 2E concat
    columns and a CSR over them (what Dataset used to cache)."""
    both_src = jnp.concatenate([ds.table.column("from"),
                                ds.table.column("to")])
    both_dst = jnp.concatenate([ds.table.column("to"),
                                ds.table.column("from")])
    return Context(table=ds.table, rows=ds.rows,
                   csr=build_csr(both_src, ds.num_vertices),
                   join_src=both_src, join_dst=both_dst,
                   rcsr=build_csr(both_dst, ds.num_vertices))


def _check_fused_equals_doubled(seed):
    src, dst, v, root, depth = _random_graph(seed)
    ds = _edge_dataset(src, dst, v)
    caps = _caps(len(src), "both")
    old_ctx = _doubled_context(ds)
    fused_ctx = ds.context("both")
    assert fused_ctx.bidir and not old_ctx.bidir
    for eng in ENGINE_NAMES:
        if eng.startswith("rowstore"):
            continue                       # outbound-only baseline
        plan = build_plan(RecursiveQuery(eng, depth, 0, caps,
                                         direction="both"))
        got = execute(plan, fused_ctx, root, v)
        want = execute(plan, old_ctx, root, v)
        _assert_same(got, want, (eng, seed))


@pytest.mark.parametrize("seed", [2, 5, 13])
def test_fused_both_view_equals_doubled_seeded(seed):
    _check_fused_equals_doubled(seed)


def test_fused_inbound_unchanged_by_rcsr_sharing():
    """inbound (which now shares its CSR with the pull path and the fused
    view) still equals a hand-built reverse context."""
    src, dst, v, root, depth = _random_graph(17)
    ds = _edge_dataset(src, dst, v)
    caps = _caps(len(src), "inbound")
    plan = build_plan(RecursiveQuery("precursive", depth, 0, caps,
                                     direction="inbound"))
    manual = Context(table=ds.table, rows=ds.rows,
                     csr=build_csr(ds.table.column("to"), v),
                     join_src=ds.table.column("to"),
                     join_dst=ds.table.column("from"))
    got = execute(plan, ds.context("inbound"), root, v)
    want = execute(plan, manual, root, v)
    _assert_same(got, want, "inbound")


def test_fused_view_memory_is_e_scale():
    """The 'both' view adds the reverse CSR + ONE merged indptr — no
    2E-sized array anywhere on the Dataset."""
    src, dst, v, _, _ = _random_graph(4)
    ds = _edge_dataset(src, dst, v)
    e = len(src)
    added = ds.edge_view_bytes("both")
    doubled_added = 3 * (2 * e * 4) + (v + 1) * 4
    # reverse perm (E) + reverse indptr (V+1) + merged indptr (V+1)
    assert added == 4 * (e + 2 * (v + 1))
    assert added < doubled_added
    assert int(np.asarray(ds.both_indptr)[-1]) == 2 * e  # merged covers 2E
    ctx = ds.context("both")
    assert ctx.join_src.shape[0] == e                    # no 2E columns


# ---------------------------------------------------------------------------
# 4. the switch decision is recorded and predicted
# ---------------------------------------------------------------------------

def test_level_dirs_recorded_and_push_only_for_counterparts():
    src, dst, v, root, depth = _random_graph(9)
    ds = _edge_dataset(src, dst, v)
    caps = _caps(len(src), "outbound")
    r = run_query(RecursiveQuery("diropt", depth, 0, caps), ds, root)
    dirs = np.asarray(r.level_dirs)
    assert (dirs[: int(r.depth)] >= 0).all()     # every level decided
    assert (dirs[int(r.depth):] == -1).all()     # unexecuted levels marked
    # push-only engines carry no switch log
    rb = run_query(RecursiveQuery("bitmap", depth, 0, caps), ds, root)
    assert rb.level_dirs is None


def test_planner_predicts_level_dirs_for_diropt():
    from repro.planner import plan

    src, dst, v, root, depth = _random_graph(31)
    ds = _edge_dataset(src, dst, v)
    caps = _caps(len(src), "outbound")
    sql = f"""
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "from" = {root}
          UNION
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."from" = t."to"
          WHERE t.depth < {depth}
        ) SELECT * FROM t"""
    report = plan(sql, ds, caps=caps)
    by_label = {c.label: c for c in report.ranked}
    for eng in DIROPT_ENGINE_NAMES:
        dirs = by_label[eng].cost.level_dirs
        assert len(dirs) == by_label[eng].cost.levels
        assert set(dirs) <= {"push", "pull"}
    assert by_label["bitmap"].cost.level_dirs == ()
    # thresholds flow from the constants into the priced pipeline
    from repro.core.operators import DirectionSwitch
    switch = next(op for op in by_label["diropt"].pipeline.ops
                  if isinstance(op, DirectionSwitch))
    assert (switch.alpha, switch.beta) == (report.constants.pull_alpha,
                                           report.constants.pull_beta)


def test_deferred_emit_overflow_flag():
    src, dst, v, root, _ = _random_graph(6)
    ds = _edge_dataset(src, dst, v)
    tiny = EngineCaps(frontier=len(src) + 16, result=2)
    r = run_query(RecursiveQuery("diropt", 4, 0, tiny), ds, root)
    rb = run_query(RecursiveQuery("bitmap", 4, 0, tiny), ds, root)
    assert bool(r.overflow) == bool(rb.overflow)


# ---------------------------------------------------------------------------
# 5. the diropt_hybrid mispricing regression
# ---------------------------------------------------------------------------
# HybridPullStep.estimate used to omit the per-level previous-vertex-set
# rebuild (a positional frontier keeps no vertex set between levels) and
# half the hit/compact work, pricing a pull level ~2.5x UNDER the dense
# push it replaces.  That kept diropt_hybrid a near-tied planner candidate
# while the paired bench measured it at 0.33-0.37x of plain hybrid on the
# bench tree profile.

def test_hybrid_pull_estimate_prices_prev_set_rebuild():
    from repro.core.operators import CostEnv, HybridPullStep, HybridStep

    def env(frontier_cap, visited_rows=0.0):
        return CostEnv(frontier_rows=5_000, unique_rows=5_000,
                       emitted_rows=25_000, num_vertices=20_000,
                       num_edges=100_000, frontier_cap=frontier_cap,
                       result_cap=100_008, row_bytes=28, col_bytes={},
                       visited_rows=visited_rows)

    # the rebuild term scales with the frontier cap (>= 36 B per slot,
    # the same per-row scatter factor as the sparse positional branch)
    lo = HybridPullStep().estimate(env(1_000)).bytes
    hi = HybridPullStep().estimate(env(101_000)).bytes
    assert hi - lo >= 100_000 * 36.0

    # a pull level is never priced below the dense push it replaces —
    # even at the pull-friendliest extreme (everything already visited,
    # so the bottom-up gather is free); the old estimate inverted this
    for visited in (0.0, 10_000.0, 20_000.0):
        e = env(100_008, visited_rows=visited)
        assert (HybridPullStep().estimate(e).bytes
                >= HybridStep().estimate(e).bytes), visited


def test_planner_never_picks_diropt_hybrid_on_the_tree_profile(
        tree_dataset):
    """The bench-tree profile (scaled): the paired exp1 bench measures
    diropt_hybrid at ~0.35x of its push-only counterpart there, so a
    planner that ranks it FIRST is mispricing the pull branch."""
    from repro.planner import plan

    _, ds, _ = tree_dataset
    for depth in (4, 8):
        sql = f"""
            WITH RECURSIVE t (id, "from", "to", depth) AS (
              SELECT id, "from", "to", 0 FROM edges WHERE "from" = 0
              UNION
              SELECT e.id, e."from", e."to", t.depth + 1
              FROM edges e JOIN t ON e."from" = t."to"
              WHERE t.depth < {depth}
            ) SELECT * FROM t"""
        report = plan(sql, ds, caps=EngineCaps(frontier=2048, result=4096))
        assert report.best.label != "diropt_hybrid", depth
        # and the candidate is still ranked (the fix reprices, not bans)
        assert any(c.label == "diropt_hybrid" for c in report.ranked)


# ---------------------------------------------------------------------------
# hypothesis extension (real package, or the vendored fallback engine)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    pass
else:
    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_diropt_matches_push_only_random(seed):
        _check_diropt_parity(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_forced_pull_matches_push_random(seed):
        _check_forced_pull(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_fused_both_view_equals_doubled_random(seed):
        _check_fused_equals_doubled(seed)
