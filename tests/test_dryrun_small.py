"""Reduced-scale dry-run: the production lowering path on a 16-device mesh
(subprocess; the real 512-device run is `python -m repro.launch.dryrun`)."""
import subprocess
import sys
import textwrap

import pytest

from conftest import subprocess_env


def _run(code: str, devices: int = 16, timeout: int = 560) -> str:
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=subprocess_env(devices))
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_lower_smoke_cells_on_mesh():
    out = _run("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        mesh = make_mesh((4, 4), ("data", "model"))
        for arch, shape in [("qwen2-0.5b", "train_4k"),
                            ("deepseek-v2-lite-16b", "decode_32k"),
                            ("gatedgcn", "molecule"),
                            ("deepfm", "serve_p99")]:
            plan = build_cell(arch, shape, mesh, smoke=True, concrete=False)
            jf = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                         donate_argnums=plan.donate_argnums)
            with mesh:
                c = jf.lower(*plan.args).compile()
            assert c.cost_analysis() is not None
            print("ok", arch, shape)
        print("DONE")
    """)
    assert "DONE" in out


def test_multipod_mesh_lowering():
    out = _run("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        mesh = make_mesh((2, 2, 4), ("pod", "data", "model"))
        plan = build_cell("stablelm-1.6b", "train_4k", mesh, smoke=True)
        jf = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     donate_argnums=plan.donate_argnums)
        with mesh:
            c = jf.lower(*plan.args).compile()
        text = c.as_text()
        assert "all-reduce" in text          # DP grad reduction exists
        print("DONE")
    """)
    assert "DONE" in out


def test_roofline_collective_parser_on_real_module():
    out = _run("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import build_cell
        from repro.launch.roofline import parse_collectives
        mesh = make_mesh((4, 4), ("data", "model"))
        plan = build_cell("phi3.5-moe-42b", "train_4k", mesh, smoke=True)
        jf = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     donate_argnums=plan.donate_argnums)
        with mesh:
            c = jf.lower(*plan.args).compile()
        st = parse_collectives(c.as_text())
        assert st.total_bytes > 0, st
        assert "all-reduce" in st.bytes_by_kind
        print("DONE", st.bytes_by_kind)
    """)
    assert "DONE" in out
