import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# separate process; see test_dryrun_small.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The property suites importorskip("hypothesis").  When the real package is
# not installed (offline container), register the minimal deterministic
# fallback engine so they RUN instead of skipping; the real package (pinned
# in requirements-dev.txt, installed by scripts/check.sh) always wins.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback  # noqa: E402

    _hypothesis_fallback.install()


@pytest.fixture(scope="session")
def tree_dataset():
    """Shared small tree: table + row table + CSR + python-oracle levels."""
    import jax.numpy as jnp
    from repro.core import build_csr
    from repro.core.engine import Dataset
    from repro.data.treegen import TreeSpec, bfs_reference, make_edge_table

    spec = TreeSpec(num_vertices=3000, height=10, payload_cols=4, seed=11)
    table = make_edge_table(spec)
    ds = Dataset.prepare(table, spec.num_vertices)
    src = np.asarray(table.column("from"))
    dst = np.asarray(table.column("to"))
    levels = bfs_reference(src, dst, 0, 10, spec.num_vertices)
    return spec, ds, levels


def subprocess_env(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return env
