"""Metamorphic traversal properties over random seeded graphs.

Instead of comparing engines against one oracle, these tests check
RELATIONS that must hold between traversals regardless of the graph:

* **reversal** — an outbound BFS on the reversed graph (from/to swapped)
  emits exactly the rows of an inbound BFS on the original graph: same
  edge ids at the same depths.  Checked for all nine engines on the
  reversed graph and, for the engines that support ``inbound``, the other
  way around too (rowstore engines model the outbound-only PostgreSQL
  baseline).
* **both-direction closure** — ``direction="both"`` reachability equals
  the UNDIRECTED reference closure (the fixed point of unioning outbound
  and inbound steps) and therefore contains the union of the outbound and
  inbound closures (the union alone is only a lower bound: alternating
  in/out paths reach vertices neither one-directional closure does).
* **depth monotonicity** — ``row_depths`` are monotone non-decreasing in
  emission order, and every emitted edge leaves a vertex discovered
  exactly one level earlier (the root counts as discovered at level -1's
  end, i.e. its edges are the depth-0 rows).
* **planner parity** — the planner-chosen plan is row-for-row (edge id +
  depth multiset) equal to EVERY forced engine.
* **weight scaling** — multiplying every edge weight by ``c > 0`` scales
  every (min, +) shortest-path distance by exactly ``c`` (the semiring
  value plane is homogeneous in the weights);
* **unit weights degenerate to BFS** — with all weights 1, shortest-path
  distances equal first-discovery BFS depths (+1: the root's out-edges
  are depth-0 rows but 1-hop paths), for every weighted engine;
* **DAG aggregation** — on an acyclic graph, ``aggregate_sum`` equals the
  per-path ⊕-fold of ⊗-products computed by a python reference (⊗
  distributes over ⊕, so the per-level combine must not change the answer).

The deterministic seeded slice always runs; the hypothesis property (real
package or the vendored fallback engine) extends the seed set.
"""
import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import (ENGINE_NAMES, WEIGHTED_ENGINE_NAMES, Dataset,
                               RecursiveQuery, run_query)
from repro.core.table import ColumnTable
from repro.planner import plan

DIRECTIONS = ("outbound", "inbound", "both")


def _legal(engine, direction):
    return direction == "outbound" or not engine.startswith("rowstore")


def _edge_dataset(src, dst, num_vertices, w=None):
    e = len(src)
    cols = {
        "id": np.arange(e, dtype=np.int32),
        "from": np.asarray(src, np.int32),
        "to": np.asarray(dst, np.int32),
        "name": np.zeros((e, 4), np.float32)}
    if w is not None:
        cols["w"] = np.asarray(w, np.float32)
    return Dataset.prepare(ColumnTable.from_numpy(cols), num_vertices)


def _random_graph(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(6, 40))
    e = int(rng.integers(2, 3 * v))
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    depth = int(rng.integers(1, 5))
    root = int(rng.integers(0, v))
    return src, dst, v, root, depth


def _caps(e):
    return EngineCaps(frontier=e + 16, result=e + 16)


def _rows(r):
    """(edge id, depth) multiset of a BFSResult (ids are arange(e), so the
    id doubles as the edge index)."""
    n = int(r.count)
    ids = np.asarray(r.values["id"])[:n].tolist()
    depths = np.asarray(r.row_depths)[:n].tolist()
    return sorted(zip(ids, depths))


def _bfs_edge_levels(src, dst, root, max_depth, v):
    """Reference dedup-BFS: edge index -> emission depth (0..max_depth).
    An edge is emitted at depth d iff its source endpoint entered the
    (deduped) frontier at the end of level d-1 (the root seeds level 0)."""
    visited = np.zeros(v, bool)
    frontier = np.zeros(v, bool)
    visited[root] = frontier[root] = True
    out = {}
    for d in range(max_depth + 1):
        idx = np.nonzero(frontier[src])[0]
        if idx.size == 0:
            break
        for i in idx:
            out[int(i)] = d
        new = np.zeros(v, bool)
        new[dst[idx]] = True
        new &= ~visited
        visited |= new
        frontier = new
    return out


def _undirected_closure(src, dst, root, max_depth, v):
    """Vertices within ``max_depth + 1`` undirected hops of the root (the
    vertex set a depth-bounded both-direction traversal can touch)."""
    u = np.concatenate([src, dst])
    w = np.concatenate([dst, src])
    seen = np.zeros(v, bool)
    frontier = np.zeros(v, bool)
    seen[root] = frontier[root] = True
    for _ in range(max_depth + 1):
        idx = np.nonzero(frontier[u])[0]
        new = np.zeros(v, bool)
        if idx.size:
            new[w[idx]] = True
        new &= ~seen
        seen |= new
        frontier = new
    return {int(x) for x in np.nonzero(seen)[0]}


def _result_vertices(r, root):
    n = int(r.count)
    out = {root}
    out.update(int(x) for x in np.asarray(r.values["from"])[:n])
    out.update(int(x) for x in np.asarray(r.values["to"])[:n])
    return out


# ---------------------------------------------------------------------------
# 1. reversal: outbound on reversed G == inbound on G
# ---------------------------------------------------------------------------

def _check_reversal(seed):
    src, dst, v, root, depth = _random_graph(seed)
    ds = _edge_dataset(src, dst, v)
    rev = _edge_dataset(dst, src, v)          # same edge ids, arrows flipped
    caps = _caps(len(src))
    # inbound BFS on G follows edges backwards == outbound BFS on reversed G
    want = sorted((i, d) for i, d in
                  _bfs_edge_levels(dst, src, root, depth, v).items())
    for eng in ENGINE_NAMES:
        q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                           caps=caps, direction="outbound")
        assert _rows(run_query(q, rev, root)) == want, (eng, seed)
        if _legal(eng, "inbound"):
            qi = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                                caps=caps, direction="inbound")
            assert _rows(run_query(qi, ds, root)) == want, (eng, seed)


@pytest.mark.parametrize("seed", [0, 3])
def test_reversal_metamorphic_seeded(seed):
    _check_reversal(seed)


# ---------------------------------------------------------------------------
# 2. direction="both" == the undirected closure (>= union of one-way)
# ---------------------------------------------------------------------------

def _check_both_closure(seed):
    src, dst, v, root, depth = _random_graph(seed)
    ds = _edge_dataset(src, dst, v)
    caps_both = EngineCaps(frontier=2 * len(src) + 16,
                           result=2 * len(src) + 16)
    caps = _caps(len(src))
    undirected = _undirected_closure(src, dst, root, depth, v)

    qo = RecursiveQuery(engine="precursive", max_depth=depth,
                        payload_cols=0, caps=caps, direction="outbound")
    qi = RecursiveQuery(engine="precursive", max_depth=depth,
                        payload_cols=0, caps=caps, direction="inbound")
    union = (_result_vertices(run_query(qo, ds, root), root)
             | _result_vertices(run_query(qi, ds, root), root))

    for eng in ENGINE_NAMES:
        if not _legal(eng, "both"):
            continue
        qb = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                            caps=caps_both, direction="both")
        got = _result_vertices(run_query(qb, ds, root), root)
        assert got == undirected, (eng, seed)
        assert got >= union, (eng, seed)


@pytest.mark.parametrize("seed", [1, 4])
def test_both_direction_closure_seeded(seed):
    _check_both_closure(seed)


# ---------------------------------------------------------------------------
# 3. row_depths are monotone along discovered edges
# ---------------------------------------------------------------------------

def _check_depth_monotone(seed):
    src, dst, v, root, depth = _random_graph(seed)
    ds = _edge_dataset(src, dst, v)
    caps = _caps(len(src))
    for direction in ("outbound", "inbound"):
        # the frontier endpoint of a row ('from' going forward, 'to' going
        # backward) and the endpoint the row discovers
        src_col, dst_col = (("from", "to") if direction == "outbound"
                            else ("to", "from"))
        for eng in ENGINE_NAMES:
            if not _legal(eng, direction):
                continue
            q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                               caps=caps, direction=direction)
            r = run_query(q, ds, root)
            n = int(r.count)
            depths = np.asarray(r.row_depths)[:n]
            srcs = np.asarray(r.values[src_col])[:n]
            dsts = np.asarray(r.values[dst_col])[:n]
            # first-discovery depth per vertex: the minimum depth of any
            # row reaching it (row ORDER is an engine detail — the dense
            # engines emit by edge position, not by level)
            disc = {root: -1}
            for w, d in zip(dsts, depths):
                w, d = int(w), int(d)
                if d < disc.get(w, depth + 1):
                    disc[w] = d
            for u, w, d in zip(srcs, dsts, depths):
                u, w, d = int(u), int(w), int(d)
                # each row leaves a vertex discovered exactly one level
                # earlier (root at "level -1": its rows are the depth-0
                # rows), and can only lower its target's depth to d — so
                # depths are monotone non-decreasing along every
                # discovered edge
                assert disc[u] == d - 1, (eng, direction, seed)
                assert disc[w] <= d, (eng, direction, seed)


@pytest.mark.parametrize("seed", [2, 5])
def test_row_depths_monotone_seeded(seed):
    _check_depth_monotone(seed)


# ---------------------------------------------------------------------------
# 4. the planner-chosen plan == every forced engine, row for row
# ---------------------------------------------------------------------------

def _check_planner_parity(seed):
    src, dst, v, root, depth = _random_graph(seed)
    ds = _edge_dataset(src, dst, v)
    caps = _caps(len(src))
    sql = f"""
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "from" = {root}
          UNION
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."from" = t."to"
          WHERE t.depth < {depth}
        ) SELECT * FROM t"""
    best = plan(sql, ds, caps=caps).best
    want = _rows(best.run(ds, root))
    for eng in ENGINE_NAMES:
        q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                           caps=caps)
        assert _rows(run_query(q, ds, root)) == want, (eng, seed)


@pytest.mark.parametrize("seed", [6, 7])
def test_planner_matches_forced_engines_seeded(seed):
    _check_planner_parity(seed)


# ---------------------------------------------------------------------------
# 5. weighted value-plane properties (the semiring refactor's contract)
# ---------------------------------------------------------------------------

def _random_weighted_graph(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(6, 30))
    e = int(rng.integers(4, 3 * v))
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    w = rng.uniform(0.25, 4.0, e)
    depth = int(rng.integers(2, 6))
    root = int(rng.integers(0, v))
    return src, dst, w, v, root, depth


def _sssp_query(engine, max_depth, caps):
    return RecursiveQuery(engine=engine, max_depth=max_depth,
                          payload_cols=0, caps=caps, dedup=True,
                          workload="shortest_path", weight_col="w")


def _check_weight_scaling(seed):
    """dist(c * w) == c * dist(w): the (min, +) plane is homogeneous."""
    src, dst, w, v, root, depth = _random_weighted_graph(seed)
    c = 0.5 + (seed % 7)
    caps = EngineCaps(frontier=len(src) + 16, result=8 * len(src) + 32)
    for eng in WEIGHTED_ENGINE_NAMES:
        base = run_query(_sssp_query(eng, depth, caps),
                         _edge_dataset(src, dst, v, w=w), root)
        scaled = run_query(_sssp_query(eng, depth, caps),
                           _edge_dataset(src, dst, v, w=c * w), root)
        a = np.asarray(base.vertex_values)
        b = np.asarray(scaled.vertex_values)
        fa, fb = np.isfinite(a), np.isfinite(b)
        assert (fa == fb).all(), (eng, seed)
        np.testing.assert_allclose(b[fb], c * a[fa], rtol=1e-5,
                                   err_msg=f"{eng} seed={seed}")


def _check_unit_weights_are_bfs(seed):
    """All-ones weights: shortest-path distance == BFS hop count, i.e.
    first-discovery row depth + 1, for every weighted engine."""
    src, dst, v, root, depth = _random_graph(seed)
    e = len(src)
    ds = _edge_dataset(src, dst, v, w=np.ones(e))
    caps = EngineCaps(frontier=e + 16, result=8 * e + 32)
    levels = _bfs_edge_levels(src, dst, root, depth, v)
    disc = {root: -1}
    for i, d in levels.items():
        t = int(dst[i])
        if d < disc.get(t, depth + 1):
            disc[t] = d
    for eng in WEIGHTED_ENGINE_NAMES:
        vv = np.asarray(run_query(_sssp_query(eng, depth, caps),
                                  ds, root).vertex_values)
        for vertex in range(v):
            if vertex in disc:
                assert vv[vertex] == disc[vertex] + 1, (eng, seed, vertex)
            else:
                assert not np.isfinite(vv[vertex]), (eng, seed, vertex)


def _dag_path_fold(src, dst, w, root, max_depth):
    """Reference per-path UNION ALL fold on a DAG: for every vertex, the
    sum over root-paths of at most ``max_depth + 1`` edges of the product
    of edge weights (the answer ⊗-distributivity promises the per-level
    combine reproduces)."""
    adj = {}
    for i, (s, d) in enumerate(zip(src, dst)):
        adj.setdefault(int(s), []).append((int(d), float(w[i])))
    total = {root: 1.0}

    def rec(u, prod, used):
        if used > max_depth:
            return
        for t, wt in adj.get(u, ()):
            total[t] = total.get(t, 0.0) + prod * wt
            rec(t, prod * wt, used + 1)

    rec(root, 1.0, 0)
    return total


def _check_dag_aggregation(seed):
    rng = np.random.default_rng(seed)
    v = int(rng.integers(6, 24))
    e = int(rng.integers(4, 3 * v))
    a = rng.integers(0, v, e)
    b = rng.integers(0, v, e)
    keep = a != b
    src = np.minimum(a, b)[keep].astype(np.int32)   # edges point up: a DAG
    dst = np.maximum(a, b)[keep].astype(np.int32)
    if len(src) == 0:
        return
    w = rng.uniform(0.25, 2.0, len(src))
    depth = int(rng.integers(2, 5))
    root = int(src[0])
    want = _dag_path_fold(src, dst, w, root, depth)
    ds = _edge_dataset(src, dst, v, w=w)
    caps = EngineCaps(frontier=len(src) + 16, result=16 * len(src) + 64)
    for eng in WEIGHTED_ENGINE_NAMES:
        q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                           caps=caps, dedup=False,
                           workload="aggregate_sum", weight_col="w")
        r = run_query(q, ds, root)
        assert not bool(r.overflow), (eng, seed)
        vv = np.asarray(r.vertex_values)
        for vertex, val in want.items():
            np.testing.assert_allclose(vv[vertex], val, rtol=1e-5,
                                       err_msg=f"{eng} seed={seed} "
                                               f"vertex={vertex}")


@pytest.mark.parametrize("seed", [8, 11])
def test_weight_scaling_seeded(seed):
    _check_weight_scaling(seed)


@pytest.mark.parametrize("seed", [9, 13])
def test_unit_weights_are_bfs_seeded(seed):
    _check_unit_weights_are_bfs(seed)


@pytest.mark.parametrize("seed", [10, 14])
def test_dag_aggregation_seeded(seed):
    _check_dag_aggregation(seed)


# ---------------------------------------------------------------------------
# hypothesis extension (real package, or the vendored fallback engine)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    pass
else:
    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_reversal_metamorphic_random(seed):
        _check_reversal(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_both_direction_closure_random(seed):
        _check_both_closure(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_row_depths_monotone_random(seed):
        _check_depth_monotone(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_planner_matches_forced_engines_random(seed):
        _check_planner_parity(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_weight_scaling_random(seed):
        _check_weight_scaling(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_unit_weights_are_bfs_random(seed):
        _check_unit_weights_are_bfs(seed)

    @settings(max_examples=2, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_dag_aggregation_random(seed):
        _check_dag_aggregation(seed)
