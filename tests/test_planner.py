"""Planner subsystem: parser, statistics, cost-based selection, EXPLAIN.

The load-bearing guarantees:

* the planner-chosen pipeline returns results identical to EVERY forced
  engine (hypothesis property over random graphs — not just trees);
* the planner's execution path is bit-identical to ``run_query`` with the
  engine it chose (same RecursiveQuery through the same PLAN_BUILDERS);
* all three paper-listing query shapes are answered without an engine name;
* ``EXPLAIN`` output is golden-snapshotted for the three listings and shows
  per-operator cost estimates for every ENGINE_NAMES candidate;
* ``depth`` is a real queryable output column and ``WHERE depth <= k`` is
  pushed down into the recursion bound.
"""
import os

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core.engine import (ENGINE_NAMES, PLAN_BUILDERS, Dataset,
                               RecursiveQuery, build_plan, explain,
                               plan_and_run, positions_available, run_query)
from repro.core.operators import Pipeline
from repro.core.table import ColumnTable
from repro.data.treegen import TreeSpec, make_edge_table
from repro.planner import (ParseError, paper_listing, parse, plan,
                           PlannerReport)

CAPS = EngineCaps(frontier=2048, result=4096)
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def golden_dataset():
    spec = TreeSpec(num_vertices=3000, height=10, payload_cols=4, seed=11)
    return Dataset.prepare(make_edge_table(spec), spec.num_vertices)


def _ids(r):
    return sorted(np.asarray(r.values["id"])[:int(r.count)].tolist())


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

def test_parse_paper_listings():
    a1 = parse(paper_listing(1, root=7, depth=10))
    assert a1.carried_cols == ("id", "from", "to", "name")
    assert a1.carries_depth and a1.union_all and not a1.top_level_join
    assert (a1.root, a1.max_depth, a1.direction) == (7, 10, "outbound")

    a2 = parse(paper_listing(2, root=0, depth=5, payload_cols=3))
    assert a2.carried_cols[-3:] == ("column1", "column2", "column3")

    a3 = parse(paper_listing(3, root=0, depth=5))
    assert a3.carried_cols == ("id", "to") and a3.top_level_join


def test_parse_direction_and_union():
    inbound = parse("""
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "to" = 5
          UNION
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."to" = t."from" WHERE t.depth < 4
        ) SELECT * FROM t""")
    assert inbound.direction == "inbound" and not inbound.union_all

    both = parse("""
        WITH RECURSIVE t (id, "from", "to") AS (
          SELECT id, "from", "to" FROM edges WHERE "from" = 5
          UNION
          SELECT e.id, e."from", e."to" FROM edges e
          JOIN t ON e."from" = t."to" OR e."to" = t."from"
        ) SELECT * FROM t""")
    assert both.direction == "both" and both.max_depth is None


def test_parse_depth_bound_inclusive_vs_exclusive():
    lt = parse(paper_listing(1, depth=6))
    le = parse(paper_listing(1, depth=6).replace("t.depth < 6",
                                                 "t.depth <= 6"))
    assert lt.max_depth == 6 and le.max_depth == 7


@pytest.mark.parametrize("bad, match", [
    ("SELECT 1", "expected 'with'"),
    ("WITH RECURSIVE t AS (SELECT id FROM edges WHERE \"from\" = 0 "
     "UNION ALL SELECT e.id FROM edges e JOIN t ON e.name = t.id) "
     "SELECT * FROM t", "join condition"),
    ("WITH RECURSIVE t (id) AS (SELECT id FROM edges WHERE \"from\" = 0 "
     "UNION ALL SELECT e.id FROM edges e JOIN t ON e.\"from\" = t.\"to\") "
     "SELECT * FROM wrong", "outer SELECT"),
])
def test_parse_errors(bad, match):
    with pytest.raises(ParseError, match=match):
        parse(bad)


def test_seed_predicate_must_match_join_direction():
    with pytest.raises(ParseError, match="contradicts"):
        parse("""
            WITH RECURSIVE t (id) AS (
              SELECT id FROM edges WHERE "to" = 0
              UNION ALL
              SELECT e.id FROM edges e JOIN t ON e."from" = t."to"
            ) SELECT * FROM t""")


# ---------------------------------------------------------------------------
# statistics
# ---------------------------------------------------------------------------

def _edge_dataset(src, dst, num_vertices):
    e = len(src)
    t = ColumnTable.from_numpy({
        "id": np.arange(e, dtype=np.int32),
        "from": np.asarray(src, np.int32),
        "to": np.asarray(dst, np.int32),
        "name": np.zeros((e, 4), np.float32)})
    return Dataset.prepare(t, num_vertices)


def test_stats_tree_is_forest(golden_dataset):
    st = golden_dataset.stats("outbound")
    assert st.is_forest
    assert st.num_edges == 2999 and st.num_vertices == 3000
    assert sum(st.degree_histogram) > 0
    assert st.level_edges and st.max_level_edges >= 1
    # stats are cached per direction on the Dataset
    assert golden_dataset.stats("outbound") is st


def test_stats_ring_is_not_forest():
    ds = _edge_dataset([0, 1, 2, 3], [1, 2, 3, 0], 4)
    assert not ds.stats("outbound").is_forest


def test_stats_diamond_is_not_forest():
    # two paths into vertex 3: in-degree 2, acyclic
    ds = _edge_dataset([0, 0, 1, 2], [1, 2, 3, 3], 4)
    assert not ds.stats("outbound").is_forest


# ---------------------------------------------------------------------------
# plan_and_run on the three paper listings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("listing", [1, 2, 3])
def test_listings_answered_without_engine_name(golden_dataset, listing):
    ds = golden_dataset
    n_pay = 0 if listing == 1 else 4
    sql = paper_listing(listing, root=0, depth=7, payload_cols=n_pay)
    report = plan(sql, ds, caps=CAPS)
    assert isinstance(report, PlannerReport)
    assert len(report.ranked) == len(ENGINE_NAMES)     # all legal here
    r = report.best.run(ds, 0)

    # bit-identical to run_query with the chosen engine name
    forced = run_query(report.best.query, ds, 0)
    assert int(r.count) == int(forced.count)
    assert np.array_equal(np.asarray(r.positions),
                          np.asarray(forced.positions))
    for k in r.values:
        if k == "depth":
            assert np.array_equal(np.asarray(r.values[k]),
                                  np.asarray(forced.row_depths))
        else:
            assert np.array_equal(np.asarray(r.values[k]),
                                  np.asarray(forced.values[k]))

    # same answer as every forced engine
    q_pay = report.logical.payload_cols
    for eng in ENGINE_NAMES:
        rf = run_query(RecursiveQuery(eng, 7, q_pay, CAPS), ds, 0)
        assert _ids(rf) == _ids(r), eng


def test_plan_and_run_entry_point_and_depth_column(golden_dataset):
    ds = golden_dataset
    r = plan_and_run(paper_listing(1, root=0, depth=5), ds, caps=CAPS)
    assert "depth" in r.values            # the CTE carries a depth counter
    n = int(r.count)
    d = np.asarray(r.values["depth"])[:n]
    assert d.min() == 0 and d.max() == 5
    # depth column == the engine's row-depth tags
    assert np.array_equal(d, np.asarray(r.row_depths)[:n])


def test_depth_filter_pushdown(golden_dataset):
    ds = golden_dataset
    sql = paper_listing(1, root=0, depth=9) + " WHERE depth <= 2"
    report = plan(sql, ds, caps=CAPS)
    assert report.logical.max_depth == 2    # pushed into the recursion bound
    r = report.best.run(ds, 0)
    ref = run_query(RecursiveQuery("precursive", 2, 0, CAPS), ds, 0)
    assert _ids(r) == _ids(ref)
    assert int(np.asarray(r.values["depth"])[:int(r.count)].max()) == 2
    # strict < is off by one
    lt = plan(paper_listing(1, root=0, depth=9) + " WHERE depth < 2",
              ds, caps=CAPS)
    assert lt.logical.max_depth == 1


def test_batched_roots_single_dispatch(golden_dataset):
    ds = golden_dataset
    roots = [0, 1, 17]
    rb = plan_and_run(paper_listing(1, depth=4), ds, roots, caps=CAPS)
    assert rb.count.shape == (3,)
    for i, root in enumerate(roots):
        r1 = plan_and_run(paper_listing(1, depth=4), ds, root, caps=CAPS)
        assert int(r1.count) == int(rb.count[i])
        assert np.array_equal(np.asarray(r1.values["id"]),
                              np.asarray(rb.values["id"][i]))


def test_auto_caps_no_overflow(golden_dataset):
    r = plan_and_run(paper_listing(1, root=0, depth=10), golden_dataset)
    assert not bool(r.overflow)
    assert int(r.count) > 0


def test_union_all_on_non_forest_excludes_dense_engines():
    ds = _edge_dataset([0, 1, 2, 3], [1, 2, 3, 0], 4)   # a ring
    sql = """
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "from" = 0
          UNION ALL
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."from" = t."to" WHERE t.depth < 3
        ) SELECT * FROM t"""
    report = plan(sql, ds, caps=EngineCaps(64, 256))
    skipped = dict(report.skipped)
    assert "bitmap" in skipped and "hybrid" in skipped
    assert not report.logical.dedup
    # and it still runs (raw UNION ALL walk, depth-bounded)
    r = report.best.run(ds, 0)
    assert int(r.count) == 4                            # depths 0..3


def test_non_contiguous_payload_reference(golden_dataset):
    """Referencing only column3 must materialize the prefix up to N=3 and
    return CORRECT column3 values (max index, not a count of names)."""
    ds = golden_dataset
    sql = """
        WITH RECURSIVE t (id, "to", column3, depth) AS (
          SELECT id, "to", column3, 0 FROM edges WHERE "from" = 0
          UNION ALL
          SELECT e.id, e."to", e.column3, t.depth + 1
          FROM edges e JOIN t ON e."from" = t."to" WHERE t.depth < 4
        ) SELECT * FROM t"""
    report = plan(sql, ds, caps=CAPS)
    assert report.logical.payload_cols == 3
    r = report.best.run(ds, 0)
    assert "column3" in r.values
    n = int(r.count)
    ref = run_query(RecursiveQuery(report.best.engine, 4, 3, CAPS), ds, 0)
    assert np.array_equal(np.asarray(r.values["column3"])[:n],
                          np.asarray(ref.values["column3"])[:n])


def test_star_plus_explicit_payload_column(golden_dataset):
    """'SELECT *, columnK' must materialize columnK even when the CTE
    carries no payloads (N from ALL referenced columns, not just carried)."""
    ds = golden_dataset
    sql = """
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "from" = 0
          UNION ALL
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."from" = t."to" WHERE t.depth < 4
        ) SELECT *, column2 FROM t"""
    report = plan(sql, ds, caps=CAPS)
    assert report.logical.payload_cols == 2
    r = report.best.run(ds, 0)
    assert "column2" in r.values


def test_top_level_join_with_explicit_select_list(golden_dataset):
    """An explicit outer select list is honored even with the Listing-1.3
    join — no silent star-expansion to every payload column."""
    ds = golden_dataset
    sql = paper_listing(3, root=0, depth=4).replace(
        "SELECT e.*", "SELECT name")
    report = plan(sql, ds, caps=CAPS)
    assert report.logical.want_cols == ("name",)
    assert report.logical.payload_cols == 0
    r = report.best.run(ds, 0)
    assert sorted(r.values) == ["name"]


def test_outer_join_tables_validated(golden_dataset):
    bad = paper_listing(3, root=0, depth=4).replace(
        "FROM t JOIN edges AS e ON t.id = e.id",
        "FROM foo AS x JOIN bar AS y ON x.id = y.id")
    with pytest.raises(ParseError, match="outer SELECT must read the CTE"):
        plan(bad, golden_dataset)
    bad_on = paper_listing(3, root=0, depth=4).replace(
        "ON t.id = e.id", "ON z.id = e.id")
    with pytest.raises(ParseError, match="top-level join"):
        plan(bad_on, golden_dataset)


def test_unknown_column_rejected_at_plan_time(golden_dataset):
    sql = """
        WITH RECURSIVE t (id, bogus) AS (
          SELECT id, bogus FROM edges WHERE "from" = 0
          UNION ALL
          SELECT e.id, e.bogus FROM edges e JOIN t ON e."from" = t."to"
          WHERE t.depth < 3
        ) SELECT * FROM t"""
    with pytest.raises(ParseError, match="unknown column 'bogus'"):
        plan(sql, golden_dataset)


def test_overflow_raises_instead_of_truncating(golden_dataset):
    tiny = EngineCaps(frontier=8, result=16)
    with pytest.raises(RuntimeError, match="capacity overflow"):
        plan_and_run(paper_listing(1, root=0, depth=8), golden_dataset,
                     caps=tiny)
    # opt-out returns the flagged partial result
    report = plan(paper_listing(1, root=0, depth=8), golden_dataset,
                  caps=tiny)
    r = report.best.run(golden_dataset, 0, check_overflow=False)
    assert bool(np.asarray(r.overflow))


def test_union_all_without_bound_on_cycle_is_rejected():
    ds = _edge_dataset([0, 1, 2, 3], [1, 2, 3, 0], 4)
    sql = """
        WITH RECURSIVE t (id, "from", "to") AS (
          SELECT id, "from", "to" FROM edges WHERE "from" = 0
          UNION ALL
          SELECT e.id, e."from", e."to" FROM edges e
          JOIN t ON e."from" = t."to"
        ) SELECT * FROM t"""
    with pytest.raises(ParseError, match="depth bound"):
        plan(sql, ds)


def test_inbound_query_skips_rowstore(golden_dataset):
    ds = golden_dataset
    dst = np.asarray(ds.table.column("to"))
    leaf = int(dst[-1])
    sql = f"""
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "to" = {leaf}
          UNION ALL
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."to" = t."from" WHERE t.depth < 10
        ) SELECT * FROM t"""
    report = plan(sql, ds, caps=CAPS)
    skipped = {e for e, _ in report.skipped}
    assert skipped == {e for e in ENGINE_NAMES if e.startswith("rowstore")}
    ref = run_query(RecursiveQuery("precursive", 10, 0, CAPS,
                                   direction="inbound"), ds, leaf)
    assert _ids(report.best.run(ds, leaf)) == _ids(ref)


def test_both_direction_through_planner(golden_dataset):
    ds = golden_dataset
    root = int(np.asarray(ds.table.column("to"))[0])
    sql = f"""
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "from" = {root}
          UNION
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."from" = t."to" OR e."to" = t."from"
          WHERE t.depth < 2
        ) SELECT * FROM t"""
    report = plan(sql, ds, caps=CAPS)
    assert report.logical.direction == "both"
    ref = run_query(RecursiveQuery("precursive", 2, 0, CAPS,
                                   direction="both"), ds, root)
    assert _ids(report.best.run(ds, root)) == _ids(ref)


# ---------------------------------------------------------------------------
# property: planner == every forced engine on random graphs
# ---------------------------------------------------------------------------

def _check_random_graph(seed):
    """For a random (non-tree) graph and a UNION query, the planner's pick
    returns the same BFS answer as every one of the nine forced engines,
    and is bit-identical to run_query with the engine it chose."""
    rng = np.random.default_rng(seed)
    v = int(rng.integers(4, 50))
    e = int(rng.integers(1, 4 * v))
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    ds = _edge_dataset(src, dst, v)
    root = int(rng.integers(0, v))
    depth = int(rng.integers(0, 8))
    caps = EngineCaps(frontier=e + 16, result=e + 16)
    sql = f"""
        WITH RECURSIVE t (id, "from", "to", depth) AS (
          SELECT id, "from", "to", 0 FROM edges WHERE "from" = {root}
          UNION
          SELECT e.id, e."from", e."to", t.depth + 1
          FROM edges e JOIN t ON e."from" = t."to"
          WHERE t.depth < {depth}
        ) SELECT * FROM t"""
    report = plan(sql, ds, caps=caps)
    r = report.best.run(ds, root)
    assert not bool(r.overflow)

    forced_same = run_query(report.best.query, ds, root)
    assert int(r.count) == int(forced_same.count)
    assert np.array_equal(np.asarray(r.values["id"]),
                          np.asarray(forced_same.values["id"]))

    n = int(r.count)
    want_ids = _ids(r)
    want_depths = sorted(np.asarray(r.row_depths)[:n].tolist())
    pos_ref = (sorted(np.asarray(r.positions)[:n].tolist())
               if positions_available(report.best.engine) else None)
    for eng in ENGINE_NAMES:
        rf = run_query(RecursiveQuery(eng, depth, 0, caps), ds, root)
        assert not bool(rf.overflow)
        assert _ids(rf) == want_ids, eng
        nf = int(rf.count)
        assert sorted(np.asarray(rf.row_depths)[:nf].tolist()) \
            == want_depths, eng
        if pos_ref is not None and positions_available(eng):
            assert sorted(np.asarray(rf.positions)[:nf].tolist()) \
                == pos_ref, eng


@pytest.mark.parametrize("seed", [0, 1, 7, 42, 1234, 99991])
def test_planner_matches_all_forced_engines_seeded(seed):
    """Deterministic slice of the property (always runs, even without
    hypothesis)."""
    _check_random_graph(seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                       # pragma: no cover
    pass
else:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_planner_matches_all_forced_engines_random_graphs(seed):
        _check_random_graph(seed)


# ---------------------------------------------------------------------------
# EXPLAIN: golden snapshots + coverage of all candidates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("listing", [1, 2, 3])
def test_explain_golden_snapshot(golden_dataset, listing):
    n_pay = 0 if listing == 1 else 4
    sql = paper_listing(listing, root=0, depth=7, payload_cols=n_pay)
    got = explain(sql, golden_dataset, caps=CAPS)
    path = os.path.join(GOLDEN_DIR, f"explain_listing{listing}.txt")
    with open(path) as f:
        assert got == f.read()


@pytest.mark.parametrize("listing", [1, 2, 3])
def test_plan_json_golden_snapshot(golden_dataset, listing):
    """The MACHINE-READABLE plan (explain.to_json, schema_version 2) is
    golden-snapshotted alongside the text EXPLAIN: external tooling diffs
    these across PRs, so an unintended schema or costing change must show
    up as a snapshot diff.  Regenerate with the same dataset/caps and
    ``json.dump(doc, f, indent=1, sort_keys=True)`` after an INTENDED
    change."""
    import json

    from repro.planner import explain_json

    n_pay = 0 if listing == 1 else 4
    sql = paper_listing(listing, root=0, depth=7, payload_cols=n_pay)
    got = explain_json(sql, golden_dataset, caps=CAPS)
    path = os.path.join(GOLDEN_DIR, f"plan_listing{listing}.json")
    with open(path) as f:
        want = json.load(f)
    assert got == want
    # and the document is strict-JSON stable (what the snapshot stores)
    assert json.loads(json.dumps(got)) == want


def test_explain_covers_every_engine(golden_dataset):
    out = explain(paper_listing(1, root=0, depth=7), golden_dataset,
                  caps=CAPS)
    for i in range(len(ENGINE_NAMES)):
        assert f"#{i + 1} " in out
    for needle in ("bytes~", "rows~", "<- CHOSEN", "est "):
        assert needle in out
    # every engine's plan appears, with its per-operator estimates
    for eng in ENGINE_NAMES:
        assert f" {eng} " in out or f" {eng}  " in out


# ---------------------------------------------------------------------------
# PLAN_BUILDERS typing + validation (satellite)
# ---------------------------------------------------------------------------

def test_plan_builders_are_typed_callables():
    # multiquery is a first-class plan builder but NOT an engine users can
    # force by name: it only makes sense per coalesced batch (lanes > 1)
    assert set(PLAN_BUILDERS) == set(ENGINE_NAMES) | {"multiquery"}
    for name, builder in PLAN_BUILDERS.items():
        assert callable(builder), name
        lanes = 8 if name == "multiquery" else 1
        p = builder(RecursiveQuery(name, 3, 2, CAPS, lanes=lanes))
        assert isinstance(p, Pipeline), name


def test_unknown_engine_error_lists_known_names():
    with pytest.raises(ValueError) as exc:
        build_plan(RecursiveQuery("no_such_engine", 3, 0, CAPS))
    msg = str(exc.value)
    assert "no_such_engine" in msg
    for eng in ENGINE_NAMES:
        assert eng in msg
