"""Operator-algebra refactor guarantees.

* golden parity: every engine name produces IDENTICAL (values, count,
  depth) to the pre-refactor reference engines on a fixed seeded graph
  (constants below were captured by running the original monolithic
  engines of commit 2636a20 on TreeSpec(3000, 10, 4, seed=11));
* batched multi-root execution matches per-root sequential runs and is
  served by a single jitted dispatch;
* the positions contract: positional pipelines carry real edge positions,
  tuple/row pipelines mark them unavailable (all -1);
* per-row depth tracking matches the python BFS oracle;
* direction (outbound / inbound / both) is consistent across engines.
"""
import hashlib

import numpy as np
import pytest

from repro.core import EngineCaps
from repro.core import operators
from repro.core.engine import (ENGINE_NAMES, Dataset, RecursiveQuery,
                               plan_repr, positions_available, run_query,
                               run_query_batch)
from repro.data.treegen import TreeSpec, bfs_reference, make_edge_table

CAPS = EngineCaps(frontier=2048, result=4096)

# (count, depth, sha256(sorted ids)[:16], sum(sorted column2 payload)) per
# (engine, max_depth), captured from the pre-refactor engines.
_POSITIONAL_GOLDEN = {
    0: (61, 0, "702e2ad5216fae7b", -3.68),
    3: (816, 3, "df7c8c7255be3827", 5.651),
    7: (1898, 7, "b4e8619e95a1430f", -53.498),
}
# the dense engine's loop is emit-inside-the-body: depth runs one higher
_BITMAP_GOLDEN = {
    0: (61, 1, "702e2ad5216fae7b", -3.68),
    3: (816, 4, "df7c8c7255be3827", 5.651),
    7: (1898, 8, "b4e8619e95a1430f", -53.498),
}
# diropt shares bitmap's emit-inside-the-body loop accounting (its
# push-only counterpart); diropt_hybrid shares hybrid's positional one
GOLDEN = {(eng, d): (_BITMAP_GOLDEN if eng in ("bitmap", "diropt")
                     else _POSITIONAL_GOLDEN)[d]
          for eng in ENGINE_NAMES for d in (0, 3, 7)}


@pytest.fixture(scope="module")
def golden_dataset():
    spec = TreeSpec(num_vertices=3000, height=10, payload_cols=4, seed=11)
    table = make_edge_table(spec)
    ds = Dataset.prepare(table, spec.num_vertices)
    src = np.asarray(table.column("from"))
    dst = np.asarray(table.column("to"))
    levels = bfs_reference(src, dst, 0, 10, spec.num_vertices)
    return ds, levels


@pytest.mark.parametrize("engine", ENGINE_NAMES)
@pytest.mark.parametrize("depth", [0, 3, 7])
def test_golden_parity_with_pre_refactor_engines(golden_dataset, engine,
                                                 depth):
    ds, _ = golden_dataset
    r = run_query(RecursiveQuery(engine, depth, 4, CAPS), ds, 0)
    n = int(r.count)
    ids = np.sort(np.asarray(r.values["id"])[:n].astype(np.int64))
    h = hashlib.sha256(ids.tobytes()).hexdigest()[:16]
    pay = round(float(np.sort(
        np.asarray(r.values["column2"])[:n].ravel()).sum()), 3)
    assert (n, int(r.depth), h, pay) == GOLDEN[(engine, depth)]
    assert not bool(r.overflow)


@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_positions_contract(golden_dataset, engine):
    """Positional pipelines carry real positions; tuple/row pipelines mark
    them unavailable — explicit in Pipeline.carries_positions."""
    ds, _ = golden_dataset
    r = run_query(RecursiveQuery(engine, 4, 4, CAPS), ds, 0)
    n = int(r.count)
    pos = np.asarray(r.positions)
    if positions_available(engine):
        assert (pos[:n] >= 0).all() and (pos[:n] < ds.table.num_rows).all()
    else:
        assert (pos == -1).all()


EXPECT_POSITIONAL = {"precursive", "bitmap", "hybrid", "trecursive_rewrite",
                     "rowstore_rewrite", "rowstore_index_rewrite",
                     "diropt", "diropt_hybrid"}


def test_positions_contract_matches_expectation():
    got = {e for e in ENGINE_NAMES if positions_available(e)}
    assert got == EXPECT_POSITIONAL


@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_row_depth_tracking(golden_dataset, engine):
    """Every emitted row is tagged with its BFS level."""
    ds, levels = golden_dataset
    lvl_of = {p: i for i, s in enumerate(levels) for p in s}
    r = run_query(RecursiveQuery(engine, 5, 4, CAPS), ds, 0)
    n = int(r.count)
    rd = np.asarray(r.row_depths)[:n]
    if positions_available(engine):
        pos = np.asarray(r.positions)[:n]
        assert np.array_equal(rd, np.array([lvl_of[p] for p in pos]))
    else:
        # no positions: check the per-level cardinalities instead
        want = {i: len(levels[i]) for i in range(6) if levels[i]}
        got = dict(zip(*np.unique(rd, return_counts=True)))
        assert {int(k): int(v) for k, v in got.items()} == want


@pytest.mark.parametrize("engine", ENGINE_NAMES)
def test_batch_matches_sequential(golden_dataset, engine):
    """run_query_batch over >= 8 roots == per-root run_query, bit-exact."""
    ds, _ = golden_dataset
    roots = [0, 1, 2, 5, 17, 100, 2000, 2999]
    q = RecursiveQuery(engine, 4, 4, CAPS)
    rb = run_query_batch(q, ds, roots)
    assert rb.count.shape == (len(roots),)
    for i, root in enumerate(roots):
        r1 = run_query(q, ds, root)
        assert int(r1.count) == int(rb.count[i])
        assert int(r1.depth) == int(rb.depth[i])
        n = int(r1.count)
        for k in r1.values:
            assert np.array_equal(np.asarray(r1.values[k])[:n],
                                  np.asarray(rb.values[k][i])[:n]), (root, k)
        assert np.array_equal(np.asarray(r1.positions),
                              np.asarray(rb.positions[i]))
        assert np.array_equal(np.asarray(r1.row_depths),
                              np.asarray(rb.row_depths[i]))


def test_batch_is_single_jitted_dispatch(golden_dataset):
    """The whole batch is served by ONE jitted executable: repeat calls with
    the same plan hit the compile cache; the vmapped result carries the
    batch dimension."""
    ds, _ = golden_dataset
    q = RecursiveQuery("precursive", 3, 4, CAPS)
    run_query_batch(q, ds, list(range(8)))           # compile
    cache_size = getattr(operators._batch_impl, "_cache_size", None)
    before = cache_size() if cache_size else None
    rb = run_query_batch(q, ds, list(range(8, 16)))  # cached dispatch
    if cache_size:  # private jax API; skip the cache probe if it moves
        assert cache_size() == before
    assert rb.count.shape == (8,)


def test_direction_inbound_walks_ancestors(golden_dataset):
    ds, _ = golden_dataset
    src = np.asarray(ds.table.column("from"))
    dst = np.asarray(ds.table.column("to"))
    leaf = int(dst[-1])
    parent = {int(d): (i, int(s)) for i, (s, d) in enumerate(zip(src, dst))}
    anc, v = set(), leaf
    while v in parent:
        i, v = parent[v]
        anc.add(i)
    r = run_query(RecursiveQuery("precursive", 10, 4, CAPS,
                                 direction="inbound"), ds, leaf)
    n = int(r.count)
    assert set(np.asarray(r.positions)[:n].tolist()) == anc


def test_direction_both_consistent_across_engines(golden_dataset):
    ds, _ = golden_dataset
    root = int(np.asarray(ds.table.column("to"))[0])
    results = {}
    for eng in ("precursive", "trecursive", "bitmap"):
        r = run_query(RecursiveQuery(eng, 2, 4, CAPS, direction="both"),
                      ds, root)
        n = int(r.count)
        results[eng] = sorted(np.asarray(r.values["id"])[:n].tolist())
    assert results["precursive"] == results["trecursive"] == results["bitmap"]
    # undirected reach must strictly include the directed reach
    fwd = run_query(RecursiveQuery("precursive", 2, 4, CAPS), ds, root)
    assert len(results["precursive"]) > int(fwd.count)


def test_rowstore_rejects_non_outbound(golden_dataset):
    ds, _ = golden_dataset
    with pytest.raises(ValueError, match="outbound-only"):
        run_query(RecursiveQuery("rowstore", 3, 4, CAPS,
                                 direction="inbound"), ds, 0)


def test_plan_repr_is_derived_from_composition():
    """plan_repr must render the actual pipeline, not a template: every
    loop operator's description appears."""
    from repro.core.engine import build_plan
    q = RecursiveQuery("precursive", 4, 2, CAPS)
    plan = build_plan(q)
    rendered = plan_repr("precursive", 4, 2)
    for op in plan.ops:
        assert op.describe() in rendered
    assert plan.finisher.describe() in rendered
    assert plan.seed.describe().replace("$root", "0") in rendered
