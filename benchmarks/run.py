"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks datasets for
CI-speed runs; full sizes reproduce the paper's relative results.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: exp1,exp2,exp3,kern")
    args = ap.parse_args(argv)

    from . import (exp1_bfs, exp2_payload, exp3_rewrite, exp_claims,
                   kernels_bench)

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")

    if not only or "exp1" in only:
        if args.quick:
            exp1_bfs.run(num_vertices=20_000, height=10, depths=(4, 8),
                         repeat=3)
        else:
            exp1_bfs.run()
    if not only or "exp2" in only:
        if args.quick:
            exp2_payload.run(num_vertices=20_000, height=10, depths=(4, 8),
                             payloads=(2, 16), repeat=3)
        else:
            exp2_payload.run()
    if not only or "exp3" in only:
        if args.quick:
            exp3_rewrite.run(num_vertices=20_000, height=10, depths=(4, 8),
                             payloads=(16,), repeat=3)
        else:
            exp3_rewrite.run()
    if not only or "claims" in only:
        if args.quick:
            exp_claims.run(num_vertices=50_000, height=500, depth=8,
                           repeat=3)
        else:
            exp_claims.run()
    if not only or "kern" in only:
        kernels_bench.run(repeat=3 if args.quick else 5)


if __name__ == "__main__":
    main()
