"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks datasets for
CI-speed runs — it is the documented CI profile:

  PYTHONPATH=src python -m benchmarks.run --quick --json BENCH_bfs.json

``--json PATH`` additionally writes every emitted row as
``{name: {"us_per_call": float, "derived": str}}`` plus a ``"_meta"`` entry
(backend, host, git sha, timestamp, the quick/only profile) so the perf
trajectory can be tracked across PRs (one BENCH_bfs.json artifact per run).
``--history PATH`` appends one compact JSON line — the meta plus every
``us_per_call`` — to a history log (e.g. ``BENCH_history.jsonl``); the
drift report in ``scripts/perf_gate.py`` reads it.  Full sizes (no
``--quick``) reproduce the paper's relative results.
"""
from __future__ import annotations

import argparse
import json
import platform
import subprocess


def run_meta(args) -> dict:
    """The provenance stamp for one benchmark run.  Timestamps come from
    the caller (``--timestamp``, e.g. ``$(date -u +%Y-%m-%dT%H:%M:%SZ)``)
    so artifact regeneration is reproducible byte-for-byte; the git sha is
    best-effort (absent outside a checkout)."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    import jax
    return {
        "backend": jax.default_backend(),
        "host": platform.node(),
        "git_sha": sha,
        "timestamp": args.timestamp,
        "quick": bool(args.quick),
        "only": args.only,
        "tier1_count": args.tier1_count,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small datasets; the CI profile")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as JSON (e.g. BENCH_bfs.json)")
    ap.add_argument("--only", default=None,
                    help="comma list: exp1,exp2,exp3,claims,kern,planner,"
                         "serving,direction,weighted")
    ap.add_argument("--kernel", action="store_true",
                    help="benchmark the Pallas frontier_expand kernel via "
                         "CSRIndexJoin(expand_fn=) and let the planner "
                         "cost it as a physical alternative")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append one JSON line (meta + every us_per_call) "
                         "to PATH (e.g. BENCH_history.jsonl)")
    ap.add_argument("--timestamp", default=None,
                    help="ISO timestamp to stamp into _meta/history "
                         "(callers pass it; omitted -> null)")
    ap.add_argument("--tier1-count", type=int, default=None,
                    help="tier-1 test count to record in _meta/history")
    args = ap.parse_args(argv)

    from . import (bench_util, exp1_bfs, exp2_payload, exp3_rewrite,
                   exp_claims, exp_direction, exp_planner, exp_serving,
                   exp_weighted, kernels_bench)

    bench_util.RESULTS.clear()     # fresh per invocation (notebook reuse)
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")

    if not only or "exp1" in only:
        if args.quick:
            exp1_bfs.run(num_vertices=20_000, height=10, depths=(4, 8),
                         repeat=3)
        else:
            exp1_bfs.run()
    if not only or "exp2" in only:
        if args.quick:
            exp2_payload.run(num_vertices=20_000, height=10, depths=(4, 8),
                             payloads=(2, 16), repeat=3)
        else:
            exp2_payload.run()
    if not only or "exp3" in only:
        if args.quick:
            exp3_rewrite.run(num_vertices=20_000, height=10, depths=(4, 8),
                             payloads=(16,), repeat=3)
        else:
            exp3_rewrite.run()
    if not only or "claims" in only:
        if args.quick:
            exp_claims.run(num_vertices=50_000, height=500, depth=8,
                           repeat=3)
        else:
            exp_claims.run()
    if not only or "planner" in only:
        if args.quick:
            exp_planner.run(num_vertices=20_000, height=10, depths=(4, 8),
                            payloads=16, repeat=3,
                            include_kernel=args.kernel)
        else:
            exp_planner.run(include_kernel=args.kernel)
    if not only or "serving" in only:
        if args.quick:
            exp_serving.run(num_vertices=20_000, height=10, depth=4,
                            repeat=3)
        else:
            exp_serving.run()
    if not only or "direction" in only:
        if args.quick:
            exp_direction.run(num_vertices=20_000, height=10, depth=8,
                              repeat=3)
        else:
            exp_direction.run()
    if not only or "weighted" in only:
        if args.quick:
            exp_weighted.run(num_vertices=20_000, height=10, depth=8,
                             repeat=3)
        else:
            exp_weighted.run()
    if not only or "kern" in only:
        kernels_bench.run(repeat=3 if args.quick else 5)

    if args.json or args.history:
        rows = {name: {"us_per_call": us, "derived": derived}
                for name, us, derived in bench_util.RESULTS}
        meta = run_meta(args)
    if args.json:
        doc = dict(rows)
        doc["_meta"] = meta
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}")
    if args.history:
        line = {"meta": meta,
                "rows": {name: round(r["us_per_call"], 3)
                         for name, r in rows.items()}}
        with open(args.history, "a") as f:
            f.write(json.dumps(line, sort_keys=True) + "\n")
        print(f"# appended {len(rows)} rows to {args.history}")


if __name__ == "__main__":
    main()
