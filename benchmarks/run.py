"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks datasets for
CI-speed runs — it is the documented CI profile:

  PYTHONPATH=src python -m benchmarks.run --quick --json BENCH_bfs.json

``--json PATH`` additionally writes every emitted row as
``{name: {"us_per_call": float, "derived": str}}`` so the perf trajectory
can be tracked across PRs (one BENCH_bfs.json artifact per run).  Full
sizes (no ``--quick``) reproduce the paper's relative results.
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small datasets; the CI profile")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as JSON (e.g. BENCH_bfs.json)")
    ap.add_argument("--only", default=None,
                    help="comma list: exp1,exp2,exp3,claims,kern,planner,"
                         "serving,direction")
    ap.add_argument("--kernel", action="store_true",
                    help="benchmark the Pallas frontier_expand kernel via "
                         "CSRIndexJoin(expand_fn=) and let the planner "
                         "cost it as a physical alternative")
    args = ap.parse_args(argv)

    from . import (bench_util, exp1_bfs, exp2_payload, exp3_rewrite,
                   exp_claims, exp_direction, exp_planner, exp_serving,
                   kernels_bench)

    bench_util.RESULTS.clear()     # fresh per invocation (notebook reuse)
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")

    if not only or "exp1" in only:
        if args.quick:
            exp1_bfs.run(num_vertices=20_000, height=10, depths=(4, 8),
                         repeat=3)
        else:
            exp1_bfs.run()
    if not only or "exp2" in only:
        if args.quick:
            exp2_payload.run(num_vertices=20_000, height=10, depths=(4, 8),
                             payloads=(2, 16), repeat=3)
        else:
            exp2_payload.run()
    if not only or "exp3" in only:
        if args.quick:
            exp3_rewrite.run(num_vertices=20_000, height=10, depths=(4, 8),
                             payloads=(16,), repeat=3)
        else:
            exp3_rewrite.run()
    if not only or "claims" in only:
        if args.quick:
            exp_claims.run(num_vertices=50_000, height=500, depth=8,
                           repeat=3)
        else:
            exp_claims.run()
    if not only or "planner" in only:
        if args.quick:
            exp_planner.run(num_vertices=20_000, height=10, depths=(4, 8),
                            payloads=16, repeat=3,
                            include_kernel=args.kernel)
        else:
            exp_planner.run(include_kernel=args.kernel)
    if not only or "serving" in only:
        if args.quick:
            exp_serving.run(num_vertices=20_000, height=10, depth=4,
                            repeat=3)
        else:
            exp_serving.run()
    if not only or "direction" in only:
        if args.quick:
            exp_direction.run(num_vertices=20_000, height=10, depth=8,
                              repeat=3)
        else:
            exp_direction.run()
    if not only or "kern" in only:
        kernels_bench.run(repeat=3 if args.quick else 5)

    if args.json:
        rows = {name: {"us_per_call": us, "derived": derived}
                for name, us, derived in bench_util.RESULTS}
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
