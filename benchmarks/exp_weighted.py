"""Weighted-workload experiment: the semiring value plane under load.

Cells:

* ``exp_weighted/sssp_bucketed/dD`` — the GATED delta-stepping-style
  cell: a root batch mixing the hub root with leaf-ish roots, weighted
  shortest path, reach-bucketed dispatch (``bucket_roots`` + the shared
  ``dispatch_buckets`` executor, each bucket at its own right-sized caps)
  against ONE lockstep batched dispatch at the global caps.  Lockstep
  vmaps every lane through the hub root's level count and pads every lane
  to the hub root's caps; bucketing lets the leaf bucket's label-
  correcting loop converge in a few cheap levels.  The
  ``sssp_bucketed_vs_lockstep`` ratio is measured PAIRED (calls
  interleaved, shared-host drift cancels) and gated >= 1.0 by
  ``scripts/perf_gate.py``.
* ``exp_weighted/sssp_vs_reach/dD`` — informational (ungated): the
  planner-chosen SSSP traversal against the planner-chosen boolean reach
  on the same tree, single root — the price of carrying the value plane.
* ``exp_weighted/aggregate_sum/dD`` — informational (ungated): the
  bill-of-materials shape (``SUM(t.value * e.w)``, UNION ALL) through the
  planner-chosen weighted engine, depth-bounded; reports the chosen
  engine and the per-call time of the walk-aggregation fold.

See docs/workloads.md for the semiring table and the SQL forms.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine import Dataset, dispatch_buckets, run_query_batch
from repro.core.table import ColumnTable
from repro.data.treegen import TreeSpec, make_edge_table
from repro.planner import plan
from repro.planner.ast import normalize, parse, weighted_listing
from repro.planner.optimize import bucket_roots

from .bench_util import emit, level_caps, time_call, time_ratio

BATCH_ROOTS = 8

_WEIGHTED: dict = {}


def weighted_tree_dataset(num_vertices: int, height: int,
                          seed: int = 0) -> Dataset:
    """The shared bench tree plus a positional edge-weight column ``w``
    (uniform in [0.5, 2.0): strictly positive, mean ~1, so weighted
    distances stay depth-scale and the improving frontier converges like
    BFS)."""
    key = (num_vertices, height, seed)
    if key not in _WEIGHTED:
        spec = TreeSpec(num_vertices=num_vertices, height=height,
                        payload_cols=0, seed=seed)
        table = make_edge_table(spec)
        rng = np.random.default_rng(seed + 1)
        cols = {name: np.asarray(table.column(name))
                for name in table.names}
        cols["w"] = rng.uniform(0.5, 2.0,
                                table.num_rows).astype(np.float32)
        _WEIGHTED[key] = Dataset.prepare(ColumnTable.from_numpy(cols),
                                         spec.num_vertices)
    return _WEIGHTED[key]


def run(num_vertices: int = 200_000, height: int = 60, depth: int = 8,
        repeat: int = 5) -> dict:
    ds = weighted_tree_dataset(num_vertices, height)
    caps = level_caps(num_vertices, height, depth)
    sql = weighted_listing("shortest_path", root=0, depth=depth,
                           weight_col="w")
    lg = normalize(parse(sql), ds)
    best = plan(lg, ds, caps=caps).best
    # the serving mix: the hub root plus true leaves (the regime where
    # lockstep batching pads every lane to the hub's caps and rides every
    # lane through the hub's level count)
    roots = [0] + [num_vertices - 1 - i for i in range(BATCH_ROOTS - 1)]
    out = {}

    buckets = bucket_roots(ds, roots, direction=best.query.direction,
                           max_depth=depth, dedup=best.query.dedup,
                           caps=caps, max_buckets=4)
    # per-bucket re-costing, exactly like ServingSession._bucket_choice:
    # the capacity-aware model lets the leaf bucket pick the positional
    # engine even when the hub bucket (and the whole batch) price dense
    bucket_q = tuple(plan(lg, ds, caps=b.caps).best.query for b in buckets)

    def _dispatch(i, b, bcaps):
        q = bucket_q[i]
        if bcaps != q.caps:
            q = dataclasses.replace(q, caps=bcaps)
        return run_query_batch(q, ds, list(b.roots))

    def _bucketed():
        return dispatch_buckets(buckets, _dispatch, fallback_caps=caps,
                                to_host=False)

    def _lockstep():
        return run_query_batch(best.query, ds, roots)

    us_bucketed = time_call(_bucketed, repeat=repeat)
    us_lockstep = time_call(_lockstep, repeat=repeat)
    ratio = time_ratio(_lockstep, _bucketed, repeat=max(repeat, 9))
    out["sssp_bucketed_vs_lockstep"] = ratio
    emit(f"exp_weighted/sssp_bucketed/d{depth}", us_bucketed,
         f"sssp_bucketed_vs_lockstep={ratio:.2f},"
         f"lockstep_us={us_lockstep:.1f},buckets={len(buckets)},"
         f"engine={best.label},batch={BATCH_ROOTS}")

    # -- the value plane's price vs boolean reach (informational) ---------
    from repro.planner import paper_listing
    reach_best = plan(paper_listing(1, root=0, depth=depth), ds,
                      caps=caps).best
    us_sssp = time_call(lambda: best.run(ds, 0), repeat=repeat)
    reach_ratio = time_ratio(lambda: best.run(ds, 0),
                             lambda: reach_best.run(ds, 0),
                             repeat=max(repeat, 7))
    out["sssp_vs_reach"] = reach_ratio
    emit(f"exp_weighted/sssp_vs_reach/d{depth}", us_sssp,
         f"sssp_over_reach={reach_ratio:.2f},sssp={best.label},"
         f"reach={reach_best.label}")

    # -- the walk-aggregation fold (informational) ------------------------
    agg_depth = min(depth, 4)       # UNION ALL row volume is depth-bounded
    agg_sql = weighted_listing("aggregate_sum", root=0, depth=agg_depth,
                               weight_col="w")
    agg_best = plan(normalize(parse(agg_sql), ds), ds, caps=caps).best
    us_agg = time_call(lambda: agg_best.run(ds, 0), repeat=repeat)
    out["aggregate_us"] = us_agg
    emit(f"exp_weighted/aggregate_sum/d{agg_depth}", us_agg,
         f"engine={agg_best.label},workload=aggregate_sum")
    return out


if __name__ == "__main__":
    run()
