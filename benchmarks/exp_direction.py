"""Direction experiment: the fused bidirectional CSR's memory footprint and
the direction-optimizing traversal on the wide-frontier regime.

Cells:

* ``exp_direction/both_view_memory`` — bytes of the index arrays backing
  ``direction='both'``.  The fused view (the reverse CSR — shared with
  ``inbound`` and the pull path — plus one merged indptr) must be
  ~E-scale; the old doubled view materialized three 2E-sized arrays
  (``concat(from,to)``, ``concat(to,from)``, and a 2E CSR perm).
  ``fused_vs_doubled`` is the reduction factor.
* ``exp_direction/diropt_wide/dD`` — the wide-frontier regime the paper's
  exp1 identifies as hardest (depth grows, frontiers widen, E > V): a
  dense random graph, ``diropt`` against the best static push engine.
  The gated ``diropt_vs_push_only`` ratio is measured PAIRED (calls
  interleaved) so shared-host noise cancels.  The cell also reports the
  push/pull crossover level read from ``BFSResult.level_dirs`` — the
  measured counterpart of the plan's predicted ``level_dirs``.
* ``exp_direction/diropt_crossover/dD`` — the measured push->pull switch
  decisions on a SMALL dense graph whose frontier occupancy actually
  crosses the pull threshold.  (An earlier revision ran this cell on the
  quick tree graph, whose in-degree-1 frontiers never out-weigh the
  unvisited remainder — the cell dutifully reported ``crossover_level=-1,
  pull_levels=0`` forever, gating nothing.)  The cell now RAISES if no
  pull level executes: a dead crossover cell is a bench bug, not a datum.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import EngineCaps
from repro.core.engine import Dataset, RecursiveQuery, run_query
from repro.core.table import ColumnTable

from .bench_util import emit, time_call, time_ratio, tree_dataset

PUSH_ENGINES = ("precursive", "bitmap", "hybrid")

_DENSE: dict = {}


def dense_dataset(num_vertices: int, num_edges: int, seed: int = 7
                  ) -> Dataset:
    """A dense random graph (E > V): the wide-frontier regime."""
    key = (num_vertices, num_edges, seed)
    if key not in _DENSE:
        rng = np.random.default_rng(seed)
        e = num_edges
        cols = {
            "id": np.arange(e, dtype=np.int32),
            "from": rng.integers(0, num_vertices, e).astype(np.int32),
            "to": rng.integers(0, num_vertices, e).astype(np.int32),
            "name": np.zeros((e, 4), np.float32)}
        _DENSE[key] = Dataset.prepare(ColumnTable.from_numpy(cols),
                                      num_vertices)
    return _DENSE[key]


def _dirs_summary(dirs: np.ndarray) -> tuple[int, int, int]:
    executed = dirs[dirs >= 0]
    pulls = np.nonzero(dirs == 1)[0]
    crossover = int(pulls[0]) if pulls.size else -1
    return crossover, int((executed == 1).sum()), int(executed.size)


def run(num_vertices: int = 200_000, height: int = 60, depth: int = 8,
        repeat: int = 5, edge_factor: int = 5) -> dict:
    ds = tree_dataset(num_vertices, height, payload_cols=0)
    out = {}

    # --- fused both-view memory ------------------------------------------
    t0 = time.perf_counter()
    fused = ds.edge_view_bytes("both")
    build_us = (time.perf_counter() - t0) * 1e6
    e = ds.table.num_rows
    v = ds.num_vertices
    # what the pre-fused layout materialized for 'both': both_src +
    # both_dst + both_csr.perm (2E int32 each) + both_csr.indptr
    doubled = 3 * (2 * e * 4) + (v + 1) * 4
    out["both_bytes"] = fused
    emit("exp_direction/both_view_memory", build_us,
         f"fused_bytes={fused},doubled_bytes={doubled},"
         f"fused_vs_doubled={doubled / max(fused, 1):.2f},"
         f"bytes_per_edge={fused / max(e, 1):.2f}")

    # --- the wide-frontier regime: dense graph, diropt vs best push ------
    wide = dense_dataset(num_vertices, edge_factor * num_vertices)
    wcaps = EngineCaps(frontier=wide.table.num_rows + 8,
                       result=wide.table.num_rows + 8)
    push_us = {}
    for eng in PUSH_ENGINES:
        q = RecursiveQuery(engine=eng, max_depth=depth, payload_cols=0,
                           caps=wcaps)
        push_us[eng] = time_call(run_query, q, wide, 0, repeat=repeat)
    best_push = min(push_us, key=push_us.get)
    qp = RecursiveQuery(engine=best_push, max_depth=depth, payload_cols=0,
                        caps=wcaps)
    qd = RecursiveQuery(engine="diropt", max_depth=depth, payload_cols=0,
                        caps=wcaps)
    us_diropt = time_call(run_query, qd, wide, 0, repeat=repeat)
    ratio = time_ratio(lambda: run_query(qp, wide, 0),
                       lambda: run_query(qd, wide, 0),
                       repeat=max(repeat, 9))
    crossover, pulls, executed = _dirs_summary(
        np.asarray(run_query(qd, wide, 0).level_dirs))
    out["wide_ratio"] = ratio
    emit(f"exp_direction/diropt_wide/d{depth}", us_diropt,
         f"diropt_vs_push_only={ratio:.2f},push_only={best_push},"
         f"crossover_level={crossover},pull_levels={pulls},"
         f"executed_levels={executed}")

    # --- the measured push->pull crossover --------------------------------
    # a small dense graph (E = 8V) whose frontier occupancy crosses the
    # pull threshold within a few levels; the tree graph the cell used to
    # run on never crosses (in-degree 1), which left the cell dead
    xv = max(num_vertices // 8, 4096)
    xds = dense_dataset(xv, 8 * xv, seed=9)
    xcaps = EngineCaps(frontier=xds.table.num_rows + 8,
                       result=xds.table.num_rows + 8)
    q = RecursiveQuery(engine="diropt", max_depth=depth, payload_cols=0,
                       caps=xcaps)
    us = time_call(run_query, q, xds, 0, repeat=repeat)
    crossover, pulls, executed = _dirs_summary(
        np.asarray(run_query(q, xds, 0).level_dirs))
    if pulls == 0 or crossover < 0:
        raise RuntimeError(
            f"diropt_crossover measured no pull levels (crossover_level="
            f"{crossover}, executed_levels={executed}) — the cell's graph "
            f"no longer crosses the push->pull threshold and the cell is "
            f"dead; regenerate it on a denser graph")
    out["crossover"] = crossover
    emit(f"exp_direction/diropt_crossover/d{depth}", us,
         f"crossover_level={crossover},pull_levels={pulls},"
         f"executed_levels={executed}")
    return out


if __name__ == "__main__":
    run()
